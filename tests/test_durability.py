"""Durability & crash recovery: mutation WAL, atomic checkpoints,
fault-injection crash-recovery, and background-thread supervision.

The core property extends PR 5's mutation invariant across a process
death: kill the process state at EVERY registered fault-injection
point during randomized mutation traffic, recover via
``DurableIndex.open`` (newest valid checkpoint + torn-tail truncation
+ idempotent WAL replay), and search over the recovered index must be
bit-identical to a fresh build over the serially-replayed durable
mutation prefix — with every *acknowledged* mutation inside that
prefix.  On flat AND IVF backends.
"""
import json
import time

import jax
import numpy as np
import pytest

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex, CorruptIndexError
from repro.serving import (
    BackgroundCompactor, DurableIndex, QueryEngine, ServingFrontend,
    WriteAheadLog,
)
from repro.serving.frontend import FrontendClosed
from repro.serving.wal import (
    KIND_ADD, KIND_DELETE, KIND_MARKER, read_log,
)
from repro.testing import faults
from test_mutation import _Oracle, _assert_matches_fresh_build, _build

DIM = 16
N0 = 48  # initial build size
POOL = 240  # vector pool adds draw from
CHUNK = 8  # rows per add batch
BACKENDS = ("flat", "ivf")


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(7)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, POOL, DIM)
    Qm = embedding_dataset(kq, 4, DIM)
    cfg = ASHConfig(b=2, d=8, n_landmarks=8)
    model = AshIndex.build(kb, X[:N0], cfg, backend="flat").model
    return np.asarray(X), Qm, cfg, model, kb


def _search_kw(backend):
    kw = {"rerank": 0}
    if backend == "ivf":
        kw["nprobe"] = 2  # partial probe: the gathered pre-DMA path
    return kw


def _wait_until(pred, timeout=10.0, interval=0.002):
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


# ---------------------------------------------------------------------
# fault-point registry: every point the production code registers must
# be exercised by the crash matrix below — a new point that isn't
# added to the expectations fails here, not silently
# ---------------------------------------------------------------------

EXPECTED_POINTS = {
    "wal.append", "wal.fsync", "wal.rotate",
    "engine.apply", "engine.apply.logged", "engine.apply.applied",
    "ckpt.begin", "ckpt.gc",
    "save.replace", "save.between_replace",
    "compactor.swap",
}


def test_every_fault_point_is_registered():
    assert {p.name for p in faults.points()} == EXPECTED_POINTS


def _crash_cases():
    cases = []
    for name in sorted(EXPECTED_POINTS):
        cases.append((name, faults.Crash(at=1)))
        if name.startswith(("wal.", "engine.")):
            # later hits land mid-traffic, after acknowledged work
            cases.append((name, faults.Crash(at=3)))
    cases.append(("wal.append", faults.Torn(at=2, fraction=0.3)))
    cases.append(("wal.append", faults.Torn(at=4, fraction=0.8)))
    return cases


def _run_traffic_until_crash(setup, root, backend, plan, steps=8):
    """Drive a deterministic mutation script through an engine with
    durability attached, under ``plan``.  Returns (muts, acked,
    crashed): the full submission-order mutation list, the tickets
    that RESOLVED before the crash, and whether the plan fired."""
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, backend, "dot", X[:N0])
    dur = DurableIndex.create(idx, root, fsync="always")
    eng = QueryEngine(idx)
    eng.attach_durability(dur)
    rng = np.random.RandomState(1234)
    muts = []  # ("add", pool_rows) | ("del", ids), submission order
    acked = []  # (mutation position 0-based, ticket)
    crashed = False
    try:
        with faults.active(plan):
            for step in range(steps):
                if step == steps // 2:
                    # a mid-traffic checkpoint exercises the ckpt/save
                    # points while acknowledged records exist on both
                    # sides of it
                    dur.checkpoint(barrier=eng.mutation_barrier())
                total_ids = N0 + CHUNK * sum(
                    1 for k, _ in muts if k == "add"
                )
                if rng.rand() < 0.55:
                    pool_rows = rng.randint(0, POOL, CHUNK)
                    muts.append(("add", pool_rows))
                    t = eng.submit_add(X[pool_rows])
                else:
                    victims = rng.randint(0, total_ids, CHUNK // 2)
                    muts.append(("del", victims))
                    t = eng.submit_delete(victims)
                t.result()  # undriven: applies (and WAL-logs) now
                acked.append((len(muts) - 1, t))
    except faults.SimulatedCrash:
        crashed = True
    # the "process" is dead: abandon every in-memory object.  (The
    # file contents are already past the process — appends flush —
    # so closing the fd is only hygiene.)
    try:
        dur.wal.close()
    except Exception:
        pass
    return muts, acked, crashed


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize(
    "point,action", _crash_cases(),
    ids=lambda v: v if isinstance(v, str) else
    f"{type(v).__name__}@{v.at}",
)
def test_crash_recovery_at_every_point(
    setup, tmp_path, backend, point, action
):
    """Kill the process state at ``point``; recovery must serve
    bit-identically to a fresh build over the durable mutation prefix,
    and every acknowledged mutation must be inside that prefix."""
    muts, acked, crashed = _run_traffic_until_crash(
        setup, tmp_path / "dur", backend, {point: action}
    )
    rec = DurableIndex.open(tmp_path / "dur", fsync="always")
    report = rec.report
    if not crashed:
        # the plan never fired on this script (e.g. a compactor-only
        # point): clean shutdown — everything submitted is durable
        assert report.last_seqno == len(muts)
    # no checkpoint/marker traffic in this script consumes seqnos, so
    # mutation i (0-based) was logged under seqno i+1 and the durable
    # set is exactly the first last_seqno mutations
    durable_n = report.last_seqno
    assert 0 <= durable_n <= len(muts)
    for pos, ticket in acked:
        assert ticket.wal_seqno == pos + 1
        assert ticket.wal_seqno <= durable_n, (
            f"acknowledged mutation {pos} (seqno {ticket.wal_seqno}) "
            f"lost: durable prefix ends at {durable_n}"
        )
    oracle = _Oracle(N0)
    for kind, payload in muts[:durable_n]:
        if kind == "add":
            oracle.add(list(payload))
        else:
            oracle.delete(payload)
    _assert_matches_fresh_build(
        setup, rec.index, oracle, backend, "dot", _search_kw(backend)
    )
    rec.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_recovery_is_idempotent(setup, tmp_path, backend):
    """open() twice (the second time after a clean close with no new
    traffic) replays nothing new and serves identically."""
    muts, acked, crashed = _run_traffic_until_crash(
        setup, tmp_path / "dur", backend,
        {"engine.apply.logged": faults.Crash(at=4)},
    )
    assert crashed
    rec1 = DurableIndex.open(tmp_path / "dur")
    s1, i1 = rec1.index.search(setup[1], k=10, **_search_kw(backend))
    rec1.checkpoint()
    rec1.close()
    rec2 = DurableIndex.open(tmp_path / "dur")
    assert rec2.report.replayed_adds == 0
    assert rec2.report.replayed_deletes == 0
    s2, i2 = rec2.index.search(setup[1], k=10, **_search_kw(backend))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    rec2.close()


# ---------------------------------------------------------------------
# WAL unit behaviour
# ---------------------------------------------------------------------

def test_wal_roundtrip_and_fsync_policies(tmp_path):
    rows = np.arange(12, dtype=np.float32).reshape(3, 4)
    wal = WriteAheadLog(tmp_path / "w", fsync="always")
    assert wal.append_add(rows, [5, 6, 7]) == 1
    assert wal.append_delete([6]) == 2
    assert wal.append_marker("compact") == 3
    assert wal.stats()["fsyncs"] == 3
    wal.close()
    recs, torn = read_log(tmp_path / "w")
    assert torn == 0
    assert [r.seqno for r in recs] == [1, 2, 3]
    assert [r.kind for r in recs] == [KIND_ADD, KIND_DELETE, KIND_MARKER]
    np.testing.assert_array_equal(recs[0].rows, rows)
    np.testing.assert_array_equal(recs[0].ids, [5, 6, 7])
    np.testing.assert_array_equal(recs[1].ids, [6])
    assert recs[2].text == "compact"

    woff = WriteAheadLog(tmp_path / "w2", fsync="off")
    woff.append_delete([1])
    assert woff.stats()["fsyncs"] == 0
    woff.close()


def test_wal_torn_tail_detected_and_truncated(tmp_path):
    wal = WriteAheadLog(tmp_path / "w", fsync="off")
    for i in range(3):
        wal.append_delete([i])
    seg = wal.segments()[0]
    wal.sync()
    good_len = seg.stat().st_size
    wal.append_delete([3])
    wal.close()
    full_len = seg.stat().st_size
    # tear the 4th record in half, as a mid-write crash would
    cut = good_len + (full_len - good_len) // 2
    with open(seg, "r+b") as f:
        f.truncate(cut)
    recs, torn = read_log(tmp_path / "w", truncate=True)
    assert [r.seqno for r in recs] == [1, 2, 3]
    assert torn == cut - good_len
    assert seg.stat().st_size == good_len  # tail cut off on disk
    recs2, torn2 = read_log(tmp_path / "w")
    assert torn2 == 0 and len(recs2) == 3


def test_wal_bitflip_ends_durable_prefix(tmp_path):
    wal = WriteAheadLog(tmp_path / "w", fsync="off")
    for i in range(4):
        wal.append_delete([10 + i])
    seg = wal.segments()[0]
    wal.close()
    data = bytearray(seg.read_bytes())
    data[len(data) // 2] ^= 0xFF  # flip a bit mid-log
    seg.write_bytes(bytes(data))
    recs, torn = read_log(tmp_path / "w")
    assert torn > 0
    assert [r.seqno for r in recs] == list(
        range(1, len(recs) + 1)
    )  # intact prefix only, in order


def test_wal_rotation_and_segment_gc(tmp_path):
    wal = WriteAheadLog(tmp_path / "w", fsync="off")
    wal.append_delete([1])
    wal.append_delete([2])
    wal.rotate()
    wal.append_delete([3])
    assert len(wal.segments()) == 2
    assert wal.drop_segments_through(2) == 1
    recs, _ = read_log(tmp_path / "w")
    assert [r.seqno for r in recs] == [3]
    wal.close()


def test_wal_delay_fault_is_benign(tmp_path):
    wal = WriteAheadLog(tmp_path / "w", fsync="off")
    with faults.active({"wal.append": faults.Delay(at=1, seconds=0.01)}):
        t0 = time.perf_counter()
        wal.append_delete([1])
        assert time.perf_counter() - t0 >= 0.01
    recs, torn = read_log(tmp_path / "w")
    assert len(recs) == 1 and torn == 0
    wal.close()


def test_wal_error_requeues_batch_and_retries(setup, tmp_path):
    """An ordinary WAL failure (disk full, EIO) must neither resolve
    nor lose the batch: tickets stay pending, the batch requeues, and
    the retry logs exactly once (no duplicate records)."""
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:N0])
    dur = DurableIndex.create(idx, tmp_path / "dur", fsync="always")
    eng = QueryEngine(idx)
    eng.attach_durability(dur)
    with faults.active({"wal.append": faults.Error(at=1)}):
        t = eng.submit_add(X[:CHUNK])
        with pytest.raises(TimeoutError):
            t.result(timeout=0.1)
        assert eng.stats.wal_failures == 1
        assert "InjectedError" in eng.stats.wal_last_error
        snap = eng.stats.snapshot()
        assert snap["durability"]["wal_failures"] == 1
    ids = t.result()  # retry path: logs then applies
    np.testing.assert_array_equal(ids, np.arange(N0, N0 + CHUNK))
    assert t.wal_seqno == 1
    recs, _ = read_log(tmp_path / "dur" / "wal")
    assert [r.kind for r in recs] == [KIND_ADD]
    dur.close()


# ---------------------------------------------------------------------
# atomic save / typed corruption (satellite: ALL load-path corruption
# raises CorruptIndexError naming path + failed check)
# ---------------------------------------------------------------------

@pytest.fixture()
def saved(setup, tmp_path):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:N0])
    idx.save(tmp_path / "idx")
    return idx, tmp_path / "idx"


def _assert_same_search(setup, a, b):
    Qm = setup[1]
    sa, ia = a.search(Qm, k=10)
    sb, ib = b.search(Qm, k=10)
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


def test_load_truncated_npz_raises_typed(saved):
    idx, p = saved
    data = (p / "arrays.npz").read_bytes()
    (p / "arrays.npz").write_bytes(data[: len(data) // 2])
    with pytest.raises(CorruptIndexError) as e:
        AshIndex.load(p)
    assert str(p) in str(e.value)


def test_load_bitflipped_npz_raises_typed(saved):
    idx, p = saved
    data = bytearray((p / "arrays.npz").read_bytes())
    data[len(data) // 2] ^= 0xFF
    (p / "arrays.npz").write_bytes(bytes(data))
    with pytest.raises(CorruptIndexError):
        AshIndex.load(p)


def test_load_missing_files_raise_typed(saved, tmp_path):
    idx, p = saved
    with pytest.raises(CorruptIndexError, match="config.json missing"):
        AshIndex.load(tmp_path / "nowhere")
    (p / "arrays.npz").unlink()
    with pytest.raises(CorruptIndexError, match="arrays.npz missing"):
        AshIndex.load(p)


def test_load_bad_manifest_raises_typed(saved):
    idx, p = saved
    (p / "config.json").write_text("{not json")
    with pytest.raises(CorruptIndexError, match="unreadable"):
        AshIndex.load(p)


def test_load_wrong_format_version_raises_typed(saved):
    idx, p = saved
    meta = json.loads((p / "config.json").read_text())
    meta["format_version"] = 999
    (p / "config.json").write_text(json.dumps(meta))
    with pytest.raises(CorruptIndexError, match="format_version"):
        AshIndex.load(p)


def test_load_legacy_save_without_checksums(setup, saved):
    """Pre-manifest saves (no per-array checksums) still load — and
    still fail TYPED when their npz is corrupt."""
    idx, p = saved
    meta = json.loads((p / "config.json").read_text())
    del meta["checksums"]
    (p / "config.json").write_text(json.dumps(meta))
    _assert_same_search(setup, idx, AshIndex.load(p))
    data = (p / "arrays.npz").read_bytes()
    (p / "arrays.npz").write_bytes(data[: len(data) - 40])
    with pytest.raises(CorruptIndexError):
        AshIndex.load(p)


def test_save_crash_before_fresh_replace_leaves_nothing(setup, saved,
                                                        tmp_path):
    idx, _ = saved
    target = tmp_path / "fresh"
    with pytest.raises(faults.SimulatedCrash):
        with faults.active({"save.replace": faults.Crash()}):
            idx.save(target)
    assert not target.exists()  # only the dot-tmp dir, never a torn mix
    idx.save(target)  # and the retry lands cleanly
    _assert_same_search(setup, idx, AshIndex.load(target))


def test_save_crash_between_over_replaces_rolls_forward(setup, saved):
    """Crash between the two renames of an over-save: new arrays under
    the old manifest.  load() must detect the mismatch and finish the
    save from the durable config.new.json."""
    X = setup[0]
    idx, p = saved
    idx.add(X[N0:N0 + CHUNK])  # make the second save differ
    with pytest.raises(faults.SimulatedCrash):
        with faults.active({"save.between_replace": faults.Crash()}):
            idx.save(p)
    assert (p / "config.new.json").exists()
    loaded = AshIndex.load(p)  # roll-forward
    assert loaded.n == idx.n
    _assert_same_search(setup, idx, loaded)
    assert not (p / "config.new.json").exists()  # save completed
    _assert_same_search(setup, idx, AshIndex.load(p))


def test_save_garbage_new_files_are_ignored(setup, saved):
    """Leftover partial .new files from a crash mid-write must not
    shadow the intact live pair."""
    idx, p = saved
    (p / "arrays.new.npz").write_bytes(b"partial garbage")
    (p / "config.new.json").write_text("{also garb")
    _assert_same_search(setup, idx, AshIndex.load(p))


# ---------------------------------------------------------------------
# frontend: drain/abort vs the WAL (satellite)
# ---------------------------------------------------------------------

def _frontend_fixture(setup, root, max_wait_s=60.0):
    """Engine + durability + driver whose cadence will NOT apply
    mutations on its own (huge max_wait_s, huge mutation backlog
    bound) — staged-but-unapplied is the steady state until stop()."""
    X = setup[0]
    idx = _build(setup, "flat", "dot", X[:N0])
    dur = DurableIndex.create(idx, root, fsync="always")
    eng = QueryEngine(
        idx, max_wait_s=max_wait_s, max_pending_mutations=10_000
    )
    eng.attach_durability(dur)
    fe = ServingFrontend(eng, poll_interval_s=0.002).start()
    return idx, dur, eng, fe


def test_frontend_drain_applies_and_logs_staged_mutations(
    setup, tmp_path
):
    X = setup[0]
    idx, dur, eng, fe = _frontend_fixture(setup, tmp_path / "dur")
    ta = fe.submit_add(X[:CHUNK])
    td = fe.submit_delete([0, 1, 2])
    assert idx.pending_rows == CHUNK  # staged, not applied
    assert not ta.done and not td.done
    fe.stop(drain=True)
    assert ta.done and td.done  # applied before the driver exited
    assert ta.wal_seqno == 1 and td.wal_seqno == 2  # and WAL-logged
    assert td.result() == 3
    recs, torn = read_log(tmp_path / "dur" / "wal")
    assert torn == 0
    assert [r.kind for r in recs] == [KIND_ADD, KIND_DELETE]
    dur.close()
    rec = DurableIndex.open(tmp_path / "dur")
    assert rec.index.n_live == idx.n_live
    _assert_same_search(setup, idx, rec.index)
    rec.close()


def test_frontend_abort_leaves_replayable_wal(setup, tmp_path):
    """stop(drain=False) fails queued QUERY tickets but still applies
    + logs pending mutations — the WAL replays to the exact state."""
    X, Qm = setup[0], setup[1]
    idx, dur, eng, fe = _frontend_fixture(setup, tmp_path / "dur")
    ta = fe.submit_add(X[CHUNK:2 * CHUNK])
    tq = fe.submit(Qm[:1], k=5)  # sub-bucket: parked until stop
    fe.stop(drain=False)
    assert ta.done and ta.wal_seqno == 1
    assert isinstance(tq.error, FrontendClosed)
    dur.close()
    rec = DurableIndex.open(tmp_path / "dur")
    assert rec.report.replayed_adds == 1
    _assert_same_search(setup, idx, rec.index)
    rec.close()


# ---------------------------------------------------------------------
# compactor: checkpoint-then-truncate + supervision
# ---------------------------------------------------------------------

def test_compactor_swap_checkpoints_and_truncates_wal(setup, tmp_path):
    X = setup[0]
    idx = _build(setup, "flat", "dot", X[:N0])
    dur = DurableIndex.create(idx, tmp_path / "dur", fsync="always")
    eng = QueryEngine(idx, auto_compact=0.01)
    eng.attach_durability(dur)
    comp = BackgroundCompactor(eng)  # attached; run synchronously
    eng.submit_add(X[:CHUNK]).result()
    eng.submit_delete(list(range(10))).result()
    bytes_before = dur.wal.nbytes
    assert bytes_before > 0
    assert comp.run_once("default")  # swap + checkpoint + truncate
    stats = dur.stats()
    # the marker logged at swap is covered by the checkpoint too
    assert stats["checkpoint_seqno"] == stats["last_seqno"] == 3
    assert dur.wal.nbytes == 0  # covered segments dropped
    rec = DurableIndex.open(tmp_path / "dur")
    assert rec.report.checkpoint_seqno == 3
    assert rec.report.replayed_adds == 0  # nothing left to replay
    assert rec.index.n_dead == 0  # the compacted state was persisted
    _assert_same_search(setup, idx, rec.index)
    rec.close()
    dur.close()


def test_compactor_records_failures_and_health(setup, tmp_path):
    X = setup[0]
    idx = _build(setup, "flat", "dot", X[:N0])
    eng = QueryEngine(idx)
    comp = BackgroundCompactor(eng, max_dead_fraction=0.0,
                               max_failures=2).start()
    idx.delete(list(range(8)))
    try:
        with faults.active(
            {"compactor.swap": faults.Error(at=1, repeat=True)}
        ):
            for _ in range(2):
                comp.request("default")
                assert comp.wait_idle(10.0)
                assert _wait_until(
                    lambda: eng.stats.compact_failures >= 1
                )
            assert _wait_until(
                lambda: eng.stats.compact_consecutive_failures >= 2
            )
            assert not comp.healthy()
            assert "InjectedError" in comp.last_error
            snap = eng.stats.snapshot()["supervision"]
            assert snap["compact_failures"] >= 2
        # fault cleared: the next run succeeds and resets the streak
        comp.request("default")
        assert comp.wait_idle(10.0)
        assert _wait_until(
            lambda: eng.stats.compact_consecutive_failures == 0
        )
        assert comp.healthy()
        assert idx.n_dead == 0
    finally:
        comp.stop()


def test_driver_failure_streak_fails_queued_tickets(setup, tmp_path):
    """A persistently failing driver tick must not hang callers: after
    max_driver_failures consecutive failures, queued query tickets
    fail with the captured cause, and healthy() flips False — then
    recovers once the fault clears."""
    X, Qm = setup[0], setup[1]
    idx = _build(setup, "flat", "dot", X[:N0])
    eng = QueryEngine(idx, max_wait_s=0.005)
    fe = ServingFrontend(
        eng, poll_interval_s=0.002, max_driver_failures=3
    ).start()
    try:
        with faults.active(
            {"engine.apply": faults.Error(at=1, repeat=True)}
        ):
            tm = fe.submit_add(X[:CHUNK])  # every aged apply now fails
            assert _wait_until(
                lambda: eng.stats.driver_consecutive_failures >= 3
            )
            assert not fe.healthy()
            assert "InjectedError" in fe.last_error
            tq = fe.submit(Qm[:1], k=5)
            assert _wait_until(lambda: tq.done, timeout=5.0)
            assert isinstance(tq.error, faults.InjectedError)
            with pytest.raises(RuntimeError):
                tq.result(timeout=1.0)
            assert not tm.done  # mutations stay queued, never lost
        # fault cleared: the driver applies the backlog and recovers
        ids = tm.result(timeout=10.0)
        np.testing.assert_array_equal(ids, np.arange(N0, N0 + CHUNK))
        assert _wait_until(
            lambda: eng.stats.driver_consecutive_failures == 0
        )
        assert fe.healthy()
        snap = eng.stats.snapshot()["supervision"]
        assert snap["driver_failures"] >= 3
    finally:
        fe.stop()
