"""Live mutation pipeline: tombstone delete, batched engine-queued
mutations, compaction, and persistence.

Core property (the ScanPlan/packed-strip invariant PR 4 established,
now pinned under mutations): for ANY interleaving of add/delete/search
on ANY backend x metric — rerank and IVF partial probes included —
results are bit-identical to a fresh build over the surviving rows
under the same model (values, tie order; ids equal after mapping the
rebuild's rows through the survivor list, which is monotonic so tie
order transfers exactly), and deleted ids never surface, even when k
exceeds the live-row count.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from _hypothesis_compat import given, st
from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.serving.engine import QueryEngine

BACKENDS = ("flat", "ivf", "sharded")
METRICS = ("dot", "l2", "cos")
CHUNK = 16  # add/delete batch size: keeps payload shapes a closed set
N0 = 400  # initial build size
POOL = 1200  # vector pool the script draws adds from


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(99)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, POOL, 24)
    Qm = embedding_dataset(kq, 6, 24)
    cfg = ASHConfig(b=2, d=12, n_landmarks=8)
    model = AshIndex.build(kb, X[:N0], cfg, backend="flat").model
    return np.asarray(X), Qm, cfg, model, kb


def _build(setup, backend, metric, X_rows, **opts):
    X, Qm, cfg, model, kb = setup
    return AshIndex.build(
        kb, jnp.asarray(X_rows), cfg, backend=backend, metric=metric,
        model=model, keep_raw=True, **opts,
    )


class _Oracle:
    """Host-side mirror of the mutation history: which pool row each
    user id encodes, and which ids are alive."""

    def __init__(self, n0):
        self.src = list(range(n0))  # user id -> pool row
        self.alive = set(range(n0))

    def add(self, pool_rows):
        start = len(self.src)
        self.src.extend(pool_rows)
        self.alive.update(range(start, start + len(pool_rows)))
        return list(range(start, start + len(pool_rows)))

    def delete(self, ids):
        self.alive -= set(int(i) for i in ids)

    @property
    def survivors(self):
        """Surviving user ids in insertion (ascending-id) order — the
        row order of a fresh build over the surviving vectors."""
        return sorted(self.alive)


def _assert_matches_fresh_build(setup, idx, oracle, backend, metric,
                                search_kw):
    """Mutated-index search == fresh build over survivors (same model):
    scores bitwise, ids after the monotonic survivor mapping."""
    X, Qm, cfg, model, kb = setup
    surv = np.asarray(oracle.survivors, dtype=np.int64)
    fresh = _build(setup, backend, metric, X[[oracle.src[i] for i in surv]])
    s_m, i_m = idx.search(Qm, k=10, **search_kw)
    s_f, i_f = fresh.search(Qm, k=10, **search_kw)
    i_f = np.asarray(i_f)
    mapped = np.where(i_f < 0, -1, surv[np.maximum(i_f, 0)])
    np.testing.assert_array_equal(np.asarray(s_m), np.asarray(s_f))
    np.testing.assert_array_equal(np.asarray(i_m), mapped)


@pytest.mark.parametrize("backend", BACKENDS)
@given(
    metric=st.sampled_from(METRICS),
    rerank=st.sampled_from((0, 30)),
    nprobe=st.sampled_from((2, 8)),
    do_compact=st.sampled_from((False, True)),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_mutation_interleaving_equals_fresh_build(
    setup, backend, metric, rerank, nprobe, do_compact, seed
):
    """The core equivalence, over random add/delete/search scripts.

    nprobe only routes on IVF (2 = the gathered pre-DMA-drop path,
    8 = nlist = the dense full scan); rerank exercises the exact-rerank
    shortlist under tombstones on every backend.
    """
    X, Qm, cfg, model, kb = setup
    rng = np.random.RandomState(seed)
    idx = _build(setup, backend, metric, X[:N0])
    oracle = _Oracle(N0)
    search_kw = {"rerank": rerank}
    if backend == "ivf":
        search_kw["nprobe"] = nprobe

    for _ in range(rng.randint(2, 5)):
        op = rng.rand()
        if op < 0.4:
            pool_rows = rng.randint(0, POOL, CHUNK)
            got = np.asarray(idx.stage_add(X[pool_rows]))
            idx.apply_pending()
            expect = oracle.add(list(pool_rows))
            np.testing.assert_array_equal(got, expect)
        elif op < 0.8 and len(oracle.alive) > CHUNK + 8:
            victims = rng.choice(
                sorted(oracle.alive), size=CHUNK, replace=False
            )
            # over-asking is fine: unknown/dead ids are ignored
            removed = idx.delete(np.concatenate([victims, victims[:3]]))
            assert removed == CHUNK
            oracle.delete(victims)
        else:
            s, ids = idx.search(Qm, k=10, **search_kw)
            ids = np.asarray(ids)
            dead = np.setdiff1d(
                np.arange(len(oracle.src)), sorted(oracle.alive)
            )
            assert not np.isin(ids, dead).any()

    assert idx.n_live == len(oracle.alive)
    if do_compact:
        idx.compact()
        assert idx.n == idx.n_live == len(oracle.alive)
    _assert_matches_fresh_build(
        setup, idx, oracle, backend, metric, search_kw
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_deleted_ids_never_appear_when_k_exceeds_live(setup, backend):
    """k past the live-row count pads with -inf / -1 — tombstones can
    never leak back in to fill the tail."""
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, backend, "dot", X[:CHUNK])
    dead = list(range(1, CHUNK, 2))
    assert idx.delete(dead) == len(dead)
    kw = {"nprobe": 8} if backend == "ivf" else {}
    s, ids = idx.search(Qm, k=CHUNK, **kw)
    s, ids = np.asarray(s), np.asarray(ids)
    live = CHUNK - len(dead)
    assert not np.isin(ids, dead).any()
    for r in range(ids.shape[0]):
        valid = ids[r][ids[r] >= 0]
        assert len(valid) == live and len(set(valid)) == live
    assert np.isneginf(s[:, live:]).all()
    assert (ids[:, live:] == -1).all()


@pytest.mark.parametrize(
    "backend,n_shards",
    [("flat", None), ("ivf", None),
     ("sharded", 1), ("sharded", 2), ("sharded", 4)],
)
def test_save_load_with_tombstones_and_pending(
    setup, backend, n_shards, tmp_path
):
    """Round-trip with live tombstones AND a staged-add buffer:
    search stays bit-identical, the buffer survives, and
    compact()-then-search equals a fresh build over the survivors."""
    X, Qm, cfg, model, kb = setup
    opts = {}
    if n_shards is not None:
        opts = dict(
            mesh=Mesh(np.array(jax.devices()[:n_shards]), ("data",)),
            axes=("data",),
        )
    idx = _build(setup, backend, "l2", X[:N0], **opts)
    oracle = _Oracle(N0)
    victims = np.arange(7, N0, 9)
    idx.delete(victims)
    oracle.delete(victims)
    staged = idx.stage_add(X[N0:N0 + CHUNK])
    assert list(staged) == list(range(N0, N0 + CHUNK))

    idx.save(tmp_path / "idx")
    idx2 = AshIndex.load(tmp_path / "idx", **opts)
    assert idx2.n_dead == len(victims)
    assert idx2.pending_rows == CHUNK
    s1, i1 = idx.search(Qm, k=10, rerank=40)
    s2, i2 = idx2.search(Qm, k=10, rerank=40)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    # the loaded copy applies its persisted buffer and compacts to the
    # same state the original reaches
    for ix in (idx, idx2):
        assert ix.apply_pending() == CHUNK
        ix.compact()
    oracle.add(list(range(N0, N0 + CHUNK)))
    sa, ia = idx.search(Qm, k=10, rerank=40)
    sb, ib = idx2.search(Qm, k=10, rerank=40)
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    _assert_matches_fresh_build(
        setup, idx2, oracle, backend, "l2", {"rerank": 40}
    )


def test_sharded_add_recomputes_stats_and_raw(setup, tmp_path):
    """Regression: sharded add() must extend stats AND bf16 raw shards
    for the appended rows the way build does — including on an index
    loaded from a pre-stats save (stats rebuilt, raw preserved) — or
    shard-local rerank would silently serve a truncated raw shard."""
    X, Qm, cfg, model, kb = setup
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    opts = dict(mesh=mesh, axes=("data",))
    idx = _build(setup, "sharded", "l2", X[:N0], **opts)
    idx.save(tmp_path / "full")

    # simulate a pre-stats snapshot: strip the stats arrays (and their
    # manifest checksums — a genuine pre-stats save carries neither,
    # and load() rightly rejects a manifest/npz entry mismatch)
    with np.load(tmp_path / "full" / "arrays.npz") as npz:
        kept = {k: npz[k] for k in npz.files if not k.startswith("stats.")}
    np.savez(tmp_path / "full" / "arrays.npz", **kept)
    meta = json.loads((tmp_path / "full" / "config.json").read_text())
    assert any(k.startswith("stats.") for k in meta["dtypes"])  # was saved
    meta["checksums"] = {
        k: v for k, v in meta["checksums"].items()
        if not k.startswith("stats.")
    }
    (tmp_path / "full" / "config.json").write_text(json.dumps(meta))

    for source in ("live", "loaded"):
        ix = idx if source == "live" else AshIndex.load(
            tmp_path / "full", **opts
        )
        ix.add(jnp.asarray(X[N0:N0 + CHUNK]))
        st_ = ix._state
        assert st_.stats is not None
        assert st_.stats.res_norm.shape[0] == N0 + CHUNK
        assert st_.raw is not None and st_.raw.shape[0] == N0 + CHUNK
        assert st_.sharded_raw is not None

    # rerank search over the grown index == fresh build (same model)
    oracle = _Oracle(N0)
    oracle.add(list(range(N0, N0 + CHUNK)))
    _assert_matches_fresh_build(
        setup, idx, oracle, "sharded", "l2", {"rerank": 40}
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_retired_ids_are_never_reused(setup, backend):
    """Deleting the top ids and compacting must not hand the retired
    ids back out on the next add."""
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, backend, "dot", X[:CHUNK * 2])
    top = list(range(CHUNK, CHUNK * 2))
    idx.delete(top)
    idx.compact()
    assert idx.next_id == CHUNK * 2
    ids = idx.stage_add(X[:4])
    assert list(ids) == [CHUNK * 2, CHUNK * 2 + 1,
                         CHUNK * 2 + 2, CHUNK * 2 + 3]
    idx.apply_pending()
    s, got = idx.search(Qm, k=5)
    assert not np.isin(np.asarray(got), top).any()


def test_compact_refuses_to_empty_the_index(setup):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:CHUNK])
    idx.delete(np.arange(CHUNK))
    assert idx.n_live == 0
    with pytest.raises(ValueError, match="every row"):
        idx.compact()
    # still searchable: all slots are missing-candidate sentinels
    s, ids = idx.search(Qm, k=CHUNK)
    assert (np.asarray(ids) == -1).all()
    assert np.isneginf(np.asarray(s)).all()


def test_delete_semantics(setup):
    """Unknown and repeated ids are ignored; counts reflect only rows
    newly tombstoned; dead_fraction tracks the bitmap."""
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:100])
    assert idx.delete([5, 5, 6, 100, 10**9, -3]) == 2
    assert idx.delete([5, 6]) == 0
    assert idx.n_dead == 2 and idx.n_live == 98
    assert idx.dead_fraction == pytest.approx(0.02)
    assert "dead=2" in repr(idx)


# ---------------------------------------------------------------------------
# Engine-queued mutations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_mutations_match_direct(setup, backend):
    """submit/submit_add/submit_delete interleaved through the engine
    == the same ops applied directly: pre-mutation queries are
    barrier-flushed against the old state, post-mutation queries see
    exactly the mutations submitted before them, results bit-identical
    to direct search on the equivalently-mutated index."""
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, backend, "dot", X[:N0])
    direct = _build(setup, backend, "dot", X[:N0])
    eng = QueryEngine(idx, batch_buckets=(8,), k_buckets=(10,),
                      max_wait_s=60.0)

    t_pre = eng.submit(np.asarray(Qm[:2]), k=10)
    s_pre_d, i_pre_d = direct.search(Qm[:2], k=10)

    ta = eng.submit_add(X[N0:N0 + CHUNK])
    assert t_pre.done and t_pre.stats.flush_reason == "barrier"
    np.testing.assert_array_equal(
        t_pre.result()[1], np.asarray(i_pre_d)
    )
    assert list(ta.ids) == list(range(N0, N0 + CHUNK))

    td = eng.submit_delete(np.arange(0, 40))
    t_post = eng.submit(np.asarray(Qm[:2]), k=10)
    assert eng.stats.mutation_batches == 0  # nothing applied yet
    eng.flush()
    assert eng.stats.mutation_batches == 1  # ONE batched apply
    np.testing.assert_array_equal(ta.result(), ta.ids)
    assert td.result() == 40

    direct.add(jnp.asarray(X[N0:N0 + CHUNK]))
    direct.delete(np.arange(0, 40))
    s_d, i_d = direct.search(Qm[:2], k=10)
    s_e, i_e = t_post.result()
    np.testing.assert_array_equal(s_e, np.asarray(s_d))
    np.testing.assert_array_equal(i_e, np.asarray(i_d))

    snap = eng.stats.snapshot()
    assert snap["added_rows"] == CHUNK
    assert snap["deleted_rows"] == 40
    assert snap["flushes"]["barrier"] >= 1


def test_engine_mutation_ticket_forces_apply(setup):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:100])
    eng = QueryEngine(idx, max_wait_s=60.0)
    td = eng.submit_delete([1, 2, 3])
    assert not td.done
    assert td.result() == 3  # result() applies the queued batch
    assert idx.n_dead == 3
    assert td.apply_s >= 0.0


def test_engine_mutation_backlog_overflow_applies(setup):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:100])
    eng = QueryEngine(idx, max_wait_s=60.0, max_pending_mutations=32)
    t1 = eng.submit_add(X[:16])
    assert not t1.done and idx.pending_rows == 16
    t2 = eng.submit_add(X[16:32])  # hits the 32-row backlog bound
    assert t1.done and t2.done
    assert idx.n == 132 and idx.pending_rows == 0


def test_engine_auto_compact(setup):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:200])
    eng = QueryEngine(idx, max_wait_s=60.0, auto_compact=0.25)
    eng.submit_delete(np.arange(10))  # 5% dead: below threshold
    eng.flush()
    assert idx.n == 200 and idx.n_dead == 10
    eng.submit_delete(np.arange(10, 80))  # 40% dead: evicted
    eng.flush()
    assert idx.n == 120 and idx.n_dead == 0
    assert eng.stats.compactions == 1


def test_engine_poll_applies_aged_mutations(setup):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:100])
    eng = QueryEngine(idx, max_wait_s=0.0)
    td = eng.submit_delete([1])
    eng.poll()
    assert td.done and idx.n_dead == 1


def test_engine_register_settles_queued_mutations(setup):
    """Re-registering a name applies queued mutations against the OLD
    binding first — the rows are staged on that index, so erroring the
    tickets would strand rows the old index still ingests later."""
    X, Qm, cfg, model, kb = setup
    old = _build(setup, "flat", "dot", X[:100])
    new = _build(setup, "flat", "dot", X[:100])
    eng = QueryEngine(old, max_wait_s=60.0)
    ta = eng.submit_add(X[100:104])
    td = eng.submit_delete([0, 1])
    eng.register("default", new)
    assert list(ta.result()) == [100, 101, 102, 103]
    assert td.result() == 2
    assert old.n == 104 and old.n_dead == 2  # applied to the old index
    assert new.n == 100 and new.pending_rows == 0


def test_engine_rejects_bad_add(setup):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, "flat", "dot", X[:100])
    eng = QueryEngine(idx, max_wait_s=60.0)
    with pytest.raises(ValueError, match="add rows"):
        eng.submit_add(np.zeros((2, 7), np.float32))
    with pytest.raises(KeyError):
        eng.submit_add(X[:2], index="nope")
