"""Micro-batching QueryEngine: bit-identical parity vs direct search,
bucket/flush semantics, prep-cache accounting, trace reuse."""
import jax
import jax.numpy as jnp
import numpy as onp
import pytest

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.index import flat as F
from repro.serving.engine import EngineConfig, QueryEngine

BACKENDS = ("flat", "ivf", "sharded")


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(99)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, 2500, 32)
    Qm = embedding_dataset(kq, 48, 32)
    cfg = ASHConfig(b=2, d=16, n_landmarks=8)
    model = AshIndex.build(kb, X, cfg, backend="flat").model
    indexes = {
        "flat": AshIndex.build(kb, X, cfg, backend="flat", model=model,
                               keep_raw=True),
        "ivf": AshIndex.build(kb, X, cfg, backend="ivf", model=model,
                              keep_raw=True),
        "sharded": AshIndex.build(kb, X, cfg, backend="sharded",
                                  model=model),
    }
    return X, Qm, indexes


def _engine(indexes, **kw):
    kw.setdefault("batch_buckets", (4, 16))
    kw.setdefault("k_buckets", (8,))
    kw.setdefault("max_wait_s", 60.0)  # flush explicitly in tests
    return QueryEngine(indexes, **kw)


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_bit_identical(setup, backend):
    """Batched+padded engine results == per-request direct search,
    bit-for-bit (scores AND ids), cold and warm prep cache."""
    X, Qm, indexes = setup
    idx = indexes[backend]
    kw = {"nprobe": 4} if backend == "ivf" else {}
    eng = _engine({backend: idx})
    for round_ in range(2):  # round 2 serves fully from the prep cache
        sizes = [1, 3, 2, 5, 1]
        offs = onp.cumsum([0] + sizes)
        tickets = [
            eng.submit(Qm[offs[i]:offs[i + 1]], k=7, index=backend, **kw)
            for i in range(len(sizes))
        ]
        eng.flush()
        for i, t in enumerate(tickets):
            s, ids = t.result()
            ds, di = idx.search(Qm[offs[i]:offs[i + 1]], k=7, **kw)
            assert jnp.array_equal(jnp.asarray(s), ds), (backend, round_, i)
            assert jnp.array_equal(jnp.asarray(ids), di), (backend, round_, i)
    assert eng.stats.prep_hits > 0  # round 2 actually hit the cache


@pytest.mark.parametrize("backend", ("flat", "ivf"))
@pytest.mark.parametrize(
    "k,rerank,k_buckets",
    [
        (5, 30, (8,)),  # shortlist (rerank) beyond the k bucket
        (5, 6, (8,)),  # shortlist below the k bucket
        (20, 50, (10, 100)),  # k pads to 100 > rerank: the bucketized
        # k must not widen the shortlist past the direct path's
        # max(rerank, k) (regression: this returned different ids)
    ],
)
def test_parity_with_rerank(setup, backend, k, rerank, k_buckets):
    X, Qm, indexes = setup
    idx = indexes[backend]
    kw = {"rerank": rerank}
    if backend == "ivf":
        kw["nprobe"] = 4
    eng = _engine({backend: idx}, k_buckets=k_buckets)
    t1 = eng.submit(Qm[:1], k=k, index=backend, **kw)
    t2 = eng.submit(Qm[1:4], k=k, index=backend, **kw)
    eng.flush()
    assert eng.stats.batches == 1  # same shortlist: one fused call
    for t, sl in ((t1, slice(0, 1)), (t2, slice(1, 4))):
        s, ids = t.result()
        ds, di = idx.search(Qm[sl], k=k, **kw)
        assert jnp.array_equal(jnp.asarray(s), ds)
        assert jnp.array_equal(jnp.asarray(ids), di)


def test_rerank_mixed_k_groups_by_shortlist(setup):
    """rerank < k requests need a shortlist of exactly their k, so each
    distinct max(rerank, k) forms its own group/fused call — and every
    request still matches per-request search bit-for-bit."""
    X, Qm, indexes = setup
    idx = indexes["flat"]
    eng = _engine({"flat": idx})
    t1 = eng.submit(Qm[:2], k=4, index="flat", rerank=2)
    t2 = eng.submit(Qm[2:5], k=7, index="flat", rerank=2)
    eng.flush()
    assert eng.stats.batches == 2  # shortlists 4 and 7 cannot fuse
    for t, sl, k in ((t1, slice(0, 2), 4), (t2, slice(2, 5), 7)):
        s, ids = t.result()
        ds, di = idx.search(Qm[sl], k=k, rerank=2)
        assert jnp.array_equal(jnp.asarray(s), ds)
        assert jnp.array_equal(jnp.asarray(ids), di)


def test_mixed_k_share_one_bucket(setup):
    """Different requested k ride one bucket (k padded to a k-bucket,
    per-request prefix sliced) — one fused call, exact results."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(16,))
    t1 = eng.submit(Qm[:2], k=3, index="flat")
    t2 = eng.submit(Qm[2:5], k=8, index="flat")
    eng.flush()
    assert eng.stats.batches == 1
    assert t1.result()[0].shape == (2, 3)
    assert jnp.array_equal(
        jnp.asarray(t1.result()[1]), indexes["flat"].search(Qm[:2], k=3)[1]
    )
    assert jnp.array_equal(
        jnp.asarray(t2.result()[1]),
        indexes["flat"].search(Qm[2:5], k=8)[1],
    )


def test_k_larger_than_n():
    """k > index size clamps the fused call and pads results with the
    missing-candidate sentinel (score -inf, id -1)."""
    X = embedding_dataset(jax.random.PRNGKey(5), 30, 16)
    idx = AshIndex.build(
        jax.random.PRNGKey(0), X, ASHConfig(b=2, d=8, n_landmarks=2)
    )
    eng = _engine({"tiny": idx}, batch_buckets=(4,), k_buckets=(8,))
    s, ids = eng.search(X[:2], k=50, index="tiny")
    assert s.shape == (2, 50) and ids.shape == (2, 50)
    assert (ids[:, 30:] == -1).all()
    assert onp.isneginf(s[:, 30:]).all()
    ds, di = idx.search(X[:2], k=30)
    assert jnp.array_equal(jnp.asarray(ids[:, :30]), di)
    assert jnp.array_equal(jnp.asarray(s[:, :30]), ds)


def test_empty_flush_and_poll(setup):
    X, Qm, indexes = setup
    eng = _engine(indexes)
    assert eng.flush() == 0
    assert eng.poll() == 0
    assert eng.pending_requests == 0


def test_flush_on_size(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,))
    tickets = [eng.submit(Qm[i:i + 1], k=5, index="flat")
               for i in range(4)]
    # 4 rows == largest bucket: flushed inside the last submit
    assert all(t.done for t in tickets)
    assert tickets[0].stats.flush_reason == "size"
    assert tickets[0].stats.bucket_rows == 4


def test_flush_on_timeout(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(64,),
                  max_wait_s=0.0)
    t = eng.submit(Qm[:1], k=5, index="flat")
    eng.poll()
    assert t.done
    assert t.stats.flush_reason == "timeout"


def test_bounded_queue_applies_backpressure(setup):
    """Exceeding max_pending rows forces a serve — requests are never
    dropped and the queue never grows past the bound."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(64,),
                  max_pending=8)
    t1 = eng.submit(Qm[:4], k=5, index="flat")
    t2 = eng.submit(Qm[4:8], k=5, index="flat")
    assert not t1.done  # still queued: bound not exceeded yet
    t3 = eng.submit(Qm[8:12], k=5, index="flat")
    assert t1.done and t2.done  # backpressure flush served the backlog
    # queue-pressure flushes are their own telemetry bucket, distinct
    # from explicit flush() calls
    assert t1.stats.flush_reason == "pressure"
    assert eng.stats.flushes["pressure"] == 1
    eng.flush()
    assert t3.done
    assert t3.stats.flush_reason == "manual"


def test_prep_cache_hit_miss_counts(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,))
    t1 = eng.submit(Qm[:2], k=5, index="flat")
    eng.flush()
    assert t1.stats.prep_hits == 0 and t1.stats.prep_misses == 2
    t2 = eng.submit(Qm[:2], k=5, index="flat")  # identical rows
    t3 = eng.submit(Qm[2:3], k=5, index="flat")  # fresh row
    eng.flush()
    assert t2.stats.prep_hits == 2 and t2.stats.prep_misses == 0
    assert t3.stats.prep_hits == 0 and t3.stats.prep_misses == 1
    assert eng.stats.prep_hits == 2
    assert eng.stats.prep_misses == 3
    # results served off cached preps are still exact
    assert jnp.array_equal(
        jnp.asarray(t2.result()[1]), jnp.asarray(t1.result()[1])
    )


def test_prep_cache_disabled_and_eviction(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, prep_cache_entries=0)
    eng.search(Qm[:2], k=5, index="flat")
    eng.search(Qm[:2], k=5, index="flat")
    assert eng.stats.prep_hits == 0 and eng.stats.prep_misses == 4
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,),
                  prep_cache_entries=2)
    eng.search(Qm[:4], k=5, index="flat")
    assert len(eng._prep_cache) == 2  # LRU evicted down to the bound


def test_prep_cache_byte_bound_evicts(setup):
    """The LRU is byte-bounded: inserts beyond prep_cache_bytes evict
    oldest rows, and the live footprint is exposed for capacity
    planning."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,))
    eng.search(Qm[:1], k=5, index="flat")
    per_row = eng.prep_cache_bytes
    assert per_row > 0
    # budget for exactly 2 rows: the third insert evicts the oldest
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,),
                  prep_cache_bytes=2 * per_row)
    eng.search(Qm[:4], k=5, index="flat")
    assert len(eng._prep_cache) == 2
    assert eng.prep_cache_bytes == 2 * per_row
    # eviction keeps the most-recent rows: Qm[2:4] now hit, Qm[0] misses
    eng.search(Qm[2:4], k=5, index="flat")
    assert eng.stats.prep_hits == 2
    eng.search(Qm[:1], k=5, index="flat")
    assert eng.stats.prep_hits == 2  # Qm[0] was evicted
    # results served under eviction pressure stay exact
    s, ids = eng.search(Qm[:4], k=5, index="flat")
    ds, di = indexes["flat"].search(Qm[:4], k=5)
    assert jnp.array_equal(jnp.asarray(ids), di)


def test_prep_cache_bytes_zero_disables(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, prep_cache_bytes=0)
    eng.search(Qm[:2], k=5, index="flat")
    eng.search(Qm[:2], k=5, index="flat")
    assert eng.stats.prep_hits == 0 and eng.stats.prep_misses == 4
    assert eng.prep_cache_bytes == 0


def test_prep_cache_invalidate_restores_byte_accounting(setup):
    X, Qm, indexes = setup
    eng = _engine(indexes, batch_buckets=(4,))
    eng.search(Qm[:2], k=5, index="flat")
    eng.search(Qm[:2], k=5, index="ivf", nprobe=4)
    assert eng.prep_cache_bytes > 0
    before = eng.prep_cache_bytes
    eng.invalidate_prep_cache("flat")
    assert 0 < eng.prep_cache_bytes < before
    eng.invalidate_prep_cache()
    assert eng.prep_cache_bytes == 0 and len(eng._prep_cache) == 0


def test_snapshot_reports_hit_rate(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,))
    eng.search(Qm[:2], k=5, index="flat")
    eng.search(Qm[:2], k=5, index="flat")
    snap = eng.stats.snapshot()
    assert snap["prep_hit_rate"] == pytest.approx(0.5)


def test_pad_rows_not_cached(setup):
    """Zero-pad rows of an underfilled bucket never enter the prep
    cache — LRU capacity is spent on real queries only."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,))
    eng.search(Qm[:2], k=5, index="flat")  # cold: 2 real + 2 pad rows
    assert len(eng._prep_cache) == 2
    # warm: Qm[1] hits, Qm[2] misses, 2 pad rows miss but stay uncached
    eng.search(Qm[1:3], k=5, index="flat")
    assert len(eng._prep_cache) == 3


def test_ivf_nprobe_clamps_before_grouping(setup):
    """nprobe values at/above nlist route identically, so they must
    share one group and one trace (nlist == 8 in this setup)."""
    X, Qm, indexes = setup
    idx = indexes["ivf"]
    eng = _engine({"ivf": idx}, batch_buckets=(16,))
    t1 = eng.submit(Qm[:2], k=5, index="ivf", nprobe=8)
    t2 = eng.submit(Qm[2:4], k=5, index="ivf", nprobe=1000)
    t3 = eng.submit(Qm[4:6], k=5, index="ivf")  # default, also clamped
    eng.flush()
    assert eng.stats.batches == 1
    assert len(eng.stats.compiled_buckets) == 1
    s, ids = t2.result()
    ds, di = idx.search(Qm[2:4], k=5, nprobe=1000)
    assert jnp.array_equal(jnp.asarray(s), ds)
    assert jnp.array_equal(jnp.asarray(ids), di)
    assert t1.done and t3.done


def test_submit_rejects_mismatched_query_dim(setup):
    """A query whose width differs from the index dim is rejected at
    submit — inside a group it would fail the whole fused call and take
    unrelated requests down with it."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]})
    with pytest.raises(ValueError, match="dim"):
        eng.submit(onp.zeros((1, 16), onp.float32), k=5, index="flat")
    assert eng.pending_requests == 0


def test_submit_survives_failing_flush(setup):
    """A flush triggered inside submit() must not swallow the caller's
    Ticket: the error is delivered by the failing request's result(),
    and unrelated requests keep working."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, max_wait_s=0.0)
    bad = eng.submit(Qm[:1], k=5, index="flat", bogus=True)
    assert bad.done  # timeout-flushed (and failed) inside submit
    good = eng.submit(Qm[:2], k=5, index="flat")
    eng.poll()
    s, ids = good.result()
    assert jnp.array_equal(
        jnp.asarray(ids), indexes["flat"].search(Qm[:2], k=5)[1]
    )
    with pytest.raises(RuntimeError, match="fused scoring call"):
        bad.result()


def test_trace_reuse_across_requests(setup):
    """Many requests of novel shapes ride ONE jit trace per bucket: the
    underlying compiled-call cache grows by at most the bucket count,
    not per request."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(8,))
    before = F._search_prepped._cache_size()
    for i in range(12):  # request shapes 1..4 rows, all pad to bucket 8
        eng.submit(Qm[i:i + 1 + (i % 4)], k=5, index="flat")
    eng.flush()
    after = F._search_prepped._cache_size()
    assert eng.stats.batches >= 3  # several fused calls actually ran
    assert after - before <= 1  # ... through at most ONE new trace
    assert len(eng.stats.compiled_buckets) == 1


def test_multi_index_routing(setup):
    """One engine fronts several tenant indexes; requests route by
    name and never cross-contaminate."""
    X, Qm, indexes = setup
    eng = _engine(indexes)
    tf = eng.submit(Qm[:2], k=5, index="flat")
    ti = eng.submit(Qm[:2], k=5, index="ivf", nprobe=4)
    ts = eng.submit(Qm[:2], k=5, index="sharded")
    eng.flush()
    assert jnp.array_equal(
        jnp.asarray(tf.result()[1]), indexes["flat"].search(Qm[:2], k=5)[1]
    )
    assert jnp.array_equal(
        jnp.asarray(ti.result()[1]),
        indexes["ivf"].search(Qm[:2], k=5, nprobe=4)[1],
    )
    assert jnp.array_equal(
        jnp.asarray(ts.result()[1]),
        indexes["sharded"].search(Qm[:2], k=5)[1],
    )
    with pytest.raises(KeyError, match="unknown index"):
        eng.submit(Qm[:1], k=5, index="nope")


def test_request_stats_populated(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]})
    t = eng.submit(Qm[:3], k=5, index="flat")
    eng.flush()
    st = t.stats
    assert st.queue_wait_s >= 0.0
    assert st.batch_rows == 3 and st.bucket_rows == 4
    assert st.scoring_us > 0.0
    assert st.flush_reason == "manual"


def test_oversized_request_rides_alone(setup):
    """A request larger than the largest bucket pads to a multiple of
    it (closed shape set) and still returns exact results."""
    X, Qm, indexes = setup
    idx = indexes["flat"]
    eng = _engine({"flat": idx}, batch_buckets=(8,))
    s, ids = eng.search(Qm[:20], k=5, index="flat")
    ds, di = idx.search(Qm[:20], k=5)
    assert jnp.array_equal(jnp.asarray(s), ds)
    assert jnp.array_equal(jnp.asarray(ids), di)
    assert t_bucket(eng) == 24
    assert eng.stats.padded_rows == 4


def t_bucket(eng):
    (entry,) = eng.stats.compiled_buckets
    return entry[2]


def test_sharded_rerank_without_raw_errors_at_result(setup):
    """Sharded rerank needs distributed raw shards; without keep_raw
    the backend error reaches the ticket instead of vanishing."""
    X, Qm, indexes = setup
    eng = _engine(indexes)
    t = eng.submit(Qm[:1], k=5, index="sharded", rerank=10)
    with pytest.raises(ValueError, match="keep_raw"):
        eng.flush()  # explicit flush re-raises at the flush site
    with pytest.raises(RuntimeError, match="fused scoring"):
        t.result()  # ... and the ticket carries it too


def test_sharded_rerank_through_engine_matches_direct(setup):
    """Engine-served sharded rerank == direct search bit-for-bit (the
    shard-local rerank path honors the shortlist grouping)."""
    X, Qm, indexes = setup
    model = indexes["sharded"].model
    cfg = model.config
    idx = AshIndex.build(
        jax.random.PRNGKey(0), X, cfg, backend="sharded", model=model,
        keep_raw=True,
    )
    eng = _engine({"sharded": idx})
    t1 = eng.submit(Qm[:3], k=5, index="sharded", rerank=20)
    t2 = eng.submit(Qm[3:4], k=5, index="sharded", rerank=20)
    eng.flush()
    ds, di = idx.search(Qm[:4], k=5, rerank=20)
    got_s = onp.concatenate([t1.result()[0], t2.result()[0]])
    got_i = onp.concatenate([t1.result()[1], t2.result()[1]])
    assert onp.array_equal(got_s, onp.asarray(ds))
    assert onp.array_equal(got_i, onp.asarray(di))


def test_engine_config_validation():
    with pytest.raises(ValueError, match="ascending"):
        EngineConfig(batch_buckets=(32, 8))
    with pytest.raises(ValueError, match="non-empty"):
        EngineConfig(batch_buckets=())
