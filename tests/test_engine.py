"""Micro-batching QueryEngine: bit-identical parity vs direct search,
bucket/flush semantics, prep-cache accounting, trace reuse."""
import jax
import jax.numpy as jnp
import numpy as onp
import pytest

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.index import flat as F
from repro.serving.engine import EngineConfig, QueryEngine

BACKENDS = ("flat", "ivf", "sharded")


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(99)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, 2500, 32)
    Qm = embedding_dataset(kq, 48, 32)
    cfg = ASHConfig(b=2, d=16, n_landmarks=8)
    model = AshIndex.build(kb, X, cfg, backend="flat").model
    indexes = {
        "flat": AshIndex.build(kb, X, cfg, backend="flat", model=model,
                               keep_raw=True),
        "ivf": AshIndex.build(kb, X, cfg, backend="ivf", model=model,
                              keep_raw=True),
        "sharded": AshIndex.build(kb, X, cfg, backend="sharded",
                                  model=model),
    }
    return X, Qm, indexes


def _engine(indexes, **kw):
    kw.setdefault("batch_buckets", (4, 16))
    kw.setdefault("k_buckets", (8,))
    kw.setdefault("max_wait_s", 60.0)  # flush explicitly in tests
    return QueryEngine(indexes, **kw)


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_bit_identical(setup, backend):
    """Batched+padded engine results == per-request direct search,
    bit-for-bit (scores AND ids), cold and warm prep cache."""
    X, Qm, indexes = setup
    idx = indexes[backend]
    kw = {"nprobe": 4} if backend == "ivf" else {}
    eng = _engine({backend: idx})
    for round_ in range(2):  # round 2 serves fully from the prep cache
        sizes = [1, 3, 2, 5, 1]
        offs = onp.cumsum([0] + sizes)
        tickets = [
            eng.submit(Qm[offs[i]:offs[i + 1]], k=7, index=backend, **kw)
            for i in range(len(sizes))
        ]
        eng.flush()
        for i, t in enumerate(tickets):
            s, ids = t.result()
            ds, di = idx.search(Qm[offs[i]:offs[i + 1]], k=7, **kw)
            assert jnp.array_equal(jnp.asarray(s), ds), (backend, round_, i)
            assert jnp.array_equal(jnp.asarray(ids), di), (backend, round_, i)
    assert eng.stats.prep_hits > 0  # round 2 actually hit the cache


@pytest.mark.parametrize("backend", ("flat", "ivf"))
def test_parity_with_rerank(setup, backend):
    X, Qm, indexes = setup
    idx = indexes[backend]
    kw = {"rerank": 30}
    if backend == "ivf":
        kw["nprobe"] = 4
    eng = _engine({backend: idx})
    t1 = eng.submit(Qm[:1], k=5, index=backend, **kw)
    t2 = eng.submit(Qm[1:4], k=5, index=backend, **kw)
    eng.flush()
    for t, sl in ((t1, slice(0, 1)), (t2, slice(1, 4))):
        s, ids = t.result()
        ds, di = idx.search(Qm[sl], k=5, **kw)
        assert jnp.array_equal(jnp.asarray(s), ds)
        assert jnp.array_equal(jnp.asarray(ids), di)


def test_mixed_k_share_one_bucket(setup):
    """Different requested k ride one bucket (k padded to a k-bucket,
    per-request prefix sliced) — one fused call, exact results."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(16,))
    t1 = eng.submit(Qm[:2], k=3, index="flat")
    t2 = eng.submit(Qm[2:5], k=8, index="flat")
    eng.flush()
    assert eng.stats.batches == 1
    assert t1.result()[0].shape == (2, 3)
    assert jnp.array_equal(
        jnp.asarray(t1.result()[1]), indexes["flat"].search(Qm[:2], k=3)[1]
    )
    assert jnp.array_equal(
        jnp.asarray(t2.result()[1]),
        indexes["flat"].search(Qm[2:5], k=8)[1],
    )


def test_k_larger_than_n():
    """k > index size clamps the fused call and pads results with the
    missing-candidate sentinel (score -inf, id -1)."""
    X = embedding_dataset(jax.random.PRNGKey(5), 30, 16)
    idx = AshIndex.build(
        jax.random.PRNGKey(0), X, ASHConfig(b=2, d=8, n_landmarks=2)
    )
    eng = _engine({"tiny": idx}, batch_buckets=(4,), k_buckets=(8,))
    s, ids = eng.search(X[:2], k=50, index="tiny")
    assert s.shape == (2, 50) and ids.shape == (2, 50)
    assert (ids[:, 30:] == -1).all()
    assert onp.isneginf(s[:, 30:]).all()
    ds, di = idx.search(X[:2], k=30)
    assert jnp.array_equal(jnp.asarray(ids[:, :30]), di)
    assert jnp.array_equal(jnp.asarray(s[:, :30]), ds)


def test_empty_flush_and_poll(setup):
    X, Qm, indexes = setup
    eng = _engine(indexes)
    assert eng.flush() == 0
    assert eng.poll() == 0
    assert eng.pending_requests == 0


def test_flush_on_size(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,))
    tickets = [eng.submit(Qm[i:i + 1], k=5, index="flat")
               for i in range(4)]
    # 4 rows == largest bucket: flushed inside the last submit
    assert all(t.done for t in tickets)
    assert tickets[0].stats.flush_reason == "size"
    assert tickets[0].stats.bucket_rows == 4


def test_flush_on_timeout(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(64,),
                  max_wait_s=0.0)
    t = eng.submit(Qm[:1], k=5, index="flat")
    eng.poll()
    assert t.done
    assert t.stats.flush_reason == "timeout"


def test_bounded_queue_applies_backpressure(setup):
    """Exceeding max_pending rows forces a serve — requests are never
    dropped and the queue never grows past the bound."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(64,),
                  max_pending=8)
    t1 = eng.submit(Qm[:4], k=5, index="flat")
    t2 = eng.submit(Qm[4:8], k=5, index="flat")
    assert not t1.done  # still queued: bound not exceeded yet
    t3 = eng.submit(Qm[8:12], k=5, index="flat")
    assert t1.done and t2.done  # backpressure flush served the backlog
    eng.flush()
    assert t3.done


def test_prep_cache_hit_miss_counts(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,))
    t1 = eng.submit(Qm[:2], k=5, index="flat")
    eng.flush()
    assert t1.stats.prep_hits == 0 and t1.stats.prep_misses == 2
    t2 = eng.submit(Qm[:2], k=5, index="flat")  # identical rows
    t3 = eng.submit(Qm[2:3], k=5, index="flat")  # fresh row
    eng.flush()
    assert t2.stats.prep_hits == 2 and t2.stats.prep_misses == 0
    assert t3.stats.prep_hits == 0 and t3.stats.prep_misses == 1
    assert eng.stats.prep_hits == 2
    assert eng.stats.prep_misses == 3
    # results served off cached preps are still exact
    assert jnp.array_equal(
        jnp.asarray(t2.result()[1]), jnp.asarray(t1.result()[1])
    )


def test_prep_cache_disabled_and_eviction(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, prep_cache_entries=0)
    eng.search(Qm[:2], k=5, index="flat")
    eng.search(Qm[:2], k=5, index="flat")
    assert eng.stats.prep_hits == 0 and eng.stats.prep_misses == 4
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(4,),
                  prep_cache_entries=2)
    eng.search(Qm[:4], k=5, index="flat")
    assert len(eng._prep_cache) == 2  # LRU evicted down to the bound


def test_trace_reuse_across_requests(setup):
    """Many requests of novel shapes ride ONE jit trace per bucket: the
    underlying compiled-call cache grows by at most the bucket count,
    not per request."""
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]}, batch_buckets=(8,))
    before = F._search_prepped._cache_size()
    for i in range(12):  # request shapes 1..4 rows, all pad to bucket 8
        eng.submit(Qm[i:i + 1 + (i % 4)], k=5, index="flat")
    eng.flush()
    after = F._search_prepped._cache_size()
    assert eng.stats.batches >= 3  # several fused calls actually ran
    assert after - before <= 1  # ... through at most ONE new trace
    assert len(eng.stats.compiled_buckets) == 1


def test_multi_index_routing(setup):
    """One engine fronts several tenant indexes; requests route by
    name and never cross-contaminate."""
    X, Qm, indexes = setup
    eng = _engine(indexes)
    tf = eng.submit(Qm[:2], k=5, index="flat")
    ti = eng.submit(Qm[:2], k=5, index="ivf", nprobe=4)
    ts = eng.submit(Qm[:2], k=5, index="sharded")
    eng.flush()
    assert jnp.array_equal(
        jnp.asarray(tf.result()[1]), indexes["flat"].search(Qm[:2], k=5)[1]
    )
    assert jnp.array_equal(
        jnp.asarray(ti.result()[1]),
        indexes["ivf"].search(Qm[:2], k=5, nprobe=4)[1],
    )
    assert jnp.array_equal(
        jnp.asarray(ts.result()[1]),
        indexes["sharded"].search(Qm[:2], k=5)[1],
    )
    with pytest.raises(KeyError, match="unknown index"):
        eng.submit(Qm[:1], k=5, index="nope")


def test_request_stats_populated(setup):
    X, Qm, indexes = setup
    eng = _engine({"flat": indexes["flat"]})
    t = eng.submit(Qm[:3], k=5, index="flat")
    eng.flush()
    st = t.stats
    assert st.queue_wait_s >= 0.0
    assert st.batch_rows == 3 and st.bucket_rows == 4
    assert st.scoring_us > 0.0
    assert st.flush_reason == "manual"


def test_oversized_request_rides_alone(setup):
    """A request larger than the largest bucket pads to a multiple of
    it (closed shape set) and still returns exact results."""
    X, Qm, indexes = setup
    idx = indexes["flat"]
    eng = _engine({"flat": idx}, batch_buckets=(8,))
    s, ids = eng.search(Qm[:20], k=5, index="flat")
    ds, di = idx.search(Qm[:20], k=5)
    assert jnp.array_equal(jnp.asarray(s), ds)
    assert jnp.array_equal(jnp.asarray(ids), di)
    assert t_bucket(eng) == 24
    assert eng.stats.padded_rows == 4


def t_bucket(eng):
    (entry,) = eng.stats.compiled_buckets
    return entry[2]


def test_sharded_rejects_rerank_at_submit(setup):
    X, Qm, indexes = setup
    eng = _engine(indexes)
    with pytest.raises(ValueError, match="rerank"):
        eng.submit(Qm[:1], k=5, index="sharded", rerank=10)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="ascending"):
        EngineConfig(batch_buckets=(32, 8))
    with pytest.raises(ValueError, match="non-empty"):
        EngineConfig(batch_buckets=())
