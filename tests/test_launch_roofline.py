"""Launch-layer units: sharding rules, roofline parsing, mesh builders,
cost algebra — everything the dry-run relies on, testable on 1 CPU."""
import jax
import jax.numpy as jnp
import numpy as onp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import registry
from repro.launch import roofline as RL
from repro.launch import sharding as SH
from repro.launch.analysis import CostVec
from repro.launch.mesh import dp_axes, make_test_mesh, mesh_size
from repro.launch.sharding import ShardingPolicy


class FakeMesh:
    """Duck-typed mesh: shape mapping only (rule logic needs no devices)."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = FakeMesh(data=16, model=16)
MESH2 = FakeMesh(pod=2, data=16, model=16)


def test_pick_divisibility():
    assert SH.pick(MESH1, 64, "model") == "model"
    assert SH.pick(MESH1, 40, "model", "pod") is None  # no pod axis
    assert SH.pick(MESH2, 40, "model", "pod") == "pod"
    assert SH.pick(MESH2, 1_000_000, ("pod", "data", "model"),
                   ("pod", "data")) == ("pod", "data")
    assert SH.pick(MESH1, 7, "data", "model") is None


def test_fit_spec():
    assert SH.fit_spec(P(None, "model", "pod", None), 3) == P(
        None, "model", "pod"
    )
    assert SH.fit_spec(P("data", "model"), 2) == P("data", "model")
    assert SH.fit_spec(P("data", "model"), 1) == P()  # can't drop used


@pytest.mark.parametrize("arch_id", sorted(registry.ARCHS))
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["1pod", "2pod"])
def test_param_rules_valid_for_all_archs(arch_id, mesh):
    """Every param leaf gets a spec that (a) fits its rank and (b) only
    assigns axes that divide the dim — for both production meshes."""
    arch = registry.get(arch_id)
    pol = ShardingPolicy(seq_parallel=True, **arch.policy_overrides)
    rules = arch.param_rules(mesh, pol)
    params = arch.abstract_params()

    def check(path, leaf):
        spec = SH.fit_spec(
            rules(SH._path_str(path), tuple(leaf.shape)), len(leaf.shape)
        )
        assert len(spec) <= len(leaf.shape)
        used = []
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            total = 1
            for a in axes:
                assert a in mesh.shape, (path, a)
                assert a not in used, f"axis {a} reused in {spec}"
                used.append(a)
                total *= mesh.shape[a]
            assert leaf.shape[i] % total == 0, (
                SH._path_str(path), leaf.shape, spec
            )

    jax.tree_util.tree_map_with_path(check, params)


def test_shape_bytes_and_collective_parser():
    hlo = """
  %ag = f32[128,256]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[64]{0} all-reduce-start(%y)
  %ar.2 = bf16[64]{0} all-reduce-done(%ar.1)
  %rs = f32[32,32]{1,0} reduce-scatter(%z)
  %dot = f32[8,8]{1,0} dot(%a, %b)
"""
    stats = RL.parse_collectives(hlo)
    assert stats.bytes_by_kind["all-gather"] == 128 * 256 * 4
    assert stats.bytes_by_kind["all-reduce"] == 64 * 2  # start counted once
    assert stats.bytes_by_kind["reduce-scatter"] == 32 * 32 * 4
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.async_pairs == 1  # the -start form


def test_ghost_detector():
    hlo = """
  %big = bf16[1000,100000]{1,0} add(%a, %b)
  %gh = f32[1000,100000]{1,0} convert(%big)
  %small = f32[10]{0} convert(%c)
"""
    g = RL.cpu_float_norm_ghost_bytes(hlo, min_bytes=2**20)
    assert g == 1000 * 100000 * 4


def test_roofline_terms_and_bottleneck():
    r = RL.Roofline(flops=197e12, hbm_bytes=819e9 * 2,
                    collective_bytes=50e9 * 0.5, n_chips=256,
                    model_flops=197e12 * 0.5)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.roofline_frac - 0.25) < 1e-9  # 0.5s useful / 2s bound
    assert abs(r.useful_flops_frac - 0.5) < 1e-9


def test_model_flops_conventions():
    arch = registry.get("llama3.2-3b")
    cell = arch.cells["train_4k"]
    mf = RL.model_flops_for(arch, cell)
    n = arch.cfg.param_count()
    assert abs(mf - 6.0 * n * 256 * 4096) / mf < 1e-9
    # MoE uses ACTIVE params
    kimi = registry.get("kimi-k2-1t-a32b")
    mf_k = RL.model_flops_for(kimi, kimi.cells["train_4k"])
    assert mf_k < 6.0 * kimi.cfg.param_count() * 256 * 4096 * 0.1


def test_costvec_algebra():
    a = CostVec(1.0, 2.0, 3.0)
    b = CostVec(0.5, 0.5, 0.5)
    c = 2 * (a - b) + b
    assert (c.flops, c.hbm_bytes, c.coll_bytes) == (1.5, 3.5, 5.5)


def test_mesh_builders():
    m = make_test_mesh()
    assert mesh_size(m) == jax.device_count()
    assert dp_axes(m) == ("data",)


def test_make_constrain_noop_off_policy():
    mesh = make_test_mesh(shape=(1, 1), axes=("data", "model"))
    pol = ShardingPolicy(pin_ffn_hidden=False, pin_attn_boundary=False)
    c = SH.make_constrain(mesh, pol)
    x = jnp.ones((4, 8, 16))
    assert c(x, "ffn_hidden") is x  # disabled pins return inputs as-is
    y = jnp.ones((4, 8, 2, 4))
    assert c(y, "attn_out") is y


def test_batch_rules_fallback_chain():
    rules = SH.batch_rules_leading_dp(MESH2, ShardingPolicy())
    # divisible by pod*data=32
    assert rules("x", (64, 5)) == P(("pod", "data"), None)
    # divisible only by pod
    assert rules("x", (2, 5)) == P(("pod",), None)
    # prime: replicated
    assert rules("x", (7, 5)) == P(None, None)
