"""Baseline quantizers + the paper's comparative claims (Figs. 5-8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import eden, leanvec, lopq, pq, rabitq
from repro.core import ASHConfig, train, encode, prepare_queries, score_dot
from repro.data.synthetic import embedding_dataset


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(21)
    kx, kq = jax.random.split(key)
    X = embedding_dataset(kx, 3000, 64)
    Qm = embedding_dataset(kq, 12, 64)
    return X, Qm, Qm @ X.T


def _corr(est, true):
    return float(jnp.corrcoef(est.ravel(), true.ravel())[0, 1])


def test_pq_adc(data):
    X, Qm, true = data
    st = pq.train(jax.random.PRNGKey(0), X, M=8, b=4)
    est = pq.score(st, pq.encode(st, X), Qm)
    assert _corr(est, true) > 0.92
    # decode consistency: ADC == <q, decode(codes)>
    codes = pq.encode(st, X[:50])
    est2 = Qm @ pq.decode(st, codes).T
    np.testing.assert_allclose(
        np.asarray(pq.score(st, codes, Qm)), np.asarray(est2),
        rtol=1e-3, atol=1e-3,
    )


def test_opq_beats_pq(data):
    X, Qm, true = data
    st0 = pq.train(jax.random.PRNGKey(0), X, M=8, b=4)
    st1 = pq.train(jax.random.PRNGKey(0), X, M=8, b=4, opq_iters=3)
    e0 = _corr(pq.score(st0, pq.encode(st0, X), Qm), true)
    e1 = _corr(pq.score(st1, pq.encode(st1, X), Qm), true)
    assert e1 >= e0 - 0.005


def test_lopq(data):
    X, Qm, true = data
    st = lopq.train(jax.random.PRNGKey(0), X, M=8, b=4, C=4,
                    local_iters=2)
    est = lopq.score(st, lopq.encode(st, X), Qm)
    assert _corr(est, true) > 0.96


@pytest.mark.parametrize("variant", ["eden", "turboquant"])
def test_eden_tq(data, variant):
    X, Qm, true = data
    st = eden.train(jax.random.PRNGKey(0), X, b=2, variant=variant)
    est = eden.score(st, eden.encode(st, X), Qm)
    assert _corr(est, true) > 0.9


def test_eden_decode_norm_preserved(data):
    X, _, _ = data
    st = eden.train(jax.random.PRNGKey(0), X, b=2, variant="eden")
    recon = eden.decode(st, eden.encode(st, X[:100]))
    # EDEN's s = ||x||/||recon_unscaled|| preserves norms
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(recon, axis=1)),
        np.asarray(jnp.linalg.norm(X[:100], axis=1)),
        rtol=1e-3,
    )


def test_leanvec(data):
    X, Qm, true = data
    st = leanvec.train(jax.random.PRNGKey(0), X, d=32, b=4)
    est = leanvec.score(st, leanvec.encode(st, X), Qm)
    assert _corr(est, true) > 0.95


def test_lloyd_max_grid_is_sorted_and_symmetric():
    for b in (1, 2, 3, 4):
        g = eden.lloyd_max_grid_np(b)
        assert len(g) == 2**b
        assert np.all(np.diff(g) > 0)
        np.testing.assert_allclose(g, -g[::-1], atol=2e-2)


def test_ash_beats_baselines_at_iso_bits(data):
    """The paper's headline: ASH > PQ and > EDEN/TQ at iso-compression.

    Budget ~ 128 code bits/vector on 64-dim anisotropic data.
    """
    X, Qm, true = data
    # ASH: b=2, d=64 -> 128 bits
    model, _ = train(jax.random.PRNGKey(1), X,
                     ASHConfig(b=2, d=64, n_landmarks=8))
    prep = prepare_queries(model, Qm)
    ash_corr = _corr(score_dot(model, prep, encode(model, X)), true)
    # PQ: M=16 segments x 8 bits = 128 bits
    st = pq.train(jax.random.PRNGKey(1), X, M=16, b=8, kmeans_iters=15)
    pq_corr = _corr(pq.score(st, pq.encode(st, X), Qm), true)
    # EDEN: b=2 x 64 dims = 128 bits
    se = eden.train(jax.random.PRNGKey(1), X, b=2)
    eden_corr = _corr(eden.score(se, eden.encode(se, X), Qm), true)
    assert ash_corr > eden_corr, (ash_corr, eden_corr)
    assert ash_corr > 0.98
    # PQ with 256-centroid codebooks is strong; ASH must be comparable+
    assert ash_corr > pq_corr - 0.005, (ash_corr, pq_corr)


def test_rabitq_is_ash_special_case(data):
    """RaBitQ == data-agnostic ASH with d=D, C=1, b=1."""
    X, Qm, true = data
    model = rabitq.train(jax.random.PRNGKey(2), X, b=1)
    assert model.config.b == 1
    assert model.d == model.D
    assert model.landmarks.shape[0] == 1
    est = rabitq.score(model, rabitq.encode(model, X), Qm)
    assert _corr(est, true) > 0.75  # centered 1-bit on 64 dims
