"""Training substrate: optimizers, microbatching, checkpoint/FT,
gradient compression."""
import functools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.data.synthetic import IteratorState, TokenStream
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.train import optim as O
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (
    CompressionConfig, compress_decompress, _hadamard,
)
from repro.train.trainer import TrainConfig, init_state, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=128, dtype=jnp.float32, param_dtype=jnp.float32,
        remat=False, q_chunk=0,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("opt,lr", [("adamw", 1e-3), ("adafactor", 1e-2),
                                    ("muon", 2e-3)])
def test_optimizers_decrease_loss(tiny, opt, lr):
    cfg, params = tiny
    tcfg = TrainConfig(opt=O.OptConfig(name=opt, lr=lr, warmup_steps=2,
                                       total_steps=200))
    state = init_state(jax.random.PRNGKey(0), params, tcfg)
    step = jax.jit(make_train_step(
        functools.partial(loss_fn, cfg=cfg), tcfg
    ))
    stream = TokenStream(IteratorState(seed=5), 8, 16, 128)
    losses = []
    for _ in range(30):
        state, m = step(state, stream.next())
        losses.append(float(m["loss"]))
    first, last = sum(losses[:5]) / 5, sum(losses[-5:]) / 5
    assert last < first, (opt, first, last)


def test_adafactor_momentum_free_state(tiny):
    cfg, params = tiny
    tcfg = TrainConfig(opt=O.OptConfig(name="adafactor", b1=0.0))
    state = init_state(jax.random.PRNGKey(0), params, tcfg)
    # b1=0: mu buffers are dummy (1,)-shaped — the 1T memory saving
    for leaf in jax.tree_util.tree_leaves(state.opt_state.mu):
        assert leaf.shape == (1,)


def test_microbatch_grad_equivalence(tiny):
    """k=1 vs k=4 gradient accumulation: same update (fp32, lr=0 wd=0)."""
    cfg, params = tiny
    stream = TokenStream(IteratorState(seed=9), 8, 16, 128)
    batch = stream.next()

    def grads_with(k):
        tcfg = TrainConfig(opt=O.OptConfig(lr=1e-3), microbatches=k)
        state = init_state(jax.random.PRNGKey(0), params, tcfg)
        step = jax.jit(make_train_step(
            functools.partial(loss_fn, cfg=cfg), tcfg
        ))
        new_state, m = step(state, batch)
        return new_state.params, float(m["loss"])

    p1, l1 = grads_with(1)
    p4, l4 = grads_with(4)
    assert abs(l1 - l4) < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_checkpoint_restart_bitwise(tiny, tmp_path):
    cfg, params = tiny
    tcfg = TrainConfig(opt=O.OptConfig(lr=1e-3))
    state = init_state(jax.random.PRNGKey(0), params, tcfg)
    step = jax.jit(make_train_step(
        functools.partial(loss_fn, cfg=cfg), tcfg
    ))
    stream = TokenStream(IteratorState(seed=3), 8, 16, 128)
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for _ in range(3):
        state, _ = step(state, stream.next())
    mgr.save(3, state, extra=stream.state.to_dict())

    cont = []
    s2 = state
    for _ in range(3):
        s2, m = step(s2, stream.next())
        cont.append(float(m["loss"]))

    restored, extra = mgr.restore(state)
    stream2 = TokenStream(IteratorState.from_dict(extra), 8, 16, 128)
    replay = []
    for _ in range(3):
        restored, m = step(restored, stream2.next())
        replay.append(float(m["loss"]))
    assert cont == replay  # bitwise-deterministic restart


def test_checkpoint_atomic_commit_and_gc(tiny, tmp_path):
    cfg, params = tiny
    tcfg = TrainConfig()
    state = init_state(jax.random.PRNGKey(0), params, tcfg)
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]  # GC kept last 2
    # a dir without COMMIT marker is invisible
    import os, shutil

    src = tmp_path / "step_0000000004"
    dst = tmp_path / "step_0000000009"
    shutil.copytree(src, dst)
    os.remove(dst / "COMMIT")
    assert mgr.latest_step() == 4


def test_checkpoint_bfloat16_roundtrip(tmp_path):
    tree = {"a": jnp.arange(7, dtype=jnp.bfloat16) / 3,
            "b": {"c": jnp.float32(2.5)}}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, tree)
    restored, _ = mgr.restore(tree)
    assert restored["a"].dtype == jnp.bfloat16
    assert jnp.array_equal(restored["a"], tree["a"])


def test_failure_restart_via_launcher(tmp_path):
    """Kill the training loop mid-run, restart, verify resume."""
    from repro.launch import train as TL

    args = ["--arch", "llama3.2-3b", "--reduced", "--steps", "8",
            "--batch", "4", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
            "--log-every", "1"]
    with pytest.raises(SystemExit) as ei:
        TL.main(args + ["--die-at-step", "5"])
    assert ei.value.code == 42  # simulated node failure
    assert TL.main(args) == 0  # restart resumes from step 4 and finishes


def test_hadamard_orthogonal():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
    y = _hadamard(x)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5,
    )
    # involution: H(H(x)) = x
    np.testing.assert_allclose(
        np.asarray(_hadamard(y)), np.asarray(x), atol=1e-4
    )


@pytest.mark.parametrize("bits,max_rel", [(1, 0.75), (2, 0.45), (4, 0.15)])
def test_compression_error_bounds(bits, max_rel):
    g = jax.random.normal(jax.random.PRNGKey(1), (8192,))
    ghat = compress_decompress(
        jax.random.PRNGKey(77), g, CompressionConfig(bits=bits, enabled=True)
    )
    rel = float(jnp.linalg.norm(ghat - g) / jnp.linalg.norm(g))
    assert rel < max_rel, rel


def test_compression_with_error_feedback_converges(tiny):
    """EF: repeated compression of a CONSTANT gradient converges to it."""
    from repro.train.compression import EFState, compress_tree, ef_init

    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (2048,))}
    cfg = CompressionConfig(bits=1, enabled=True, error_feedback=True)
    ef = ef_init(g)
    acc = jnp.zeros_like(g["w"])
    n = 30
    for i in range(n):
        out, ef = compress_tree(jax.random.PRNGKey(i), g, ef, cfg)
        acc = acc + out["w"]
    mean = acc / n
    rel = float(jnp.linalg.norm(mean - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.15, rel  # EF kills the bias


def test_lr_schedule_shape():
    cfg = O.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(O.lr_at(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]  # cosine decay
    assert lrs[4] >= 0.1 * 0.9  # floor


def test_grad_clip():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, gn = O.clip_by_global_norm(tree, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(gn) > 100.0
