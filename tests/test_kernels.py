"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the brief: sweep shapes/dtypes per kernel and assert_allclose
against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as Q
from repro.kernels import ref
from repro.kernels.ash_score import ash_score_pallas
from repro.kernels.ash_kv_attn import ash_kv_attn_pallas
from repro.kernels import ops


def _mk_score_inputs(key, b, d, n, m, C):
    ks = jax.random.split(key, 6)
    vals = Q.quant(jax.random.normal(ks[0], (n, d)), b)
    codes = Q.pack_codes(vals, b)
    d_pad = codes.shape[1] * Q.codes_per_word(b)
    q = jnp.pad(jax.random.normal(ks[1], (m, d)), ((0, 0), (0, d_pad - d)))
    scale = jax.random.uniform(ks[2], (n,), minval=0.5, maxval=2.0)
    offset = jax.random.normal(ks[3], (n,))
    cluster = jax.random.randint(ks[4], (n,), 0, C)
    ipq = jax.random.normal(ks[5], (m, C))
    return codes, q, scale, offset, cluster, ipq


SCORE_CASES = [
    (1, 256, 700, 5, 1),
    (1, 64, 100, 1, 4),
    (2, 384, 1000, 33, 64),
    (2, 128, 257, 2, 256),
    (4, 128, 513, 3, 8),
    (4, 512, 1024, 8, 1),
    (8, 96, 300, 17, 2),
]


@pytest.mark.parametrize("b,d,n,m,C", SCORE_CASES)
def test_ash_score_kernel_vs_ref(b, d, n, m, C):
    key = jax.random.PRNGKey(b * 1000 + d)
    args = _mk_score_inputs(key, b, d, n, m, C)
    want = ref.ash_score_ref(*args, b=b)
    got = ash_score_pallas(
        *args, b=b, interpret=True, compute_dtype=jnp.float32
    )
    # atol covers blocked-vs-whole-axis reduction-order drift; the
    # multi-device CPU test env shifts XLA's matmul blocking slightly,
    # so the d=512 case needs a little extra absolute headroom
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=5e-4
    )


@pytest.mark.parametrize("block_m,block_n,block_d", [
    (8, 128, 128), (128, 512, 256), (32, 256, 512),
])
def test_ash_score_block_shape_sweep(block_m, block_n, block_d):
    b, d, n, m, C = 2, 320, 777, 13, 16
    key = jax.random.PRNGKey(99)
    args = _mk_score_inputs(key, b, d, n, m, C)
    want = ref.ash_score_ref(*args, b=b)
    got = ash_score_pallas(
        *args, b=b, interpret=True, compute_dtype=jnp.float32,
        block_m=block_m, block_n=block_n, block_d=block_d,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4
    )


def test_ash_score_bf16_compute_close():
    b, d, n, m, C = 2, 256, 512, 9, 8
    args = _mk_score_inputs(jax.random.PRNGKey(3), b, d, n, m, C)
    want = ref.ash_score_ref(*args, b=b)
    got = ash_score_pallas(
        *args, b=b, interpret=True, compute_dtype=jnp.bfloat16
    )
    # bf16 MXU operands (f32 accumulation): error scales with the dot's
    # magnitude (~||q|| ||v|| 2^-8), not with the final score, so judge
    # against the score DISTRIBUTION, not per-element relative error.
    g, w = np.asarray(got), np.asarray(want)
    err = np.abs(g - w)
    assert err.max() < 0.05 * w.std() + 0.5, err.max()
    corr = np.corrcoef(g.ravel(), w.ravel())[0, 1]
    assert corr > 0.9999, corr


KV_CASES = [
    (1, 1, 128, 128, 300),
    (2, 2, 128, 128, 1000),
    (1, 4, 256, 64, 513),
    (4, 1, 64, 256, 1024),
    (4, 4, 96, 96, 77),
]


@pytest.mark.parametrize("bk,bv,dk,dv,S", KV_CASES)
def test_ash_kv_attn_kernel_vs_ref(bk, bv, dk, dv, S):
    key = jax.random.PRNGKey(bk * 100 + bv)
    ks = jax.random.split(key, 8)
    kvals = Q.quant(jax.random.normal(ks[0], (S, dk)), bk)
    vvals = Q.quant(jax.random.normal(ks[1], (S, dv)), bv)
    k_codes, v_codes = Q.pack_codes(kvals, bk), Q.pack_codes(vvals, bv)
    qk = jax.random.normal(ks[2], (dk,)) * 0.1
    k_scale = jax.random.uniform(ks[3], (S,), minval=0.5, maxval=1.5) * 0.05
    k_bias = jax.random.normal(ks[4], (S,)) * 0.1
    v_scale = jax.random.uniform(ks[5], (S,), minval=0.5, maxval=1.5)
    mask = jnp.arange(S) < (S - 3)
    want, _ = ref.ash_kv_attn_ref(
        qk, k_codes, k_scale, k_bias, v_codes, v_scale, bk, bv, mask=mask
    )
    got = ash_kv_attn_pallas(
        qk, k_codes, k_scale, k_bias, v_codes, v_scale, mask,
        b_k=bk, b_v=bv, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_ops_batched_kv_attention():
    H, S, dk, dv, b = 3, 200, 128, 128, 2
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    kvals = Q.quant(jax.random.normal(ks[0], (H, S, dk)), b)
    vvals = Q.quant(jax.random.normal(ks[1], (H, S, dv)), b)
    kc, vc = Q.pack_codes(kvals, b), Q.pack_codes(vvals, b)
    qk = jax.random.normal(ks[2], (H, dk)) * 0.1
    kscale = jnp.full((H, S), 0.05)
    kbias = jnp.zeros((H, S))
    vscale = jnp.ones((H, S))
    mask = jnp.ones((H, S), bool)
    got = ops.ash_kv_attention(
        qk, kc, kscale, kbias, vc, vscale, mask, b_k=b, b_v=b,
        interpret=True,
    )
    want = ops.ash_kv_attention(
        qk, kc, kscale, kbias, vc, vscale, mask, b_k=b, b_v=b,
        use_pallas=False,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_ops_ash_score_end_to_end():
    """Kernel wrapper == scoring.score_dot on a real encoded payload."""
    from repro.core import ASHConfig, train, encode, prepare_queries
    from repro.core import scoring as S
    from repro.data.synthetic import embedding_dataset

    key = jax.random.PRNGKey(0)
    X = embedding_dataset(key, 2000, 64)
    Qm = embedding_dataset(jax.random.PRNGKey(1), 8, 64)
    model, _ = train(key, X, ASHConfig(b=2, d=32, n_landmarks=8,
                                       store_fp16=False))
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    want = S.score_dot(model, prep, pay)
    got = ops.ash_score(model, prep, pay, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-3
    )
