"""Host-memory tiered IVF backend: bit-identity to the HBM-resident
backend, paging under byte budgets, persistence, and the serving
engine's tier gauges / paging cost bill.

The contract under test is exact: at equal probe sets the tiered
backend returns bitwise-identical (scores, ids) to ``backend="ivf"``
for EVERY option combination and EVERY hot-set budget — including a
zero-byte budget (every probe pages) and a covering one (everything
resident after the first touch).  The budget may change what moves
over PCIe, never what comes back.
"""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.index.tiered import TieredIVFBackend
from repro.serving.engine import EngineConfig, QueryEngine

METRICS = ("dot", "l2", "cos")
# zero = page every probe; small = constant eviction; huge = covering
BUDGETS = (0, 1 << 14, 1 << 30)
CHUNK = 16
N0 = 400
POOL = 1200


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(17)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, POOL, 24)
    Qm = embedding_dataset(kq, 6, 24)
    cfg = ASHConfig(b=2, d=12, n_landmarks=8)
    model = AshIndex.build(kb, X[:N0], cfg, backend="flat").model
    return np.asarray(X), Qm, cfg, model, kb


def _build(setup, backend, metric, X_rows, **opts):
    X, Qm, cfg, model, kb = setup
    import jax.numpy as jnp

    return AshIndex.build(
        kb, jnp.asarray(X_rows), cfg, backend=backend, metric=metric,
        model=model, keep_raw=True, **opts,
    )


def _assert_same(a, b, msg=None):
    np.testing.assert_array_equal(
        np.asarray(a[0]), np.asarray(b[0]), err_msg=msg
    )
    np.testing.assert_array_equal(
        np.asarray(a[1]), np.asarray(b[1]), err_msg=msg
    )


SEARCH_KW = (
    {"nprobe": 3},
    {"nprobe": 3, "rerank": 20},
    {"nprobe": 4, "coarse": "int8", "shortlist": 64},
    {"nprobe": 8},  # nprobe == nlist: the dense full-scan route
    {"nprobe": 99},  # over-asking clamps identically
)


@pytest.mark.parametrize("metric", METRICS)
def test_search_matches_ivf_bitwise(setup, metric):
    """Every search option x every budget, batched and single-query."""
    X, Qm, cfg, model, kb = setup
    hbm = _build(setup, "ivf", metric, X[:N0])
    for hot in BUDGETS:
        tv = _build(setup, "tiered_ivf", metric, X[:N0],
                    hot_bytes=hot)
        for kw in SEARCH_KW:
            _assert_same(
                tv.search(Qm, k=10, **kw), hbm.search(Qm, k=10, **kw),
                msg=f"hot={hot} kw={kw}",
            )
            _assert_same(  # m=1 pads through its own route
                tv.search(Qm[:1], k=5, **kw),
                hbm.search(Qm[:1], k=5, **kw),
                msg=f"m=1 hot={hot} kw={kw}",
            )


def test_zero_budget_pages_every_probe(setup):
    """hot_bytes=0 serves correctly while caching nothing: paging,
    not OOM, and the gauges show it."""
    X, Qm, cfg, model, kb = setup
    tv = _build(setup, "tiered_ivf", "l2", X[:N0], hot_bytes=0)
    hbm = _build(setup, "ivf", "l2", X[:N0])
    for _ in range(3):
        _assert_same(tv.search(Qm, k=10, nprobe=3),
                     hbm.search(Qm, k=10, nprobe=3))
    ts = TieredIVFBackend.tier_stats(tv._state)
    assert ts["hits"] == 0
    assert ts["resident_lists"] == 0
    assert ts["resident_bytes"] == 0
    assert ts["misses"] == ts["evictions"] > 0
    assert ts["paged_rows"] > 0 and ts["transfers"] > 0


def test_covering_budget_stops_paging(setup):
    """A covering budget pages each list once, then serves from the
    device-resident hot set."""
    X, Qm, cfg, model, kb = setup
    tv = _build(setup, "tiered_ivf", "l2", X[:N0], hot_bytes=1 << 30)
    tv.search(Qm, k=10, nprobe=8)  # full scan touches every list
    before = TieredIVFBackend.tier_stats(tv._state)
    assert before["resident_lists"] == before["nlist"]
    for _ in range(3):
        tv.search(Qm, k=10, nprobe=3)
    after = TieredIVFBackend.tier_stats(tv._state)
    assert after["paged_rows"] == before["paged_rows"]
    assert after["transfers"] == before["transfers"]
    assert after["hits"] > before["hits"]
    assert after["evictions"] == 0


def test_search_probed_matches_ivf(setup):
    """Explicit probe sets (the budgeted-gather entry point) agree,
    including the m=1 pad-probe route."""
    X, Qm, cfg, model, kb = setup
    from repro.core import scoring as S

    hbm = _build(setup, "ivf", "dot", X[:N0])
    tv = _build(setup, "tiered_ivf", "dot", X[:N0], hot_bytes=1 << 14)
    prep = S.prepare_queries(hbm.model, Qm)
    probe = TieredIVFBackend.probe_sets(tv._state, prep, nprobe=3)
    np.testing.assert_array_equal(
        probe, hbm._backend.probe_sets(hbm._state, prep, nprobe=3)
    )
    _assert_same(
        TieredIVFBackend.search_probed(tv._state, prep, probe, k=10),
        hbm._backend.search_probed(hbm._state, prep, probe, k=10),
    )
    prep1 = S.prepare_queries(hbm.model, Qm[:1])
    _assert_same(
        TieredIVFBackend.search_probed(
            tv._state, prep1, probe[:1], k=5),
        hbm._backend.search_probed(hbm._state, prep1, probe[:1], k=5),
    )


def test_save_load_roundtrip(setup, tmp_path):
    X, Qm, cfg, model, kb = setup
    tv = _build(setup, "tiered_ivf", "cos", X[:N0], hot_bytes=1 << 14)
    tv.add(X[N0:N0 + CHUNK])
    tv.delete(np.arange(10))
    tv.save(tmp_path / "t")
    back = AshIndex.load(tmp_path / "t")
    assert back.backend == "tiered_ivf"
    assert back._state.hot_bytes == 1 << 14
    _assert_same(back.search(Qm, k=10, nprobe=3, rerank=15),
                 tv.search(Qm, k=10, nprobe=3, rerank=15))
    # the budget is a load-time override, not baked into the arrays
    resized = AshIndex.load(tmp_path / "t", hot_bytes=0)
    assert resized._state.hot_bytes == 0
    _assert_same(resized.search(Qm, k=10, nprobe=3, rerank=15),
                 tv.search(Qm, k=10, nprobe=3, rerank=15))


def test_list_sizes_match_ivf(setup):
    """The engine's cost-model input agrees with the HBM backend's,
    before and after tombstones."""
    X, Qm, cfg, model, kb = setup
    hbm = _build(setup, "ivf", "dot", X[:N0])
    tv = _build(setup, "tiered_ivf", "dot", X[:N0])
    from repro.index.api import IVFBackend

    np.testing.assert_array_equal(
        TieredIVFBackend.list_sizes(tv._state),
        IVFBackend.list_sizes(hbm._state),
    )
    hbm.delete(np.arange(30))
    tv.delete(np.arange(30))
    np.testing.assert_array_equal(
        TieredIVFBackend.list_sizes(tv._state),
        IVFBackend.list_sizes(hbm._state),
    )


# -- satellite: property test under interleaved mutation traffic ------


@given(
    metric=st.sampled_from(METRICS),
    hot_bytes=st.sampled_from(BUDGETS),
    nprobe=st.sampled_from((2, 8)),
    rerank=st.sampled_from((0, 30)),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_tiered_tracks_ivf_under_mutations(
    setup, metric, hot_bytes, nprobe, rerank, seed
):
    """Random interleaved add/delete/compact scripts applied to a
    tiered index and an HBM IVF twin stay bitwise in lockstep at every
    probe depth and budget — including compaction mid-script, which
    re-sorts rows between lists and drops the whole hot set."""
    X, Qm, cfg, model, kb = setup
    rng = np.random.RandomState(seed)
    tv = _build(setup, "tiered_ivf", metric, X[:N0],
                hot_bytes=hot_bytes)
    hbm = _build(setup, "ivf", metric, X[:N0])
    kw = {"nprobe": nprobe, "rerank": rerank}
    live_ids = list(range(N0))
    next_id = N0

    for _ in range(rng.randint(2, 5)):
        op = rng.rand()
        if op < 0.35:
            rows = X[rng.randint(0, POOL, CHUNK)]
            tv.add(rows)
            hbm.add(rows)
            live_ids.extend(range(next_id, next_id + CHUNK))
            next_id += CHUNK
        elif op < 0.65 and len(live_ids) > CHUNK + 8:
            victims = rng.choice(live_ids, size=CHUNK, replace=False)
            assert tv.delete(victims) == hbm.delete(victims) == CHUNK
            live_ids = [i for i in live_ids if i not in set(victims)]
        elif op < 0.8:
            tv.compact()
            hbm.compact()
        else:
            _assert_same(tv.search(Qm, k=10, **kw),
                         hbm.search(Qm, k=10, **kw))

    assert tv.n_live == hbm.n_live == len(live_ids)
    _assert_same(tv.search(Qm, k=10, **kw), hbm.search(Qm, k=10, **kw))
    _assert_same(tv.search(Qm, k=10, nprobe=8), hbm.search(Qm, k=10, nprobe=8))


# -- serving engine integration ---------------------------------------


def test_engine_serves_tiered_bitwise_with_gauges(setup):
    X, Qm, cfg, model, kb = setup
    tv = _build(setup, "tiered_ivf", "l2", X[:N0], hot_bytes=1 << 14)
    hbm = _build(setup, "ivf", "l2", X[:N0])
    s_d, i_d = hbm.search(Qm, k=10, nprobe=3)
    eng = QueryEngine(tv)
    tix = [eng.submit(np.asarray(Qm)[i:i + 1], k=10, nprobe=3)
           for i in range(Qm.shape[0])]
    eng.flush()
    for i, t in enumerate(tix):
        s, ids = t.result(timeout=60)
        np.testing.assert_array_equal(ids[0], np.asarray(i_d[i]))
        np.testing.assert_array_equal(s[0], np.asarray(s_d[i]))
    snap = eng.stats.snapshot()
    ts = snap["tier"]["default"]
    for key in ("hits", "misses", "hit_rate", "evictions",
                "resident_lists", "resident_bytes", "hot_bytes",
                "total_bytes", "paged_rows", "paged_bytes",
                "transfers"):
        assert key in ts
    assert ts["hits"] + ts["misses"] > 0
    assert ts["total_bytes"] > ts["hot_bytes"]


def test_engine_mutations_keep_tier_counters(setup):
    """Mutation re-hosts must not reset the lifetime tier gauges."""
    X, Qm, cfg, model, kb = setup
    tv = _build(setup, "tiered_ivf", "dot", X[:N0], hot_bytes=1 << 14)
    eng = QueryEngine(tv)
    t = eng.submit(np.asarray(Qm), k=10, nprobe=3)
    eng.flush()
    t.result(timeout=60)
    before = eng.stats.snapshot()["tier"]["default"]
    tk = eng.submit_add(X[:CHUNK])
    eng.flush()
    tk.result(timeout=60)
    after = eng.stats.snapshot()["tier"]["default"]
    assert after["misses"] >= before["misses"]
    assert after["paged_rows"] >= before["paged_rows"]


def test_engine_bills_cold_lists_at_page_cost(setup):
    """_billed_list_sizes surcharges non-resident lists so the row
    budget and adaptive nprobe see paging cost."""
    X, Qm, cfg, model, kb = setup
    tv = _build(setup, "tiered_ivf", "dot", X[:N0], hot_bytes=1 << 30)
    eng = QueryEngine(tv, row_budget=100_000, page_row_cost=2.0)
    live = eng._live_list_sizes("default", eng._indexes["default"])
    # nothing resident yet: everything bills at the surcharge
    billed = eng._billed_list_sizes("default", eng._indexes["default"])
    np.testing.assert_array_equal(
        billed, np.ceil(live * 2.0).astype(np.int64)
    )
    tv.search(Qm, k=10, nprobe=8)  # covering budget: all lists warm
    billed = eng._billed_list_sizes("default", eng._indexes["default"])
    np.testing.assert_array_equal(billed, live)
    # non-tiered indexes never pay the surcharge
    hbm = _build(setup, "ivf", "dot", X[:N0])
    eng.register("h", hbm)
    np.testing.assert_array_equal(
        eng._billed_list_sizes("h", eng._indexes["h"]),
        eng._live_list_sizes("h", eng._indexes["h"]),
    )


def test_engine_config_rejects_bad_page_cost():
    with pytest.raises(ValueError, match="page_row_cost"):
        EngineConfig(page_row_cost=0.5)
