"""Use hypothesis when installed; otherwise fall back to a tiny
deterministic sampler so the suite runs in minimal environments.

The fallback implements just the strategy surface this suite uses
(``sampled_from``, ``integers``, ``floats``) and a ``given`` decorator
that replays a fixed number of seeded examples.  Property coverage is
thinner than real hypothesis but the tests stay executable and
deterministic.
"""
from __future__ import annotations

try:  # pragma: no cover - prefer the real library when available
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal stand-in
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _N_EXAMPLES = 25

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    class strategies:  # noqa: N801 - mirrors `hypothesis.strategies`
        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value)
            )

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kw):
                rng = random.Random(0xA54)
                for _ in range(_N_EXAMPLES):
                    drawn = {k: s.sample(rng) for k, s in strats.items()}
                    fn(*args, **kw, **drawn)

            # Hide the strategy-drawn params from pytest's fixture
            # resolution (real hypothesis does the same); remaining
            # params (e.g. pytest fixtures) stay visible.
            sig = inspect.signature(fn)
            run.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in strats
                ]
            )
            return run

        return deco

    class HealthCheck:
        too_slow = "too_slow"
        data_too_large = "data_too_large"

    class _Settings:
        """No-op `settings` shim (profiles only matter to hypothesis)."""

        def __init__(self, *a, **kw):
            pass

        @staticmethod
        def register_profile(name, *a, **kw):
            pass

        @staticmethod
        def load_profile(name):
            pass

    settings = _Settings

st = strategies

__all__ = [
    "HAVE_HYPOTHESIS", "HealthCheck", "given", "settings",
    "strategies", "st",
]
