"""quant_b correctness: exact sweep vs brute force, packing, properties."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import quantization as Q


def _brute_force(u_row: np.ndarray, b: int) -> np.ndarray:
    grid = np.array([2 * c - (2**b - 1) for c in range(2**b)], np.float64)
    combos = np.array(
        list(itertools.product(grid, repeat=len(u_row))), np.float64
    )
    cos = (combos @ u_row) / np.linalg.norm(combos, axis=1)
    return combos[np.argmax(cos)]


@pytest.mark.parametrize("b", [1, 2, 3])
def test_exact_matches_brute_force(b):
    key = jax.random.PRNGKey(b)
    u = jax.random.normal(key, (12, 5))
    got = np.asarray(Q.quant_exact(u, b), np.float64)
    un = np.asarray(u, np.float64)
    for i in range(u.shape[0]):
        best = _brute_force(un[i], b)
        cos_got = got[i] @ un[i] / np.linalg.norm(got[i])
        cos_best = best @ un[i] / np.linalg.norm(best)
        assert cos_got >= cos_best - 1e-9


@pytest.mark.parametrize("b", [2, 4])
def test_exact_at_least_as_good_as_grid(b):
    key = jax.random.PRNGKey(b)
    u = jax.random.normal(key, (64, 48))
    ve = np.asarray(Q.quant_exact(u, b), np.float64)
    vg = np.asarray(Q.quant_grid(u, b, n_scales=256), np.float64)
    un = np.asarray(u, np.float64)
    ce = np.einsum("nd,nd->n", ve, un) / np.linalg.norm(ve, axis=1)
    cg = np.einsum("nd,nd->n", vg, un) / np.linalg.norm(vg, axis=1)
    # fp32 cumsums in the sweep can mis-rank near-ties by ~1e-5
    assert np.all(ce >= cg - 5e-5)
    # and the 256-scale grid search is within a few % of optimal
    assert np.max((ce - cg) / np.abs(ce)) < 0.05


@pytest.mark.parametrize("b", [1, 2, 4, 8])
@pytest.mark.parametrize("d", [1, 7, 32, 37, 128])
def test_pack_unpack_roundtrip(b, d):
    key = jax.random.PRNGKey(d * 10 + b)
    v = Q.quant(jax.random.normal(key, (9, d)), b)
    w = Q.pack_codes(v, b)
    assert w.dtype == jnp.uint32
    assert w.shape == (9, Q.packed_width(d, b))
    v2 = Q.unpack_codes(w, d, b)
    assert jnp.array_equal(v, v2)


@given(
    b=st.sampled_from([1, 2, 4]),
    d=st.integers(2, 24),
    seed=st.integers(0, 2**30),
)
def test_quant_output_on_grid(b, d, seed):
    u = jax.random.normal(jax.random.PRNGKey(seed), (3, d))
    v = np.asarray(Q.quant(u, b))
    assert v.min() >= -(2**b - 1) and v.max() <= 2**b - 1
    assert np.all(v % 2 != 0)  # odd-integer grid
    # sign agreement wherever u != 0
    un = np.asarray(u)
    nz = np.abs(un) > 1e-6
    assert np.all(np.sign(v[nz]) == np.sign(un[nz]))


@given(
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**30),
)
def test_quant_scale_invariance(scale, seed):
    u = jax.random.normal(jax.random.PRNGKey(seed), (4, 16))
    v1 = Q.quant(u, 2)
    v2 = Q.quant(u * scale, 2)
    assert jnp.array_equal(v1, v2)


def test_quant_b1_is_sign():
    u = jnp.array([[0.5, -0.1, 0.0, -3.0]])
    v = Q.quant(u, 1)
    assert jnp.array_equal(v, jnp.array([[1, -1, 1, -1]]))


def test_levels_values_involution():
    for b in (1, 2, 4, 8):
        vals = Q.grid_values(b)
        lv = Q.values_to_levels(vals, b)
        assert jnp.array_equal(Q.levels_to_values(lv, b), vals)
