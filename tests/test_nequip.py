"""NequIP: exactness of the Gaunt couplings + E(3) symmetry properties."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.spatial.transform as sst
from _hypothesis_compat import given, st

from repro.models import nequip as NQ


def _random_graph(key, N=10, E=30, species=4):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pos = jax.random.normal(k1, (N, 3)) * 2.0
    src = jax.random.randint(k2, (E,), 0, N)
    dst = (src + 1 + jax.random.randint(k3, (E,), 0, N - 1)) % N
    sp = jax.random.randint(k4, (N,), 0, species)
    return {"positions": pos, "species": sp,
            "edge_src": src, "edge_dst": dst}


@pytest.fixture(scope="module")
def model():
    cfg = NQ.NequIPConfig(n_layers=2, channels=8, n_species=4)
    params = NQ.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_gaunt_known_values():
    # (1,1,0): Y1m Y1m' integrates to delta_mm' / sqrt(4pi) * Y00 coupling
    C = NQ.gaunt_tensor(1, 1, 0)[:, :, 0]
    np.testing.assert_allclose(
        C, np.eye(3) * 0.5 / math.sqrt(math.pi), atol=1e-6
    )
    # (0,l,l): coupling with the scalar is identity x Y00
    for l in (1, 2):
        C = NQ.gaunt_tensor(0, l, l)[0]
        np.testing.assert_allclose(
            C, np.eye(2 * l + 1) * 0.5 / math.sqrt(math.pi), atol=1e-6
        )
    # selection rule: odd total parity vanishes
    assert np.abs(NQ.gaunt_tensor(1, 1, 1)).max() < 1e-10


def test_sph_harm_orthonormal():
    """Quadrature check: <Y_lm, Y_l'm'> = delta."""
    t, w = np.polynomial.legendre.leggauss(16)
    phi = (np.arange(32) + 0.5) * (2 * np.pi / 32)
    st_ = np.sqrt(1 - t**2)
    xyz = np.stack([
        st_[:, None] * np.cos(phi), st_[:, None] * np.sin(phi),
        np.broadcast_to(t[:, None], (16, 32)),
    ], -1)
    ws = np.broadcast_to(w[:, None] * (2 * np.pi / 32), (16, 32))
    Ys = [NQ.sph_harm_np(l, xyz) for l in range(3)]
    allY = np.concatenate(Ys, -1)  # (T, P, 9)
    gram = np.einsum("tpa,tpb,tp->ab", allY, allY, ws)
    np.testing.assert_allclose(gram, np.eye(9), atol=1e-6)


def test_sph_harm_jnp_matches_np():
    xyz = np.random.RandomState(0).randn(50, 3)
    xyz /= np.linalg.norm(xyz, axis=1, keepdims=True)
    for l in range(3):
        np.testing.assert_allclose(
            np.asarray(NQ.sph_harm(l, jnp.asarray(xyz, jnp.float32))),
            NQ.sph_harm_np(l, xyz), rtol=1e-5, atol=1e-6,
        )


@given(seed=st.integers(0, 1000))
def test_energy_rotation_translation_invariance(model, seed):
    cfg, params = model
    batch = _random_graph(jax.random.PRNGKey(seed))
    R = jnp.asarray(
        sst.Rotation.random(random_state=seed).as_matrix(), jnp.float32
    )
    e0 = NQ.forward(params, batch, cfg)
    b2 = dict(batch)
    b2["positions"] = batch["positions"] @ R.T + 3.7
    e1 = NQ.forward(params, b2, cfg)
    np.testing.assert_allclose(
        np.asarray(e0), np.asarray(e1), rtol=2e-4, atol=2e-4
    )


def test_force_equivariance(model):
    cfg, params = model
    batch = _random_graph(jax.random.PRNGKey(7))
    R = jnp.asarray(
        sst.Rotation.random(random_state=1).as_matrix(), jnp.float32
    )
    _, f0 = NQ.energy_and_forces(params, batch, cfg)
    b2 = dict(batch)
    b2["positions"] = batch["positions"] @ R.T
    _, f1 = NQ.energy_and_forces(params, b2, cfg)
    np.testing.assert_allclose(
        np.asarray(f1), np.asarray(f0 @ R.T), atol=1e-5
    )


def test_permutation_invariance(model):
    cfg, params = model
    batch = _random_graph(jax.random.PRNGKey(9), N=8, E=20)
    perm = jnp.asarray(np.random.RandomState(0).permutation(8))
    inv = jnp.argsort(perm)
    b2 = {
        "positions": batch["positions"][perm],
        "species": batch["species"][perm],
        "edge_src": inv[batch["edge_src"]],
        "edge_dst": inv[batch["edge_dst"]],
    }
    e0 = NQ.forward(params, batch, cfg)
    e1 = NQ.forward(params, b2, cfg)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=1e-4)


def test_cutoff_locality(model):
    """Atoms beyond the cutoff radius contribute nothing."""
    cfg, params = model
    batch = _random_graph(jax.random.PRNGKey(3), N=6, E=10)
    far = dict(batch)
    # push node 0 outside everyone's cutoff
    far["positions"] = batch["positions"].at[0].set(
        jnp.array([100.0, 100.0, 100.0])
    )
    e = NQ.forward(params, far, cfg)
    # removing node-0 edges entirely gives the same energy
    mask = (batch["edge_src"] != 0) & (batch["edge_dst"] != 0)
    pruned = dict(far)
    pruned["edge_mask"] = mask
    e2 = NQ.forward(params, pruned, cfg)
    np.testing.assert_allclose(np.asarray(e), np.asarray(e2), rtol=1e-4)


def test_padding_masks_are_neutral(model):
    cfg, params = model
    batch = _random_graph(jax.random.PRNGKey(5), N=8, E=16)
    e0 = NQ.forward(params, batch, cfg)
    padded = {
        "positions": jnp.pad(batch["positions"], ((0, 4), (0, 0))),
        "species": jnp.pad(batch["species"], (0, 4)),
        "edge_src": jnp.pad(batch["edge_src"], (0, 6)),
        "edge_dst": jnp.pad(batch["edge_dst"], (0, 6)),
        "edge_mask": jnp.pad(jnp.ones(16, bool), (0, 6)),
        "node_mask": jnp.pad(jnp.ones(8, bool), (0, 4)),
    }
    e1 = NQ.forward(params, padded, cfg)
    np.testing.assert_allclose(
        np.asarray(e0), np.asarray(e1), rtol=1e-3, atol=1e-3
    )


def test_bessel_and_cutoff():
    r = jnp.linspace(0.01, 6.0, 50)
    env = NQ.poly_cutoff(r, 5.0)
    assert float(env[0]) > 0.99
    assert float(env[-1]) == 0.0
    assert np.all(np.diff(np.asarray(env)) <= 1e-6)
    basis = NQ.bessel_basis(r, 8, 5.0)
    assert basis.shape == (50, 8)
    assert not bool(jnp.any(jnp.isnan(basis)))
