"""Coarse -> refine pipeline: the symmetric int8 first pass.

Covers: the coarse scan kernel against its jnp oracle BITWISE (exact
integer accumulation + an identical float epilogue) across code widths
b in {1, 2, 4, 8} and ragged (non-multiple-of-tile) shapes; the fused
coarse top-k kernel against materialize-then-``top_k``, with and
without the runtime row masks; coarse + refine parity with the pure
asymmetric path whenever the shortlist covers the candidate set
(flat / IVF partial probe / 1-2-4-shard meshes — the L >= n clamp in
``execute_plan``); shortlist quality at serving sizes; and
engine-batched coarse search against the direct path under add /
delete / compact mutations.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import ASHConfig
from repro.core import scoring as S
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.index import common as C
from repro.kernels import ops
from repro.serving.engine import QueryEngine

METRICS = ("dot", "l2", "cos")


@functools.lru_cache(maxsize=None)
def _kernel_setup(b):
    """Trained model + encoded payload at a RAGGED shape (n, m prime)
    so every kernel-tile edge path runs."""
    key = jax.random.PRNGKey(11 + b)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, 997, 32)
    Qm = embedding_dataset(kq, 7, 32)
    cfg = ASHConfig(b=b, d=16, n_landmarks=8)
    idx = AshIndex.build(kb, X, cfg, backend="flat")
    return idx.model, idx.prepare(Qm), idx._state


# ---------------------------------------------------------------------------
# Kernel vs oracle bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,metric",
    [(1, "dot"), (2, "dot"), (2, "l2"), (2, "cos"), (4, "dot"),
     (8, "dot")],
)
def test_coarse_kernel_matches_oracle_bitwise(b, metric):
    """Coarse scan kernel == jnp coarse oracle bit-for-bit: integer
    accumulation is exact on both sides (int32 MXU vs fp32 BLAS, values
    < 2^24) and the float epilogues share one op order."""
    model, prep, st = _kernel_setup(b)
    kw = dict(metric=metric, stats=st.stats, coarse=st.coarse)
    want = ops.ash_score_coarse(
        model, prep, st.payload, use_pallas=False, **kw
    )
    got = ops.ash_score_coarse(
        model, prep, st.payload, use_pallas=True, interpret=True, **kw
    )
    assert got.dtype == want.dtype
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("k", (8, 32))
def test_coarse_fused_topk_matches_materialize(metric, k):
    """Fused coarse shortlist selection == top_k over the materialized
    coarse scores — values, ids AND tie order."""
    model, prep, st = _kernel_setup(2)
    kw = dict(metric=metric, stats=st.stats, coarse=st.coarse)
    ws, wi = ops.ash_score_coarse_topk(
        model, prep, st.payload, k, use_pallas=False, **kw
    )
    gs, gi = ops.ash_score_coarse_topk(
        model, prep, st.payload, k, use_pallas=True, interpret=True,
        **kw
    )
    assert np.array_equal(np.asarray(gs), np.asarray(ws))
    assert np.array_equal(np.asarray(gi), np.asarray(wi))


def test_coarse_topk_row_masks_agree_across_routes():
    """n_valid truncation + row_valid tombstones fold into the coarse
    selection identically on the fused kernel and the materializing
    oracle, and masked rows never surface."""
    model, prep, st = _kernel_setup(2)
    n = st.payload.n
    rng = np.random.RandomState(5)
    row_valid = jnp.asarray(rng.rand(n) > 0.3)
    n_valid = jnp.int32(700)
    kw = dict(
        metric="l2", stats=st.stats, coarse=st.coarse,
        n_valid=n_valid, row_valid=row_valid, k=16,
    )
    ws, wi = ops.ash_score_coarse_topk(
        model, prep, st.payload, use_pallas=False, **kw
    )
    gs, gi = ops.ash_score_coarse_topk(
        model, prep, st.payload, use_pallas=True, interpret=True, **kw
    )
    assert np.array_equal(np.asarray(gs), np.asarray(ws))
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    dead = set(np.nonzero(~np.asarray(row_valid))[0]) | set(
        range(700, n)
    )
    assert not (set(np.asarray(wi).ravel().tolist()) & dead)


def test_coarse_gather_matches_dense_on_full_lists():
    """The gathered coarse scorer (IVF partial probes) reduces over
    exact integers, so scoring the identity candidate list equals the
    dense coarse scan bit-for-bit."""
    model, prep, st = _kernel_setup(2)
    m = prep.q.shape[0]
    rows = jnp.broadcast_to(
        jnp.arange(st.payload.n, dtype=jnp.int32), (m, st.payload.n)
    )
    dense = ops.ash_score_coarse(
        model, prep, st.payload, metric="dot", stats=st.stats,
        coarse=st.coarse, use_pallas=False,
    )
    got = ops.ash_score_coarse_gather(
        model, prep, st.payload, rows, metric="dot", stats=st.stats,
        coarse=st.coarse,
    )
    assert np.array_equal(np.asarray(got), np.asarray(dense))


# ---------------------------------------------------------------------------
# Backend parity: covering shortlist == pure asymmetric path
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def backend_setup():
    key = jax.random.PRNGKey(29)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, 3000, 32)
    Qm = embedding_dataset(kq, 16, 32)
    cfg = ASHConfig(b=2, d=16, n_landmarks=8)
    model = AshIndex.build(kb, X, cfg, backend="flat").model
    return X, Qm, cfg, model, kb


@pytest.mark.parametrize("metric", METRICS)
def test_flat_covering_shortlist_is_bitwise_asymmetric(
    backend_setup, metric
):
    """shortlist >= n: the coarse pass is clamped away and the flat
    search equals the pure asymmetric search bit-for-bit."""
    X, Qm, cfg, model, kb = backend_setup
    idx = AshIndex.build(kb, X, cfg, metric=metric, model=model)
    s, ids = idx.search(Qm, k=10)
    cs, cids = idx.search(Qm, k=10, coarse="int8", shortlist=idx.n)
    assert np.array_equal(np.asarray(cs), np.asarray(s))
    assert np.array_equal(np.asarray(cids), np.asarray(ids))


def test_flat_covering_shortlist_with_rerank(backend_setup):
    """The L >= n clamp composes with exact rerank: coarse + rerank ==
    plain rerank bit-for-bit when the shortlist covers the corpus."""
    X, Qm, cfg, model, kb = backend_setup
    idx = AshIndex.build(
        kb, X, cfg, metric="cos", model=model, keep_raw=True
    )
    s, ids = idx.search(Qm, k=10, rerank=100)
    cs, cids = idx.search(
        Qm, k=10, rerank=100, coarse="int8", shortlist=idx.n
    )
    assert np.array_equal(np.asarray(cs), np.asarray(s))
    assert np.array_equal(np.asarray(cids), np.asarray(ids))


@pytest.mark.parametrize("nprobe", (3, 8))
def test_ivf_covering_shortlist_is_bitwise_asymmetric(
    backend_setup, nprobe
):
    """IVF partial probes (gathered plan, nprobe < nlist) and full
    scans (nprobe == nlist lowers dense): shortlist >= candidate count
    reproduces the asymmetric result bit-for-bit on both routes."""
    X, Qm, cfg, model, kb = backend_setup
    idx = AshIndex.build(kb, X, cfg, backend="ivf", model=model)
    s, ids = idx.search(Qm, k=10, nprobe=nprobe)
    cs, cids = idx.search(
        Qm, k=10, nprobe=nprobe, coarse="int8", shortlist=idx.n
    )
    assert np.array_equal(np.asarray(cs), np.asarray(s))
    assert np.array_equal(np.asarray(cids), np.asarray(ids))


@pytest.mark.parametrize("n_shards", (1, 2, 4))
def test_sharded_covering_shortlist_matches_flat(
    backend_setup, n_shards
):
    """Sharded coarse search with a covering shortlist (per-shard
    L >= n_local clamp in every local scan) == the FLAT pure
    asymmetric search bit-for-bit across 1/2/4-shard meshes."""
    X, Qm, cfg, model, kb = backend_setup
    if n_shards > jax.device_count():
        pytest.skip("needs more devices")
    flat = AshIndex.build(kb, X, cfg, metric="dot", model=model)
    fs, fids = flat.search(Qm, k=10)
    mesh = Mesh(np.array(jax.devices()[:n_shards]), ("data",))
    si = AshIndex.build(
        kb, X, cfg, backend="sharded", model=model, mesh=mesh,
        axes=("data",),
    )
    ss, sids = si.search(
        Qm, k=10, coarse="int8", shortlist=si.n
    )
    assert np.array_equal(np.asarray(ss), np.asarray(fs))
    assert np.array_equal(np.asarray(sids), np.asarray(fids))


def test_small_shortlist_recall(backend_setup):
    """A serving-sized shortlist loses little: recall@10 of the coarse
    pipeline against the asymmetric path stays >= 0.9 at L = default
    (the benchmark sweep holds >= 0.99 at the full corpus shape; the
    bar here is loose because this corpus is tiny)."""
    X, Qm, cfg, model, kb = backend_setup
    idx = AshIndex.build(kb, X, cfg, model=model)
    base = np.asarray(idx.search(Qm, k=10)[1])
    ids = np.asarray(idx.search(Qm, k=10, coarse="int8")[1])
    rec = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / 10
        for a, b in zip(ids, base)
    ])
    assert rec >= 0.9, rec


def test_coarse_cache_rebuild_matches_fresh_build(backend_setup):
    """add/compact rebuild the CoarseCodes value cache over the whole
    payload (the mean spans ALL rows), so a mutated index's cache ==
    a from-scratch build's over the same rows."""
    X, Qm, cfg, model, kb = backend_setup
    idx = AshIndex.build(kb, X[:2000], cfg, model=model)
    idx.add(X[2000:])
    fresh = AshIndex.build(kb, X, cfg, model=model)
    got, want = idx._state.coarse, fresh._state.coarse
    assert np.array_equal(
        np.asarray(got.values), np.asarray(want.values)
    )
    assert np.array_equal(np.asarray(got.mean), np.asarray(want.mean))
    s, ids = idx.search(Qm, k=10, coarse="int8")
    fs, fids = fresh.search(Qm, k=10, coarse="int8")
    assert np.array_equal(np.asarray(s), np.asarray(fs))
    assert np.array_equal(np.asarray(ids), np.asarray(fids))


# ---------------------------------------------------------------------------
# Engine-batched coarse == direct coarse, across mutations
# ---------------------------------------------------------------------------


def _engine_results(engine, Qm, **kw):
    tickets = [
        engine.submit(Qm[i:i + 4], k=10, **kw)
        for i in range(0, Qm.shape[0], 4)
    ]
    engine.flush()
    outs = [t.result() for t in tickets]
    return (
        np.concatenate([np.asarray(s) for s, _ in outs]),
        np.concatenate([np.asarray(i) for _, i in outs]),
    )


def test_engine_batched_coarse_matches_direct_under_mutations(
    backend_setup
):
    """The engine groups coarse requests by their (coarse, shortlist)
    opts and runs the same fused call as the direct path, so batched
    results == direct results bit-for-bit — before and after engine
    adds, deletes and a compact."""
    X, Qm, cfg, model, kb = backend_setup
    idx = AshIndex.build(kb, X, cfg, model=model)
    engine = QueryEngine(idx, batch_buckets=(8,), max_wait_s=0.005)
    kw = dict(coarse="int8", shortlist=32)

    es, eids = _engine_results(engine, Qm, **kw)
    ds, dids = idx.search(Qm, k=10, **kw)
    assert np.array_equal(es, np.asarray(ds))
    assert np.array_equal(eids, np.asarray(dids))

    engine.submit_add(np.asarray(X[:5]) * 0.5).result()
    engine.submit_delete(np.arange(10, 20)).result()
    es, eids = _engine_results(engine, Qm, **kw)
    ds, dids = idx.search(Qm, k=10, **kw)
    assert np.array_equal(es, np.asarray(ds))
    assert np.array_equal(eids, np.asarray(dids))
    assert not (set(eids.ravel().tolist()) & set(range(10, 20)))

    idx.compact()
    es, eids = _engine_results(engine, Qm, **kw)
    ds, dids = idx.search(Qm, k=10, **kw)
    assert np.array_equal(es, np.asarray(ds))
    assert np.array_equal(eids, np.asarray(dids))


# ---------------------------------------------------------------------------
# Tombstone coherence of the coarse cache on IVF partial probes
# ---------------------------------------------------------------------------


def test_ivf_coarse_partial_probe_respects_tombstones(backend_setup):
    """Tombstoned rows must vanish from the coarse gathered path the
    moment they are deleted: the int8 first pass scores candidates the
    pre-DMA drop already masked, so a dead row can neither surface in
    the shortlist nor displace a live candidate from it.  Covers every
    shortlist regime (clamped-away, serving-sized) on the gathered
    route (nprobe < nlist)."""
    X, Qm, cfg, model, kb = backend_setup
    idx = AshIndex.build(kb, X, cfg, backend="ivf", model=model)
    dead = np.arange(0, 600, 3)
    assert idx.delete(dead) == dead.size
    for kw in (
        dict(coarse="int8", shortlist=idx.n),  # clamp-away regime
        dict(coarse="int8", shortlist=64),  # real first pass
    ):
        s, ids = idx.search(Qm, k=10, nprobe=3, **kw)
        assert not np.isin(np.asarray(ids), dead).any(), kw


def test_ivf_coarse_after_delete_compact_matches_fresh(backend_setup):
    """delete -> compact -> coarse partial probe == a fresh build over
    the survivors (same model), scores bitwise and ids after the
    monotonic survivor mapping.  Compact rebuilds the CoarseCodes
    cache over the surviving rows only — its corpus mean is a global
    reduction, so a stale or partially-masked cache would shift every
    coarse score, not just the deleted rows'."""
    X, Qm, cfg, model, kb = backend_setup
    idx = AshIndex.build(kb, X, cfg, backend="ivf", model=model)
    dead = np.arange(0, 600, 3)
    idx.delete(dead)
    idx.compact()
    surv = np.setdiff1d(np.arange(X.shape[0]), dead)
    fresh = AshIndex.build(
        kb, X[surv], cfg, backend="ivf", model=model
    )
    for kw in (
        dict(coarse="int8", shortlist=64),
        dict(coarse="int8", shortlist=idx.n),
    ):
        s_m, i_m = idx.search(Qm, k=10, nprobe=3, **kw)
        s_f, i_f = fresh.search(Qm, k=10, nprobe=3, **kw)
        i_f = np.asarray(i_f)
        mapped = np.where(i_f < 0, -1, surv[np.maximum(i_f, 0)])
        np.testing.assert_array_equal(
            np.asarray(s_m), np.asarray(s_f), err_msg=str(kw)
        )
        np.testing.assert_array_equal(
            np.asarray(i_m), mapped, err_msg=str(kw)
        )


# ---------------------------------------------------------------------------
# Sharded coarse shortlist clamp across shard counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", (1, 2, 4))
@pytest.mark.parametrize("n_rows", (2999, 3000))
def test_sharded_coarse_clamp_parity_non_dividing(
    backend_setup, n_shards, n_rows
):
    """The per-shard covering clamp (L >= n_local skips the coarse
    stage) must hold per SHARD, not per corpus: with row counts that
    do not divide the mesh, the padded last shard's local n differs
    from the rest, and a corpus-level clamp would run the coarse
    stage on some shards but not others.  Parity bar: sharded coarse
    with a covering shortlist == flat asymmetric, bit for bit, at
    1/2/4 shards for both dividing and non-dividing row counts."""
    X, Qm, cfg, model, kb = backend_setup
    if n_shards > jax.device_count():
        pytest.skip("needs more devices")
    Xr = X[:n_rows]
    flat = AshIndex.build(kb, Xr, cfg, metric="dot", model=model)
    fs, fids = flat.search(Qm, k=10)
    mesh = Mesh(np.array(jax.devices()[:n_shards]), ("data",))
    si = AshIndex.build(
        kb, Xr, cfg, backend="sharded", model=model, mesh=mesh,
        axes=("data",),
    )
    ss, sids = si.search(Qm, k=10, coarse="int8", shortlist=si.n)
    np.testing.assert_array_equal(np.asarray(ss), np.asarray(fs))
    np.testing.assert_array_equal(np.asarray(sids), np.asarray(fids))
    # a serving-sized shortlist stays well-formed on the padded mesh:
    # k live ids per query, no pad sentinel leaks
    ps, pids = si.search(Qm, k=10, coarse="int8", shortlist=64)
    pids = np.asarray(pids)
    assert pids.shape == (Qm.shape[0], 10)
    assert (pids >= 0).all() and (pids < n_rows).all()
