"""tools/check_bench.py: health gate + trajectory diffing."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
import check_bench  # noqa: E402


def _doc(rows, quick=False, group="kernels"):
    return {
        "schema_version": 1, "group": group, "quick": quick,
        "rows": rows,
    }


def _row(name, us, derived=None, error=None):
    return {"name": name, "us_per_call": us,
            "derived": derived or {}, "error": error}


def _write(path, doc):
    path.write_text(json.dumps(doc))
    return str(path)


def test_health_check_flags_errors_and_empty(tmp_path):
    ok = _write(tmp_path / "ok.json", _doc([_row("a", 1.0)]))
    assert check_bench.check(ok) == []
    bad = _write(tmp_path / "bad.json",
                 _doc([_row("a", 0.0, error="boom")]))
    assert any("ERROR row" in p for p in check_bench.check(bad))
    empty = _write(tmp_path / "empty.json", _doc([]))
    assert any("no benchmark rows" in p for p in check_bench.check(empty))


def test_diff_warn_and_fail_thresholds(tmp_path):
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_kernels.json",
           _doc([_row("fast", 100.0), _row("warny", 100.0),
                 _row("faily", 100.0)]))
    cur = _write(
        tmp_path / "BENCH_kernels.json",
        _doc([_row("fast", 101.0), _row("warny", 180.0),
              _row("faily", 500.0)]),
    )
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert len(fails) == 1 and "faily" in fails[0]
    assert len(warns) == 1 and "warny" in warns[0]


def test_diff_qps_regression_and_vanished_rows(tmp_path):
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_serving.json",
           _doc([_row("s", 0.0, {"qps": 1000.0}),
                 _row("gone", 5.0)], group="serving"))
    cur = _write(tmp_path / "BENCH_serving.json",
                 _doc([_row("s", 0.0, {"qps": 100.0})], group="serving"))
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert any("qps regressed 10.00x" in f for f in fails)
    assert any("vanished" in w for w in warns)


def test_diff_mutation_rate_regressions(tmp_path):
    """adds_per_s / deletes_per_s (the serving_mutation rows) are
    higher-is-better throughputs: a drop fails like a qps drop; the
    *_ms latencies are lower-is-better and diffed with the inverted
    ratio (a 500x p99 blowup fails the gate)."""
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_serving.json",
           _doc([_row("serving/mutation_flat_10pct", 0.0,
                      {"adds_per_s": 500.0, "deletes_per_s": 400.0,
                       "p99_ms": 1.0})], group="serving"))
    cur = _write(
        tmp_path / "BENCH_serving.json",
        _doc([_row("serving/mutation_flat_10pct", 0.0,
                   {"adds_per_s": 100.0, "deletes_per_s": 390.0,
                    "p99_ms": 500.0})], group="serving"),
    )
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert any("adds_per_s regressed 5.00x" in f for f in fails)
    assert not any("deletes_per_s" in m for m in fails + warns)
    assert any("p99_ms regressed 500.00x" in f for f in fails)


def test_diff_latency_ms_lower_is_better(tmp_path):
    """*_ms latencies: warn at 1.5x, fail at 3x, and an IMPROVEMENT
    (latency dropping) never trips the gate."""
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_serving.json",
           _doc([_row("serving/engine_flat_b8", 0.0,
                      {"p50_ms": 10.0, "p99_ms": 10.0,
                       "worst_apply_ms": 10.0})], group="serving"))
    cur = _write(
        tmp_path / "BENCH_serving.json",
        _doc([_row("serving/engine_flat_b8", 0.0,
                   {"p50_ms": 18.0, "p99_ms": 40.0,
                    "worst_apply_ms": 1.0})], group="serving"),
    )
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert any("p50_ms regressed 1.80x" in w for w in warns)
    assert any("p99_ms regressed 4.00x" in f for f in fails)
    assert not any("worst_apply_ms" in m for m in fails + warns)


def test_concurrent_row_invariants(tmp_path):
    """Rows carrying the concurrent-serving metric pairs are gated
    structurally: qps < qps_single fails, and background-compaction
    p99 at or above the synchronous p99 fails."""
    good = _write(tmp_path / "good.json", _doc([_row(
        "serving/concurrent_flat_c8", 1.0,
        {"qps": 2000.0, "qps_single": 250.0,
         "p99_sync_compact_ms": 100.0, "p99_bg_compact_ms": 20.0},
    )], group="serving"))
    assert check_bench.check(good) == []

    slow = _write(tmp_path / "slow.json", _doc([_row(
        "serving/concurrent_flat_c8", 1.0,
        {"qps": 100.0, "qps_single": 250.0},
    )], group="serving"))
    probs = check_bench.check(slow)
    assert any("single-caller" in p for p in probs)

    stall = _write(tmp_path / "stall.json", _doc([_row(
        "serving/concurrent_flat_c8", 1.0,
        {"p99_sync_compact_ms": 50.0, "p99_bg_compact_ms": 50.0},
    )], group="serving"))
    probs = check_bench.check(stall)
    assert any("not off the serving path" in p for p in probs)

    # rows without the metric pairs (everything pre-concurrent) are
    # untouched by the invariants
    plain = _write(tmp_path / "plain.json", _doc([_row(
        "serving/engine_flat_b8", 1.0, {"qps": 100.0, "p99_ms": 5.0},
    )], group="serving"))
    assert check_bench.check(plain) == []


def test_durability_row_invariant(tmp_path):
    """The durability row is gated structurally: the default (interval)
    fsync policy must keep >= 0.8x the no-WAL mutation throughput.
    Rows without the metric pair are untouched."""
    good = _write(tmp_path / "good.json", _doc([_row(
        "serving/durability_flat", 1.0,
        {"nowal_muts_per_s": 100.0, "interval_muts_per_s": 95.0,
         "always_muts_per_s": 40.0, "off_muts_per_s": 99.0},
    )], group="serving"))
    assert check_bench.check(good) == []

    slow = _write(tmp_path / "slow.json", _doc([_row(
        "serving/durability_flat", 1.0,
        {"nowal_muts_per_s": 100.0, "interval_muts_per_s": 70.0},
    )], group="serving"))
    probs = check_bench.check(slow)
    assert any("durability budget" in p for p in probs)

    # a slow `always` policy alone never trips the gate — only the
    # default policy carries the throughput promise
    fsync_heavy = _write(tmp_path / "fsync.json", _doc([_row(
        "serving/durability_flat", 1.0,
        {"nowal_muts_per_s": 100.0, "interval_muts_per_s": 90.0,
         "always_muts_per_s": 5.0},
    )], group="serving"))
    assert check_bench.check(fsync_heavy) == []


def test_diff_durability_rates_are_throughputs(tmp_path):
    """The per-mode mutation rates end in _per_s, so the trajectory
    diff treats a drop as a regression (inverted ratio) and the
    per-mode p99s end in _ms (lower is better)."""
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_serving.json",
           _doc([_row("serving/durability_flat", 0.0,
                      {"interval_muts_per_s": 100.0,
                       "p99_interval_ms": 2.0})], group="serving"))
    cur = _write(
        tmp_path / "BENCH_serving.json",
        _doc([_row("serving/durability_flat", 0.0,
                   {"interval_muts_per_s": 20.0,
                    "p99_interval_ms": 8.0})], group="serving"),
    )
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert any("interval_muts_per_s regressed 5.00x" in f for f in fails)
    assert any("p99_interval_ms regressed 4.00x" in f for f in fails)


def test_ivf_cost_model_gate(tmp_path):
    """serving/engine_ivf* rows that ran the cost model (row_budget
    derived field present) must beat serving/direct_ivf: p99 at or
    below direct's, qps at >= 2x.  Uncosted rows are never gated."""
    direct = _row("serving/direct_ivf", 1.0,
                  {"qps": 250.0, "p99_ms": 24.0})

    good = _write(tmp_path / "good.json", _doc([
        direct,
        _row("serving/engine_ivf_b8", 1.0,
             {"qps": 640.0, "p99_ms": 23.0, "row_budget": 18000}),
    ], group="serving"))
    assert check_bench.check(good) == []

    # costed row losing the tail to the direct path
    tail = _write(tmp_path / "tail.json", _doc([
        direct,
        _row("serving/engine_ivf_b8", 1.0,
             {"qps": 640.0, "p99_ms": 90.0, "row_budget": 18000}),
    ], group="serving"))
    probs = check_bench.check(tail)
    assert any("lost the tail" in p for p in probs)

    # costed row below the 2x throughput bar
    slow = _write(tmp_path / "slow.json", _doc([
        direct,
        _row("serving/engine_ivf_b8-32", 1.0,
             {"qps": 300.0, "p99_ms": 20.0, "row_budget": 18000}),
    ], group="serving"))
    probs = check_bench.check(slow)
    assert any("lost the throughput win" in p for p in probs)

    # uncosted contrast row (no row_budget field): ungated even when
    # it loses both tail and throughput
    contrast = _write(tmp_path / "contrast.json", _doc([
        direct,
        _row("serving/engine_ivf_b32", 1.0,
             {"qps": 100.0, "p99_ms": 170.0}),
    ], group="serving"))
    assert check_bench.check(contrast) == []

    # client-count-suffixed names (the closed-loop rows) gate the
    # same way: direct_ivf_c32 is found by prefix
    suffixed = _write(tmp_path / "suffixed.json", _doc([
        _row("serving/direct_ivf_c32", 1.0,
             {"qps": 900.0, "p99_ms": 110.0}),
        _row("serving/engine_ivf_c32_b8", 1.0,
             {"qps": 1200.0, "p99_ms": 20.0, "row_budget": 10000}),
    ], group="serving"))
    probs = check_bench.check(suffixed)
    assert any("lost the throughput win" in p for p in probs)
    assert not any("lost the tail" in p for p in probs)

    # no direct_ivf row in the file: nothing to gate against
    lone = _write(tmp_path / "lone.json", _doc([
        _row("serving/engine_ivf_b8", 1.0,
             {"qps": 10.0, "p99_ms": 900.0, "row_budget": 18000}),
    ], group="serving"))
    assert check_bench.check(lone) == []

    # quick (smoke-size) runs skip the gate: the 2x bar is a
    # full-geometry claim (tiny corpora leave nothing to amortize)
    quick = _write(tmp_path / "quick.json", _doc([
        direct,
        _row("serving/engine_ivf_b8", 1.0,
             {"qps": 300.0, "p99_ms": 90.0, "row_budget": 18000}),
    ], group="serving", quick=True))
    assert check_bench.check(quick) == []

    # ERROR rows never reach the cross-row gate (the health check
    # already failed the file; a malformed direct row must not crash)
    broken = _write(tmp_path / "broken.json", _doc([
        _row("serving/direct_ivf", 0.0, error="boom"),
        _row("serving/engine_ivf_b8", 1.0,
             {"qps": 10.0, "p99_ms": 900.0, "row_budget": 18000}),
    ], group="serving"))
    probs = check_bench.check(broken)
    assert any("ERROR row" in p for p in probs)
    assert not any("lost the" in p for p in probs)


def test_diff_skips_quick_vs_full(tmp_path):
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_kernels.json", _doc([_row("a", 100.0)]))
    cur = _write(tmp_path / "BENCH_kernels.json",
                 _doc([_row("a", 10_000.0)], quick=True))
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert fails == []
    assert any("not comparable" in w for w in warns)


def test_diff_combined_file_maps_groups(tmp_path):
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_kernels.json", _doc([_row("a", 100.0)]))
    combined = {
        "schema_version": 1, "quick": False,
        "groups": {"kernels": [_row("a", 1000.0)]},
    }
    cur = _write(tmp_path / "bench.json", combined)
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert len(fails) == 1 and "us_per_call regressed 10.00x" in fails[0]


def test_main_exit_codes(tmp_path):
    ok = _write(tmp_path / "ok.json", _doc([_row("a", 1.0)]))
    assert check_bench.main([ok]) == 0
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "ok.json", _doc([_row("a", 1.0)]))
    assert check_bench.main([ok, "--baseline", str(base)]) == 0
    _write(base / "ok.json", _doc([_row("a", 0.1)]))
    assert check_bench.main([ok, "--baseline", str(base)]) == 1


def test_coarse_serving_gate(tmp_path):
    """Rows pairing qps with qps_asym (serving/coarse_flat) are gated
    structurally on full-size files: qps >= 1.5x qps_asym (accelerator
    platforms only — on CPU both passes are the same BLAS GEMM, so
    parity is expected and only recall gates) and recall_at_10 within
    1 point of recall_at_10_asym."""
    good = _write(tmp_path / "good.json", _doc([_row(
        "serving/coarse_flat", 1.0,
        {"qps": 900.0, "qps_asym": 500.0, "platform": "tpu",
         "recall_at_10": 0.95, "recall_at_10_asym": 0.955},
    )], group="serving"))
    assert check_bench.check(good) == []

    slow = _write(tmp_path / "slow.json", _doc([_row(
        "serving/coarse_flat", 1.0,
        {"qps": 600.0, "qps_asym": 500.0, "platform": "tpu",
         "recall_at_10": 0.95, "recall_at_10_asym": 0.95},
    )], group="serving"))
    probs = check_bench.check(slow)
    assert any("lost its throughput win" in p for p in probs)

    # the same shortfall on a cpu row (or one with no platform stamp)
    # does NOT arm the throughput half
    for plat in ({"platform": "cpu"}, {}):
        cpu = _write(tmp_path / f"cpu{len(plat)}.json", _doc([_row(
            "serving/coarse_flat", 1.0,
            {"qps": 600.0, "qps_asym": 500.0, **plat,
             "recall_at_10": 0.95, "recall_at_10_asym": 0.95},
        )], group="serving"))
        assert check_bench.check(cpu) == []

    lossy = _write(tmp_path / "lossy.json", _doc([_row(
        "serving/coarse_flat", 1.0,
        {"qps": 900.0, "qps_asym": 500.0, "platform": "cpu",
         "recall_at_10": 0.90, "recall_at_10_asym": 0.95},
    )], group="serving"))
    probs = check_bench.check(lossy)
    assert any("shortlist too aggressive" in p for p in probs)

    # quick (smoke-size) runs skip the gate: dispatch overhead, not
    # the scan, dominates tiny corpora
    quick = _write(tmp_path / "quick.json", _doc([_row(
        "serving/coarse_flat", 1.0,
        {"qps": 400.0, "qps_asym": 500.0,
         "recall_at_10": 0.90, "recall_at_10_asym": 0.95},
    )], group="serving", quick=True))
    assert check_bench.check(quick) == []

    # rows without qps_asym are untouched
    plain = _write(tmp_path / "plain.json", _doc([_row(
        "serving/engine_flat_b8", 1.0, {"qps": 100.0},
    )], group="serving"))
    assert check_bench.check(plain) == []


def test_diff_recall_drops_are_absolute(tmp_path):
    """recall_at_* metrics diff by absolute points: > 2 points down
    fails, > half a point warns, and an improvement never trips.
    Ratios would hide regressions against a ~1.0 baseline."""
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_serving.json",
           _doc([_row("serving/coarse_flat", 0.0,
                      {"recall_at_10": 0.99,
                       "recall_at_10_asym": 0.99})], group="serving"))
    cur = _write(
        tmp_path / "BENCH_serving.json",
        _doc([_row("serving/coarse_flat", 0.0,
                   {"recall_at_10": 0.96,
                    "recall_at_10_asym": 0.998})], group="serving"),
    )
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert any("recall_at_10 dropped 3.0 points" in f for f in fails)
    assert not any("recall_at_10_asym" in m for m in fails + warns)

    _write(tmp_path / "BENCH_serving.json",
           _doc([_row("serving/coarse_flat", 0.0,
                      {"recall_at_10": 0.98,
                       "recall_at_10_asym": 0.99})], group="serving"))
    fails, warns = check_bench.diff(
        str(tmp_path / "BENCH_serving.json"), str(base), 1.5, 3.0)
    assert fails == []
    assert any("dropped 1.0 points" in w for w in warns)


def test_diff_refuses_cross_shape_rows(tmp_path):
    """Rows stamped with corpus-shape metadata (n/d/b/m) refuse to
    diff against a different shape — a retuned benchmark corpus must
    not masquerade as a perf change.  Unstamped rows (serving group)
    and matching shapes diff as before."""
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_kernels.json", _doc([
        _row("kernel/a", 100.0, {"n": 20000, "d": 96, "b": 2, "m": 200}),
        _row("kernel/b", 100.0, {"n": 20000, "d": 96, "b": 2, "m": 200}),
        _row("plain", 100.0),
    ]))
    cur = _write(tmp_path / "BENCH_kernels.json", _doc([
        # 10x slower but at a DIFFERENT corpus shape: refused, no fail
        _row("kernel/a", 1000.0, {"n": 40000, "d": 96, "b": 2, "m": 200}),
        # same shape, 10x slower: fails as usual
        _row("kernel/b", 1000.0, {"n": 20000, "d": 96, "b": 2, "m": 200}),
        # unstamped, 10x slower: fails as usual
        _row("plain", 1000.0),
    ]))
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert any("diff refused" in w and "kernel/a" in w for w in warns)
    assert not any("kernel/a" in f for f in fails)
    assert any("kernel/b" in f for f in fails)
    assert any("plain" in f for f in fails)


def _tiered_derived(**over):
    d = {"qps": 90.0, "qps_hbm": 1500.0, "qps_cold": 80.0,
         "qps_cover": 130.0, "p99_hbm_ms": 2.8, "p99_cold_ms": 17.0,
         "p99_warm_ms": 16.0, "p99_cover_ms": 12.0,
         "hit_rate_warm": 0.48, "hit_rate_cover": 1.0,
         "hot_bytes": 160000, "total_bytes": 640000,
         "paged_rows_cold": 25000, "bitwise_cover": 1,
         "recall_at_10": 0.92}
    d.update(over)
    return {k: v for k, v in d.items() if v is not None}


def test_tiered_serving_gate(tmp_path):
    """Rows carrying bitwise_cover (serving/tiered_ivf) are gated
    structurally on every run including quick: covering results
    bitwise-equal to HBM, paging actually exercised, cache gauges
    present and well formed."""
    good = _write(tmp_path / "good.json", _doc(
        [_row("serving/tiered_ivf", 1.0, _tiered_derived())],
        group="serving"))
    assert check_bench.check(good) == []

    # the gate is structural, so quick files are held to it too
    diverged = _write(tmp_path / "div.json", _doc(
        [_row("serving/tiered_ivf", 1.0,
              _tiered_derived(bitwise_cover=0))],
        group="serving", quick=True))
    assert any("diverged from the HBM-resident" in p
               for p in check_bench.check(diverged))

    unpaged = _write(tmp_path / "unpaged.json", _doc(
        [_row("serving/tiered_ivf", 1.0,
              _tiered_derived(hot_bytes=10 ** 9))],
        group="serving"))
    assert any("nothing was tiered" in p
               for p in check_bench.check(unpaged))

    no_gauges = _write(tmp_path / "nog.json", _doc(
        [_row("serving/tiered_ivf", 1.0,
              _tiered_derived(hot_bytes=None, total_bytes=None,
                              hit_rate_warm=None))],
        group="serving"))
    probs = check_bench.check(no_gauges)
    assert any("missing hot_bytes/total_bytes" in p for p in probs)
    assert any("hit_rate_warm missing" in p for p in probs)

    cold_noop = _write(tmp_path / "coldn.json", _doc(
        [_row("serving/tiered_ivf", 1.0,
              _tiered_derived(paged_rows_cold=0))],
        group="serving"))
    assert any("transferred no rows" in p
               for p in check_bench.check(cold_noop))

    missy = _write(tmp_path / "missy.json", _doc(
        [_row("serving/tiered_ivf", 1.0,
              _tiered_derived(hit_rate_cover=0.7))],
        group="serving"))
    assert any("still missing the cache" in p
               for p in check_bench.check(missy))

    # rows without bitwise_cover are untouched
    plain = _write(tmp_path / "plain.json", _doc(
        [_row("serving/engine_flat_b8", 1.0, {"qps": 100.0})],
        group="serving"))
    assert check_bench.check(plain) == []


def test_diff_warns_on_one_sided_metrics(tmp_path):
    """A diffable metric present on only one side of a surviving row
    warns instead of silently dropping out of the trajectory — in
    both directions; non-diffed derived fields stay quiet."""
    base = tmp_path / "base"
    base.mkdir()
    _write(base / "BENCH_serving.json", _doc(
        [_row("s", 0.0, {"qps": 1000.0, "p99_ms": 2.0,
                         "recall_at_10": 0.9, "clients": 32})],
        group="serving"))
    cur = _write(tmp_path / "BENCH_serving.json", _doc(
        [_row("s", 0.0, {"qps": 990.0, "p50_ms": 1.0,
                         "recall_at_10": 0.9, "row_budget": 5})],
        group="serving"))
    fails, warns = check_bench.diff(cur, str(base), 1.5, 3.0)
    assert fails == []
    gone = [w for w in warns if "only in the baseline" in w]
    new = [w for w in warns if "only in the current" in w]
    assert len(gone) == 1 and "p99_ms" in gone[0]
    assert len(new) == 1 and "p50_ms" in new[0]
    # metadata fields (clients, row_budget) never warn
    assert not any("clients" in w or "row_budget" in w for w in warns)
