"""ASH retrieval serving + data pipelines + neighbor sampler."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import graphs as G
from repro.data.synthetic import (
    ClickStream, IteratorState, SequenceStream, TokenStream,
    embedding_dataset, isotropy_diagnostics,
)
from repro.index import metrics as MET
from repro.serving import retrieval as RET


def test_ash_retrieval_recall():
    key = jax.random.PRNGKey(0)
    items = embedding_dataset(key, 5000, 64, normalize=False)
    users = embedding_dataset(jax.random.PRNGKey(1), 16, 64)
    index = RET.build_index(
        jax.random.PRNGKey(2), items, bits=4, reduce=2, n_landmarks=16
    )
    _, ids = RET.serve_topk(index, users, k=100, use_pallas=False)
    _, gt = MET.exact_topk(users, items, k=10)
    assert float(MET.recall_at(jnp.asarray(ids), gt)) > 0.9
    # kernel path agrees
    _, ids_k = RET.serve_topk(index, users, k=100, use_pallas=True)
    r1 = float(MET.recall_at(jnp.asarray(ids), gt))
    r2 = float(MET.recall_at(jnp.asarray(ids_k), gt))
    assert abs(r1 - r2) < 0.02
    # serve_topk routes through the cached per-index engine
    assert RET.engine_for(index).stats.requests >= 2


def test_sasrec_end_to_end_retrieval():
    from repro.models import sasrec as SR

    cfg = SR.SASRecConfig(n_items=2000, embed_dim=16, seq_len=10,
                          n_neg=32)
    params = SR.init_params(jax.random.PRNGKey(0), cfg)
    seq = jax.random.randint(jax.random.PRNGKey(1), (4, 10), 1, 2000)
    index = RET.build_index(
        jax.random.PRNGKey(2), params["item_emb"], bits=8, reduce=1,
        n_landmarks=8,
    )
    scores, ids = RET.sasrec_retrieve(params, seq, index, cfg, k=50)
    exact = SR.retrieval_score(params, seq, jnp.arange(2000), cfg)
    _, gt = jax.lax.top_k(exact, 10)
    assert float(MET.recall_at(jnp.asarray(ids), gt)) > 0.85


def test_token_stream_determinism_and_structure():
    a = TokenStream(IteratorState(seed=4, step=10), 4, 16, 97)
    b = TokenStream(IteratorState(seed=4, step=10), 4, 16, 97)
    ba, bb = a.next(), b.next()
    assert jnp.array_equal(ba["tokens"], bb["tokens"])
    assert int(ba["tokens"].max()) < 97
    # markov structure: next token is a deterministic fn of current+step
    c = a.next()
    assert not jnp.array_equal(ba["tokens"], c["tokens"])


def test_click_stream_learnable_signal():
    s = ClickStream(IteratorState(seed=1), 4096, 4, 6, 1000)
    b = s.next()
    assert b["sparse"].shape == (4096, 6)
    # planted rule: divisible-by-5 ids raise P(label)
    feat = jnp.sum((b["sparse"] % 5 == 0), axis=-1)
    hi = b["labels"][feat >= 3].mean()
    lo = b["labels"][feat <= 1].mean()
    assert float(hi) > float(lo)


def test_sequence_stream_shapes():
    s = SequenceStream(IteratorState(seed=2), 8, 12, 500, n_neg=16)
    b = s.next()
    assert b["seq"].shape == (8, 12)
    assert b["labels"].shape == (8, 12)
    assert b["negatives"].shape == (16,)
    assert int(b["seq"].min()) >= 1  # 0 is the padding id


def test_isotropy_diagnostics_match_table4_regime():
    """Synthetic data reproduces the paper's non-isotropy findings."""
    X = embedding_dataset(jax.random.PRNGKey(5), 4000, 128)
    d = isotropy_diagnostics(X)
    assert d["mean_inf_norm"] > 0.05  # not centered
    iso = jax.random.normal(jax.random.PRNGKey(6), (4000, 128))
    d_iso = isotropy_diagnostics(iso)
    assert d["mean_inf_norm"] > 3 * d_iso["mean_inf_norm"]


def test_neighbor_sampler_valid_subgraph():
    g = G.random_graph(0, n_nodes=500, avg_degree=8, d_feat=4)
    rng = np.random.RandomState(0)
    seeds = rng.choice(500, 16, replace=False)
    sub = G.neighbor_sample(g, seeds, (5, 3), rng)
    n_real = sub["n_real_nodes"]
    assert n_real <= sub["nodes"].shape[0]
    # every real edge references sampled (local) node ids
    e_valid = sub["edge_mask"]
    assert int(sub["edge_src"][e_valid].max()) < n_real
    assert int(sub["edge_dst"][e_valid].max()) < n_real
    # fanout bound: at most seeds*5 + seeds*5*3 edges
    assert int(e_valid.sum()) <= 16 * 5 + 16 * 5 * 3
    # seeds are included in the node set
    sampled = set(sub["nodes"][:n_real].tolist())
    assert set(seeds.tolist()) <= sampled


def test_batch_small_graphs_disjoint():
    b = G.batch_small_graphs(0, n_graphs=5, nodes_per=7, edges_per=11)
    gid = b["graph_ids"]
    src_g = gid[b["edge_src"]]
    dst_g = gid[b["edge_dst"]]
    assert np.array_equal(src_g, dst_g)  # edges never cross graphs
    assert b["positions"].shape == (35, 3)


def test_csr_graph_consistency():
    g = G.random_graph(3, n_nodes=100, avg_degree=4)
    assert g.n_edges == g.indptr[-1]
    assert g.indices.max() < g.n_nodes
    degs = np.diff(g.indptr)
    assert degs.sum() == g.n_edges
