"""Flat / IVF / distributed index behaviour."""
import jax
import jax.numpy as jnp
import numpy as onp
import pytest
from jax.sharding import Mesh

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex, metrics
from repro.index import distributed as DX


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(31)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, 8000, 48)
    Qm = embedding_dataset(kq, 24, 48)
    gt_s, gt_i = metrics.exact_topk(Qm, X, k=10)
    cfg = ASHConfig(b=2, d=24, n_landmarks=32)
    return X, Qm, gt_i, cfg, kb


def test_flat_recall_and_rerank(setup):
    X, Qm, gt_i, cfg, kb = setup
    idx = AshIndex.build(kb, X, cfg, keep_raw=True)
    s, i = idx.search(Qm, k=100)
    r100 = float(metrics.recall_at(i, gt_i))
    assert r100 > 0.9, r100
    s, i = idx.search(Qm, k=10, rerank=100)
    # exact rerank of the 100-shortlist recovers ~recall@100 at k=10
    # (bf16 raw vectors can flip near-ties)
    assert float(metrics.recall_at(i, gt_i)) >= r100 - 0.02


def test_flat_l2_and_cos_metrics(setup):
    X, Qm, gt_i, cfg, kb = setup
    for metric in ("l2", "cos"):
        idx = AshIndex.build(kb, X, cfg, metric=metric)
        s, i = idx.search(Qm, k=100)
        gt = metrics.exact_topk(Qm, X, k=10, metric=metric)[1]
        assert float(metrics.recall_at(i, gt)) > 0.85


def test_ivf_nprobe_monotone(setup):
    X, Qm, gt_i, cfg, kb = setup
    idx = AshIndex.build(kb, X, cfg, backend="ivf")
    recalls = []
    for nprobe in (2, 8, 32):
        s, i = idx.search(Qm, k=100, nprobe=nprobe)
        recalls.append(float(metrics.recall_at(i, gt_i)))
    assert recalls == sorted(recalls), recalls
    assert recalls[-1] > 0.85


def test_ivf_full_probe_matches_flat(setup):
    """nprobe == nlist must equal exhaustive scan recall."""
    X, Qm, gt_i, cfg, kb = setup
    fidx = AshIndex.build(kb, X, cfg)
    iidx = AshIndex.build(kb, X, cfg, backend="ivf")
    _, fi = fidx.search(Qm, k=50)
    _, ii = iidx.search(Qm, k=50, nprobe=32)
    rf = float(metrics.recall_at(fi, gt_i))
    ri = float(metrics.recall_at(ii, gt_i))
    assert abs(rf - ri) < 0.05, (rf, ri)


def test_distributed_search_matches_flat(setup):
    X, Qm, gt_i, cfg, kb = setup
    fidx = AshIndex.build(kb, X, cfg)
    _, fi = fidx.search(Qm, k=10)
    mesh = Mesh(onp.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    pay = DX.shard_payload(
        mesh, DX.pad_to_multiple(fidx.payload, 1), ("data", "model")
    )
    fn = DX.make_sharded_search(mesh, fidx.model, ("data", "model"), k=10)
    _, di = fn(pay, Qm)
    assert jnp.array_equal(jnp.sort(di, 1), jnp.sort(fi, 1))


def test_pad_to_multiple_never_wins(setup):
    X, Qm, gt_i, cfg, kb = setup
    fidx = AshIndex.build(kb, X[:100], cfg)
    padded = DX.pad_to_multiple(fidx.payload, 64)
    assert padded.n == 128
    from repro.core import prepare_queries, score_dot

    prep = prepare_queries(fidx.model, Qm)
    sc = score_dot(fidx.model, prep, padded)
    top = jnp.argsort(-sc, axis=1)[:, :10]
    assert int(jnp.max(top)) < 100  # sentinels never retrieved


def test_recall_math():
    retrieved = jnp.array([[1, 2, 3, 9], [4, 5, 6, 7]])
    gt = jnp.array([[1, 2], [8, 9]])
    r = float(metrics.recall_at(retrieved, gt, k_gt=2))
    assert abs(r - 0.5) < 1e-6  # (2/2 + 0/2) / 2
