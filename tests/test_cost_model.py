"""IVF candidate-row cost model + load-adaptive probing.

Core properties:

* **Pressure-off identity** — with ``row_budget`` armed (splits and
  budget-triggered flushes firing), every engine request is
  bit-identical (scores AND ids) to the direct full-nprobe search of
  the same rows.  The cost model is a batching POLICY: it may change
  how groups chunk into fused calls, never what a query returns.
* **Degradation is exact at the rung** — under pressure 1.0 with
  ``nprobe_min`` armed, results equal the direct search at the ladder
  floor exactly, and top-k overlap vs the full-nprobe answer stays
  above the configured recall floor.

Plus unit coverage of the accounting itself: union-dedup billing,
budget-boundary chunk planning, the halving ladder, the pressure
gauge, config validation, and the "budget" flush reason.
"""
import time

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.serving.engine import EngineConfig, QueryEngine, _Request

N = 2500
D = 32
NLIST = 8
RECALL_FLOOR = 0.3  # top-10 overlap floor under forced degradation


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(99)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, N, D)
    Qm = np.asarray(embedding_dataset(kq, 24, D))
    cfg = ASHConfig(b=2, d=D // 2, n_landmarks=NLIST)
    index = AshIndex.build(kb, X, cfg, backend="ivf")
    return index, Qm


def _request_mix(Qm, seed):
    rng = np.random.RandomState(seed)
    out, i = [], 0
    while i < Qm.shape[0]:
        m = min(int(rng.choice([1, 1, 2, 4])), Qm.shape[0] - i)
        out.append((i, m))
        i += m
    return out


# ---------------------------------------------------------------------------
# property: the cost model never changes results
# ---------------------------------------------------------------------------


@given(nprobe=st.sampled_from([2, 3, 4]), seed=st.integers(0, 7))
def test_pressure_off_identity(setup, nprobe, seed):
    """Budget splits + budget-triggered flushes engaged, pressure off:
    every request matches the direct search bit-for-bit."""
    index, Qm = setup
    # a budget well under the corpus forces unions over the cap, so
    # flushes split into sub-batches and submits trip "budget" flushes
    engine = QueryEngine(
        index, batch_buckets=(4, 8), max_wait_s=60.0,
        row_budget=max(1, N // 4),
    )
    tickets = [
        (i, m, engine.submit(Qm[i:i + m], k=10, nprobe=nprobe))
        for i, m in _request_mix(Qm, seed)
    ]
    engine.flush()
    for i, m, t in tickets:
        s_e, i_e = t.result()
        s_d, i_d = index.search(Qm[i:i + m], k=10, nprobe=nprobe)
        np.testing.assert_array_equal(s_e, np.asarray(s_d))
        np.testing.assert_array_equal(i_e, np.asarray(i_d))
        assert t.stats.effective_nprobe == nprobe  # never degraded
        assert t.stats.scanned_rows > 0  # but always billed


def test_degraded_flush_is_exact_at_the_rung(setup):
    """Pressure 1.0 lands on the nprobe_min rung; the degraded fused
    call must equal the DIRECT search at that rung exactly — adaptive
    probing trades recall via nprobe only, never via approximation —
    and keep top-k overlap vs full fidelity above the floor."""
    index, Qm = setup
    engine = QueryEngine(
        index, batch_buckets=(4, 8), max_wait_s=60.0, nprobe_min=2,
    )
    # 4 single-row requests: under the 8-row bucket, so nothing
    # flushes on size before the forced-pressure flush below
    tickets = [
        engine.submit(Qm[i:i + 1], k=10, nprobe=4) for i in range(4)
    ]
    engine._flush_all("manual", pressure=1.0)

    overlaps = []
    for j, t in enumerate(tickets):
        s_e, i_e = t.result()
        q = Qm[j:j + 1]
        s_d, i_d = index.search(q, k=10, nprobe=2)
        np.testing.assert_array_equal(s_e, np.asarray(s_d))
        np.testing.assert_array_equal(i_e, np.asarray(i_d))
        assert t.stats.effective_nprobe == 2
        _, i_full = index.search(q, k=10, nprobe=4)
        i_full = np.asarray(i_full)
        overlaps.append(
            len(set(i_e[0]) & set(i_full[0])) / i_full.shape[1]
        )
    assert np.mean(overlaps) >= RECALL_FLOOR
    snap = engine.stats.snapshot()
    assert snap["ivf_cost"]["degraded"] >= 1
    assert snap["ivf_cost"]["effective_nprobe"].get("2", 0) > 0


def test_pressure_below_ladder_threshold_never_degrades(setup):
    """An idle queue always serves full fidelity: small nonzero
    pressure maps to the top rung."""
    index, Qm = setup
    engine = QueryEngine(
        index, batch_buckets=(4, 8), max_wait_s=60.0, nprobe_min=2,
    )
    t = engine.submit(Qm[:4], k=10, nprobe=4)
    engine._flush_all("manual", pressure=0.2)  # < 1/len(ladder)=1/2
    s_d, i_d = index.search(Qm[:4], k=10, nprobe=4)
    s_e, i_e = t.result()
    np.testing.assert_array_equal(s_e, np.asarray(s_d))
    np.testing.assert_array_equal(i_e, np.asarray(i_d))
    assert t.stats.effective_nprobe == 4


# ---------------------------------------------------------------------------
# unit: accounting
# ---------------------------------------------------------------------------


def _engine(setup, **kw):
    index, _ = setup
    return QueryEngine(index, batch_buckets=(4, 8), max_wait_s=60.0,
                       **kw)


def test_union_bill_dedups_shared_lists(setup):
    engine = _engine(setup, row_budget=10)
    sizes = np.array([5, 7, 11, 2], dtype=np.int64)
    a = np.array([[0, 1]], dtype=np.int32)
    b = np.array([[1, 2]], dtype=np.int32)
    assert engine._union_bill(sizes, [a]) == 12
    # list 1 shared by both queries is billed once: 5+7+11, not +7
    assert engine._union_bill(sizes, [a, b]) == 23
    assert engine._union_bill(sizes, [a, a, a]) == 12
    assert engine._union_bill(sizes, []) == 0
    # pad sentinels and out-of-range ids cost nothing
    junk = np.array([[-1, 99]], dtype=np.int32)
    assert engine._union_bill(sizes, [junk]) == 0


def _req(q_rows, probe_lists, dim=D):
    q = np.zeros((q_rows, dim), dtype=np.float32)
    probe = np.asarray(probe_lists, dtype=np.int32)
    return _Request(q, 10, None, time.perf_counter(), None, probe)


def test_plan_chunks_splits_on_budget_not_on_sharing(setup):
    """Disjoint probe sets overflow the budget and split; queries
    sharing the same lists bill once and batch together.  (4-row
    requests: the smallest bucket is 4, so each request is splittable
    on its own — see the bucket-floor test for sub-bucket chunks.)"""
    engine = _engine(setup, row_budget=10)
    sizes = np.array([6, 6, 6, 6, 6, 6, 6, 6], dtype=np.int64)
    engine._live_list_sizes = lambda name, idx: sizes
    group = ("default", 2, 0, None, ())

    # shared lists: 3 requests x lists {0,1} bill 12 > 10? no — the
    # union stays {0,1} = 12... use budget 12 so sharing fits exactly
    engine2 = _engine(setup, row_budget=12)
    engine2._live_list_sizes = lambda name, idx: sizes
    shared = [_req(1, [[0, 1]]) for _ in range(3)]
    eff, chunks, bills = engine2._plan_chunks(group, shared, None)
    assert eff == 2
    assert len(chunks) == 1 and len(chunks[0]) == 3
    assert bills == [12]

    # disjoint lists: each request adds 12 fresh rows -> one per chunk
    disjoint = [_req(4, [[0, 1]]), _req(4, [[2, 3]]), _req(4, [[4, 5]])]
    eff, chunks, bills = engine2._plan_chunks(group, disjoint, None)
    assert len(chunks) == 3
    assert all(len(c) == 1 for c in chunks)
    assert bills == [12, 12, 12]
    assert engine2.stats.ivf_splits == 2  # two budget-induced splits

    # a single request alone over budget (12 > 10) still rides,
    # in its own chunk — there is nothing to split away from
    alone = [_req(4, [[0, 1]])]
    eff, chunks, bills = engine._plan_chunks(group, alone, None)
    assert len(chunks) == 1 and bills == [12]


def test_plan_chunks_bucket_floor(setup):
    """A budget split never cuts a chunk below the smallest bucket:
    the chunk would pad back up to the bucket anyway, so the split
    would add a dispatch without shrinking any gather.  Disjoint
    1-row requests therefore accrete to the 4-bucket before the
    budget bites, however far over it their bill runs."""
    engine = _engine(setup, row_budget=12)
    sizes = np.full(12, 6, dtype=np.int64)
    engine._live_list_sizes = lambda name, idx: sizes
    group = ("default", 2, 0, None, ())
    # 6 disjoint 1-row requests, 12 fresh rows each (bill 72 total):
    # chunks of 4 (the smallest bucket), never 1-row slivers
    reqs = [_req(1, [[2 * j, 2 * j + 1]]) for j in range(6)]
    eff, chunks, bills = engine._plan_chunks(group, reqs, None)
    assert [len(c) for c in chunks] == [4, 2]
    assert bills == [48, 24]

    # the budget-triggered early flush respects the same floor: a
    # group below the smallest bucket is never "budget"-flushed
    engine2 = _engine(setup, row_budget=1)
    name = engine2.index_names[0]
    g = (name, 2, 0, None, ())
    engine2.driven = True  # queue without flushing
    _, Qm = setup
    engine2.submit(Qm[:2], k=10, nprobe=2)
    assert not engine2._group_over_budget(g)  # 2 rows < bucket 4
    engine2.submit(Qm[2:4], k=10, nprobe=2)
    assert engine2._group_over_budget(g)  # 4 rows, bill >> 1
    engine2.driven = False
    engine2.flush()


def test_plan_chunks_degrades_on_prefix(setup):
    """Under pressure the bill is computed on the probe column prefix
    — the degraded rung reads fewer lists, so the same requests fit
    fewer chunks."""
    engine = _engine(setup, row_budget=12, nprobe_min=1)
    sizes = np.full(8, 6, dtype=np.int64)
    engine._live_list_sizes = lambda name, idx: sizes
    group = ("default", 2, 0, None, ())
    reqs = [_req(1, [[0, 1]]), _req(1, [[2, 3]])]
    eff, chunks, bills = engine._plan_chunks(group, reqs, 1.0)
    assert eff == 1  # ladder floor
    # prefix billing: each request now costs 6; union fits one chunk
    assert len(chunks) == 1
    assert bills == [12]


def test_effective_nprobe_ladder(setup):
    engine = _engine(setup, nprobe_min=2)
    # ladder from 8: [8, 4, 2]
    assert engine._effective_nprobe(8, 0.0) == 8
    assert engine._effective_nprobe(8, 0.2) == 8  # < 1/3
    assert engine._effective_nprobe(8, 0.5) == 4
    assert engine._effective_nprobe(8, 1.0) == 2
    assert engine._effective_nprobe(2, 1.0) == 2  # already at floor
    assert engine._effective_nprobe(1, 1.0) == 1  # below floor: as-is
    off = _engine(setup)  # nprobe_min unset: never degrade
    assert off._effective_nprobe(8, 1.0) == 8


def test_probe_order_lru(setup):
    """Single-row probes are served from a per-query LRU of full list
    orders: a repeat hit returns the same lists as the cold path, a
    smaller nprobe reads a prefix of the cached order, rebinding the
    index name invalidates its entries, and the cache stays bounded."""
    index, Qm = setup
    engine = _engine(setup, row_budget=N)
    name = engine.index_names[0]
    q = np.ascontiguousarray(Qm[:1])

    cold = engine._host_probe(name, index, q, 4)
    assert len(engine._probe_orders) == 1
    hot = engine._host_probe(name, index, q, 4)
    np.testing.assert_array_equal(cold, hot)
    assert len(engine._probe_orders) == 1  # a hit, not a new entry
    # the cache stores the FULL order, so any later nprobe is a prefix
    np.testing.assert_array_equal(
        engine._host_probe(name, index, q, 2), cold[:, :2]
    )
    # and it agrees with the uncached multi-row path
    multi = engine._host_probe(name, index, np.repeat(q, 2, axis=0), 4)
    np.testing.assert_array_equal(multi[0], cold[0])

    # rebinding a name drops its cached orders (new landmarks)
    engine.register(name, index)
    assert len(engine._probe_orders) == 0

    # bounded: at the cap, each insert evicts the least-recent entry
    for j in range(8192):
        engine._probe_orders[("other", j)] = np.arange(1, dtype=np.int32)
    engine._host_probe(name, index, q, 4)
    assert len(engine._probe_orders) == 8192
    assert ("other", 0) not in engine._probe_orders


def test_queue_pressure_gauge(setup):
    index, Qm = setup
    engine = QueryEngine(
        index, batch_buckets=(4, 8), max_wait_s=60.0,
        max_pending=16, pressure_age_s=1e9,
    )
    assert engine.queue_pressure() == 0.0
    engine.driven = True  # queue without flushing
    engine.submit(Qm[:8], k=10, nprobe=2)
    assert engine.queue_pressure() == pytest.approx(0.5)  # 8/16 rows
    # age term: shrink the horizon so the queued ticket is instantly old
    object.__setattr__(engine.config, "pressure_age_s", 1e-9)
    assert engine.queue_pressure() == 1.0
    snap = engine.stats.snapshot()
    assert snap["queue_pressure"] == 1.0
    engine.driven = False
    engine.flush()


def test_config_validation():
    with pytest.raises(ValueError, match="row_budget"):
        EngineConfig(row_budget=0)
    with pytest.raises(ValueError, match="nprobe_min"):
        EngineConfig(nprobe_min=0)
    with pytest.raises(ValueError, match="pressure_age_s"):
        EngineConfig(pressure_age_s=0.0)
    cfg = EngineConfig(row_budget=1, nprobe_min=1, pressure_age_s=0.1)
    assert cfg.row_budget == 1


def test_budget_flush_reason_and_telemetry(setup):
    """A group whose bill exceeds row_budget flushes at submit time
    with reason "budget" instead of waiting for the bucket; tickets
    carry the billed rows and effective nprobe."""
    index, Qm = setup
    engine = QueryEngine(
        index, batch_buckets=(2, 32), max_wait_s=60.0, row_budget=1,
    )
    t0 = engine.submit(Qm[:1], k=10, nprobe=2)
    t1 = engine.submit(Qm[1:2], k=10, nprobe=2)
    # row_budget=1 is always exceeded: the first submit can't trigger
    # (one row is below the smallest-bucket floor), the second fills
    # the 2-bucket and flushes the group with reason "budget"
    t0.result(timeout=30.0)
    t1.result(timeout=30.0)
    engine.flush()
    assert engine.stats.flushes["budget"] >= 1
    assert t0.stats.flush_reason in ("budget", "manual")
    assert t0.stats.scanned_rows > 0
    assert t0.stats.effective_nprobe == 2
    snap = engine.stats.snapshot()
    assert snap["ivf_cost"]["scanned_rows"] > 0
    assert snap["ivf_cost"]["rows_per_query"] > 0


def test_uncosted_paths_unaffected(setup):
    """Knobs off, or a flat backend, or full-scan nprobe: no probes
    are computed and the ivf_cost counters stay zero."""
    index, Qm = setup
    engine = QueryEngine(index, batch_buckets=(4, 8), max_wait_s=60.0)
    t = engine.submit(Qm[:2], k=10, nprobe=2)
    engine.flush()
    t.result()
    assert t.stats.scanned_rows == 0
    assert t.stats.effective_nprobe == 0
    snap = engine.stats.snapshot()
    assert snap["ivf_cost"]["scanned_rows"] == 0
    assert snap["ivf_cost"]["effective_nprobe"] == {}

    # nprobe >= nlist runs the dense path: cost model stays out even
    # with the budget armed
    costed = QueryEngine(
        index, batch_buckets=(4, 8), max_wait_s=60.0, row_budget=5,
    )
    t = costed.submit(Qm[:2], k=10, nprobe=NLIST)
    costed.flush()
    t.result()
    assert t.stats.scanned_rows == 0


def test_deadline_counted_per_ticket_at_chunk_resolve(setup):
    """A budget split resolves each chunk at its own time: a ticket
    whose chunk lands before its deadline is never marked missed just
    because a LATER chunk of the same flush ran long, a ticket whose
    chunk resolves late is, and each ticket is counted exactly once
    in ``stats.deadline_missed`` — never once per chunk."""
    index, Qm = setup
    engine = _engine(setup, row_budget=12)
    sizes = np.array([6] * NLIST, dtype=np.int64)
    engine._live_list_sizes = lambda name, idx: sizes

    # warm the (bucket 4, k 10, nprobe 2) trace so the first chunk's
    # resolve time is millisecond-scale, far inside its deadline
    t = engine.submit(Qm[:4], k=10, nprobe=2)
    engine.flush()
    t.result(timeout=60.0)

    # fabricate disjoint probe pairs (12 fresh rows per request, the
    # 12-row budget splits one request per chunk); probes only steer
    # billing/planning — scoring reprobes in-graph from the queries
    pairs = iter([[0, 1], [2, 3], [4, 5]])
    engine._host_probe = lambda name, idx, q, nprobe: np.tile(
        np.asarray(next(pairs), dtype=np.int32), (q.shape[0], 1)
    )
    # chunks run FIFO; stall every chunk after the first so the same
    # 0.6 s deadline lands differently chunk by chunk
    real_run = engine._run_batch
    ran = []

    def staggered(group, chunk, reason, **kw):
        if ran:
            time.sleep(1.0)
        ran.append(len(chunk))
        return real_run(group, chunk, reason, **kw)

    engine._run_batch = staggered

    engine.driven = True  # queue without flushing
    t0 = engine.submit(Qm[:4], k=10, nprobe=2, deadline_s=0.6)
    t1 = engine.submit(Qm[4:8], k=10, nprobe=2, deadline_s=0.6)
    t2 = engine.submit(Qm[8:12], k=10, nprobe=2, deadline_s=0.0)
    engine.driven = False
    before = engine.stats.deadline_missed
    engine.flush()
    for tk in (t0, t1, t2):
        tk.result(timeout=60.0)

    assert ran == [1, 1, 1]  # three budget chunks, one request each
    # chunk 0 resolved within t0's deadline; chunk 1 resolved past
    # the SAME deadline value; t2's deadline was already due
    assert not t0.stats.deadline_missed
    assert t1.stats.deadline_missed
    assert t2.stats.deadline_missed
    assert engine.stats.deadline_missed - before == 2
