"""ScanPlan: one scoring path for dense, gathered and sharded scans.

Covers: the masked-gather kernel family (scalar-prefetch DMA gather)
against its rowwise oracle on ragged candidate lists with pad ids;
exact equality of fused gather selection vs materialize-then-``top_k``;
the dynamic ``n_valid`` row masking of the dense selection kernel;
cross-path parity — sharded l2/cos/dot vs the flat fused scan (values,
ids, tie order) across 1/2/4-shard meshes and the gather plan vs the
retained rowwise reference scorers; and shard-local exact rerank end to
end (build -> save -> load -> engine).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import ASHConfig, prepare_queries
from repro.core import quantization as Q
from repro.core import scoring as S
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.index import common as C
from repro.index import distributed as DX
from repro.kernels import ops, ref
from repro.kernels.ash_score import (
    ash_score_gather_pallas,
    ash_score_gather_topk_pallas,
    ash_score_pallas,
    ash_score_topk_pallas,
)
from repro.serving.engine import QueryEngine

METRICS = ("dot", "l2", "cos")


def _mk_inputs(key, b, d, n, m, C_):
    """Synthetic packed codes + epilogue operands (no trained model)."""
    ks = jax.random.split(key, 8)
    vals = Q.quant(jax.random.normal(ks[0], (n, d)), b)
    codes = Q.pack_codes(vals, b)
    d_pad = codes.shape[1] * Q.codes_per_word(b)
    q = jnp.pad(jax.random.normal(ks[1], (m, d)), ((0, 0), (0, d_pad - d)))
    scale = jax.random.uniform(ks[2], (n,), minval=0.5, maxval=2.0)
    offset = jax.random.normal(ks[3], (n,))
    cluster = jax.random.randint(ks[4], (n,), 0, C_)
    ipq = jax.random.normal(ks[5], (m, C_))
    qterm = jax.random.uniform(ks[6], (m,), minval=0.1, maxval=3.0)
    rowterm = jax.random.uniform(ks[7], (n,), minval=0.1, maxval=3.0)
    return codes, q, scale, offset, cluster, ipq, qterm, rowterm


def _mk_rows(key, m, R, n, pad_frac=0.3):
    """Ragged candidate lists: random rows with ~pad_frac -1 pads."""
    k1, k2 = jax.random.split(key)
    rows = jax.random.randint(k1, (m, R), 0, n)
    pads = jax.random.uniform(k2, (m, R)) < pad_frac
    return jnp.where(pads, -1, rows).astype(jnp.int32)


# b sweep x ragged m/R/d (never block multiples)
CASES = [
    (1, 96, 300, 3, 4, 21),
    (2, 130, 513, 5, 16, 37),
    (4, 48, 257, 1, 8, 130),
    (8, 36, 140, 4, 2, 9),
]


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("b,d,n,m,C_,R", CASES)
def test_gather_kernel_vs_rowwise_oracle(metric, b, d, n, m, C_, R):
    """The scalar-prefetch DMA-gather kernel matches the rowwise oracle
    on ragged candidate lists; pad ids score exactly -inf."""
    codes, q, scale, offset, cluster, ipq, qterm, rowterm = _mk_inputs(
        jax.random.PRNGKey(b * 31 + d), b, d, n, m, C_
    )
    rows = _mk_rows(jax.random.PRNGKey(R), m, R, n)
    args = (codes, rows, q, scale, offset, cluster, ipq, qterm, rowterm)
    want = ref.ash_score_gather_ref(*args, b=b, metric=metric)
    got = ash_score_gather_pallas(
        *args, b=b, metric=metric, interpret=True,
        compute_dtype=jnp.float32, block_r=16, block_d=128,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4
    )
    assert np.all(np.isneginf(np.asarray(got))[np.asarray(rows) < 0])
    assert np.all(np.isneginf(np.asarray(want))[np.asarray(rows) < 0])


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("b,d,n,m,C_,R", CASES)
def test_gather_fused_topk_exact_vs_materialize(metric, b, d, n, m, C_, R):
    """Fused gather selection == top_k over the gather kernel's scores
    EXACTLY (values, mapped rows, tie order) for k <= k̃."""
    codes, q, scale, offset, cluster, ipq, qterm, rowterm = _mk_inputs(
        jax.random.PRNGKey(b * 7 + n), b, d, n, m, C_
    )
    rows = _mk_rows(jax.random.PRNGKey(R + 1), m, R, n)
    args = (codes, rows, q, scale, offset, cluster, ipq, qterm, rowterm)
    blocks = dict(block_r=16, block_d=128)
    scores = ash_score_gather_pallas(
        *args, b=b, metric=metric, interpret=True,
        compute_dtype=jnp.float32, **blocks,
    )
    for k in (1, 7, min(R, 32)):
        ws, wp = jax.lax.top_k(scores, k)
        wrows = jnp.take_along_axis(rows, wp, axis=1)
        gs, gr = ash_score_gather_topk_pallas(
            *args, b=b, k=k, metric=metric, interpret=True,
            compute_dtype=jnp.float32, **blocks,
        )
        assert np.array_equal(np.asarray(gs), np.asarray(ws)), (metric, k)
        assert np.array_equal(np.asarray(gr), np.asarray(wrows)), (metric, k)


def test_gather_topk_all_pad_row_returns_sentinels():
    """A query whose whole candidate list is padding gets score -inf /
    row -1 in every slot."""
    b, d, n, m, C_ = 2, 64, 200, 3, 4
    codes, q, scale, offset, cluster, ipq, qterm, rowterm = _mk_inputs(
        jax.random.PRNGKey(3), b, d, n, m, C_
    )
    rows = _mk_rows(jax.random.PRNGKey(4), m, 20, n)
    rows = rows.at[1, :].set(-1)
    args = (codes, rows, q, scale, offset, cluster, ipq, qterm, rowterm)
    gs, gr = ash_score_gather_topk_pallas(
        *args, b=b, k=5, metric="l2", interpret=True,
        compute_dtype=jnp.float32, block_r=16, block_d=128,
    )
    assert np.all(np.isneginf(np.asarray(gs)[1]))
    assert np.all(np.asarray(gr)[1] == -1)


def test_dense_topk_dynamic_n_valid_masks_rows():
    """The dense selection kernel's runtime n_valid masks rows exactly
    like materialize + mask + top_k (the sharded pad-row fold)."""
    b, d, n, m, C_ = 2, 64, 300, 4, 4
    codes, q, scale, offset, cluster, ipq, qterm, rowterm = _mk_inputs(
        jax.random.PRNGKey(5), b, d, n, m, C_
    )
    args = (codes, q, scale, offset, cluster, ipq, qterm, rowterm)
    blocks = dict(block_m=8, block_n=128, block_d=128)
    scores = ash_score_pallas(
        *args, b=b, metric="l2", interpret=True,
        compute_dtype=jnp.float32, **blocks,
    )
    for nv in (10, 129, 300):
        masked = jnp.where(jnp.arange(n)[None, :] < nv, scores, -jnp.inf)
        ws, wi = jax.lax.top_k(masked, 9)
        gs, gi = ash_score_topk_pallas(
            *args, jnp.int32(nv), b=b, k=9, metric="l2", interpret=True,
            compute_dtype=jnp.float32, **blocks,
        )
        assert np.array_equal(np.asarray(gs), np.asarray(ws)), nv
        # masked rows surface only as -inf; where both sides are -inf
        # the id conventions differ (sentinel vs masked row id), which
        # the sharded merge maps to -1 either way
        finite = np.isfinite(np.asarray(ws))
        assert np.array_equal(
            np.asarray(gi)[finite], np.asarray(wi)[finite]
        ), nv


# ---------------------------------------------------------------------------
# Index-layer routing on a real encoded payload
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def index_setup():
    key = jax.random.PRNGKey(21)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, 3000, 32)
    Qm = embedding_dataset(kq, 16, 32)
    cfg = ASHConfig(b=2, d=16, n_landmarks=8)
    model = AshIndex.build(kb, X, cfg, backend="flat").model
    return X, Qm, cfg, model, kb


@pytest.mark.parametrize("metric", METRICS)
def test_gather_plan_vs_rowwise_reference_scorers(index_setup, metric):
    """The gather plan's scores track the retained rowwise reference
    scorers (``scoring.score_*`` over a gathered sub-payload) to float
    assoc-order error — the pre-ScanPlan IVF partial-probe path."""
    X, Qm, cfg, model, kb = index_setup
    idx = AshIndex.build(kb, X, cfg, backend="ivf", metric=metric,
                         model=model)
    state = idx._state
    prep = idx.prepare(Qm)
    rows = _mk_rows(jax.random.PRNGKey(0), Qm.shape[0], 64, idx.n)
    got = ops.ash_score_gather(
        model, prep, state.payload, rows, metric=metric,
        stats=state.stats, use_pallas=False,
    )

    def rowwise_one(prep_q, rows_q):
        sub = C.gather_payload(state.payload, rows_q)
        one = jax.tree_util.tree_map(
            lambda a: a[None] if hasattr(a, "ndim") else a, prep_q
        )
        if metric == "dot":
            sc = S.score_dot(model, one, sub, rowwise=True)
        elif metric == "l2":
            sc = -S.score_l2(model, one, sub, rowwise=True)
        else:
            sc = S.score_cosine(model, one, sub, rowwise=True)
        return jnp.where(rows_q >= 0, sc[0], C.NEG_INF)

    want = jax.vmap(rowwise_one)(prep, rows)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=2e-3
    )


@pytest.mark.parametrize("metric", METRICS)
def test_ivf_partial_probe_fused_equals_materialized(index_setup, metric):
    """IVF partial probes route through the gather plan: the fused
    search result == top_k over the gather scores of the probed lists
    (values, ids, tie order) — no score-matrix path left behind."""
    X, Qm, cfg, model, kb = index_setup
    idx = AshIndex.build(kb, X, cfg, backend="ivf", metric=metric,
                         model=model)
    state = idx._state
    k, nprobe = 10, 3
    s, ids = idx.search(Qm, k=k, nprobe=nprobe)

    @jax.jit
    def materialized(state, prep):
        coarse = (
            prep.ip_q_landmarks
            - 0.5 * model.landmark_sq_norms[None, :]
        )
        _, probe = jax.lax.top_k(coarse, nprobe)
        rows = state.invlists[probe].reshape(prep.q.shape[0], -1)
        sc = ops.ash_score_gather(
            model, prep, state.payload, rows, metric=metric,
            stats=state.stats,
        )
        ws, wp = jax.lax.top_k(sc, k)
        wrows = jnp.take_along_axis(rows, wp, axis=1)
        return ws, jnp.where(
            wrows < 0, -1, state.ids[jnp.maximum(wrows, 0)]
        )

    ws, wids = materialized(state, idx.prepare(Qm))
    assert np.array_equal(np.asarray(s), np.asarray(ws))
    assert np.array_equal(np.asarray(ids), np.asarray(wids))


def test_ivf_partial_probe_single_row_matches_batch(index_setup):
    """Per-row bit-identity across batch shapes on the gather path —
    the invariant the serving engine's bucketing relies on."""
    X, Qm, cfg, model, kb = index_setup
    for metric in METRICS:
        idx = AshIndex.build(kb, X, cfg, backend="ivf", metric=metric,
                             model=model)
        sb, ib = idx.search(Qm, k=9, nprobe=3)
        s1, i1 = idx.search(Qm[5:6], k=9, nprobe=3)
        assert np.array_equal(np.asarray(s1), np.asarray(sb)[5:6]), metric
        assert np.array_equal(np.asarray(i1), np.asarray(ib)[5:6]), metric


# ---------------------------------------------------------------------------
# Cross-path parity: sharded vs flat over 1/2/4-shard meshes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n_shards", (1, 2, 4))
def test_sharded_fused_matches_flat_exactly(index_setup, metric, n_shards):
    """Sharded search == flat fused search bit-for-bit — values, ids
    AND tie order — for every metric and mesh width (the local scans
    run the same fused epilogues + fused local top-k, the merge
    preserves the global tie convention)."""
    X, Qm, cfg, model, kb = index_setup
    if n_shards > jax.device_count():
        pytest.skip("needs more devices")
    fi = AshIndex.build(kb, X, cfg, metric=metric, model=model)
    fs, fids = fi.search(Qm, k=20)
    mesh = Mesh(np.array(jax.devices()[:n_shards]), ("data",))
    si = AshIndex.build(
        kb, X, cfg, backend="sharded", metric=metric, model=model,
        mesh=mesh, axes=("data",),
    )
    ss, sids = si.search(Qm, k=20)
    assert np.array_equal(np.asarray(ss), np.asarray(fs))
    assert np.array_equal(np.asarray(sids), np.asarray(fids))


def test_sharded_fused_matches_reference_searcher(index_setup):
    """The fused sharded route == the retained reference route
    (fused=False: reference scorers + materialize-then-top_k) on the
    same mesh — identical ids, scores to float assoc-order error."""
    X, Qm, cfg, model, kb = index_setup
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    si = AshIndex.build(
        kb, X, cfg, backend="sharded", metric="cos", model=model,
        mesh=mesh, axes=("data",),
    )
    state = si._state
    prep = si.prepare(Qm)
    fused = state.searcher(10)(
        state.sharded, prep, stats=state.sharded_stats
    )
    reference = DX.make_sharded_search_prepped(
        mesh, model, ("data",), 10, metric="cos", fused=False
    )(state.sharded, prep)
    assert np.array_equal(np.asarray(fused[1]), np.asarray(reference[1]))
    np.testing.assert_allclose(
        np.asarray(fused[0]), np.asarray(reference[0]),
        rtol=1e-4, atol=2e-3,
    )


def test_sharded_padded_mesh_parity(index_setup):
    """A row count that does NOT divide the mesh exercises the pad
    sentinel + derived n_valid mask: results still match flat."""
    X, Qm, cfg, model, kb = index_setup
    X_odd = X[:2999]  # 2999 rows over 4 shards -> 1 pad row
    fi = AshIndex.build(kb, X_odd, cfg, metric="l2", model=model)
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    si = AshIndex.build(
        kb, X_odd, cfg, backend="sharded", metric="l2", model=model,
        mesh=mesh, axes=("data",),
    )
    fs, fids = fi.search(Qm, k=15)
    ss, sids = si.search(Qm, k=15)
    assert np.array_equal(np.asarray(ss), np.asarray(fs))
    assert np.array_equal(np.asarray(sids), np.asarray(fids))
    assert int(np.asarray(sids).max()) < 2999


# ---------------------------------------------------------------------------
# Shard-local rerank end to end
# ---------------------------------------------------------------------------


def test_sharded_rerank_build_save_load_engine(index_setup, tmp_path):
    """The acceptance path: sharded rerank works end-to-end (build ->
    save -> load -> engine) and the engine serves it bit-identically."""
    X, Qm, cfg, model, kb = index_setup
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    idx = AshIndex.build(
        kb, X, cfg, backend="sharded", metric="cos", model=model,
        keep_raw=True, mesh=mesh, axes=("data",),
    )
    s1, i1 = idx.search(Qm, k=10, rerank=60)
    assert np.all(np.asarray(i1) >= 0)
    idx.save(tmp_path / "sharded")
    idx2 = AshIndex.load(tmp_path / "sharded")
    s2, i2 = idx2.search(Qm, k=10, rerank=60)
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    eng = QueryEngine(idx2, batch_buckets=(8, 16), k_buckets=(16,),
                      max_wait_s=60.0)
    t = eng.submit(np.asarray(Qm[:5]), k=10, rerank=60)
    eng.flush()
    es, ei = t.result()
    assert np.array_equal(es, np.asarray(s2)[:5])
    assert np.array_equal(ei, np.asarray(i2)[:5])


def test_sharded_add_keeps_raw_and_stats(index_setup):
    """add() re-places raw shards + stats; results match a fresh build
    over the concatenated rows."""
    X, Qm, cfg, model, kb = index_setup
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    kw = dict(backend="sharded", metric="l2", model=model,
              keep_raw=True, mesh=mesh, axes=("data",))
    a = AshIndex.build(kb, X[:2000], cfg, **kw)
    a.add(X[2000:])
    b = AshIndex.build(kb, X, cfg, **kw)
    sa, ia = a.search(Qm, k=10, rerank=50)
    sb, ib = b.search(Qm, k=10, rerank=50)
    assert np.array_equal(np.asarray(sa), np.asarray(sb))
    assert np.array_equal(np.asarray(ia), np.asarray(ib))


def test_pad_sentinel_never_reaches_list_assembly(index_setup):
    """The -1 pad sentinel is rejected where cluster ids feed gathers
    (IVF list assembly) — it would silently alias by wrapping."""
    X, Qm, cfg, model, kb = index_setup
    from repro.index import ivf as IV

    fi = AshIndex.build(kb, X[:100], cfg, metric="dot", model=model)
    padded = DX.pad_to_multiple(fi.payload, 64)
    assert int(np.asarray(padded.cluster)[-1]) == DX.PAD_CLUSTER
    ids = jnp.arange(padded.n, dtype=jnp.int32)
    with pytest.raises(ValueError, match="pad-sentinel"):
        IV._assemble("dot", model, padded, ids, None)


# ---------------------------------------------------------------------------
# ScanPlan validation + selection-cap fallback
# ---------------------------------------------------------------------------


def test_plan_rejects_unknown_coarse_mode(index_setup):
    X, Qm, cfg, model, kb = index_setup
    idx = AshIndex.build(kb, X, cfg, model=model)
    with pytest.raises(ValueError, match="unknown coarse mode"):
        idx.search(Qm, k=5, coarse="fp8")


def test_plan_rejects_shortlist_without_coarse(index_setup):
    X, Qm, cfg, model, kb = index_setup
    idx = AshIndex.build(kb, X, cfg, model=model)
    with pytest.raises(ValueError, match="requires"):
        idx.search(Qm, k=5, shortlist=64)


def test_plan_rejects_row_masks_on_gathered_plan(index_setup):
    """Gathered plans mask by pad id only: row_valid / n_valid are
    dense-plan concepts and must fail loudly, not no-op (a silently
    ignored tombstone bitmap would resurrect deleted rows)."""
    X, Qm, cfg, model, kb = index_setup
    idx = AshIndex.build(kb, X, cfg, model=model)
    st = idx._state
    prep = idx.prepare(Qm)
    rows = _mk_rows(jax.random.PRNGKey(1), Qm.shape[0], 32, idx.n)
    for bad in (
        {"row_valid": jnp.ones((idx.n,), bool)},
        {"n_valid": jnp.int32(10)},
    ):
        plan = C.ScanPlan(metric="dot", k=5, rows=rows, **bad)
        with pytest.raises(ValueError, match="dense plans only"):
            C.execute_plan(model, prep, st.payload, plan,
                           stats=st.stats)


def test_sharded_rerank_without_raw_raises(index_setup):
    """rerank > 0 without retained raw vectors is a loud error on the
    sharded backend — never a silent fall-back to ASH scores."""
    X, Qm, cfg, model, kb = index_setup
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    si = AshIndex.build(kb, X, cfg, backend="sharded", model=model,
                        mesh=mesh, axes=("data",))
    with pytest.raises(ValueError, match="keep_raw"):
        si.search(Qm, k=5, rerank=50)


def test_topk_beyond_fused_cap_falls_back(index_setup):
    """k above fused_topk_limit() routes to materialize-then-top_k and
    returns exactly top_k of the materialized scores — the routing
    boundary is invisible."""
    X, Qm, cfg, model, kb = index_setup
    idx = AshIndex.build(kb, X, cfg, model=model)
    k = C.fused_topk_limit() + 22
    s, ids = idx.search(Qm, k=k)
    st = idx._state
    want = jax.lax.top_k(
        C.approx_scores(model, idx.prepare(Qm), st.payload, "dot",
                        stats=st.stats),
        k,
    )
    assert np.array_equal(np.asarray(s), np.asarray(want[0]))
    assert np.array_equal(np.asarray(ids), np.asarray(want[1]))
