"""End-to-end ASH core behaviour: learning, encode/decode, scoring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ASHConfig, train, encode, decode, random_model,
    prepare_queries, score_dot, score_dot_1bit, score_l2, score_cosine,
    score_symmetric_dot,
)
from repro.core import scoring as S
from repro.core.ash import reconstruction_error
from repro.data.synthetic import embedding_dataset


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(11)
    kx, kq = jax.random.split(key)
    X = embedding_dataset(kx, 3000, 64)
    Qm = embedding_dataset(kq, 16, 64)
    return X, Qm


def test_w_row_orthonormal(data):
    X, _ = data
    model, _ = train(jax.random.PRNGKey(0), X,
                     ASHConfig(b=2, d=32, n_landmarks=4))
    WWt = model.W @ model.W.T
    np.testing.assert_allclose(
        np.asarray(WWt), np.eye(32), atol=1e-5
    )


def test_itq_loss_decreases(data):
    X, _ = data
    _, hist = train(jax.random.PRNGKey(0), X,
                    ASHConfig(b=1, d=64, n_landmarks=1))
    assert len(hist) >= 2
    assert hist[-1] <= hist[0] + 1e-6


def test_learned_beats_random_projection(data):
    """Paper Fig. 1: learned W beats Johnson-Lindenstrauss at d < D."""
    X, _ = data
    cfg = ASHConfig(b=2, d=32, n_landmarks=1)
    learned, _ = train(jax.random.PRNGKey(0), X, cfg)
    rnd = random_model(jax.random.PRNGKey(0), 64, cfg, X_for_landmarks=X)
    assert float(reconstruction_error(learned, X)) < float(
        reconstruction_error(rnd, X)
    )


def test_reduce_dim_higher_bits_wins(data):
    """Paper key insight: at iso-B, b=2 d=D/2 beats b=1 d=D (learned)."""
    X, _ = data
    m1, _ = train(jax.random.PRNGKey(0), X, ASHConfig(b=1, d=64, n_landmarks=1))
    m2, _ = train(jax.random.PRNGKey(0), X, ASHConfig(b=2, d=32, n_landmarks=1))
    e1 = float(reconstruction_error(m1, X))
    e2 = float(reconstruction_error(m2, X))
    assert e2 < e1, (e1, e2)


def test_encode_decode_roundtrip(data):
    X, _ = data
    cfg = ASHConfig(b=4, d=48, n_landmarks=8, store_fp16=False)
    model, _ = train(jax.random.PRNGKey(1), X, cfg)
    pay = encode(model, X)
    Xhat = decode(model, pay)
    rel = float(jnp.linalg.norm(Xhat - X) / jnp.linalg.norm(X))
    assert rel < 0.35, rel
    # higher bitrate must reconstruct better at same d
    cfg2 = ASHConfig(b=8, d=48, n_landmarks=8, store_fp16=False)
    model2, _ = train(jax.random.PRNGKey(1), X, cfg2)
    rel2 = float(jnp.linalg.norm(decode(model2, encode(model2, X)) - X)
                 / jnp.linalg.norm(X))
    assert rel2 < rel


def test_recovered_terms_match_truth(data):
    """Table-1 recovery: ||x-mu*|| and <x,mu*> from scale/offset."""
    X, _ = data
    cfg = ASHConfig(b=4, d=64, n_landmarks=4, store_fp16=False)
    model, _ = train(jax.random.PRNGKey(2), X, cfg)
    pay = encode(model, X)
    _, _, res_norm, ip_x_mu = S.recovered_terms(model, pay)
    mu = model.landmarks[pay.cluster]
    true_norm = jnp.linalg.norm(X - mu, axis=-1)
    true_ip = jnp.sum(X * mu, axis=-1)
    np.testing.assert_allclose(
        np.asarray(res_norm), np.asarray(true_norm), rtol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(ip_x_mu), np.asarray(true_ip),
        rtol=1e-3, atol=1e-2,
    )


def test_score_dot_accuracy(data):
    X, Qm = data
    cfg = ASHConfig(b=4, d=48, n_landmarks=8, store_fp16=False)
    model, _ = train(jax.random.PRNGKey(3), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    est = score_dot(model, prep, pay)
    true = Qm @ X.T
    corr = float(jnp.corrcoef(est.ravel(), true.ravel())[0, 1])
    assert corr > 0.99, corr


def test_1bit_specialization_matches_general(data):
    X, Qm = data
    cfg = ASHConfig(b=1, d=64, n_landmarks=4, store_fp16=False)
    model, _ = train(jax.random.PRNGKey(4), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    a = score_dot(model, prep, pay)
    bb = score_dot_1bit(model, prep, pay)
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(bb), rtol=1e-3, atol=1e-3
    )


def test_l2_and_cosine_orderings(data):
    X, Qm = data
    cfg = ASHConfig(b=4, d=48, n_landmarks=8, store_fp16=False)
    model, _ = train(jax.random.PRNGKey(5), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    l2 = score_l2(model, prep, pay)
    true_l2 = jnp.sum((Qm[:, None] - X[None]) ** 2, axis=-1)
    assert float(jnp.corrcoef(l2.ravel(), true_l2.ravel())[0, 1]) > 0.99
    cos = score_cosine(model, prep, pay)
    true_cos = (Qm @ X.T) / (
        jnp.linalg.norm(Qm, axis=1)[:, None]
        * jnp.linalg.norm(X, axis=1)[None, :]
    )
    assert float(jnp.corrcoef(cos.ravel(), true_cos.ravel())[0, 1]) > 0.98


def test_symmetric_scoring(data):
    """Appendix B: symmetric dot products between encoded sets (C=1)."""
    X, _ = data
    cfg = ASHConfig(b=4, d=64, n_landmarks=1, store_fp16=False)
    model, _ = train(jax.random.PRNGKey(6), X, cfg)
    pa = encode(model, X[:128])
    pb = encode(model, X[128:256])
    est = score_symmetric_dot(model, pa, pb)
    true = X[:128] @ X[128:256].T
    corr = float(jnp.corrcoef(est.ravel(), true.ravel())[0, 1])
    assert corr > 0.97, corr


def test_bias_fit_and_debias(data):
    X, Qm = data
    cfg = ASHConfig(b=1, d=64, n_landmarks=1, store_fp16=False)
    model, _ = train(jax.random.PRNGKey(7), X, cfg)
    pay = encode(model, X)
    model2 = S.fit_bias(model, pay, X, Qm, sample=16)
    # rho should be near but not exactly 1 (paper Fig. 4)
    assert 0.5 < float(model2.bias_rho) < 2.0
    prep = prepare_queries(model2, Qm)
    est = S.debias(model2, score_dot(model2, prep, pay))
    true = Qm @ X.T
    # debiased slope ~1
    A = jnp.stack([true.ravel(), jnp.ones_like(true.ravel())], 1)
    coef, *_ = jnp.linalg.lstsq(A, est.ravel(), rcond=None)
    assert abs(float(coef[0]) - 1.0) < 0.15


def test_more_landmarks_help(data):
    """Paper Fig. 3: search accuracy improves with the landmark count
    (the paper's claim is about recall; the per-vector reconstruction
    error of the NORMALIZED residual is not monotone in C)."""
    X, Qm = data
    from repro.index import metrics as MET

    gt = MET.exact_topk(Qm, X, k=10)[1]
    recalls = []
    for C in (1, 64):
        cfg = ASHConfig(b=1, d=32, n_landmarks=C)
        model, _ = train(jax.random.PRNGKey(8), X, cfg)
        pay = encode(model, X)
        prep = prepare_queries(model, Qm)
        ids = jax.lax.top_k(score_dot(model, prep, pay), 50)[1]
        recalls.append(float(MET.recall_at(ids, gt)))
    assert recalls[1] >= recalls[0] - 0.02, recalls


def test_payload_bits_formula():
    cfg = ASHConfig(b=2, d=128, n_landmarks=64)
    # 2*16 header + log2(64)=6 + 256 code bits
    assert cfg.payload_bits() == 32 + 6 + 256


def test_rabitq_expected_dot():
    from repro.baselines.rabitq import expected_dot_1bit

    v = float(expected_dot_1bit(1000))
    assert abs(v - 0.798) < 2e-3  # paper: ~0.798 for D ~ 1000
