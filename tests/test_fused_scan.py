"""Fused all-metric scoring + on-chip top-k selection (tentpole of the
metric/selection kernel family).

Covers: l2/cos epilogue parity vs the jnp oracles across bitrates and
ragged (non-block-multiple) shapes in interpret mode; exact equality of
the fused-selection kernel against the materialize-then-``top_k``
oracle (values, ids AND tie order) for every k <= k̃; NEG_INF /
padded-row masking; the k̃ < k recall mode; and the index-layer routing
(flat fused path, IVF full-probe full scan, stats save/load).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ASHConfig, encode, payload_stats, prepare_queries, train,
)
from repro.core import scoring as S
from repro.core import quantization as Q
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.index import common as C
from repro.kernels import ops, ref
from repro.kernels.ash_score import ash_score_pallas, ash_score_topk_pallas

METRICS = ("dot", "l2", "cos")


def _mk_inputs(key, b, d, n, m, C_):
    """Synthetic packed codes + epilogue operands (no trained model)."""
    ks = jax.random.split(key, 8)
    vals = Q.quant(jax.random.normal(ks[0], (n, d)), b)
    codes = Q.pack_codes(vals, b)
    d_pad = codes.shape[1] * Q.codes_per_word(b)
    q = jnp.pad(jax.random.normal(ks[1], (m, d)), ((0, 0), (0, d_pad - d)))
    scale = jax.random.uniform(ks[2], (n,), minval=0.5, maxval=2.0)
    offset = jax.random.normal(ks[3], (n,))
    cluster = jax.random.randint(ks[4], (n,), 0, C_)
    ipq = jax.random.normal(ks[5], (m, C_))
    qterm = jax.random.uniform(ks[6], (m,), minval=0.1, maxval=3.0)
    rowterm = jax.random.uniform(ks[7], (n,), minval=0.1, maxval=3.0)
    return codes, q, scale, offset, cluster, ipq, qterm, rowterm


# b sweep x ragged m/n/d (never block multiples) per the brief
CASES = [
    (1, 96, 300, 3, 4),
    (2, 130, 513, 9, 16),
    (4, 48, 257, 1, 8),
    (8, 36, 140, 5, 2),
]


@pytest.mark.parametrize("metric", ("l2", "cos"))
@pytest.mark.parametrize("b,d,n,m,C_", CASES)
def test_metric_epilogue_kernel_vs_oracle(metric, b, d, n, m, C_):
    args = _mk_inputs(jax.random.PRNGKey(b * 31 + d), b, d, n, m, C_)
    want = ref.ash_score_metric_ref(*args, b=b, metric=metric)
    got = ash_score_pallas(
        *args, b=b, metric=metric, interpret=True,
        compute_dtype=jnp.float32,
        block_m=8, block_n=128, block_d=128,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4
    )


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("b,d,n,m,C_", CASES)
def test_fused_topk_exact_vs_materialize(metric, b, d, n, m, C_):
    """Fused selection == materialize + lax.top_k EXACTLY (values, ids,
    tie order) for k <= k̃, on multi-tile ragged grids."""
    args = _mk_inputs(jax.random.PRNGKey(b * 7 + n), b, d, n, m, C_)
    blocks = dict(block_m=8, block_n=128, block_d=128)
    scores = ash_score_pallas(
        *args, b=b, metric=metric, interpret=True,
        compute_dtype=jnp.float32, **blocks,
    )
    for k in (1, 7, 128):
        k = min(k, n)
        ws, wi = jax.lax.top_k(scores, k)
        gs, gi = ash_score_topk_pallas(
            *args, b=b, k=k, metric=metric, interpret=True,
            compute_dtype=jnp.float32, **blocks,
        )
        assert np.array_equal(np.asarray(gs), np.asarray(ws)), (metric, k)
        assert np.array_equal(np.asarray(gi), np.asarray(wi)), (metric, k)


def test_fused_topk_neg_inf_rows_and_padding():
    """Rows carrying -inf scores keep lax.top_k's tie order (ascending
    id), block-padding columns never surface, and fully exhausted
    candidate strips pad with score -inf / id -1."""
    b, d, n, m, C_ = 2, 64, 200, 4, 4
    codes, q, scale, offset, cluster, ipq, qterm, rowterm = _mk_inputs(
        jax.random.PRNGKey(5), b, d, n, m, C_
    )
    # dot-metric sentinel convention: offset = -inf silences a row
    offset = offset.at[50:].set(-jnp.inf)  # 150 dead rows
    args = (codes, q, scale, offset, cluster, ipq, qterm, rowterm)
    blocks = dict(block_m=8, block_n=128, block_d=128)
    scores = ash_score_pallas(
        *args, b=b, metric="dot", interpret=True,
        compute_dtype=jnp.float32, **blocks,
    )
    k = 80  # deep enough that -inf rows enter the result
    ws, wi = jax.lax.top_k(scores, k)
    gs, gi = ash_score_topk_pallas(
        *args, b=b, k=k, metric="dot", interpret=True,
        compute_dtype=jnp.float32, **blocks,
    )
    assert np.array_equal(np.asarray(gs), np.asarray(ws))
    assert np.array_equal(np.asarray(gi), np.asarray(wi))
    assert int(np.asarray(gi).max()) < n  # padding cols never returned
    # k̃ smaller than the per-tile -inf population: tiles emit k̃ = 8
    # candidates each (2 tiles), so k = 16 is still exactly covered but
    # the sentinel -1 shows up when k exceeds what the strip holds
    gs2, gi2 = ash_score_topk_pallas(
        *args, b=b, k=16, k_tilde=8, metric="dot", interpret=True,
        compute_dtype=jnp.float32, **blocks,
    )
    assert np.asarray(gs2).shape == (m, 16)
    valid = np.asarray(gi2) >= 0
    assert valid[:, :8].all()  # k <= k̃ prefix is the exact top-8
    assert np.array_equal(np.asarray(gs2)[:, :8], np.asarray(ws)[:, :8])
    assert np.array_equal(np.asarray(gi2)[:, :8], np.asarray(wi)[:, :8])


def test_fused_topk_recall_mode_is_valid_subset():
    """k̃ < k trades exactness for VMEM: results must still be real
    (score, id) pairs without duplicates, drawn from the true scores."""
    b, d, n, m, C_ = 2, 64, 513, 3, 8
    args = _mk_inputs(jax.random.PRNGKey(9), b, d, n, m, C_)
    blocks = dict(block_m=8, block_n=128, block_d=128)
    scores = np.asarray(ash_score_pallas(
        *args, b=b, metric="dot", interpret=True,
        compute_dtype=jnp.float32, **blocks,
    ))
    gs, gi = ash_score_topk_pallas(
        *args, b=b, k=24, k_tilde=8, metric="dot", interpret=True,
        compute_dtype=jnp.float32, **blocks,
    )
    gs, gi = np.asarray(gs), np.asarray(gi)
    for r in range(m):
        ids = gi[r][gi[r] >= 0]
        assert len(set(ids.tolist())) == len(ids)  # no duplicates
        np.testing.assert_array_equal(gs[r][: len(ids)], scores[r][ids])


def test_topk_k_exceeding_candidate_strip_raises():
    b, d, n, m, C_ = 2, 64, 120, 2, 2
    args = _mk_inputs(jax.random.PRNGKey(2), b, d, n, m, C_)
    with pytest.raises(ValueError, match="candidate strip"):
        ash_score_topk_pallas(
            *args, b=b, k=64, k_tilde=8, metric="dot", interpret=True,
            block_m=8, block_n=128, block_d=128,
        )


# ---------------------------------------------------------------------------
# ops wrappers on a real encoded payload
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def payload_setup():
    key = jax.random.PRNGKey(11)
    X = embedding_dataset(key, 2000, 48)
    Qm = embedding_dataset(jax.random.PRNGKey(12), 7, 48)
    model, _ = train(key, X, ASHConfig(b=2, d=24, n_landmarks=8))
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    stats = payload_stats(model, pay)
    return model, pay, prep, stats


@pytest.mark.parametrize("metric", METRICS)
def test_ops_metric_oracle_tracks_reference_scorers(payload_setup, metric):
    """The epilogue-form oracle approximates the reference scorers to
    float assoc-order error (same math, different grouping)."""
    model, pay, prep, stats = payload_setup
    ref_scores = {
        "dot": lambda: S.score_dot(model, prep, pay),
        "l2": lambda: -S.score_l2(model, prep, pay),
        "cos": lambda: S.score_cosine(model, prep, pay),
    }[metric]()
    got = ops.ash_score(
        model, prep, pay, metric=metric, stats=stats, use_pallas=False
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref_scores), rtol=1e-4, atol=2e-3
    )


@pytest.mark.parametrize("metric", METRICS)
def test_ops_topk_interpret_matches_oracle_routing(payload_setup, metric):
    """ops.ash_score_topk in interpret mode == top_k of the interpreted
    metric kernel (the acceptance-criterion oracle), k up to the cap."""
    model, pay, prep, stats = payload_setup
    scores = ops.ash_score(
        model, prep, pay, metric=metric, stats=stats,
        use_pallas=True, interpret=True,
    )
    for k in (1, 10, ops.FUSED_TOPK_MAX_K):
        ws, wi = jax.lax.top_k(scores, k)
        gs, gi = ops.ash_score_topk(
            model, prep, pay, k, metric=metric, stats=stats,
            use_pallas=True, interpret=True,
        )
        assert np.array_equal(np.asarray(gs), np.asarray(ws)), (metric, k)
        assert np.array_equal(np.asarray(gi), np.asarray(wi)), (metric, k)


def test_stats_on_the_fly_matches_prebuilt(payload_setup):
    """stats=None rebuilds ASHStats in-call — same scores bit-for-bit."""
    model, pay, prep, stats = payload_setup
    a = ops.ash_score(
        model, prep, pay, metric="cos", stats=stats, use_pallas=False
    )
    b = ops.ash_score(
        model, prep, pay, metric="cos", stats=None, use_pallas=False
    )
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Index-layer routing
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def index_setup():
    key = jax.random.PRNGKey(21)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, 3000, 32)
    Qm = embedding_dataset(kq, 16, 32)
    cfg = ASHConfig(b=2, d=16, n_landmarks=8)
    model = AshIndex.build(kb, X, cfg, backend="flat").model
    return X, Qm, cfg, model, kb


@pytest.mark.parametrize("metric", METRICS)
def test_flat_fused_selection_equals_materialized_topk(index_setup, metric):
    """The flat fused-selection route == top_k over the fused scores
    (the routing boundary at k > FUSED_TOPK_MAX_K is invisible)."""
    X, Qm, cfg, model, kb = index_setup
    idx = AshIndex.build(kb, X, cfg, metric=metric, model=model)
    k = 20
    s, i = idx.search(Qm, k=k)

    # the oracle must be jitted as one program with the same argument
    # structure as _search_prepped (closure constants vs jit arguments
    # change XLA fusion, hence last-ulp score bits)
    @jax.jit
    def materialized(index, prep):
        scores = C.approx_scores(
            index.model, prep, index.payload, metric,
            use_pallas=None, stats=index.stats,
        )
        return jax.lax.top_k(scores, k)

    ws, wi = materialized(idx._state, idx.prepare(Qm))
    assert np.array_equal(np.asarray(s), np.asarray(ws))
    assert np.array_equal(np.asarray(i), np.asarray(wi))
    # beyond the fused-selection cap the materialize fallback serves
    # identical prefixes
    big_k = min(C.fused_topk_limit() + 50, idx.n)
    s2, i2 = idx.search(Qm, k=big_k)
    assert np.array_equal(np.asarray(s2)[:, :k], np.asarray(s))
    assert np.array_equal(np.asarray(i2)[:, :k], np.asarray(i))


@pytest.mark.parametrize("metric", METRICS)
def test_ivf_full_probe_routes_to_full_scan(index_setup, metric):
    """nprobe >= nlist runs the fused dense scan: same candidates as
    the flat backend (identical per-row scores, ids mapped back)."""
    X, Qm, cfg, model, kb = index_setup
    fi = AshIndex.build(kb, X, cfg, metric=metric, model=model)
    ii = AshIndex.build(kb, X, cfg, backend="ivf", metric=metric,
                        model=model)
    fs, fids = fi.search(Qm, k=15)
    is_, iids = ii.search(Qm, k=15, nprobe=cfg.n_landmarks)
    assert np.array_equal(np.sort(np.asarray(fids), 1),
                          np.sort(np.asarray(iids), 1))
    np.testing.assert_allclose(
        np.sort(np.asarray(fs), 1), np.sort(np.asarray(is_), 1),
        rtol=1e-5, atol=1e-5,
    )
    # over-large nprobe normalizes onto the same path/trace
    s2, i2 = ii.search(Qm, k=15, nprobe=10_000)
    assert np.array_equal(np.asarray(i2), np.asarray(iids))


def test_flat_single_row_matches_batch_rows(index_setup):
    """Per-row bit-identity across batch shapes on the fused path — the
    invariant the serving engine's bucketing relies on."""
    X, Qm, cfg, model, kb = index_setup
    for metric in METRICS:
        idx = AshIndex.build(kb, X, cfg, metric=metric, model=model)
        sb, ib = idx.search(Qm, k=9)
        s1, i1 = idx.search(Qm[3:4], k=9)
        assert np.array_equal(np.asarray(s1), np.asarray(sb)[3:4]), metric
        assert np.array_equal(np.asarray(i1), np.asarray(ib)[3:4]), metric


@pytest.mark.parametrize("backend", ("flat", "ivf"))
def test_stats_save_load_bit_identity(index_setup, backend, tmp_path):
    """ASHStats survives persistence bit-for-bit, and loading a
    pre-stats save (no stats.* arrays) rebuilds identical values."""
    X, Qm, cfg, model, kb = index_setup
    idx = AshIndex.build(kb, X, cfg, backend=backend, metric="cos",
                         model=model)
    assert idx.stats is not None and idx.stats.n == idx.n
    path = tmp_path / backend
    idx.save(path)
    idx2 = AshIndex.load(path)
    for f in ("res_norm", "ip_x_mu", "x_sq"):
        assert np.array_equal(
            np.asarray(getattr(idx.stats, f)),
            np.asarray(getattr(idx2.stats, f)),
        ), f
    s1, i1 = idx.search(Qm, k=10)
    s2, i2 = idx2.search(Qm, k=10)
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    assert np.array_equal(np.asarray(i1), np.asarray(i2))

    # simulate a pre-stats save: strip the stats arrays and reload
    import numpy as onp
    with onp.load(path / "arrays.npz") as npz:
        arrays = {k: npz[k] for k in npz.files if not k.startswith("stats.")}
    onp.savez(path / "arrays.npz", **arrays)
    import json
    meta = json.loads((path / "config.json").read_text())
    meta["dtypes"] = {
        k: v for k, v in meta["dtypes"].items()
        if not k.startswith("stats.")
    }
    # a genuine pre-stats save predates the checksum manifest too
    meta["checksums"] = {
        k: v for k, v in meta.get("checksums", {}).items()
        if not k.startswith("stats.")
    }
    (path / "config.json").write_text(json.dumps(meta))
    idx3 = AshIndex.load(path)
    assert idx3.stats is not None
    s3, i3 = idx3.search(Qm, k=10)
    assert np.array_equal(np.asarray(s1), np.asarray(s3))
    assert np.array_equal(np.asarray(i1), np.asarray(i3))


def test_flat_add_extends_stats(index_setup):
    """add() concatenates stats == a from-scratch build's stats."""
    X, Qm, cfg, model, kb = index_setup
    a = AshIndex.build(kb, X[:2000], cfg, metric="l2", model=model)
    a.add(X[2000:])
    b = AshIndex.build(kb, X, cfg, metric="l2", model=model)
    for f in ("res_norm", "ip_x_mu", "x_sq"):
        assert np.array_equal(
            np.asarray(getattr(a.stats, f)),
            np.asarray(getattr(b.stats, f)),
        ), f
