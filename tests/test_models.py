"""Model-zoo unit behaviour beyond the arch smoke tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import common as cm
from repro.models.moe import MoEConfig, init_moe, moe_block
from repro.models.transformer import (
    TransformerConfig, decode_step, forward, init_cache, init_params,
)


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 6, 2, 8))
    pos = jnp.arange(6)[None]
    y = cm.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5,
    )
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(key, (8,))
    k = jax.random.normal(jax.random.PRNGKey(1), (8,))

    def dot_at(m, n):
        qm = cm.apply_rope(q[None, None, None, :], jnp.array([[m]]), 1e4)
        kn = cm.apply_rope(k[None, None, None, :], jnp.array([[n]]), 1e4)
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


def test_gqa_chunked_matches_unchunked():
    key = jax.random.PRNGKey(2)
    B, S, H, KV, dh = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(3), (B, S, KV, dh))
    v = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, dh))
    full = cm.gqa_attention(q, k, v, causal=True, q_chunk=0)
    chunked = cm.gqa_attention(q, k, v, causal=True, q_chunk=16)
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(chunked), rtol=2e-3, atol=2e-3
    )


def test_embedding_bag_combiners():
    table = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    idx = jnp.array([0, 1, 2, 3])
    seg = jnp.array([0, 0, 1, 1])
    s = cm.embedding_bag(table, idx, seg, num_bags=3, combiner="sum")
    np.testing.assert_allclose(
        np.asarray(s),
        [[table[0, 0] + table[1, 0], table[0, 1] + table[1, 1]],
         [table[2, 0] + table[3, 0], table[2, 1] + table[3, 1]],
         [0.0, 0.0]],
    )
    m = cm.embedding_bag(table, idx, seg, num_bags=3, combiner="mean")
    np.testing.assert_allclose(np.asarray(m[0]), np.asarray(s[0]) / 2)


def test_moe_routing_mass_and_dropping():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16,
                    capacity_factor=10.0, group_size=32)
    params = init_moe(jax.random.PRNGKey(0), cfg, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    out, aux = moe_block(params, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 0
    # generous capacity: no drops -> output invariant to token order
    perm = jax.random.permutation(jax.random.PRNGKey(2), 32)
    out_p, _ = moe_block(params, x[perm], cfg)
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out[perm]), rtol=2e-4, atol=2e-4
    )


def test_moe_capacity_drops_reduce_output():
    cfg_hi = MoEConfig(n_experts=2, top_k=2, d_ff=8,
                       capacity_factor=10.0, group_size=16)
    cfg_lo = MoEConfig(n_experts=2, top_k=2, d_ff=8,
                       capacity_factor=0.25, group_size=16)
    params = init_moe(jax.random.PRNGKey(0), cfg_hi, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    hi, _ = moe_block(params, x, cfg_hi)
    lo, _ = moe_block(params, x, cfg_lo)
    # tight capacity zeroes some tokens' contributions
    assert float(jnp.linalg.norm(lo)) < float(jnp.linalg.norm(hi))


def test_softmax_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 9))
    labels = jnp.array([1, 3, 0, 8])
    got = cm.softmax_cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits)
    want = -jnp.mean(p[jnp.arange(4), labels])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_ashkv_cache_smaller_and_accurate():
    """ASH-KV decode: cache bytes shrink ~8x at b=4,dc=dh/2; logits stay
    highly correlated with the exact-cache decode."""
    base = dict(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, dtype=jnp.float32, param_dtype=jnp.float32,
        remat=False, q_chunk=0,
    )
    # b=4, full code dim on these tiny 8-dim heads (dim reduction on
    # random 8-d vectors is hopeless; real heads are 128-d)
    cfg_q = TransformerConfig(**base, kv_quant_bits=4, kv_quant_dim=8)
    cfg_e = TransformerConfig(**base)
    pq_ = init_params(jax.random.PRNGKey(2), cfg_q)
    pe = {k: v for k, v in pq_.items() if k != "kv_quant"}
    cache_q = init_cache(cfg_q, 1, 16)
    cache_e = init_cache(cfg_e, 1, 16)

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree))

    assert nbytes(cache_q) < 0.5 * nbytes(cache_e)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 10), 0, 64)
    lq, le = [], []
    for t in range(10):
        a, cache_q = decode_step(pq_, cache_q, toks[:, t], jnp.int32(t),
                                 cfg_q)
        b, cache_e = decode_step(pe, cache_e, toks[:, t], jnp.int32(t),
                                 cfg_e)
        lq.append(a)
        le.append(b)
    corr = float(jnp.corrcoef(
        jnp.stack(lq).ravel(), jnp.stack(le).ravel()
    )[0, 1])
    assert corr > 0.9, corr


def test_transformer_scan_vs_unrolled():
    cfg_s = TransformerConfig(
        name="t", n_layers=3, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=64, dtype=jnp.float32, param_dtype=jnp.float32,
        remat=False, q_chunk=0, use_scan=True,
    )
    import dataclasses

    cfg_u = dataclasses.replace(cfg_s, use_scan=False)
    params = init_params(jax.random.PRNGKey(0), cfg_s)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    a, _ = forward(params, toks, cfg_s)
    b, _ = forward(params, toks, cfg_u)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_fm_sum_square_trick():
    """FM pairwise term == explicit O(n^2) pairwise sum."""
    from repro.models.recsys import RecSysConfig, init_params as rinit
    from repro.models.recsys import _fm_forward

    cfg = RecSysConfig(name="fm", kind="fm", n_dense=0, n_sparse=5,
                       embed_dim=4, vocab_per_field=50)
    params = rinit(jax.random.PRNGKey(0), cfg)
    sparse = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0, 50)
    batch = {"sparse": sparse}
    got = _fm_forward(params, batch, cfg)
    # explicit pairwise
    from repro.models.recsys import lookup

    emb = lookup(params, sparse, cfg)  # (3, 5, 4)
    pair = 0.0
    for i in range(5):
        for j in range(i + 1, 5):
            pair += jnp.sum(emb[:, i] * emb[:, j], -1)
    offs = jnp.arange(5) * 50
    lin = jnp.sum(jnp.take(
        params["linear_sparse"], (sparse + offs).reshape(-1), axis=0
    ).reshape(3, 5), -1)
    want = pair + lin + params["bias"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_dcn_cross_layer_formula():
    from repro.models.recsys import RecSysConfig, init_params as rinit
    from repro.models.recsys import _dcn_forward

    cfg = RecSysConfig(name="d", kind="dcn_v2", n_dense=2, n_sparse=2,
                       embed_dim=3, vocab_per_field=10,
                       n_cross_layers=1, mlp_dims=(4,))
    params = rinit(jax.random.PRNGKey(0), cfg)
    batch = {
        "sparse": jnp.array([[1, 2]]),
        "dense": jnp.array([[0.5, -1.0]]),
    }
    got = _dcn_forward(params, batch, cfg)
    assert got.shape == (1,)
    assert bool(jnp.isfinite(got[0]))
