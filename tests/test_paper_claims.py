"""The paper's comparative claims, validated as tests on synthetic
embedding-like data (relative orderings — see DESIGN.md §6 item 2).

Small-scale mirrors of the EXPERIMENTS.md reproduction sections.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    ASHConfig, encode, prepare_queries, random_model, score_dot, train,
)
from repro.data.synthetic import embedding_dataset
from repro.index import metrics as MET

D = 64


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(77)
    kx, kq = jax.random.split(key)
    X = embedding_dataset(kx, 4000, D)
    Qm = embedding_dataset(kq, 32, D)
    gt = MET.exact_topk(Qm, X, k=10)[1]
    return X, Qm, gt


def _recall(model, X, Qm, gt, R=30):
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    ids = jax.lax.top_k(score_dot(model, prep, pay), R)[1]
    return float(MET.recall_at(ids, gt))


def test_fig1_learned_beats_random_and_gap_widens(data):
    """Fig. 1: learned-W recall > random-W recall; the gap grows as
    d shrinks below D."""
    X, Qm, gt = data
    gaps = []
    for d in (D, D // 2):
        cfg = ASHConfig(b=2, d=d, n_landmarks=1)
        r_l = _recall(train(jax.random.PRNGKey(0), X, cfg)[0], X, Qm, gt)
        r_r = _recall(
            random_model(jax.random.PRNGKey(0), D, cfg,
                         X_for_landmarks=X), X, Qm, gt,
        )
        gaps.append(r_l - r_r)
    assert gaps[0] >= -0.02  # d=D: learned at least matches
    assert gaps[1] > 0.02  # d=D/2: clear win
    assert gaps[1] >= gaps[0] - 0.02  # gap widens (within noise)


def test_fig1_b2_halfdim_beats_b1_fulldim(data):
    """The headline: at iso-B, (b=2, d=D/2) >= (b=1, d=D), learned."""
    X, Qm, gt = data
    r_b1 = _recall(
        train(jax.random.PRNGKey(0), X,
              ASHConfig(b=1, d=D, n_landmarks=1))[0], X, Qm, gt,
    )
    r_b2 = _recall(
        train(jax.random.PRNGKey(0), X,
              ASHConfig(b=2, d=D // 2, n_landmarks=1))[0], X, Qm, gt,
    )
    assert r_b2 >= r_b1 - 0.02, (r_b1, r_b2)


def test_fig2_learned_beats_rabitq_expectation(data):
    """Fig. 2: ITQ-learned E[<x, quant_1(Wx)>] beats the random-rotation
    closed form (Eq. 33)."""
    from repro.baselines.rabitq import expected_dot_1bit

    X, _, _ = data
    _, hist = train(jax.random.PRNGKey(1), X,
                    ASHConfig(b=1, d=D, n_landmarks=1))
    learned_cos = -hist[-1]
    assert learned_cos > float(expected_dot_1bit(D))


def test_fp16_query_negligible(data):
    """Table 6: bf16 queries change recall by ~nothing."""
    X, Qm, gt = data
    model, _ = train(jax.random.PRNGKey(2), X,
                     ASHConfig(b=2, d=D, n_landmarks=16))
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    prep_lo = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.bfloat16).astype(jnp.float32), prep
    )
    ids_hi = jax.lax.top_k(score_dot(model, prep, pay), 30)[1]
    ids_lo = jax.lax.top_k(score_dot(model, prep_lo, pay), 30)[1]
    r_hi = float(MET.recall_at(ids_hi, gt))
    r_lo = float(MET.recall_at(ids_lo, gt))
    assert abs(r_hi - r_lo) < 0.02


def test_error_purely_angular(data):
    """Sec. 2: ASH reconstruction preserves the residual norm exactly
    (error is angular) — unlike e.g. LVQ whose min-max scaling distorts
    norms."""
    from repro.core import decode
    from repro.core import learning as L

    X, _, _ = data
    model, _ = train(jax.random.PRNGKey(3), X,
                     ASHConfig(b=2, d=D, n_landmarks=4,
                               store_fp16=False))
    pay = encode(model, X)
    Xhat = decode(model, pay)
    mu = model.landmarks[pay.cluster]
    r_true = jnp.linalg.norm(X - mu, axis=1)
    r_hat = jnp.linalg.norm(Xhat - mu, axis=1)
    import numpy as np

    np.testing.assert_allclose(
        np.asarray(r_hat), np.asarray(r_true), rtol=1e-4
    )
