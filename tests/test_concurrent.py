"""Concurrent serving subsystem: event-backed tickets, the
ServingFrontend driver thread, backpressure/deadlines, the asyncio
facade, and background compaction.

The load-bearing properties:

* **exactly-once resolution** — N threads racing one ticket's
  ``result()`` trigger exactly ONE fused scoring call (the per-index
  execution lock serializes; losers find the group gone and wait on
  the event), and no ticket is ever lost or resolved twice.
* **linearizable mutation order** — under concurrent mixed
  search/add/delete traffic, every search observes exactly the
  mutations submitted before it (submission order is the contract),
  so the whole run is bit-identical to a serial replay of the same
  submission sequence on a twin index.
* **compaction invisibility** — background compaction may swap
  survivor state at ANY point between flushes; results stay
  bit-identical to a fresh build over the survivors regardless of
  when the swap lands.
"""
import asyncio
import threading

import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.index import flat as F
from repro.serving.compactor import BackgroundCompactor
from repro.serving.engine import QueryEngine
from repro.serving.frontend import (
    FrontendClosed, FrontendConfig, ServingFrontend,
)
from test_mutation import (  # noqa: F401  (setup is a fixture)
    BACKENDS, CHUNK, N0, _assert_matches_fresh_build, _build, _Oracle,
    setup,
)


def _mk(setup, backend="flat", n=N0, **eng_kw):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, backend, "dot", X[:n])
    eng_kw.setdefault("batch_buckets", (8,))
    eng_kw.setdefault("k_buckets", (10,))
    return idx, QueryEngine(idx, **eng_kw)


# ---------------------------------------------------------------------------
# Ticket re-entrancy / exactly-once resolution
# ---------------------------------------------------------------------------


def test_ticket_result_hammered_runs_one_fused_call(setup):
    """8 threads racing one ticket's result(): exactly one fused call
    serves the group (jit cache grows by at most the one new trace),
    every caller gets the same arrays, resolution fires once."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=60.0)
    ticket = eng.submit(np.asarray(Qm[:2]), k=5)
    resolved = []
    ticket.add_done_callback(lambda t: resolved.append(t))
    before = F._search_prepped._cache_size()

    results, errors = [], []
    barrier = threading.Barrier(8)

    def hammer():
        try:
            barrier.wait()
            results.append(ticket.result(timeout=30.0))
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(results) == 8
    assert eng.stats.batches == 1  # ONE fused call despite 8 racers
    assert F._search_prepped._cache_size() - before <= 1
    assert len(resolved) == 1  # done callback fired exactly once
    s0, i0 = results[0]
    for s, i in results[1:]:  # everyone woke on the same resolution
        assert s is s0 and i is i0


def test_mutation_ticket_result_hammered_applies_once(setup):
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, n=100, max_wait_s=60.0)
    ticket = eng.submit_delete(np.arange(10))
    barrier = threading.Barrier(8)
    results = []

    def hammer():
        barrier.wait()
        results.append(ticket.result(timeout=30.0))

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert results == [10] * 8
    assert eng.stats.mutation_batches == 1
    assert idx.n_dead == 10


def test_ticket_result_timeout(setup):
    """On a driven engine result() waits instead of flushing — an
    unserved ticket times out rather than jumping the driver."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=60.0)
    eng.driven = True  # driven, but nobody is driving
    t = eng.submit(np.asarray(Qm[:1]), k=5)
    with pytest.raises(TimeoutError, match="driver"):
        t.result(timeout=0.05)
    eng.driven = False
    s, i = t.result(timeout=5.0)  # undriven again: caller may flush
    assert s.shape == (1, 5)


# ---------------------------------------------------------------------------
# ServingFrontend: driver cadence, backpressure, deadlines, lifecycle
# ---------------------------------------------------------------------------


def test_frontend_driver_owns_flushes(setup):
    """Tickets resolve without any caller flushing: the driver's
    timeout cadence serves them; result() never runs a flush (the
    fused-call count matches the driver's batches)."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=0.002)
    with ServingFrontend(eng) as fe:
        tickets = [fe.submit(np.asarray(Qm[i:i + 1]), k=5)
                   for i in range(4)]
        out = [t.result(timeout=10.0) for t in tickets]
    assert all(s.shape == (1, 5) for s, _ in out)
    reasons = {t.stats.flush_reason for t in tickets}
    assert reasons <= {"timeout", "size", "drain"}
    assert not eng.driven  # stop() returned the engine to undriven


def test_frontend_matches_direct_search(setup):
    """Driver-batched results are bit-identical to direct search."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=0.001)
    with ServingFrontend(eng) as fe:
        s, i = fe.search(np.asarray(Qm), k=5, timeout=10.0)
    sd, id_ = idx.search(Qm, k=5)
    np.testing.assert_array_equal(s, np.asarray(sd))
    np.testing.assert_array_equal(i, np.asarray(id_))


def test_frontend_deadline_flush_and_stats(setup):
    """A request deadline shorter than max_wait_s forces the flush at
    the deadline ("deadline" reason); the stats snapshot carries the
    queue gauges."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=60.0)  # timeout alone would hang
    with ServingFrontend(eng, default_deadline_s=0.01) as fe:
        t = fe.submit(np.asarray(Qm[:1]), k=5)
        s, i = t.result(timeout=10.0)
    assert t.stats.flush_reason in ("deadline", "drain")
    snap = eng.stats.snapshot()
    assert snap["flushes"]["deadline"] >= (
        1 if t.stats.flush_reason == "deadline" else 0
    )
    assert {"queue_depth", "oldest_ticket_age_s", "queue_hwm"} <= set(snap)


def test_frontend_backpressure_bounds_queue(setup):
    """Submitters block at max_queue_rows instead of growing the
    queue; everything still gets served and the high-water mark never
    exceeds the bound."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=0.001)
    bound = 6
    with ServingFrontend(eng, max_queue_rows=bound) as fe:
        errors = []

        def client(cid):
            try:
                for j in range(6):
                    fe.search(np.asarray(Qm[(cid + j) % 6][None, :]),
                              k=5, timeout=10.0)
            except Exception as e:
                errors.append(e)

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
    assert eng.stats.queue_hwm <= bound
    assert eng.stats.requests == 48


def test_frontend_submit_timeout_when_clogged(setup):
    """A queue that cannot drain (huge max_wait, bucket never fills)
    times blocked submitters out rather than hanging them."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=60.0)
    fe = ServingFrontend(eng, max_queue_rows=2,
                         submit_timeout_s=0.05).start()
    try:
        fe.submit(np.asarray(Qm[:2]), k=5)  # fills the bound
        with pytest.raises(TimeoutError, match="queue full"):
            fe.submit(np.asarray(Qm[:2]), k=5)
    finally:
        fe.stop()  # drain serves the queued request


def test_frontend_stop_drains_and_closes(setup):
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=60.0)
    fe = ServingFrontend(eng).start()
    ta = fe.submit_add(X[N0:N0 + 4])
    t = fe.submit(np.asarray(Qm[:1]), k=5)
    fe.stop(drain=True)
    assert t.done and t.stats.flush_reason == "drain"
    assert list(ta.result(timeout=1.0)) == list(range(N0, N0 + 4))
    with pytest.raises(FrontendClosed):
        fe.submit(np.asarray(Qm[:1]), k=5)
    with pytest.raises(FrontendClosed):
        fe.submit_add(X[:1])
    fe.stop()  # idempotent


def test_frontend_abort_fails_tickets_but_applies_mutations(setup):
    """stop(drain=False): queued query tickets fail with
    FrontendClosed; mutations still apply (their rows are already
    staged on the index — failing them would strand state)."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=60.0)
    fe = ServingFrontend(eng).start()
    td = fe.submit_delete([0, 1, 2])
    t = fe.submit(np.asarray(Qm[:1]), k=5)  # after the mutation: a
    # mutation submitted later would barrier-flush this group
    fe.stop(drain=False)
    with pytest.raises(RuntimeError):
        t.result(timeout=1.0)
    assert isinstance(t.error, FrontendClosed)
    assert td.result(timeout=1.0) == 3 and idx.n_dead == 3


def test_frontend_config_validation(setup):
    X, Qm, cfg, model, kb = setup
    with pytest.raises(ValueError, match="poll_interval_s"):
        FrontendConfig(poll_interval_s=0.0)
    with pytest.raises(ValueError, match="max_queue_rows"):
        FrontendConfig(max_queue_rows=0)


def test_frontend_asyncio_facade(setup):
    """await frontend.asearch(...) resolves on the event loop via the
    ticket's done callback; errors surface as exceptions; the
    mutation coroutines resolve to ids / removed counts."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, max_wait_s=0.001)
    sd, id_ = idx.search(Qm[:2], k=5)  # pre-mutation reference
    with ServingFrontend(eng) as fe:
        async def run():
            s, i = await fe.asearch(np.asarray(Qm[:2]), k=5)
            ids = await fe.asubmit_add(X[N0:N0 + 4])
            removed = await fe.asubmit_delete(ids[:2])
            return (s, i), list(ids), removed

        (s, i), ids, removed = asyncio.run(run())
    assert s.shape == (2, 5)
    assert ids == list(range(N0, N0 + 4)) and removed == 2
    np.testing.assert_array_equal(s, np.asarray(sd))
    np.testing.assert_array_equal(i, np.asarray(id_))


# ---------------------------------------------------------------------------
# The acceptance stress test: 8 threads, mixed traffic, serial replay
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ("flat", "ivf"))
def test_stress_mixed_traffic_matches_serial_replay(setup, backend):
    """≥8 threads of mixed search/add/delete through the frontend —
    with background compaction swapping mid-stream — finish with zero
    lost or double-resolved tickets, and every search is bit-identical
    to the same submission sequence replayed serially on a twin index.

    Submissions are serialized by a test-side log lock (defining THE
    submission order the engine contract promises to honor); execution
    and resolution stay fully concurrent (driver thread + barrier
    flushes + compactor swaps)."""
    X, Qm, cfg, model, kb = setup
    search_kw = {"nprobe": 4} if backend == "ivf" else {}
    idx = _build(setup, backend, "dot", X[:N0])
    twin = _build(setup, backend, "dot", X[:N0])
    eng = QueryEngine(idx, batch_buckets=(8,), k_buckets=(10,),
                      max_wait_s=0.002, auto_compact=0.05)
    compactor = BackgroundCompactor(eng).start()

    log = []  # ("add", pool_rows) | ("del", ids) | ("search", q, ticket)
    log_lock = threading.Lock()
    resolutions = []  # one entry per done-callback firing
    errors = []
    n_threads = 8
    start = threading.Barrier(n_threads)

    with ServingFrontend(eng) as fe:
        def worker(wid):
            rng = np.random.RandomState(1000 + wid)
            try:
                start.wait()
                for _ in range(6):
                    op = rng.rand()
                    if op < 0.2:
                        rows = rng.randint(0, X.shape[0], 4)
                        with log_lock:
                            t = fe.submit_add(X[rows])
                            log.append(("add", rows))
                    elif op < 0.4:
                        with log_lock:
                            hi = idx.next_id
                            victims = rng.randint(0, hi, 6)
                            t = fe.submit_delete(victims)
                            log.append(("del", victims))
                    else:
                        q = np.asarray(
                            Qm[rng.randint(0, Qm.shape[0], 2)]
                        )
                        with log_lock:
                            t = fe.submit(q, k=10, **search_kw)
                            log.append(("search", q, t))
                    t.add_done_callback(
                        lambda _t: resolutions.append(_t)
                    )
                    t.result(timeout=60.0)
            except Exception as e:
                errors.append((wid, e))

        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    compactor.wait_idle(30.0)
    compactor.stop()
    assert not errors, errors[:3]

    # zero lost, zero double-resolved: every logged ticket resolved,
    # and the done callback fired exactly once per ticket
    tickets = [e[2] for e in log if e[0] == "search"]
    assert all(t.done for t in tickets)
    assert len(resolutions) == len(log)
    assert len(set(map(id, resolutions))) == len(log)

    # serial replay: same submission order, direct mutations on the
    # twin; every concurrent search == the twin's state at its log
    # position.  flat scans a fixed-width payload, so coalescing
    # requests from different workers into one fused batch cannot
    # change any row's arithmetic — scores compare bitwise.  IVF sizes
    # its candidate gather to the widest probe list IN THE BATCH, so
    # coalescing legitimately changes the reduction shape — ids must
    # still match exactly, scores to fp32 accumulation noise.
    for entry in log:
        if entry[0] == "add":
            twin.add(np.asarray(X[entry[1]]))
        elif entry[0] == "del":
            twin.delete(entry[1])
        else:
            _, q, t = entry
            s_t, i_t = twin.search(q, k=10, **search_kw)
            s_c, i_c = t.result()
            if backend == "flat":
                np.testing.assert_array_equal(s_c, np.asarray(s_t))
            else:
                np.testing.assert_allclose(
                    s_c, np.asarray(s_t), rtol=1e-5, atol=1e-4
                )
            np.testing.assert_array_equal(i_c, np.asarray(i_t))


# ---------------------------------------------------------------------------
# Compaction invisibility under concurrency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@given(
    metric=st.sampled_from(("dot", "l2")),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_background_compaction_invisible(setup, backend, metric, seed):
    """Interleaved searches/adds/deletes with the background compactor
    swapping whenever the dead fraction crosses the threshold: results
    stay bit-identical to a fresh build over the survivors regardless
    of when each swap lands (the test_mutation equivalence, now with
    the rewrite racing the script on a worker thread)."""
    X, Qm, cfg, model, kb = setup
    rng = np.random.RandomState(seed)
    idx = _build(setup, backend, metric, X[:N0])
    oracle = _Oracle(N0)
    eng = QueryEngine(idx, batch_buckets=(8,), k_buckets=(10,),
                      max_wait_s=0.002, auto_compact=0.02)
    search_kw = {"nprobe": 4} if backend == "ivf" else {}

    with BackgroundCompactor(eng) as compactor:
        for _ in range(rng.randint(2, 5)):
            op = rng.rand()
            if op < 0.35:
                pool_rows = rng.randint(0, X.shape[0], CHUNK)
                t = eng.submit_add(X[pool_rows])
                expect = oracle.add(list(pool_rows))
                np.testing.assert_array_equal(t.result(), expect)
            elif op < 0.7 and len(oracle.alive) > CHUNK + 8:
                victims = rng.choice(
                    sorted(oracle.alive), size=CHUNK, replace=False
                )
                assert eng.submit_delete(victims).result() == CHUNK
                oracle.delete(victims)
            else:
                s, ids = eng.submit(np.asarray(Qm), k=10,
                                    **search_kw).result()
                dead = np.setdiff1d(
                    np.arange(len(oracle.src)), sorted(oracle.alive)
                )
                assert not np.isin(ids, dead).any()
        compactor.wait_idle(30.0)
    assert idx.n_live == len(oracle.alive)
    _assert_matches_fresh_build(
        setup, idx, oracle, backend, metric, search_kw
    )


def test_compactor_swap_is_epoch_guarded(setup):
    """A mutation landing between snapshot and swap forces a retry:
    the stale survivor build is dropped, the retry includes the
    delta, and the counters record it."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, n=200, max_wait_s=60.0)
    comp = BackgroundCompactor(eng, max_dead_fraction=0.0)
    try:
        eng.submit_delete(np.arange(40)).result()
        # race a mutation in between snapshot and swap by monkeypatching
        # the backend compact to mutate mid-build
        real_backend = idx._backend
        raced = []

        def racing_compact(state):
            out = real_backend.compact(state)
            if not raced:
                raced.append(True)
                idx.delete([50])  # lands after the snapshot
            return out

        class RacedBackend(real_backend):
            compact = staticmethod(racing_compact)

        idx._backend = RacedBackend
        assert comp.run_once("default")
        assert eng.stats.compact_retries == 1
        assert eng.stats.compact_runs == 1
        assert idx.n == 159 and idx.n_dead == 0  # delta included
    finally:
        comp.stop()


def test_compactor_skips_below_threshold_and_empty(setup):
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, n=100, max_wait_s=60.0)
    comp = BackgroundCompactor(eng, max_dead_fraction=0.5)
    try:
        eng.submit_delete(np.arange(10)).result()
        assert not comp.run_once("default")  # 10% < 50%
        assert idx.n == 100 and idx.n_dead == 10
        assert not comp.run_once("missing")  # unknown name: no-op
        idx.delete(np.arange(100))  # all dead: never compact to empty
        assert not comp.run_once("default")
        assert idx.n == 100
    finally:
        comp.stop()


def test_engine_auto_compact_routes_to_attached_compactor(setup):
    """With a compactor attached, auto_compact only signals the
    worker — the applying thread never compacts inline — and the
    telemetry lands in the background counters."""
    X, Qm, cfg, model, kb = setup
    idx, eng = _mk(setup, n=200, max_wait_s=60.0, auto_compact=0.1)
    with BackgroundCompactor(eng) as comp:
        eng.submit_delete(np.arange(80)).result()
        comp.wait_idle(30.0)
    snap = eng.stats.snapshot()
    assert snap["compactions"] == 0  # no synchronous eviction
    assert snap["compaction"]["runs"] == 1
    assert snap["compaction"]["swap_ms"] >= 0.0
    assert idx.n == 120 and idx.n_dead == 0
