"""Unified AshIndex API: backend parity, persistence, incremental add,
rerank metric-awareness, and invalid-id masking."""
import jax
import jax.numpy as jnp
import numpy as onp
import pytest
from jax.sharding import Mesh

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex, available_backends, metrics
from repro.index import distributed as DX

METRICS = ("dot", "l2", "cos")


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(77)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, 3000, 32)
    Qm = embedding_dataset(kq, 12, 32)
    cfg = ASHConfig(b=2, d=16, n_landmarks=16)
    # Train once; every test reuses the model so index assembly is the
    # only variable under test (and stays fast).
    model = AshIndex.build(kb, X, cfg, backend="flat").model
    return X, Qm, cfg, model, kb


def _build(setup, backend, metric, **opts):
    X, Qm, cfg, model, kb = setup
    return AshIndex.build(
        kb, X, cfg, backend=backend, metric=metric, model=model, **opts
    )


def test_available_backends():
    assert {"flat", "ivf", "sharded"} <= set(available_backends())


def test_unknown_backend_and_metric_raise(setup):
    X, Qm, cfg, model, kb = setup
    with pytest.raises(ValueError, match="unknown backend"):
        AshIndex.build(kb, X, cfg, backend="hnsw")
    with pytest.raises(ValueError, match="unknown metric"):
        AshIndex.build(kb, X, cfg, metric="hamming")


@pytest.mark.parametrize("metric", METRICS)
def test_backend_parity_full_probe(setup, metric):
    """flat, ivf(nprobe=nlist) and sharded agree on top-k for every
    metric — same candidates scored by the same shared dispatcher."""
    X, Qm, cfg, model, kb = setup
    fi = _build(setup, "flat", metric)
    ii = _build(setup, "ivf", metric)
    si = _build(setup, "sharded", metric)
    fs, fids = fi.search(Qm, k=20)
    is_, iids = ii.search(Qm, k=20, nprobe=cfg.n_landmarks)
    ss, sids = si.search(Qm, k=20)
    assert jnp.array_equal(jnp.sort(fids, 1), jnp.sort(iids, 1))
    assert jnp.array_equal(jnp.sort(fids, 1), jnp.sort(sids, 1))
    assert jnp.allclose(jnp.sort(fs, 1), jnp.sort(is_, 1), atol=1e-4)


@pytest.mark.parametrize("backend", ("flat", "ivf", "sharded"))
def test_save_load_bit_identical(setup, backend, tmp_path):
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, backend, "l2", keep_raw=True)
    idx.save(tmp_path / backend)
    idx2 = AshIndex.load(tmp_path / backend)
    s1, i1 = idx.search(Qm, k=10)
    s2, i2 = idx2.search(Qm, k=10)
    assert jnp.array_equal(s1, s2)
    assert jnp.array_equal(i1, i2)
    assert idx2.backend == backend and idx2.metric == "l2"
    assert idx2.config.payload_bits() == cfg.payload_bits()
    # rerank survives the round trip too (sharded included: bf16 raw
    # shards are persisted and re-distributed on load)
    r1 = idx.search(Qm, k=5, rerank=50)
    r2 = idx2.search(Qm, k=5, rerank=50)
    assert jnp.array_equal(r1[1], r2[1])


@pytest.mark.parametrize("backend", ("flat", "ivf", "sharded"))
def test_add_matches_scratch_rebuild(setup, backend):
    """build(X1) + add(X2) must search identically to a from-scratch
    assembly over X1+X2 under the same model."""
    X, Qm, cfg, model, kb = setup
    n1 = 2000
    a = _build(setup, backend, "dot")
    # rebuild `a` on the prefix only, then ingest the rest
    opts = dict(metric="dot", model=model)
    a = AshIndex.build(kb, X[:n1], cfg, backend=backend, **opts)
    a.add(X[n1:])
    b = AshIndex.build(kb, X, cfg, backend=backend, **opts)
    s1, i1 = a.search(Qm, k=10)
    s2, i2 = b.search(Qm, k=10)
    assert a.n == X.shape[0]
    assert jnp.array_equal(i1, i2)
    assert jnp.array_equal(s1, s2)


def test_ivf_short_probe_list_pads_with_minus_one():
    """A probed list shorter than k/rerank must pad results with id -1,
    never duplicate row 0 (regression for the padded-id bug)."""
    rng = onp.random.RandomState(0)
    base = rng.randn(60, 8).astype(onp.float32)
    tiny = rng.randn(3, 8).astype(onp.float32) * 0.1 + 50.0
    X = jnp.asarray(onp.concatenate([base, tiny]))
    cfg = ASHConfig(b=2, d=8, n_landmarks=4)
    idx = AshIndex.build(
        jax.random.PRNGKey(0), X, cfg, backend="ivf", keep_raw=True
    )
    q = jnp.full((1, 8), 50.0)
    for rerank in (0, 32):
        s, ids = idx.search(q, k=10, nprobe=1, rerank=rerank)
        ids_np = onp.asarray(ids[0])
        valid = ids_np[ids_np >= 0]
        # the far-off tiny cluster is its own list: exactly 3 valid hits
        assert set(valid.tolist()) == {60, 61, 62}, (rerank, ids_np)
        assert len(valid) == len(set(valid.tolist()))
        assert (ids_np[len(valid):] == -1).all()
        assert onp.isneginf(onp.asarray(s[0])[len(valid):]).all()


@pytest.mark.parametrize("backend", ("flat", "ivf"))
def test_rerank_is_metric_aware(backend):
    """Exact rerank must honor the index metric: under l2/cos the
    nearest vector wins even when a scaled copy has a larger dot."""
    rng = onp.random.RandomState(1)
    D = 8
    e1 = onp.zeros(D, onp.float32)
    e1[0] = 1.0
    e2 = onp.zeros(D, onp.float32)
    e2[1] = 1.0
    noise = rng.randn(61, D).astype(onp.float32) * 0.1
    # id 0: dot winner (scaled copy, off-axis); id 1: the query itself
    X = jnp.asarray(onp.stack([8.0 * e1 + 0.5 * e2, e1] + list(noise)))
    q = jnp.asarray(e1)[None, :]
    cfg = ASHConfig(b=4, d=D, n_landmarks=2)
    expected = {"dot": 0, "l2": 1, "cos": 1}
    for metric, want in expected.items():
        idx = AshIndex.build(
            jax.random.PRNGKey(0), X, cfg, backend=backend,
            metric=metric, keep_raw=True,
        )
        nprobe = cfg.n_landmarks if backend == "ivf" else None
        _, ids = idx.search(q, k=1, rerank=X.shape[0], nprobe=nprobe)
        assert int(ids[0, 0]) == want, (backend, metric, ids)


def test_sharded_pad_masking_l2(setup):
    """Padded rows must be masked for non-dot metrics (the offset=-inf
    sentinel only silences the dot estimator) — via the explicit n_real
    override AND the automatic cluster-sentinel derivation."""
    X, Qm, cfg, model, kb = setup
    fi = _build(setup, "flat", "l2")
    mesh = Mesh(onp.array(jax.devices())[:1], ("data",))
    padded = DX.pad_to_multiple(fi.payload, 64)
    assert padded.n > fi.payload.n
    _, fids = fi.search(Qm, k=10)
    sharded = DX.shard_payload(mesh, padded, ("data",))
    fn = DX.make_sharded_search(
        mesh, model, ("data",), k=10, metric="l2", n_real=fi.payload.n
    )
    s, ids = fn(sharded, Qm)
    assert jnp.array_equal(jnp.sort(ids, 1), jnp.sort(fids, 1))
    assert bool(jnp.all(jnp.isfinite(s)))
    # n_real omitted: the pad rows' cluster == -1 sentinel derives the
    # same mask, so l2/cos callers can no longer forget it
    fn2 = DX.make_sharded_search(mesh, model, ("data",), k=10, metric="l2")
    s2, ids2 = fn2(sharded, Qm)
    assert jnp.array_equal(ids2, ids)
    assert jnp.array_equal(s2, s)


def test_flat_rerank_larger_than_index():
    """rerank > n must clamp the shortlist, not crash top_k."""
    X = embedding_dataset(jax.random.PRNGKey(3), 40, 16)
    idx = AshIndex.build(
        jax.random.PRNGKey(0), X, ASHConfig(b=2, d=8, n_landmarks=2),
        keep_raw=True,
    )
    s, ids = idx.search(X[:2], k=5, rerank=100)
    assert ids.shape == (2, 5)
    assert bool(jnp.all(ids >= 0))


def test_sharded_rerank_requires_raw(setup):
    si = _build(setup, "sharded", "dot")
    X, Qm, cfg, model, kb = setup
    with pytest.raises(ValueError, match="keep_raw"):
        si.search(Qm, k=5, rerank=20)


def test_sharded_rerank_end_to_end(setup):
    """Shard-local exact rerank returns exact-scored candidates from a
    per-shard shortlist union that is a SUPERSET of the flat global
    shortlist — so at every rank its exact score is >= flat's (ids may
    legitimately differ when the superset surfaces a better candidate
    the global approx shortlist missed)."""
    X, Qm, cfg, model, kb = setup
    si = _build(setup, "sharded", "l2", keep_raw=True)
    fi = _build(setup, "flat", "l2", keep_raw=True)
    ss, sids = si.search(Qm, k=10, rerank=100)
    fs, fids = fi.search(Qm, k=10, rerank=100)
    assert bool(jnp.all(ss >= fs))
    assert bool(jnp.all(sids >= 0))
    # every returned id carries its true exact score (recompute on raw)
    from repro.index import common as C
    prep = si.prepare(Qm)
    cand = X[jnp.maximum(sids, 0)].astype(jnp.bfloat16).astype(
        jnp.float32
    )
    exact = C.exact_scores(prep, cand, "l2")
    assert jnp.allclose(ss, exact, atol=1e-3)


@pytest.mark.parametrize("backend", ("flat", "ivf", "sharded"))
def test_search_prepped_matches_search(setup, backend):
    """search(Q) and search_prepped(prepare(Q)) are the same compiled
    arithmetic — bit-identical (the serving engine relies on this)."""
    X, Qm, cfg, model, kb = setup
    idx = _build(setup, backend, "l2")
    s1, i1 = idx.search(Qm, k=10)
    s2, i2 = idx.search_prepped(idx.prepare(Qm), k=10)
    assert jnp.array_equal(s1, s2)
    assert jnp.array_equal(i1, i2)


def test_search_recall_sanity(setup):
    """The facade path preserves retrieval quality end to end."""
    X, Qm, cfg, model, kb = setup
    gt = metrics.exact_topk(Qm, X, k=10)[1]
    idx = _build(setup, "ivf", "dot", keep_raw=True)
    _, ids_few = idx.search(Qm, k=100, nprobe=4)
    _, ids = idx.search(Qm, k=100, nprobe=cfg.n_landmarks)
    r_few = float(metrics.recall_at(ids_few, gt))
    r_full = float(metrics.recall_at(ids, gt))
    assert r_full >= r_few  # more probes never hurt
    assert r_full > 0.85, r_full
