import os

# Tests run on 4 virtual CPU devices so sharded-backend coverage spans
# real 1/2/4-shard meshes — the 512-device override is strictly
# dryrun.py-local (per the brief).  Must be set before jax initializes.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    )

import pathlib

import jax
import pytest

from _hypothesis_compat import HealthCheck, settings

settings.register_profile(
    "ci",
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("ci")


def pytest_sessionstart(session):
    """Refuse to run against stale bytecode under ``src/``.

    A ``__pycache__`` entry older than its source means the interpreter
    about to import the tree cached a PREVIOUS revision — mtime-based
    invalidation usually catches this, but not when checkouts or file
    syncs preserve timestamps (git checkout keeps pyc mtimes, rsync -t
    restores py mtimes), and a silently stale module makes every test
    result a lie.  Deleting the listed ``__pycache__`` dirs is always
    safe: they are derived, untracked (.gitignore) artifacts."""
    src = pathlib.Path(__file__).resolve().parent.parent / "src"
    stale = []
    for pyc in src.rglob("__pycache__/*.pyc"):
        py = pyc.parent.parent / (pyc.name.split(".")[0] + ".py")
        if py.exists() and pyc.stat().st_mtime < py.stat().st_mtime:
            stale.append(str(pyc.parent))
    if stale:
        raise pytest.UsageError(
            "stale bytecode caches predate their sources — delete "
            "them and rerun: " + " ".join(sorted(set(stale)))
        )


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


# Core-library suites carry the `tier1` marker so CI can fail fast on
# them (`pytest -m tier1`) before the heavier model/training stacks
# (`-m "not tier1"`).  The two halves partition the full suite — the
# canonical tier-1 verify (`pytest -x -q`) still runs everything.
TIER1_EXCLUDED = {
    "test_arch_smoke",
    "test_launch_roofline",
    "test_models",
    "test_nequip",
    "test_train",
}


def pytest_collection_modifyitems(config, items):
    for item in items:
        module = getattr(item, "module", None)
        name = getattr(module, "__name__", "")
        if name not in TIER1_EXCLUDED:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(autouse=True, scope="module")
def _fresh_jit_caches_for_training_stack(request):
    """Drop jax's compiled-executable caches when a single-process run
    crosses from the core suites into the model/training stack.

    A full `pytest -x -q` run compiles several hundred XLA CPU
    executables before the training modules start; compiling the large
    grad graphs on top of that much accumulated LLVM JIT state can
    segfault the CPU compiler.  CI never sees this because the tier1 /
    not-tier1 halves run as separate processes — this fixture gives the
    excluded modules the same fresh-compiler start locally.  Clearing
    per excluded module (not per test) keeps the recompile cost to one
    warmup per module.
    """
    if request.module.__name__ in TIER1_EXCLUDED:
        jax.clear_caches()
    yield
