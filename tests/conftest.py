import os

# Tests run on the single real CPU device — the 512-device override is
# strictly dryrun.py-local (per the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

from _hypothesis_compat import HealthCheck, settings

settings.register_profile(
    "ci",
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
