"""Per-assigned-architecture smoke tests (deliverable f).

Each arch is instantiated at a REDUCED config of the same family (small
width/depth/experts/tables/graphs) and runs one forward + one train step
on CPU, asserting output shapes and absence of NaNs.  The FULL configs
are exercised via the dry-run (ShapeDtypeStruct only).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.launch.train import make_stream, reduced_arch
from repro.train.trainer import init_state, make_train_step

ARCH_IDS = sorted(registry.ARCHS)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_smoke_train_step(arch_id):
    arch = reduced_arch(registry.get(arch_id))
    from repro import models

    fam = getattr(models, arch.family)
    key = jax.random.PRNGKey(0)
    params = fam.init_params(key, arch.cfg)
    state = init_state(key, params, arch.train_cfg)
    stream = make_stream(arch, batch=8, seq=32, seed=1)
    step = jax.jit(make_train_step(arch.loss_fn(lambda a, k: a),
                                   arch.train_cfg))
    batch = stream.next()
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), (arch_id, loss)
    assert float(metrics["grad_norm"]) > 0.0
    # one more step: loss is a finite scalar and state advanced
    state, metrics2 = step(state, stream.next())
    assert jnp.isfinite(float(metrics2["loss"]))
    assert int(state.step) == 2


@pytest.mark.parametrize("arch_id", [
    "deepseek-7b", "qwen2-72b", "llama3.2-3b",
    "granite-moe-3b-a800m", "kimi-k2-1t-a32b",
])
def test_reduced_lm_forward_and_decode(arch_id):
    arch = reduced_arch(registry.get(arch_id))
    from repro.models import transformer as T

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, arch.cfg)
    tokens = jax.random.randint(key, (2, 12), 0, arch.cfg.vocab)
    logits, aux = T.forward(params, tokens, arch.cfg)
    assert logits.shape == (2, 12, arch.cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # decode parity with forward on a short prompt
    cache = T.init_cache(arch.cfg, 2, 16)
    outs = []
    for t in range(12):
        lg, cache = T.decode_step(
            params, cache, tokens[:, t], jnp.int32(t), arch.cfg
        )
        outs.append(lg)
    inc = jnp.stack(outs, axis=1)
    diff = float(jnp.max(jnp.abs(inc - logits)))
    # MoE capacity drops can differ between batch shapes; dense must match
    tol = 2e-2 if arch.cfg.moe else 2e-3
    assert diff < tol, (arch_id, diff)


@pytest.mark.parametrize("arch_id", ["sasrec", "dcn-v2", "fm", "autoint"])
def test_reduced_recsys_serving_paths(arch_id):
    arch = reduced_arch(registry.get(arch_id))
    from repro import models

    fam = getattr(models, arch.family)
    key = jax.random.PRNGKey(0)
    params = fam.init_params(key, arch.cfg)
    if arch.family == "sasrec":
        seq = jax.random.randint(key, (4, arch.cfg.seq_len), 1,
                                 arch.cfg.n_items)
        scores = fam.retrieval_score(params, seq, jnp.arange(50), arch.cfg)
        assert scores.shape == (4, 50)
    else:
        batch = {
            "sparse": jax.random.randint(
                key, (4, arch.cfg.n_sparse), 0, arch.cfg.vocab_per_field
            ),
            "dense": jax.random.normal(key, (4, arch.cfg.n_dense))
            if arch.cfg.n_dense else None,
        }
        batch = {k: v for k, v in batch.items() if v is not None}
        logits = fam.forward(params, batch, arch.cfg)
        assert logits.shape == (4,)
        scores = fam.retrieval_score(
            params, batch, jnp.arange(50), arch.cfg
        )
        assert scores.shape == (50,)
    assert not bool(jnp.any(jnp.isnan(scores)))


def test_nequip_reduced_energy_forces():
    arch = reduced_arch(registry.get("nequip"))
    from repro.models import nequip as NQ
    from repro.data import graphs as G

    params = NQ.init_params(jax.random.PRNGKey(0), arch.cfg)
    b = G.batch_small_graphs(0, n_graphs=4, nodes_per=10, edges_per=24,
                             n_species=arch.cfg.n_species)
    b = {k: (jnp.asarray(v) if not isinstance(v, int) else v)
         for k, v in b.items()}
    e = NQ.forward(params, b, arch.cfg)
    assert e.shape == (4,)
    assert not bool(jnp.any(jnp.isnan(e)))
    e2, f = NQ.energy_and_forces(params, b, arch.cfg)
    assert f.shape == b["positions"].shape
    assert not bool(jnp.any(jnp.isnan(f)))


def test_registry_covers_all_assigned():
    assert set(registry.ARCHS) == {
        "deepseek-7b", "qwen2-72b", "llama3.2-3b",
        "granite-moe-3b-a800m", "kimi-k2-1t-a32b", "nequip",
        "sasrec", "dcn-v2", "fm", "autoint",
    }


def test_official_cell_matrix_counts():
    """35 official cells: 5 LM x 4 - 5 skips + 4 GNN + 4x4 recsys."""
    official = list(registry.all_cells(include_skipped=False))
    assert len(official) == 35
    skipped = [
        (a.arch_id, c.name)
        for a, c in registry.all_cells(include_skipped=True)
        if c.skip
    ]
    # 5 long_500k skips + 5 extra ashkv cells
    assert len([s for s in skipped if s[1] == "long_500k"]) == 5


def test_exact_assigned_configs():
    """The config files encode the EXACT assigned architecture specs."""
    a = registry.get("deepseek-7b").cfg
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab) == (30, 4096, 32, 32, 11008, 102400)
    a = registry.get("qwen2-72b").cfg
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab, a.qkv_bias) == (80, 8192, 64, 8, 29568, 152064, True)
    a = registry.get("llama3.2-3b").cfg
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab) == (28, 3072, 24, 8, 8192, 128256)
    a = registry.get("granite-moe-3b-a800m").cfg
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads,
            a.vocab) == (32, 1536, 24, 8, 49155)
    assert (a.moe.n_experts, a.moe.top_k, a.moe.d_ff) == (40, 8, 512)
    a = registry.get("kimi-k2-1t-a32b").cfg
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads,
            a.vocab) == (61, 7168, 64, 8, 163840)
    assert (a.moe.n_experts, a.moe.top_k, a.moe.d_ff) == (384, 8, 2048)
    # ~1T total params, ~32B active
    assert 0.9e12 < a.param_count() < 1.3e12
    assert 25e9 < a.active_param_count() < 40e9
    n = registry.get("nequip").cfg
    assert (n.n_layers, n.channels, n.l_max, n.n_rbf,
            n.cutoff) == (5, 32, 2, 8, 5.0)
    s = registry.get("sasrec").cfg
    assert (s.embed_dim, s.n_blocks, s.n_heads, s.seq_len) == (50, 2, 1, 50)
    d = registry.get("dcn-v2").cfg
    assert (d.n_dense, d.n_sparse, d.embed_dim, d.n_cross_layers,
            d.mlp_dims) == (13, 26, 16, 3, (1024, 1024, 512))
    f = registry.get("fm").cfg
    assert (f.n_sparse, f.embed_dim) == (39, 10)
    ai = registry.get("autoint").cfg
    assert (ai.n_sparse, ai.embed_dim, ai.n_attn_layers, ai.n_attn_heads,
            ai.d_attn) == (39, 16, 3, 2, 32)
