"""Quickstart: learn ASH, encode a vector set, run asymmetric search.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.core import ASHConfig, decode, encode, train
from repro.data.synthetic import embedding_dataset
from repro.index import AshIndex
from repro.index import metrics as MET


def main():
    key = jax.random.PRNGKey(0)
    kx, kq, kt = jax.random.split(key, 3)

    # 1. An "embedding dataset": 20k vectors, 128 dims, anisotropic like
    #    real text-embedding outputs (paper Table 4).
    X = embedding_dataset(kx, 20_000, 128)
    queries = embedding_dataset(kq, 100, 128)

    # 2. Learn ASH: 2 bits/dim at half the dimensionality = 32x
    #    compression vs fp32, with a learned orthonormal projection.
    cfg = ASHConfig(b=2, d=64, n_landmarks=64)
    model, history = train(kt, X, cfg)
    print(f"trained: {len(history)} ITQ iterations, "
          f"payload {cfg.payload_bits()} bits/vector "
          f"({32 * 128 / cfg.payload_bits():.1f}x compression)")

    # 3. Encode the database (packed uint32 codes + fp16 headers).
    payload = encode(model, X)
    print(f"codes: {payload.codes.shape} uint32, "
          f"scale/offset: {payload.scale.dtype}")

    # 4. Asymmetric search through the unified index API: queries stay
    #    full-precision.  The same AshIndex surface serves the "ivf" and
    #    "sharded" backends and the "l2"/"cos" metrics.
    index = AshIndex.from_parts(model, payload, backend="flat",
                                metric="dot")
    _, ids = index.search(queries, k=100)

    gt = MET.exact_topk(queries, X, k=10)[1]
    rec = MET.recall_curve(ids, gt, Rs=(10, 100))
    print(f"10-recall@10 = {rec[10]:.4f}  10-recall@100 = {rec[100]:.4f}"
          f"  (retrieve 100, exact-rerank to recover @10)")

    # 5. Persistence: npz arrays + JSON config; search results after a
    #    save/load round trip are bit-identical.
    with tempfile.TemporaryDirectory() as td:
        index.save(f"{td}/idx")
        reloaded = AshIndex.load(f"{td}/idx")
        _, ids2 = reloaded.search(queries, k=100)
        print(f"save/load round-trip identical: "
              f"{bool(jnp.array_equal(ids, ids2))}")

    # 6. Decode (lossy) — reconstruction is purely angular (Sec. 2).
    Xhat = decode(model, payload)
    rel = float(jnp.linalg.norm(Xhat - X) / jnp.linalg.norm(X))
    print(f"reconstruction relative error = {rel:.4f}")


if __name__ == "__main__":
    main()
