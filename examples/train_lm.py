"""Train a reduced llama3.2-3b-family LM for a few hundred steps with
checkpoint/restart fault tolerance (kill it mid-run and re-launch: it
resumes exactly).

  PYTHONPATH=src python examples/train_lm.py
"""
import sys

from repro.launch import train as TL


def main():
    return TL.main([
        "--arch", "llama3.2-3b", "--reduced",
        "--steps", "200", "--batch", "16", "--seq", "64",
        "--ckpt-dir", "/tmp/repro_lm_ckpt", "--ckpt-every", "50",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    sys.exit(main())
