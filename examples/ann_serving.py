"""End-to-end driver (the paper's kind): serve a mixed stream of ANN
requests from an ASH-compressed IVF index through the micro-batching
QueryEngine, with exact-rerank and latency stats.

  PYTHONPATH=src python examples/ann_serving.py
"""
import time

import jax
import numpy as np

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset, isotropy_diagnostics
from repro.index import AshIndex, metrics
from repro.serving import QueryEngine


def main():
    key = jax.random.PRNGKey(7)
    kx, kq, kb = jax.random.split(key, 3)
    D, n = 128, 50_000
    X = embedding_dataset(kx, n, D)
    print("dataset diagnostics (paper Table 4 regime):",
          isotropy_diagnostics(X))

    cfg = ASHConfig(b=2, d=64, n_landmarks=128)  # nlist = 128
    t0 = time.time()
    index = AshIndex.build(kb, X, cfg, backend="ivf", keep_raw=True)
    print(f"index built in {time.time() - t0:.1f}s ({index!r})")

    # mixed request stream: single queries and small batches, the shape
    # traffic actually arrives in — the engine buckets them so only a
    # handful of jit traces serve everything
    rng = np.random.RandomState(0)
    sizes = rng.choice([1, 2, 4, 8], size=64, p=[0.4, 0.3, 0.2, 0.1])
    queries = [embedding_dataset(jax.random.fold_in(kq, i), int(m), D)
               for i, m in enumerate(sizes)]
    gt = [metrics.exact_topk(q, X, k=10)[1] for q in queries]

    for nprobe in (4, 16, 64):
        # untimed warmup pass compiles every bucket trace this stream
        # will hit (throwaway engine so the timed pass starts cold on
        # the prep cache too)
        warm = QueryEngine(index, batch_buckets=(8, 32),
                           max_wait_s=0.002)
        for q in queries:
            warm.submit(q, k=10, nprobe=nprobe, rerank=50)
        warm.flush()
        engine = QueryEngine(index, batch_buckets=(8, 32),
                             max_wait_s=0.002)
        t0 = time.time()
        tickets = [engine.submit(q, k=10, nprobe=nprobe, rerank=50)
                   for q in queries]
        engine.flush()
        dt = time.time() - t0
        rec = [float(metrics.recall_at(np.asarray(t.result()[1]), g))
               for t, g in zip(tickets, gt)]
        lat = sorted(t.stats.latency_s * 1e3 for t in tickets)
        st = engine.stats.snapshot()
        print(f"nprobe={nprobe:3d}: 10-recall@10="
              f"{sum(rec) / len(rec):.4f}  "
              f"p50={lat[len(lat) // 2]:.1f}ms  p99~={lat[-1]:.1f}ms  "
              f"({int(sizes.sum()) / dt:.0f} QPS, "
              f"{st['batches']} fused calls for {st['requests']} reqs, "
              f"fill={st['bucket_fill']:.2f})")


if __name__ == "__main__":
    main()
