"""End-to-end driver (the paper's kind): serve batched ANN requests
from an ASH-compressed IVF index, with exact-rerank and latency stats.

  PYTHONPATH=src python examples/ann_serving.py
"""
import time

import jax

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset, isotropy_diagnostics
from repro.index import AshIndex, metrics


def main():
    key = jax.random.PRNGKey(7)
    kx, kq, kb = jax.random.split(key, 3)
    D, n = 128, 50_000
    X = embedding_dataset(kx, n, D)
    print("dataset diagnostics (paper Table 4 regime):",
          isotropy_diagnostics(X))

    cfg = ASHConfig(b=2, d=64, n_landmarks=128)  # nlist = 128
    t0 = time.time()
    index = AshIndex.build(kb, X, cfg, backend="ivf", keep_raw=True)
    print(f"index built in {time.time() - t0:.1f}s ({index!r})")

    # batched request stream
    batches = [embedding_dataset(jax.random.fold_in(kq, i), 32, D)
               for i in range(8)]
    gt = [metrics.exact_topk(b, X, k=10)[1] for b in batches]

    for nprobe in (4, 16, 64):
        # warmup then serve
        index.search(batches[0], k=10, nprobe=nprobe, rerank=50)
        lat, rec = [], []
        for b, g in zip(batches, gt):
            t0 = time.perf_counter()
            _, ids = jax.block_until_ready(
                index.search(b, k=10, nprobe=nprobe, rerank=50)
            )
            lat.append((time.perf_counter() - t0) * 1e3)
            rec.append(float(metrics.recall_at(ids, g)))
        lat.sort()
        print(f"nprobe={nprobe:3d}: 10-recall@10="
              f"{sum(rec)/len(rec):.4f}  "
              f"p50={lat[len(lat)//2]:.1f}ms  p99~={lat[-1]:.1f}ms  "
              f"({32*1000/lat[len(lat)//2]:.0f} QPS/batch32)")


if __name__ == "__main__":
    main()
