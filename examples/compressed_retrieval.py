"""SASRec next-item retrieval over an ASH-compressed catalog — the
paper's technique integrated into a recsys serving path (DESIGN.md §3).

  PYTHONPATH=src python examples/compressed_retrieval.py
"""
import time

import jax
import jax.numpy as jnp

from repro.index import metrics as MET
from repro.models import sasrec as SR
from repro.serving import retrieval as RET


def main():
    key = jax.random.PRNGKey(3)
    cfg = SR.SASRecConfig(n_items=100_000, embed_dim=48, seq_len=20,
                          n_neg=64)
    params = SR.init_params(key, cfg)
    # stand-in for a TRAINED catalog: item embeddings with the low-rank,
    # clustered structure real recommenders learn (random-init gaussian
    # embeddings have no structure for any compressor to exploit)
    from repro.data.synthetic import embedding_dataset

    params["item_emb"] = embedding_dataset(
        jax.random.PRNGKey(9), cfg.n_items, cfg.embed_dim
    ) * 0.2

    # Compress the 100k-item catalog with learned ASH (4 bits, d/2):
    t0 = time.time()
    index = RET.build_index(
        jax.random.PRNGKey(1), params["item_emb"], bits=4, reduce=2,
        n_landmarks=32,
    )
    payload = index.payload
    fp32_bytes = params["item_emb"].size * 4
    ash_bytes = payload.codes.size * 4 + payload.scale.size * 2 \
        + payload.offset.size * 2 + payload.cluster.size
    print(f"catalog compressed {fp32_bytes/ash_bytes:.1f}x "
          f"in {time.time()-t0:.1f}s ({index!r})")

    # Serve: user sequences -> user state -> ASH MIPS over the catalog
    seq = jax.random.randint(jax.random.PRNGKey(2), (64, 20), 1,
                             cfg.n_items)
    t0 = time.perf_counter()
    scores, ids = jax.block_until_ready(
        RET.sasrec_retrieve(params, seq, index, cfg, k=10)
    )
    dt = time.perf_counter() - t0
    # recall vs exact full-precision MIPS
    exact = SR.retrieval_score(params, seq, jnp.arange(cfg.n_items), cfg)
    gt = jax.lax.top_k(exact, 10)[1]
    rec = float(MET.recall_at(ids, gt))
    print(f"64 users x 100k items in {dt*1e3:.0f}ms "
          f"-> 10-recall@10 = {rec:.4f}")


if __name__ == "__main__":
    main()
