"""Scoring-path microbenchmarks (CPU wall-clock; TPU numbers come from
the roofline analysis — kernels only interpret on CPU).

Contrasts the ASH matmul-style scoring against PQ's gather-style ADC —
the Table 2/3 comparison transplanted to this backend — plus the packed
-code memory footprint that drives the TPU HBM roofline term, and the
fused metric/selection paths (``kernels.ops`` epilogue form, the jnp
oracle of the Pallas kernels) against their pure-jnp reference
counterparts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import D, N, NQ, QUICK, dataset, row, timed
from repro.baselines import pq
from repro.core import ASHConfig, encode, payload_stats, prepare_queries, train
from repro.core import scoring as S
from repro.kernels import ops


def srow(name: str, us: float, derived: str, *, b: int = 2) -> str:
    """A kernel row stamped with the corpus shape it was measured on
    — (n, d, b, m) — so ``tools/check_bench.py --baseline`` can refuse
    to diff timings taken on different problem sizes."""
    shape = f"n={N};d={D};b={b};m={NQ}"
    return row(name, us, f"{derived};{shape}" if derived else shape)


def scoring_paths():
    X, Qm, _ = dataset()
    rows = []
    cfg = ASHConfig(b=2, d=D, n_landmarks=16)
    model, _ = train(jax.random.PRNGKey(0), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)

    _, us = timed(S.score_dot, model, prep, pay, repeats=3)
    n_scores = Qm.shape[0] * X.shape[0]
    rows.append(srow("kernel/ash_score_jnp", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    _, us = timed(
        lambda: ops.ash_score(model, prep, pay, use_pallas=False),
        repeats=3,
    )
    rows.append(srow("kernel/ash_score_ref", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    st = pq.train(jax.random.PRNGKey(0), X, M=12, b=8, kmeans_iters=10)
    enc = pq.encode(st, X)
    _, us = timed(pq.score, st, enc, Qm, repeats=3)
    rows.append(srow("kernel/pq_adc_gather", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    # payload footprint: packed codes vs fp32 vectors
    fp32 = X.size * 4
    packed = (
        pay.codes.size * 4 + pay.scale.size * 2 + pay.offset.size * 2
        + pay.cluster.size * 1
    )
    rows.append(srow("kernel/payload_bytes", 0.0,
                    f"fp32={fp32};ash={packed};"
                    f"compression={fp32 / packed:.1f}x"))
    return rows


def fused_metric_paths():
    """Fused l2/cos epilogues and fused top-k selection vs the jnp
    reference scorers + materialize-then-top_k (both sides jitted)."""
    X, Qm, _ = dataset()
    rows = []
    cfg = ASHConfig(b=2, d=D, n_landmarks=16)
    model, _ = train(jax.random.PRNGKey(0), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    stats = payload_stats(model, pay)
    n_scores = Qm.shape[0] * X.shape[0]

    refs = {
        "l2": jax.jit(lambda: -S.score_l2(model, prep, pay)),
        "cos": jax.jit(lambda: S.score_cosine(model, prep, pay)),
    }
    for metric in ("l2", "cos"):
        _, us = timed(refs[metric], repeats=3)
        rows.append(srow(f"kernel/ash_score_{metric}_jnp", us,
                        f"ns_per_dot={1e3 * us / n_scores:.3f}"))
        fused = jax.jit(functools.partial(
            ops.ash_score, model, prep, pay, metric=metric, stats=stats,
            use_pallas=False,
        ))
        _, us_f = timed(fused, repeats=3)
        rows.append(srow(f"kernel/ash_score_{metric}_fused", us_f,
                        f"ns_per_dot={1e3 * us_f / n_scores:.3f};"
                        f"speedup_vs_jnp={us / max(us_f, 1e-9):.2f}x"))

    k = 100
    mat = jax.jit(lambda: jax.lax.top_k(
        ops.ash_score(model, prep, pay, metric="l2", stats=stats,
                      use_pallas=False), k))
    _, us_m = timed(mat, repeats=3)
    rows.append(srow("kernel/ash_score_topk_materialize", us_m,
                    f"k={k};ns_per_dot={1e3 * us_m / n_scores:.3f}"))
    fused_tk = jax.jit(functools.partial(
        ops.ash_score_topk, model, prep, pay, k, metric="l2",
        stats=stats, use_pallas=False,
    ))
    _, us_t = timed(fused_tk, repeats=3)
    rows.append(srow("kernel/ash_score_topk_fused", us_t,
                    f"k={k};ns_per_dot={1e3 * us_t / n_scores:.3f};"
                    f"speedup_vs_materialize={us_m / max(us_t, 1e-9):.2f}x"))
    return rows


def gathered_scan_paths():
    """Masked-gather scoring (IVF partial-probe primitive) vs the
    retained rowwise reference (per-query payload gather + rowwise
    scorers) on ragged candidate lists with pad ids, plus fused gather
    selection vs materialize-then-``top_k``.  CPU numbers time the
    fused oracle (the kernel only interprets on CPU)."""
    from repro.index import common as C

    X, Qm, _ = dataset()
    rows_out = []
    cfg = ASHConfig(b=2, d=D, n_landmarks=16)
    model, _ = train(jax.random.PRNGKey(0), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    stats = payload_stats(model, pay)
    R = 256 if QUICK else 512
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    cand = jax.random.randint(k1, (Qm.shape[0], R), 0, pay.n)
    pads = jax.random.uniform(k2, cand.shape) < 0.2
    cand = jnp.where(pads, -1, cand).astype(jnp.int32)
    n_scores = cand.size

    def rowwise_one(prep_q, rows_q):
        sub = C.gather_payload(pay, rows_q)
        one = jax.tree_util.tree_map(lambda a: a[None], prep_q)
        sc = -S.score_l2(model, one, sub, rowwise=True)[0]
        return jnp.where(rows_q >= 0, sc, -jnp.inf)

    rowwise = jax.jit(lambda: jax.vmap(rowwise_one)(prep, cand))
    _, us_r = timed(rowwise, repeats=3)
    rows_out.append(srow("kernel/ash_score_gather_rowwise", us_r,
                        f"R={R};ns_per_dot={1e3 * us_r / n_scores:.3f}"))

    fused = jax.jit(functools.partial(
        ops.ash_score_gather, model, prep, pay, cand, metric="l2",
        stats=stats, use_pallas=False,
    ))
    _, us_f = timed(fused, repeats=3)
    rows_out.append(srow("kernel/ash_score_gather_fused", us_f,
                        f"R={R};ns_per_dot={1e3 * us_f / n_scores:.3f};"
                        f"speedup_vs_rowwise={us_r / max(us_f, 1e-9):.2f}x"))

    k = 100
    mat = jax.jit(lambda: jax.lax.top_k(fused(), k))
    _, us_m = timed(mat, repeats=3)
    rows_out.append(srow("kernel/ash_score_gather_topk_materialize", us_m,
                        f"k={k};R={R}"))
    fused_tk = jax.jit(functools.partial(
        ops.ash_score_gather_topk, model, prep, pay, cand, k,
        metric="l2", stats=stats, use_pallas=False,
    ))
    _, us_t = timed(fused_tk, repeats=3)
    rows_out.append(srow(
        "kernel/ash_score_gather_topk_fused", us_t,
        f"k={k};R={R};"
        f"speedup_vs_materialize={us_m / max(us_t, 1e-9):.2f}x"))
    return rows_out


def sharded_scan_paths():
    """Sharded local scan: the fused route (metric epilogues off
    encode-time stats + fused local top-k) vs the retained reference
    route (pure-jnp scorers + materialize-then-``top_k``), same mesh,
    same merge."""
    import numpy as np
    from jax.sharding import Mesh

    from repro.index import AshIndex
    from repro.index import distributed as DX

    X, Qm, _ = dataset()
    rows_out = []
    cfg = ASHConfig(b=2, d=D, n_landmarks=16)
    model, _ = train(jax.random.PRNGKey(0), X, cfg)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    idx = AshIndex.from_parts(
        model, encode(model, X), backend="sharded", metric="l2",
        mesh=mesh, axes=("data",),
    )
    state = idx._state
    prep = idx.prepare(Qm)
    n_scores = Qm.shape[0] * X.shape[0]

    ref_fn = DX.make_sharded_search_prepped(
        mesh, model, ("data",), 10, metric="l2", fused=False
    )
    _, us_r = timed(
        lambda: ref_fn(state.sharded, prep), repeats=3
    )
    rows_out.append(srow("kernel/sharded_scan_ref", us_r,
                        f"ns_per_dot={1e3 * us_r / n_scores:.3f}"))

    fused_fn = state.searcher(10)
    _, us_f = timed(
        lambda: fused_fn(state.sharded, prep,
                         stats=state.sharded_stats),
        repeats=3,
    )
    rows_out.append(srow("kernel/sharded_scan_fused", us_f,
                        f"ns_per_dot={1e3 * us_f / n_scores:.3f};"
                        f"speedup_vs_ref={us_r / max(us_f, 1e-9):.2f}x"))
    return rows_out


def coarse_scan_paths():
    """Symmetric int8 first pass vs the asymmetric scan it shortcuts,
    plus the shortlist-recall sweep behind ``ops.DEFAULT_SHORTLIST``.

    The coarse jnp row is one fp32 BLAS matmul over the persisted
    ``CoarseCodes`` value cache (no per-call unpack); the fused row is
    the full coarse-topk + asymmetric-refine pipeline
    (``ops.coarse_refine_topk``).  The sweep reports recall@10 of the
    coarse+refine pipeline against the pure asymmetric top-10 across
    shortlist sizes L — the exactness loss the first pass trades for
    its scan speed.

    Expect speedup ~1.0x on CPU: XLA:CPU fuses the code unpack into
    the asymmetric scan for free and runs both passes as the
    same-size f32 BLAS GEMM, so the rows document BLAS parity there.
    The int8 win these rows exist to track appears where an integer
    MXU runs the coarse accumulation at a multiple of fp32
    throughput (and at a quarter of the operand bandwidth) —
    check_bench's serving-side throughput gate likewise only arms on
    accelerator platforms."""
    X, Qm, _ = dataset()
    rows = []
    cfg = ASHConfig(b=2, d=D, n_landmarks=16)
    model, _ = train(jax.random.PRNGKey(0), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    stats = payload_stats(model, pay)
    coarse = S.coarse_codes(pay)
    cprep = S.prepare_coarse_queries(prep, coarse.mean)
    n_scores = Qm.shape[0] * X.shape[0]
    k = 10

    # Operands ride as traced jit ARGUMENTS here, never as bound
    # constants: constant operands let XLA fold entire GEMMs at
    # compile time (the compile log even warns about it), and a
    # folded scan "benchmarks" at dispatch cost.
    asym = jax.jit(lambda mo, pr, pa, st: ops.ash_score(
        mo, pr, pa, metric="dot", stats=st, use_pallas=False))
    _, us_a = timed(asym, model, prep, pay, stats, repeats=3)

    cjnp = jax.jit(lambda mo, pr, pa, st, co, cp: ops.ash_score_coarse(
        mo, pr, pa, metric="dot", stats=st, coarse=co, cprep=cp,
        use_pallas=False))
    _, us = timed(cjnp, model, prep, pay, stats, coarse, cprep,
                  repeats=3)
    rows.append(srow("kernel/ash_score_coarse_jnp", us,
                     f"ns_per_dot={1e3 * us / n_scores:.3f};"
                     f"speedup_vs_asym={us_a / max(us, 1e-9):.2f}x"))

    L = ops.DEFAULT_SHORTLIST
    fused = jax.jit(lambda mo, pr, pa, st, co: ops.coarse_refine_topk(
        mo, pr, pa, k, shortlist=L, metric="dot", stats=st, coarse=co,
        use_pallas=False))
    asym_tk = jax.jit(lambda mo, pr, pa, st: ops.ash_score_topk(
        mo, pr, pa, k, metric="dot", stats=st, use_pallas=False))
    _, us_at = timed(asym_tk, model, prep, pay, stats, repeats=3)
    _, us_f = timed(fused, model, prep, pay, stats, coarse, repeats=3)
    rows.append(srow("kernel/ash_score_coarse_fused", us_f,
                     f"k={k};L={L};"
                     f"ns_per_dot={1e3 * us_f / n_scores:.3f};"
                     f"speedup_vs_asym_topk="
                     f"{us_at / max(us_f, 1e-9):.2f}x"))

    # shortlist sweep: recall@10 of coarse+refine vs asymmetric top-10
    # per L.  DEFAULT_SHORTLIST (ops.py) is the smallest swept L that
    # holds recall >= 0.999 on this corpus — re-run after retuning.
    import numpy as np

    base = np.asarray(asym_tk(model, prep, pay, stats)[1])
    parts = []
    for L_s in (32, 64, 128, 256, 512):
        ids = np.asarray(ops.coarse_refine_topk(
            model, prep, pay, k, shortlist=L_s, metric="dot",
            stats=stats, coarse=coarse, use_pallas=False,
        )[1])
        rec = float(np.mean([
            len(set(a.tolist()) & set(b.tolist())) / k
            for a, b in zip(ids, base)
        ]))
        parts.append(f"recall_at_10_L{L_s}={rec:.4f}")
    rows.append(srow("kernel/coarse_shortlist_sweep", 0.0,
                     ";".join(parts) + f";default_L={L}"))
    return rows


ALL = [scoring_paths, fused_metric_paths, gathered_scan_paths,
       sharded_scan_paths, coarse_scan_paths]
