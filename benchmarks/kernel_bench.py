"""Scoring-path microbenchmarks (CPU wall-clock; TPU numbers come from
the roofline analysis — kernels only interpret on CPU).

Contrasts the ASH matmul-style scoring against PQ's gather-style ADC —
the Table 2/3 comparison transplanted to this backend — plus the packed
-code memory footprint that drives the TPU HBM roofline term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import D, dataset, row, timed
from repro.baselines import pq
from repro.core import ASHConfig, encode, prepare_queries, train
from repro.core import scoring as S
from repro.kernels import ops


def scoring_paths():
    X, Qm, _ = dataset()
    rows = []
    cfg = ASHConfig(b=2, d=D, n_landmarks=16)
    model, _ = train(jax.random.PRNGKey(0), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)

    _, us = timed(S.score_dot, model, prep, pay, repeats=3)
    n_scores = Qm.shape[0] * X.shape[0]
    rows.append(row("kernel/ash_score_jnp", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    _, us = timed(
        lambda: ops.ash_score(model, prep, pay, use_pallas=False),
        repeats=3,
    )
    rows.append(row("kernel/ash_score_ref", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    st = pq.train(jax.random.PRNGKey(0), X, M=12, b=8, kmeans_iters=10)
    enc = pq.encode(st, X)
    _, us = timed(pq.score, st, enc, Qm, repeats=3)
    rows.append(row("kernel/pq_adc_gather", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    # payload footprint: packed codes vs fp32 vectors
    fp32 = X.size * 4
    packed = (
        pay.codes.size * 4 + pay.scale.size * 2 + pay.offset.size * 2
        + pay.cluster.size * 1
    )
    rows.append(row("kernel/payload_bytes", 0.0,
                    f"fp32={fp32};ash={packed};"
                    f"compression={fp32 / packed:.1f}x"))
    return rows


ALL = [scoring_paths]
