"""Scoring-path microbenchmarks (CPU wall-clock; TPU numbers come from
the roofline analysis — kernels only interpret on CPU).

Contrasts the ASH matmul-style scoring against PQ's gather-style ADC —
the Table 2/3 comparison transplanted to this backend — plus the packed
-code memory footprint that drives the TPU HBM roofline term, and the
fused metric/selection paths (``kernels.ops`` epilogue form, the jnp
oracle of the Pallas kernels) against their pure-jnp reference
counterparts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import D, dataset, row, timed
from repro.baselines import pq
from repro.core import ASHConfig, encode, payload_stats, prepare_queries, train
from repro.core import scoring as S
from repro.kernels import ops


def scoring_paths():
    X, Qm, _ = dataset()
    rows = []
    cfg = ASHConfig(b=2, d=D, n_landmarks=16)
    model, _ = train(jax.random.PRNGKey(0), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)

    _, us = timed(S.score_dot, model, prep, pay, repeats=3)
    n_scores = Qm.shape[0] * X.shape[0]
    rows.append(row("kernel/ash_score_jnp", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    _, us = timed(
        lambda: ops.ash_score(model, prep, pay, use_pallas=False),
        repeats=3,
    )
    rows.append(row("kernel/ash_score_ref", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    st = pq.train(jax.random.PRNGKey(0), X, M=12, b=8, kmeans_iters=10)
    enc = pq.encode(st, X)
    _, us = timed(pq.score, st, enc, Qm, repeats=3)
    rows.append(row("kernel/pq_adc_gather", us,
                    f"ns_per_dot={1e3 * us / n_scores:.3f}"))

    # payload footprint: packed codes vs fp32 vectors
    fp32 = X.size * 4
    packed = (
        pay.codes.size * 4 + pay.scale.size * 2 + pay.offset.size * 2
        + pay.cluster.size * 1
    )
    rows.append(row("kernel/payload_bytes", 0.0,
                    f"fp32={fp32};ash={packed};"
                    f"compression={fp32 / packed:.1f}x"))
    return rows


def fused_metric_paths():
    """Fused l2/cos epilogues and fused top-k selection vs the jnp
    reference scorers + materialize-then-top_k (both sides jitted)."""
    X, Qm, _ = dataset()
    rows = []
    cfg = ASHConfig(b=2, d=D, n_landmarks=16)
    model, _ = train(jax.random.PRNGKey(0), X, cfg)
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    stats = payload_stats(model, pay)
    n_scores = Qm.shape[0] * X.shape[0]

    refs = {
        "l2": jax.jit(lambda: -S.score_l2(model, prep, pay)),
        "cos": jax.jit(lambda: S.score_cosine(model, prep, pay)),
    }
    for metric in ("l2", "cos"):
        _, us = timed(refs[metric], repeats=3)
        rows.append(row(f"kernel/ash_score_{metric}_jnp", us,
                        f"ns_per_dot={1e3 * us / n_scores:.3f}"))
        fused = jax.jit(functools.partial(
            ops.ash_score, model, prep, pay, metric=metric, stats=stats,
            use_pallas=False,
        ))
        _, us_f = timed(fused, repeats=3)
        rows.append(row(f"kernel/ash_score_{metric}_fused", us_f,
                        f"ns_per_dot={1e3 * us_f / n_scores:.3f};"
                        f"speedup_vs_jnp={us / max(us_f, 1e-9):.2f}x"))

    k = 100
    mat = jax.jit(lambda: jax.lax.top_k(
        ops.ash_score(model, prep, pay, metric="l2", stats=stats,
                      use_pallas=False), k))
    _, us_m = timed(mat, repeats=3)
    rows.append(row("kernel/ash_score_topk_materialize", us_m,
                    f"k={k};ns_per_dot={1e3 * us_m / n_scores:.3f}"))
    fused_tk = jax.jit(functools.partial(
        ops.ash_score_topk, model, prep, pay, k, metric="l2",
        stats=stats, use_pallas=False,
    ))
    _, us_t = timed(fused_tk, repeats=3)
    rows.append(row("kernel/ash_score_topk_fused", us_t,
                    f"k={k};ns_per_dot={1e3 * us_t / n_scores:.3f};"
                    f"speedup_vs_materialize={us_m / max(us_t, 1e-9):.2f}x"))
    return rows


ALL = [scoring_paths, fused_metric_paths]
