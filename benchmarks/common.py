"""Shared benchmark fixtures: dataset, ground truth, timing, CSV rows.

``ASH_BENCH_QUICK=1`` (set by ``benchmarks.run --quick``) shrinks the
problem size so the whole suite runs in CI-smoke time; emitted JSON is
tagged with the mode so trajectories aren't compared across sizes.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp

from repro.data.synthetic import embedding_dataset
from repro.index import metrics as MET

QUICK = os.environ.get("ASH_BENCH_QUICK", "") not in ("", "0")
D = 48 if QUICK else 96
N = 4_000 if QUICK else 20_000
NQ = 64 if QUICK else 200


@functools.lru_cache(maxsize=None)
def dataset(d: int = D, n: int = N, nq: int = NQ):
    key = jax.random.PRNGKey(1234)
    kx, kq = jax.random.split(key)
    X = embedding_dataset(kx, n, d)
    Qm = embedding_dataset(kq, nq, d)
    gt = MET.exact_topk(Qm, X, k=10)[1]
    return X, Qm, gt


def timed(fn, *args, repeats: int = 3, **kw):
    """(result, us_per_call) with one warmup."""
    out = jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jax.block_until_ready(fn(*args, **kw))
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"


def recall10(ids, gt, R: int = 10) -> float:
    return float(MET.recall_at(ids[:, :R], gt))
