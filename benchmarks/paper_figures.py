"""One function per paper table/figure (Figs. 1-9, Tables 6-7).

Each returns CSV rows ``name,us_per_call,derived`` where derived carries
the figure's metric (recall, loss, bias slope, ...).  Sizes are scaled
to this CPU container; the paper's qualitative orderings are asserted in
tests/test_paper_claims.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import D, dataset, recall10, row, timed
from repro.baselines import eden, leanvec, lopq, pq, rabitq
from repro.core import (
    ASHConfig, encode, prepare_queries, random_model, score_dot, train,
)
from repro.core import scoring as S
from repro.index import AshIndex


def _search_recall(model, X, Qm, gt, R=10):
    pay = encode(model, X)
    prep = prepare_queries(model, Qm)
    sc = score_dot(model, prep, pay)
    ids = jax.lax.top_k(sc, R)[1]
    return recall10(ids, gt, R)


def fig1_learned_vs_random():
    """Learned W vs JL-random W across (B, b) — recall@10."""
    X, Qm, gt = dataset()
    rows = []
    for B in (D, D // 2):
        for b in (1, 2, 4):
            d = B // b
            if d < 8 or d > D:
                continue
            cfg = ASHConfig(b=b, d=d, n_landmarks=1)
            t0 = time.perf_counter()
            m_l, _ = train(jax.random.PRNGKey(0), X, cfg)
            tr_us = (time.perf_counter() - t0) * 1e6
            m_r = random_model(jax.random.PRNGKey(0), D, cfg,
                               X_for_landmarks=X)
            r_l = _search_recall(m_l, X, Qm, gt)
            r_r = _search_recall(m_r, X, Qm, gt)
            rows.append(row(
                f"fig1/B{B}_b{b}_learned", tr_us, f"recall@10={r_l:.4f}"
            ))
            rows.append(row(
                f"fig1/B{B}_b{b}_random", 0.0, f"recall@10={r_r:.4f}"
            ))
    return rows


def fig2_convergence():
    """ITQ iteration count + final loss vs the RaBitQ bound (Eq. 33)."""
    X, _, _ = dataset()
    t0 = time.perf_counter()
    model, hist = train(jax.random.PRNGKey(0), X,
                        ASHConfig(b=1, d=D, n_landmarks=1))
    us = (time.perf_counter() - t0) * 1e6
    bound = float(rabitq.expected_dot_1bit(D))
    # loss is -E[cosSim]; learned should beat the random-rotation bound
    final = -hist[-1]
    return [
        row("fig2/itq_iters", us, f"iters={len(hist)}"),
        row("fig2/final_cos", 0.0,
            f"learned={final:.4f};rabitq_bound={bound:.4f};"
            f"beats_bound={final > bound}"),
    ]


def fig3_landmarks():
    X, Qm, gt = dataset()
    rows = []
    for C in (1, 16, 64):
        cfg = ASHConfig(b=2, d=D // 2, n_landmarks=C)
        (model, _), us = timed(
            lambda: train(jax.random.PRNGKey(0), X, cfg), repeats=1
        )
        r = _search_recall(model, X, Qm, gt)
        rows.append(row(f"fig3/C{C}", us, f"recall@10={r:.4f}"))
    return rows


def fig4_bias():
    X, Qm, gt = dataset()
    rows = []
    for b in (1, 2, 4):
        cfg = ASHConfig(b=b, d=D, n_landmarks=1, store_fp16=False)
        model, _ = train(jax.random.PRNGKey(0), X, cfg)
        pay = encode(model, X)
        m2, us = timed(
            lambda: S.fit_bias(model, pay, X, Qm, sample=100), repeats=1
        )
        rows.append(row(
            f"fig4/b{b}", us,
            f"rho={float(m2.bias_rho):.4f};beta={float(m2.bias_beta):.4f}"
        ))
    return rows


def tab6_query_precision():
    """bf16 query downcast: recall delta (paper: ~1e-5 for fp16)."""
    X, Qm, gt = dataset()
    rows = []
    for b in (1, 2):
        cfg = ASHConfig(b=b, d=D, n_landmarks=16)
        model, _ = train(jax.random.PRNGKey(0), X, cfg)
        pay = encode(model, X)
        prep = prepare_queries(model, Qm)
        ids32 = jax.lax.top_k(score_dot(model, prep, pay), 10)[1]
        prep_lo = jax.tree_util.tree_map(
            lambda a: a.astype(jnp.bfloat16).astype(jnp.float32), prep
        )
        (sc_lo), us = timed(score_dot, model, prep_lo, pay, repeats=1)
        ids_lo = jax.lax.top_k(sc_lo, 10)[1]
        d32 = recall10(ids32, gt)
        dlo = recall10(ids_lo, gt)
        rows.append(row(
            f"tab6/b{b}", us,
            f"recall_fp32={d32:.4f};recall_bf16={dlo:.4f};"
            f"delta={abs(d32 - dlo):.5f}"
        ))
    return rows


def fig5678_baselines():
    """Iso-bit accuracy: ASH vs PQ/LOPQ/EDEN/TQ/LeanVec/RaBitQ."""
    X, Qm, gt = dataset()
    rows = []
    true = Qm @ X.T
    gt10 = gt

    def recall_of(scores):
        ids = jax.lax.top_k(scores, 10)[1]
        return recall10(ids, gt10)

    # budget ~ 2 bits/dim (B = 2D = 192 code bits)
    for b_, d_, tag in ((2, D, "ash_b2_dD"), (4, D // 2, "ash_b4_dD2")):
        cfg = ASHConfig(b=b_, d=d_, n_landmarks=16)
        (model, _), us = timed(
            lambda: train(jax.random.PRNGKey(0), X, cfg), repeats=1
        )
        pay = encode(model, X)
        prep = prepare_queries(model, Qm)
        sc, sus = timed(score_dot, model, prep, pay, repeats=2)
        rows.append(row(f"fig5678/{tag}", sus,
                        f"recall@10={recall_of(sc):.4f};train_us={us:.0f}"))

    st = pq.train(jax.random.PRNGKey(0), X, M=24, b=8, kmeans_iters=15)
    enc = pq.encode(st, X)
    sc, sus = timed(pq.score, st, enc, Qm, repeats=2)
    rows.append(row("fig5678/pq_M24x8", sus,
                    f"recall@10={recall_of(sc):.4f}"))

    st = lopq.train(jax.random.PRNGKey(0), X, M=24, b=8, C=4,
                    local_iters=2, kmeans_iters=10)
    enc = lopq.encode(st, X)
    sc, sus = timed(lopq.score, st, enc, Qm, repeats=1)
    rows.append(row("fig5678/lopq_M24x8_C4", sus,
                    f"recall@10={recall_of(sc):.4f}"))

    for variant in ("eden", "turboquant"):
        st = eden.train(jax.random.PRNGKey(0), X, b=2, variant=variant)
        enc = eden.encode(st, X)
        sc, sus = timed(eden.score, st, enc, Qm, repeats=2)
        rows.append(row(f"fig5678/{variant}_b2", sus,
                        f"recall@10={recall_of(sc):.4f}"))

    st = leanvec.train(jax.random.PRNGKey(0), X, d=D // 2, b=4)
    enc = leanvec.encode(st, X)
    sc, sus = timed(leanvec.score, st, enc, Qm, repeats=2)
    rows.append(row("fig5678/leanvec_d48_b4", sus,
                    f"recall@10={recall_of(sc):.4f}"))

    m = rabitq.train(jax.random.PRNGKey(0), X, b=2)
    enc = rabitq.encode(m, X)
    sc, sus = timed(rabitq.score, m, enc, Qm, repeats=2)
    rows.append(row("fig5678/rabitq_b2", sus,
                    f"recall@10={recall_of(sc):.4f}"))
    return rows


def fig9_pareto():
    """IVF QPS-vs-recall sweep (CPU proxy of the paper's Fig. 9)."""
    X, Qm, gt = dataset()
    rows = []
    for b, dd in ((2, D // 2), (4, D // 2)):
        cfg = ASHConfig(b=b, d=dd, n_landmarks=64)
        index = AshIndex.build(jax.random.PRNGKey(0), X, cfg,
                               backend="ivf")
        for nprobe in (2, 8, 32):
            (sc, ids), us = timed(
                index.search, Qm, 10, nprobe=nprobe, repeats=2
            )
            qps = 1e6 * Qm.shape[0] / us
            rows.append(row(
                f"fig9/ash_b{b}_d{dd}_np{nprobe}", us / Qm.shape[0],
                f"recall@10={recall10(ids, gt):.4f};qps={qps:.0f}"
            ))
    return rows


def tab7_timing():
    """Training + encoding wall-time across (b, d) — Table 7."""
    X, _, _ = dataset()
    rows = []
    for b in (1, 2, 4):
        for dd in (D // 2, D):
            cfg = ASHConfig(b=b, d=dd, n_landmarks=32)
            t0 = time.perf_counter()
            model, hist = train(jax.random.PRNGKey(0), X, cfg)
            tr = time.perf_counter() - t0
            _, enc_us = timed(encode, model, X, repeats=1)
            rows.append(row(
                f"tab7/b{b}_d{dd}", enc_us,
                f"train_s={tr:.2f};encode_s={enc_us/1e6:.2f};"
                f"iters={len(hist)}"
            ))
    return rows


ALL = [
    fig1_learned_vs_random, fig2_convergence, fig3_landmarks, fig4_bias,
    tab6_query_precision, fig5678_baselines, fig9_pareto, tab7_timing,
]
