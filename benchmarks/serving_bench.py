"""Serving-path benchmarks: engine throughput/latency vs batch-bucket
config (``bench/serving``).

Streams single-query and small-batch requests through the
micro-batching ``QueryEngine`` and reports QPS + p50/p99 request
latency per bucket configuration, against the direct per-request
``AshIndex.search`` baseline — the measurement loop behind the paper's
"batched scoring stays a dense matmul" serving claim.
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from benchmarks.common import D, QUICK, dataset, recall10, row
from repro.core import ASHConfig
from repro.index import AshIndex
from repro.index.common import default_shortlist
from repro.serving.engine import QueryEngine


def _request_stream(Qm, seed=0):
    """(start, size) request slices with a serving-like size mix."""
    rng = np.random.RandomState(seed)
    out, i = [], 0
    while i < Qm.shape[0]:
        m = min(int(rng.choice([1, 1, 2, 4])), Qm.shape[0] - i)
        out.append((i, m))
        i += m
    return out


def _stream_through(engine, Qm, reqs, k, nprobe):
    tickets = [
        engine.submit(Qm[i:i + m], k=k, nprobe=nprobe)
        for i, m in reqs
    ]
    engine.flush()
    return tickets


def serving_engine():
    X, Qm, gt = dataset()
    cfg = ASHConfig(b=2, d=D // 2, n_landmarks=16)
    key = jax.random.PRNGKey(0)
    index = AshIndex.build(key, X, cfg, backend="flat")
    # the IVF rows get a serving-shaped partition: nprobe 8 of 32
    # lists scans ~1/4 of the corpus per query, so per-query probe
    # sets genuinely differ and the union bill has somewhere to go.
    # (nprobe 8 of 16 probes half the corpus: every union saturates
    # near n and neither the budget nor batching can matter.)
    ivf = AshIndex.build(key, X,
                         ASHConfig(b=2, d=D // 2, n_landmarks=32),
                         backend="ivf")
    Qm = np.asarray(Qm)  # host-side slicing in the request loop
    X_np = np.asarray(X)
    reqs = _request_stream(Qm)
    n_rows = Qm.shape[0]
    rows = []

    # flat baseline: direct per-request search, sequential burst
    # (fresh trace per novel shape)
    for i, m in reqs:  # warmup: compile every request shape
        index.search(Qm[i:i + m], k=10)
    t0 = time.perf_counter()
    lats = []
    for i, m in reqs:
        t1 = time.perf_counter()
        jax.block_until_ready(index.search(Qm[i:i + m], k=10))
        lats.append(time.perf_counter() - t1)
    dt = time.perf_counter() - t0
    p50, p99 = np.percentile(lats, [50, 99])
    rows.append(row(
        "serving/direct_flat", 1e6 * dt / len(reqs),
        f"qps={n_rows / dt:.0f};"
        f"p50_ms={1e3 * p50:.2f};p99_ms={1e3 * p99:.2f}",
    ))

    # flat engine rows: one fused call per bucket, traces shared
    # across requests, measured on the same sequential burst
    for buckets in ((8,), (8, 32), (32,)):
        tag = "-".join(map(str, buckets))
        engine = QueryEngine(index, batch_buckets=buckets,
                             max_wait_s=0.005)
        _stream_through(engine, Qm, reqs, 10, None)  # warmup
        engine = QueryEngine(index, batch_buckets=buckets,
                             max_wait_s=0.005)
        t0 = time.perf_counter()
        tickets = _stream_through(engine, Qm, reqs, 10, None)
        dt = time.perf_counter() - t0
        lats = [t.stats.latency_s for t in tickets]
        p50, p99 = np.percentile(lats, [50, 99])
        st = engine.stats.snapshot()
        rows.append(row(
            f"serving/engine_flat_b{tag}", 1e6 * dt / len(reqs),
            f"qps={n_rows / dt:.0f};"
            f"p50_ms={1e3 * p50:.2f};p99_ms={1e3 * p99:.2f};"
            f"batches={st['batches']};fill={st['bucket_fill']};"
            f"traces={st['unique_buckets']}",
        ))

    # coarse first pass through the engine: the same request mix and
    # bucket as engine_flat_b8, with ``coarse="int8"`` riding the opts
    # into the group key (coarse and asymmetric requests never share a
    # fused call).  Both modes are measured here back to back so the
    # row is self-contained: check_bench gates it (full size only) at
    # qps >= 1.5x qps_asym with recall@10 within 1 point — the
    # serving-side win of the symmetric first pass.  The throughput
    # half only arms on accelerator rows (see the platform stamp):
    # XLA:CPU fuses the code unpack into the asymmetric scan and runs
    # both passes as the same-size f32 BLAS GEMM, so parity (~1.0x) is
    # the expected CPU result and only the recall half gates there.
    qps_by, rec_by, dt_by = {}, {}, {}
    for mode in ("asym", "coarse"):
        kw = {} if mode == "asym" else {"coarse": "int8"}
        engine = QueryEngine(index, batch_buckets=(8,), max_wait_s=0.005)
        for i, m in reqs:  # warmup: compile the mode's trace family
            engine.submit(Qm[i:i + m], k=10, **kw)
        engine.flush()
        engine = QueryEngine(index, batch_buckets=(8,), max_wait_s=0.005)
        t0 = time.perf_counter()
        tickets = [
            engine.submit(Qm[i:i + m], k=10, **kw) for i, m in reqs
        ]
        engine.flush()
        dt = time.perf_counter() - t0
        ids = np.concatenate(
            [np.asarray(t.result()[1]) for t in tickets]
        )
        qps_by[mode] = n_rows / dt
        rec_by[mode] = recall10(ids, gt)
        dt_by[mode] = dt
    rows.append(row(
        "serving/coarse_flat",
        1e6 * dt_by["coarse"] / len(reqs),
        f"qps={qps_by['coarse']:.0f};qps_asym={qps_by['asym']:.0f};"
        f"speedup={qps_by['coarse'] / max(qps_by['asym'], 1e-9):.2f}x;"
        f"recall_at_10={rec_by['coarse']:.4f};"
        f"recall_at_10_asym={rec_by['asym']:.4f};"
        f"shortlist={default_shortlist()};"
        f"platform={jax.default_backend()}",
    ))

    # IVF rows measure serving under CONCURRENT load, where the tail
    # actually lives: closed-loop clients each submit a 1-row request
    # and block on it.  The direct baseline pays per-request dispatch,
    # prep and its own gather, and the callers serialize; the frontend
    # driver batches concurrent arrivals into fused calls and serves
    # repeated queries out of the prep LRU.  Clients draw from the Qm
    # pool — a hot set of 256 queries, the query-repetition shape real
    # traffic has.  Hot-set probes share lists heavily, so the union
    # bill grows slowly with group size: row_budget at 0.5n sits
    # above the hot-pool union at both costed rungs (~0.39n for an
    # 8-group, ~0.44n for a 16-group) — correlated traffic rides the
    # whole ladder and the bucket floor guarantees no chop below the
    # 8-bucket it pads up to — and far below a diverse (uncorrelated)
    # 16-group's union (~0.98n), which would budget-flush early and
    # split instead of serializing one monster gather.  The costed
    # ladder tops out at 16 — the fused call turns superlinear past
    # ~16 rows on this geometry, so a bigger top bucket only buys
    # tail.  The costed rows also arm the full tentpole config:
    # nprobe_min = nprobe/2 lets the pressure ladder halve probe
    # depth when the queue backs up — a recall-for-tail trade the
    # direct path cannot make, surfaced per row as degraded_batches
    # (and per engine in snapshot()["ivf_cost"]).  The
    # single-big-bucket 32 config stays uncosted as the contrast row
    # — the tail regression the cost model exists to kill.
    # check_bench gates every costed row (marked by the row_budget
    # field) at p99 <= direct_ivf* p99 and qps >= 2x direct_ivf*.
    nprobe = 8
    c = 32
    reqs_each = 6 if QUICK else 25
    jax.block_until_ready(ivf.search(X_np[:1], k=10, nprobe=nprobe))
    warm = QueryEngine(ivf, batch_buckets=(8, 16, 32), max_wait_s=0.002)
    for b in (8, 16, 32):
        warm.search(X_np[:b], k=10, nprobe=nprobe)
        # the costed rows' pressure ladder halves nprobe once (8 -> 4)
        # under load; warm that trace family too so no row compiles
        # mid-measurement
        warm.search(X_np[:b], k=10, nprobe=nprobe // 2)

    lat_d, dt_d = _closed_loop_direct(ivf, c, reqs_each, Qm, nprobe)
    p50, p99 = np.percentile(lat_d, [50, 99])
    rows.append(row(
        f"serving/direct_ivf_c{c}", 1e6 * dt_d / lat_d.size,
        f"qps={lat_d.size / dt_d:.0f};"
        f"p50_ms={1e3 * p50:.2f};p99_ms={1e3 * p99:.2f};"
        f"clients={c}",
    ))

    for tag, buckets in (("8", (8,)), ("8-16", (8, 16)),
                         ("32", (32,))):
        kw = {}
        if buckets != (32,):
            kw["row_budget"] = int(0.5 * ivf.n)
            kw["nprobe_min"] = nprobe // 2
        lats, dt, engine = _closed_loop(
            ivf, c, reqs_each, Qm, nprobe=nprobe, buckets=buckets,
            engine_kw=kw, warm_pool=Qm,
        )
        p50, p99 = np.percentile(lats, [50, 99])
        st = engine.stats.snapshot()
        extra = ""
        if kw:
            ic = st["ivf_cost"]
            extra = (
                f";row_budget={kw['row_budget']};"
                f"rows_per_q={ic['rows_per_query']};"
                f"splits={ic['splits']};"
                f"budget_flushes={st['flushes']['budget']};"
                f"nprobe_min={kw['nprobe_min']};"
                f"degraded_batches={ic['degraded']}"
            )
        rows.append(row(
            f"serving/engine_ivf_c{c}_b{tag}", 1e6 * dt / lats.size,
            f"qps={lats.size / dt:.0f};"
            f"p50_ms={1e3 * p50:.2f};p99_ms={1e3 * p99:.2f};"
            f"batches={st['batches']};fill={st['bucket_fill']};"
            f"clients={c}" + extra,
        ))

    # prep-cache effect: identical query stream served twice; hit rate
    # is measured over the warm pass only (counters are cumulative)
    engine = QueryEngine(index, batch_buckets=(32,), max_wait_s=0.005)
    _stream_through(engine, Qm, reqs, 10, None)
    hits0, miss0 = engine.stats.prep_hits, engine.stats.prep_misses
    t0 = time.perf_counter()
    _stream_through(engine, Qm, reqs, 10, None)
    dt = time.perf_counter() - t0
    hits = engine.stats.prep_hits - hits0
    misses = engine.stats.prep_misses - miss0
    hit_rate = hits / max(1, hits + misses)
    rows.append(row(
        "serving/engine_flat_warm_cache", 1e6 * dt / len(reqs),
        f"qps={n_rows / dt:.0f};prep_hit_rate={hit_rate:.2f}",
    ))
    return rows


def _mutation_stream(engine, X_np, Qm, reqs, nprobe, mutate_every):
    """Serve the request mix with every ``mutate_every``-th slot also
    carrying a mutation (alternating batched add / tombstone delete).
    Returns (query tickets, mutation tickets, wall seconds)."""
    rng = np.random.RandomState(7)
    tickets, muts = [], []
    t0 = time.perf_counter()
    for j, (i, m) in enumerate(reqs):
        if j % mutate_every == mutate_every - 1:
            if (j // mutate_every) % 2 == 0:
                rows_ = X_np[rng.randint(0, X_np.shape[0], 4)]
                muts.append(engine.submit_add(rows_))
            else:
                victims = rng.randint(0, X_np.shape[0], 4)
                muts.append(engine.submit_delete(victims))
        tickets.append(engine.submit(Qm[i:i + m], k=10, nprobe=nprobe))
    engine.flush()
    for t in muts:
        t.result()
    return tickets, muts, time.perf_counter() - t0


def serving_mutation():
    """Engine throughput under ~10% mutation traffic: adds/sec,
    deletes/sec and the search p99 while batched adds and tombstone
    deletes ride the same bucket/flush loop (the live-index serving
    scenario; compaction amortized via auto_compact)."""
    X, Qm, gt = dataset()
    X_np = np.asarray(X)
    cfg = ASHConfig(b=2, d=D // 2, n_landmarks=16)
    key = jax.random.PRNGKey(0)
    base = AshIndex.build(key, X, cfg, backend="flat")
    rows = []
    Qm = np.asarray(Qm)
    reqs = _request_stream(Qm)
    n_rows = Qm.shape[0]
    for nm, backend, nprobe in (("flat", "flat", None),
                                ("ivf", "ivf", 8)):
        # warmup engine+index compile every shape the stream hits,
        # including post-mutation payload shapes
        for pass_ in ("warm", "timed"):
            idx = AshIndex.build(
                key, X, cfg, backend=backend, model=base.model
            )
            engine = QueryEngine(
                idx, batch_buckets=(8, 32), max_wait_s=0.005,
                auto_compact=0.3,
            )
            # jit traces are warm after the first pass, but each pass
            # rebuilds the index and engine: the fresh build's device
            # arrays materialize lazily and the first flush would
            # otherwise block on them, charging ~100x p50 to whichever
            # tickets land in it (the old p99 outlier).  Block on the
            # index and serve one throwaway flush per bucket first,
            # the way launch/serve.py warms query buckets.
            jax.block_until_ready(jax.tree_util.tree_leaves(idx._state))
            for b in (8, 32):
                engine.submit(Qm[:b], k=10, nprobe=nprobe)
                engine.flush()
            tickets, muts, dt = _mutation_stream(
                engine, X_np, Qm, reqs, nprobe, mutate_every=10
            )
        added = sum(t.n_rows for t in muts if t.kind == "add")
        deleted = sum(t.result() for t in muts if t.kind == "delete")
        worst_apply = max((t.apply_s for t in muts), default=0.0)
        lats = [t.stats.latency_s for t in tickets]
        p50, p99 = np.percentile(lats, [50, 99])
        st = engine.stats.snapshot()
        rows.append(row(
            f"serving/mutation_{nm}_10pct", 1e6 * dt / len(reqs),
            f"qps={n_rows / dt:.0f};"
            f"adds_per_s={added / max(dt, 1e-9):.0f};"
            f"deletes_per_s={deleted / max(dt, 1e-9):.0f};"
            f"p50_ms={1e3 * p50:.2f};p99_ms={1e3 * p99:.2f};"
            f"mut_batches={st['mutation_batches']};"
            f"compactions={st['compactions']};"
            f"worst_apply_ms={1e3 * worst_apply:.1f}",
        ))
    return rows


def serving_durability():
    """Durability cost on the serving path: the same ~25% mutation
    request mix served with no WAL and with the WAL attached at each
    fsync policy.  Reports acked mutations/sec and the search p99 per
    mode — the number behind the fsync trade-off table in the README.
    check_bench gates interval_muts_per_s >= 0.8x nowal_muts_per_s
    (the default policy must not cost the serving path more than 20%
    of its mutation throughput)."""
    import gc
    import shutil
    import tempfile

    from repro.serving.wal import DurableIndex

    # settle the allocator before the first (nowal baseline) mode: this
    # stage runs last, and collecting the preceding stages' engine/
    # ticket graphs mid-measurement shows up directly in its p99
    gc.collect()

    X, Qm, gt = dataset()
    X_np = np.asarray(X)
    Qm = np.asarray(Qm)
    cfg = ASHConfig(b=2, d=D // 2, n_landmarks=16)
    key = jax.random.PRNGKey(0)
    base = AshIndex.build(key, X, cfg, backend="flat")
    reqs = _request_stream(Qm)
    per_mode = {}
    wal_note = ""
    us_interval = 0.0
    for mode in ("nowal", "always", "interval", "off"):
        for pass_ in ("warm", "timed"):
            idx = AshIndex.build(
                key, X, cfg, backend="flat", model=base.model
            )
            engine = QueryEngine(
                idx, batch_buckets=(8, 32), max_wait_s=0.005,
                auto_compact=0.3,
            )
            durable = None
            tmp = None
            if mode != "nowal":
                tmp = tempfile.mkdtemp(prefix=f"ash-bench-wal-{mode}-")
                durable = DurableIndex.create(
                    idx, tmp, fsync=mode
                )
                engine.attach_durability(durable)
            jax.block_until_ready(
                jax.tree_util.tree_leaves(idx._state)
            )
            for b in (8, 32):
                engine.submit(Qm[:b], k=10)
                engine.flush()
            tickets, muts, dt = _mutation_stream(
                engine, X_np, Qm, reqs, None, mutate_every=4
            )
            if durable is not None:
                if pass_ == "timed" and mode == "interval":
                    st = durable.stats()
                    wal_note = (
                        f";wal_appends={st['appends']};"
                        f"wal_bytes={st['appended_bytes']};"
                        f"wal_fsyncs={st['fsyncs']}"
                    )
                durable.close()
                shutil.rmtree(tmp, ignore_errors=True)
        p99 = np.percentile([t.stats.latency_s for t in tickets], 99)
        per_mode[mode] = (len(muts) / dt, 1e3 * p99)
        if mode == "interval":
            us_interval = 1e6 * dt / len(reqs)
    derived = ";".join(
        f"{m}_muts_per_s={r:.1f};p99_{m}_ms={p:.2f}"
        for m, (r, p) in per_mode.items()
    )
    # free the per-mode engines/ticket graphs before the next stage —
    # eight index builds of residue otherwise skews later timings
    del engine, idx, tickets, muts
    gc.collect()
    return [row("serving/durability_flat", us_interval,
                derived + wal_note)]


def _closed_loop_direct(index, n_clients, reqs_each, pool, nprobe):
    """The no-engine baseline for the closed-loop rows: each client
    thread calls ``index.search`` per request and blocks on the device
    result — every request pays its own dispatch and its own gather,
    and concurrent callers serialize instead of sharing a fused call.
    Returns (per-request latencies, wall seconds)."""
    lats = [[] for _ in range(n_clients)]
    errors = []

    def client(cid):
        rng = np.random.RandomState(1000 + cid)
        try:
            for _ in range(reqs_each):
                q = pool[rng.randint(0, pool.shape[0])][None, :]
                t1 = time.perf_counter()
                jax.block_until_ready(
                    index.search(q, k=10, nprobe=nprobe)
                )
                lats[cid].append(time.perf_counter() - t1)
        except Exception as e:
            errors.append(e)

    threads = [
        threading.Thread(target=client, args=(c,), daemon=True)
        for c in range(n_clients)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return np.concatenate([np.asarray(x) for x in lats]), dt


def _closed_loop(index, n_clients, reqs_each, Qm, *, nprobe=None,
                 mutator=None, auto_compact=None, background=False,
                 engine_kw=None, buckets=(8, 32), warm_pool=None):
    """Closed-loop clients through a ServingFrontend: each thread
    submits a 1-row request, blocks on its ticket, repeats.  Returns
    (per-request latencies, wall seconds, engine).  ``mutator(fe,
    stop)`` runs on its own thread for the duration when given;
    ``background`` attaches a BackgroundCompactor so ``auto_compact``
    leaves the serving path; ``engine_kw`` adds EngineConfig overrides
    (the adaptive-probing row arms row_budget/nprobe_min here);
    ``buckets`` picks the engine's batch-bucket ladder.  ``warm_pool``
    streams those rows through the engine before the clock starts so
    a hot-pool run measures the steady state (prep/probe caches warm,
    like the jit warmup both paths already get) rather than the
    one-time cold fill."""
    from repro.serving.compactor import BackgroundCompactor
    from repro.serving.frontend import ServingFrontend

    engine = QueryEngine(index, batch_buckets=buckets,
                         max_wait_s=0.002, auto_compact=auto_compact,
                         **(engine_kw or {}))
    if warm_pool is not None:
        wb = max(buckets)
        for s in range(0, warm_pool.shape[0], wb):
            engine.search(warm_pool[s:s + wb], k=10, nprobe=nprobe)
    compactor = (
        BackgroundCompactor(engine).start() if background else None
    )
    lats = [[] for _ in range(n_clients)]
    errors = []
    stop = threading.Event()
    t0 = time.perf_counter()
    with ServingFrontend(engine) as fe:
        def client(cid):
            rng = np.random.RandomState(1000 + cid)
            try:
                for _ in range(reqs_each):
                    q = Qm[rng.randint(0, Qm.shape[0])][None, :]
                    t1 = time.perf_counter()
                    fe.search(q, k=10, nprobe=nprobe, timeout=120.0)
                    lats[cid].append(time.perf_counter() - t1)
            except Exception as e:
                errors.append(e)

        threads = [
            threading.Thread(target=client, args=(c,), daemon=True)
            for c in range(n_clients)
        ]
        mut_thread = None
        if mutator is not None:
            mut_thread = threading.Thread(
                target=mutator, args=(fe, stop), daemon=True
            )
            mut_thread.start()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stop.set()
        if mut_thread is not None:
            mut_thread.join(timeout=120.0)
    dt = time.perf_counter() - t0
    if compactor is not None:
        compactor.wait_idle(60.0)
        compactor.stop()
    if errors:
        raise errors[0]
    return np.concatenate([np.asarray(x) for x in lats]), dt, engine


def serving_concurrent():
    """The concurrent-serving row: closed-loop multi-client QPS/p99
    through the ServingFrontend driver vs the same loop single-caller
    (concurrent clients share buckets a single caller underfills), and
    search p99 while compaction runs in the background vs synchronous
    auto-compaction stalling the serving path.  check_bench enforces
    qps >= qps_single and p99_bg_compact_ms < p99_sync_compact_ms."""
    X, Qm, gt = dataset()
    X_np = np.asarray(X)
    Qm = np.asarray(Qm)
    cfg = ASHConfig(b=2, d=D // 2, n_landmarks=16)
    key = jax.random.PRNGKey(0)
    index = AshIndex.build(key, X, cfg, backend="flat")
    reqs_each = 16 if QUICK else 40

    # warm every bucket the closed loops can hit (driver-batched 1-row
    # requests land in bucket 8; backlog spills into 32)
    warm = QueryEngine(index, batch_buckets=(8, 32), max_wait_s=0.002)
    for b in (8, 32):
        warm.search(Qm[:b] if Qm.shape[0] >= b else Qm, k=10)

    lat1, dt1, _ = _closed_loop(index, 1, 8 * reqs_each, Qm)
    qps_single = lat1.size / dt1
    lat8, dt8, engine = _closed_loop(index, 8, reqs_each, Qm)
    qps = lat8.size / dt8
    p50, p99 = np.percentile(lat8, [50, 99])
    st = engine.stats.snapshot()

    # compaction-active p99: cycles of (add B rows, delete them) push
    # the dead fraction over auto_compact every cycle; the index
    # returns to warmed shapes each cycle so the runs compare the
    # compaction path itself, not stray recompiles.  Synchronous
    # auto-compaction rebuilds survivors inline under the index
    # barrier (searches queue behind it); the background compactor
    # rebuilds off-thread and only swaps under the barrier.
    B = 128
    cycles = 4 if QUICK else 8

    def mutator(fe, stop):
        for _ in range(cycles):
            if stop.is_set():
                return
            ids = fe.submit_add(
                X_np[np.random.RandomState(5).randint(0, X_np.shape[0],
                                                      B)]
            ).result(120.0)
            fe.submit_delete(ids).result(120.0)
            # let compaction land before the next cycle so both runs
            # walk the same (warmed) payload-shape sequence
            t_wait = time.perf_counter()
            while (fe.engine.index().n_dead
                   and time.perf_counter() - t_wait < 30.0):
                time.sleep(0.001)

    def compaction_run(background):
        idx = AshIndex.build(key, X, cfg, backend="flat",
                             model=index.model)
        warm2 = QueryEngine(idx, batch_buckets=(8, 32),
                            max_wait_s=0.002)
        warm2.search(Qm[:8], k=10)
        warm_ids = warm2.submit_add(X_np[:B]).result()  # warm n0+B
        warm2.search(Qm[:8], k=10)  # trace at the grown payload shape
        warm2.submit_delete(warm_ids).result()
        idx.compact()  # back to n0; compact internals warmed
        warm2.search(Qm[:8], k=10)
        lats, _, eng = _closed_loop(
            idx, 4, reqs_each, Qm, mutator=mutator,
            auto_compact=0.001, background=background,
        )
        return float(np.percentile(lats, 99)), eng

    p99_sync, _ = compaction_run(background=False)
    p99_bg, eng_bg = compaction_run(background=True)
    comp = eng_bg.stats.snapshot()["compaction"]

    return [row(
        "serving/concurrent_flat_c8", 1e6 * dt8 / lat8.size,
        f"qps={qps:.0f};qps_single={qps_single:.0f};"
        f"p50_ms={1e3 * p50:.2f};p99_ms={1e3 * p99:.2f};"
        f"p99_sync_compact_ms={1e3 * p99_sync:.2f};"
        f"p99_bg_compact_ms={1e3 * p99_bg:.2f};"
        f"bg_runs={comp['runs']};bg_retries={comp['retries']};"
        f"fill={st['bucket_fill']}",
    )]


def serving_adaptive():
    """Load-adaptive probing under genuine queue pressure: 8
    closed-loop clients hammer an IVF index through the frontend
    driver with ``row_budget`` + ``nprobe_min`` armed and a tight
    pressure horizon.  While fused gathers hold the driver, waiting
    groups age past the horizon and flushes walk the nprobe ladder
    down; when the queue drains, fidelity recovers.  The row surfaces
    the recall-trade telemetry (degraded flush count, effective-nprobe
    floor, deduped rows per query) next to the latency it buys."""
    X, Qm, gt = dataset()
    X_np = np.asarray(X)
    cfg = ASHConfig(b=2, d=D // 2, n_landmarks=32)
    key = jax.random.PRNGKey(0)
    ivf = AshIndex.build(key, X, cfg, backend="ivf")
    reqs_each = 16 if QUICK else 40

    warm = QueryEngine(ivf, batch_buckets=(8, 32), max_wait_s=0.002)
    for b in (8, 32):
        warm.search(X_np[:b], k=10, nprobe=8)

    engine_kw = dict(
        row_budget=int(0.5 * ivf.n), nprobe_min=2,
        pressure_age_s=0.02,
    )
    # warm the degraded rungs of the ladder too (8 -> 4 -> 2), so the
    # timed loop never charges a rung's first trace to a ticket
    for np_w in (4, 2):
        warm.search(X_np[:8], k=10, nprobe=np_w)
    lats, dt, engine = _closed_loop(
        ivf, 8, reqs_each, X_np, nprobe=8, engine_kw=engine_kw
    )
    p50, p99 = np.percentile(lats, [50, 99])
    st = engine.stats.snapshot()
    ic = st["ivf_cost"]
    eff = {int(k): v for k, v in ic["effective_nprobe"].items()}
    return [row(
        "serving/adaptive_ivf_c8", 1e6 * dt / lats.size,
        f"qps={lats.size / dt:.0f};"
        f"p50_ms={1e3 * p50:.2f};p99_ms={1e3 * p99:.2f};"
        f"degraded={ic['degraded']};"
        f"min_eff_nprobe={min(eff) if eff else 8};"
        f"rows_per_q={ic['rows_per_query']};"
        f"budget_flushes={st['flushes']['budget']}",
    )]


def _tiered_pass(index, Qm, reqs, nprobe):
    """One sequential pass of the request mix through ``index.search``;
    returns (latencies, wall seconds, scores list, ids list)."""
    lats, scores, ids = [], [], []
    t0 = time.perf_counter()
    for i, m in reqs:
        t1 = time.perf_counter()
        s, out_ids = jax.block_until_ready(
            index.search(Qm[i:i + m], k=10, nprobe=nprobe)
        )
        lats.append(time.perf_counter() - t1)
        scores.append(np.asarray(s))
        ids.append(np.asarray(out_ids))
    return np.asarray(lats), time.perf_counter() - t0, scores, ids


def serving_tiered():
    """Tiered IVF (host-resident lists + device hot set) vs the
    HBM-resident IVF it pages for: the same request mix is served by
    the HBM index, by a tiered index whose hot-set budget covers a
    quarter of the payload (cold cache, then steady state), and by a
    covering-budget tiered index whose results must be bit-identical
    to HBM at equal probe sets.  The row carries the cache gauges
    (hit rates, paged rows, resident vs total bytes) the structural
    gate in tools/check_bench.py holds."""
    import tempfile

    from repro.index.tiered import TieredIVFBackend

    X, Qm, gt = dataset()
    cfg = ASHConfig(b=2, d=D // 2, n_landmarks=32)
    key = jax.random.PRNGKey(0)
    nprobe = 8
    hbm = AshIndex.build(key, X, cfg, backend="ivf")
    # same key/config/build path => same model, landmarks and probe
    # sets as the HBM index, so covering-budget results are bitwise
    # comparable request by request
    cover = AshIndex.build(key, X, cfg, backend="tiered_ivf",
                           hot_bytes=1 << 30)
    total = TieredIVFBackend.tier_stats(cover._state)["total_bytes"]
    hot = max(1, total // 4)
    with tempfile.TemporaryDirectory() as tmp:
        cover.save(f"{tmp}/tiered")  # reuse the build, resize the set
        paged = AshIndex.load(f"{tmp}/tiered", hot_bytes=hot)
    Qm = np.asarray(Qm)
    reqs = _request_stream(Qm)
    n_req = len(reqs)

    lat_h = dt_h = None
    for _ in range(2):  # pass 1 compiles the request shapes
        lat_h, dt_h, s_h, i_h = _tiered_pass(hbm, Qm, reqs, nprobe)

    # paged tiered: compile pass, then drop the hot set for a true
    # cold-cache pass, then the steady-state pass over the same mix
    _tiered_pass(paged, Qm, reqs, nprobe)
    paged._state.cache.clear()
    t0 = TieredIVFBackend.tier_stats(paged._state)
    lat_c, dt_c, _, _ = _tiered_pass(paged, Qm, reqs, nprobe)
    t1 = TieredIVFBackend.tier_stats(paged._state)
    lat_w, dt_w, _, _ = _tiered_pass(paged, Qm, reqs, nprobe)
    t2 = TieredIVFBackend.tier_stats(paged._state)
    paged_rows_cold = t1["paged_rows"] - t0["paged_rows"]
    warm_lookups = (t2["hits"] - t1["hits"]) + (t2["misses"] - t1["misses"])
    hit_warm = (t2["hits"] - t1["hits"]) / max(1, warm_lookups)

    # covering budget: one fill pass, then every probe hits the cache
    _tiered_pass(cover, Qm, reqs, nprobe)
    c1 = TieredIVFBackend.tier_stats(cover._state)
    lat_v, dt_v, s_v, i_v = _tiered_pass(cover, Qm, reqs, nprobe)
    c2 = TieredIVFBackend.tier_stats(cover._state)
    cover_lookups = (c2["hits"] - c1["hits"]) + (c2["misses"] - c1["misses"])
    hit_cover = (c2["hits"] - c1["hits"]) / max(1, cover_lookups)
    bitwise = int(all(
        np.array_equal(a, b) and np.array_equal(c, d)
        for (a, b), (c, d) in zip(zip(s_h, s_v), zip(i_h, i_v))
    ))
    rec = recall10(np.concatenate(i_v, axis=0), gt)

    p99_h, p99_c, p99_w, p99_v = (
        float(np.percentile(x, 99)) for x in (lat_h, lat_c, lat_w, lat_v)
    )
    return [row(
        "serving/tiered_ivf", 1e6 * dt_w / n_req,
        f"qps={n_req / dt_w:.0f};qps_hbm={n_req / dt_h:.0f};"
        f"qps_cold={n_req / dt_c:.0f};qps_cover={n_req / dt_v:.0f};"
        f"p99_hbm_ms={1e3 * p99_h:.2f};p99_cold_ms={1e3 * p99_c:.2f};"
        f"p99_warm_ms={1e3 * p99_w:.2f};p99_cover_ms={1e3 * p99_v:.2f};"
        f"hit_rate_warm={hit_warm:.4f};hit_rate_cover={hit_cover:.4f};"
        f"hot_bytes={hot};total_bytes={total};"
        f"paged_rows_cold={paged_rows_cold};"
        f"bitwise_cover={bitwise};recall_at_10={rec:.4f}",
    )]


# serving_durability runs LAST: its four per-mode engine builds leave
# enough allocator/jit-cache residue to visibly inflate the
# sync-vs-background compaction p99 comparison in serving_concurrent
# when it runs earlier in the process
ALL = [serving_engine, serving_mutation, serving_concurrent,
       serving_adaptive, serving_tiered, serving_durability]
