"""Benchmark harness — one function per paper table/figure plus the
serving-engine suite.

Prints ``name,us_per_call,derived`` CSV rows (plus section markers);
``--json`` additionally emits the machine-readable perf trajectory:

  PYTHONPATH=src python -m benchmarks.run                 # full suite
  PYTHONPATH=src python -m benchmarks.run fig9            # substring filter
  PYTHONPATH=src python -m benchmarks.run --json          # + BENCH_*.json
  PYTHONPATH=src python -m benchmarks.run --json out.json # + combined file
  PYTHONPATH=src python -m benchmarks.run --quick --json  # CI smoke size

With ``--json``, one ``BENCH_<group>.json`` file per benchmark group
(figures / kernels / serving) is written to the working directory so CI
artifacts and committed snapshots can track regressions over PRs;
``tools/check_bench.py`` gates on their contents.
"""
import argparse
import json
import os
import sys
import time

SCHEMA_VERSION = 1


def _parse_derived(derived: str) -> dict:
    """'k1=v1;k2=v2' -> dict, values floated where possible."""
    out = {}
    for part in str(derived).split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("filter", nargs="?", default="",
                   help="substring filter on benchmark function names")
    p.add_argument("--json", nargs="?", const="", default=None,
                   metavar="OUT",
                   help="write BENCH_<group>.json files (and a combined "
                        "file at OUT, if given)")
    p.add_argument("--quick", action="store_true",
                   help="reduced problem size (CI smoke)")
    args = p.parse_args(argv)

    if args.json and not args.json.endswith(".json"):
        # nargs="?" would otherwise swallow a positional filter, e.g.
        # `benchmarks.run --json fig9` silently running the full suite
        p.error(f"--json OUT must end in .json (got {args.json!r}); "
                f"put the filter before --json")
    if args.quick:
        os.environ["ASH_BENCH_QUICK"] = "1"
    sys.path.insert(0, "src")
    from benchmarks import kernel_bench, paper_figures, serving_bench

    groups = (
        ("figures", paper_figures.ALL),
        ("kernels", kernel_bench.ALL),
        ("serving", serving_bench.ALL),
    )
    print("name,us_per_call,derived")
    results = {g: [] for g, _ in groups}
    t0 = time.time()
    for group, fns in groups:
        for fn in fns:
            if args.filter and args.filter not in fn.__name__:
                continue
            print(f"# --- {fn.__name__} ---", flush=True)
            try:
                for r in fn():
                    print(r, flush=True)
                    name, us, derived = str(r).split(",", 2)
                    results[group].append({
                        "name": name,
                        "us_per_call": float(us),
                        "derived": _parse_derived(derived),
                        "error": None,
                    })
            except Exception as e:  # keep the harness running
                print(f"{fn.__name__},0,ERROR:{e!r}", flush=True)
                results[group].append({
                    "name": fn.__name__,
                    "us_per_call": 0.0,
                    "derived": {},
                    "error": repr(e),
                })
    total_s = time.time() - t0
    print(f"# total {total_s:.1f}s")

    if args.json is not None:
        combined = {
            "schema_version": SCHEMA_VERSION,
            "quick": args.quick,
            "filter": args.filter,
            "total_s": round(total_s, 1),
            "groups": {g: rows for g, rows in results.items() if rows},
        }
        # The BENCH_<group>.json snapshots track the full-size perf
        # trajectory across PRs — never clobber them with quick-size or
        # filtered partial rows (those go to the combined OUT only).
        if not args.quick and not args.filter:
            for group, _ in groups:
                rows = results[group]
                if not rows:
                    continue
                payload = {
                    "schema_version": SCHEMA_VERSION,
                    "group": group,
                    "quick": args.quick,
                    "rows": rows,
                }
                path = f"BENCH_{group}.json"
                with open(path, "w") as f:
                    json.dump(payload, f, indent=1)
                print(f"# wrote {path} ({len(rows)} rows)")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(combined, f, indent=1)
            print(f"# wrote {args.json}")
        elif args.quick or args.filter:
            print("# quick/filtered run: snapshot files skipped "
                  "(pass --json OUT for a combined file)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
