"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section markers).

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run fig9       # substring filter
"""
import sys
import time


def main() -> None:
    sys.path.insert(0, "src")
    filt = sys.argv[1] if len(sys.argv) > 1 else ""
    from benchmarks import kernel_bench, paper_figures

    fns = paper_figures.ALL + kernel_bench.ALL
    print("name,us_per_call,derived")
    t0 = time.time()
    for fn in fns:
        if filt and filt not in fn.__name__:
            continue
        print(f"# --- {fn.__name__} ---", flush=True)
        try:
            for r in fn():
                print(r, flush=True)
        except Exception as e:  # keep the harness running
            print(f"{fn.__name__},0,ERROR:{e!r}", flush=True)
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
