"""CI gate over benchmark JSON emitted by ``benchmarks.run --json``.

  python tools/check_bench.py bench.json BENCH_*.json

Fails (exit 1) when a file is missing/malformed, contains no rows, or
carries ERROR rows — so a benchmark function silently dying turns CI
red instead of quietly truncating the perf trajectory.
"""
from __future__ import annotations

import json
import sys

EXPECTED_SCHEMA = 1
ROW_KEYS = {"name", "us_per_call", "derived", "error"}


def _rows_of(doc: dict, path: str) -> list:
    if "groups" in doc:  # combined file from --json OUT
        rows = [r for g in doc["groups"].values() for r in g]
    else:  # per-group BENCH_<group>.json
        rows = doc.get("rows", [])
    if not isinstance(rows, list):
        raise ValueError(f"{path}: rows is not a list")
    return rows


def check(path: str) -> list[str]:
    """Problems found in one bench JSON file ([] == healthy)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    problems = []
    if doc.get("schema_version") != EXPECTED_SCHEMA:
        problems.append(
            f"{path}: schema_version {doc.get('schema_version')!r} "
            f"!= {EXPECTED_SCHEMA}"
        )
    try:
        rows = _rows_of(doc, path)
    except ValueError as e:
        return problems + [str(e)]
    if not rows:
        problems.append(f"{path}: no benchmark rows")
    for r in rows:
        if not isinstance(r, dict) or not ROW_KEYS <= set(r):
            problems.append(f"{path}: malformed row {r!r}")
        elif r["error"] is not None:
            problems.append(
                f"{path}: ERROR row {r['name']}: {r['error']}"
            )
    return problems


def main(argv: list[str]) -> int:
    paths = argv or ["bench.json"]
    problems = []
    for path in paths:
        problems.extend(check(path))
    for p in problems:
        print(f"FAIL {p}")
    if problems:
        return 1
    print(f"OK {len(paths)} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
