"""CI gate over benchmark JSON emitted by ``benchmarks.run --json``.

  python tools/check_bench.py bench.json BENCH_*.json
  python tools/check_bench.py --baseline /path/to/old BENCH_*.json

Health checks (always on) fail (exit 1) when a file is missing or
malformed, contains no rows, or carries ERROR rows — so a benchmark
function silently dying turns CI red instead of quietly truncating the
perf trajectory.  Rows carrying the concurrent-serving invariant pairs
are also checked structurally: ``qps`` must not fall below
``qps_single`` (concurrent clients sharing buckets can only help), and
``p99_bg_compact_ms`` must stay strictly below ``p99_sync_compact_ms``
(off-thread compaction must actually leave the serving path), and on
the durability row ``interval_muts_per_s`` must hold at least 0.8x
``nowal_muts_per_s`` (the default WAL fsync policy may not cost more
than 20% of the no-WAL mutation throughput).  Engine
IVF rows that ran the candidate-row cost model (marked by a
``row_budget`` derived field) are gated against the direct IVF row of
the same file: ``p99_ms`` at or below direct's and ``qps`` at >= 2x —
the batching layer must beat the path it wraps, or it has no job.
(Full-size files only: quick smoke corpora are too small for batch
amortization to reach the bar, so quick runs keep the health and
concurrent-row checks but skip this gate.)  Serving rows that ran the
symmetric int8 first pass against the asymmetric baseline (marked by a
``qps_asym`` derived field, e.g. ``serving/coarse_flat``) are gated the
same way: ``qps`` at >= 1.5x ``qps_asym`` with ``recall_at_10`` within
1 point of ``recall_at_10_asym`` — the coarse pass must buy throughput
without giving the quality back.  Also full-size only, and the
throughput half additionally requires an accelerator ``platform``
stamp (not ``cpu``): XLA:CPU lowers both passes to the same-size f32
BLAS GEMM, so the int8 win only exists where an integer MXU runs the
coarse scan — CPU rows track qps honestly but are held only to the
recall half.  Tiered-IVF serving rows (marked by a ``bitwise_cover``
derived field, e.g. ``serving/tiered_ivf``) are gated structurally on
every run including quick: a covering hot-set budget must reproduce
the HBM-resident results bit for bit, the paged configuration must
have actually paged (``total_bytes > hot_bytes``, cold rows
transferred), and the cache-gauge rates must be present and well
formed.

Trajectory diffing (``--baseline DIR``) compares each file against the
same-named snapshot in DIR row by row:

  * ``us_per_call`` and the derived latencies (any ``*_ms`` metric:
    ``p50_ms``/``p99_ms``/``worst_apply_ms``/...) are lower-is-better;
    the higher-is-better derived throughputs (``qps`` plus any
    ``*_per_s`` rate, e.g. the mutation rows'
    ``adds_per_s``/``deletes_per_s``) invert the ratio.  Regressions
    beyond ``--warn-ratio`` print WARN lines; beyond ``--fail-ratio``
    they fail the gate.
  * any ``recall_at_*`` derived metric is higher-is-better and diffed
    ABSOLUTELY, not by ratio: a drop beyond 2 points (0.02) fails, a
    drop beyond half a point warns.  Recall near 1.0 makes ratios
    useless — 0.99 -> 0.97 is a 1.02x "slowdown" but a real quality
    regression.
  * rows present in the baseline but missing from the current file
    warn (the trajectory would silently truncate otherwise); so does
    a diffable metric present on only one side of a surviving row —
    in either direction — instead of silently dropping out of the
    comparison.
  * files whose ``quick`` mode differs from the baseline's are skipped
    with a note — quick (CI-smoke) and full-size numbers are not
    comparable.
  * rows stamped with corpus-shape metadata (``n``/``d``/``b``/``m``
    derived fields, the kernel rows) refuse to diff against a
    baseline row with a DIFFERENT shape: a retuned benchmark corpus
    would otherwise masquerade as a perf change.  Mismatched rows are
    skipped with a warning.

Combined files (from ``--json OUT``) diff each group against the
baseline's ``BENCH_<group>.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

EXPECTED_SCHEMA = 1
ROW_KEYS = {"name", "us_per_call", "derived", "error"}


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _rows_of(doc: dict, path: str) -> list:
    if "groups" in doc:  # combined file from --json OUT
        rows = [r for g in doc["groups"].values() for r in g]
    else:  # per-group BENCH_<group>.json
        rows = doc.get("rows", [])
    if not isinstance(rows, list):
        raise ValueError(f"{path}: rows is not a list")
    return rows


def _invariant_problems(path: str, r: dict) -> list[str]:
    """Structural invariants on rows that carry the concurrent-serving
    metric pairs (keyed on metric presence, not row names, so future
    rows inherit the gate)."""
    problems = []
    der = r.get("derived") or {}

    def _num(key):
        v = der.get(key)
        return v if isinstance(v, (int, float)) else None

    qps, single = _num("qps"), _num("qps_single")
    if qps is not None and single is not None and qps < single:
        problems.append(
            f"{path}: {r['name']} concurrent qps {qps:g} < "
            f"single-caller qps {single:g} (batch sharing regressed)"
        )
    bg = _num("p99_bg_compact_ms")
    sync = _num("p99_sync_compact_ms")
    if bg is not None and sync is not None and bg >= sync:
        problems.append(
            f"{path}: {r['name']} p99_bg_compact_ms {bg:g} >= "
            f"p99_sync_compact_ms {sync:g} (background compaction "
            f"not off the serving path)"
        )
    nowal = _num("nowal_muts_per_s")
    interval = _num("interval_muts_per_s")
    if nowal is not None and interval is not None \
            and interval < 0.8 * nowal:
        problems.append(
            f"{path}: {r['name']} interval_muts_per_s {interval:g} < "
            f"0.8x nowal_muts_per_s {nowal:g} (WAL overhead under the "
            f"default fsync policy exceeds the durability budget)"
        )
    return problems


def _num_of(der: dict, key: str):
    v = der.get(key)
    return v if isinstance(v, (int, float)) else None


def _ivf_cost_problems(path: str, rows: "dict[str, dict]") -> list[str]:
    """Cross-row gate for the IVF cost model: every
    ``serving/engine_ivf*`` row that ran with the candidate-row cost
    model (marked by a ``row_budget`` derived field) must beat the
    file's ``serving/direct_ivf*`` row (exact name preferred, else the
    first such row — e.g. a client-count-suffixed ``direct_ivf_c32``)
    — ``p99_ms`` at or below it and ``qps`` at >= 2x.  Batching that
    loses the tail AND the throughput to the path it wraps has no job;
    uncosted contrast rows (no ``row_budget`` field) stay ungated.
    Full-size runs only (the caller skips quick files): at smoke-test
    corpus sizes the per-query device work is too small for batch
    amortization to reach 2x, so the bar is a full-geometry claim —
    same reasoning as the quick-vs-full diff skip."""
    direct = rows.get("serving/direct_ivf")
    if direct is None:
        cands = sorted(
            n for n in rows if n.startswith("serving/direct_ivf")
        )
        direct = rows[cands[0]] if cands else None
    if direct is None:
        return []
    d_der = direct.get("derived") or {}
    d_qps, d_p99 = _num_of(d_der, "qps"), _num_of(d_der, "p99_ms")
    if d_qps is None or d_p99 is None:
        return []
    problems = []
    for name, r in sorted(rows.items()):
        if not name.startswith("serving/engine_ivf"):
            continue
        der = r.get("derived") or {}
        if _num_of(der, "row_budget") is None:
            continue
        p99, qps = _num_of(der, "p99_ms"), _num_of(der, "qps")
        if p99 is not None and p99 > d_p99:
            problems.append(
                f"{path}: {name} p99_ms {p99:g} > direct_ivf p99_ms "
                f"{d_p99:g} (cost-model batching lost the tail to the "
                f"direct path)"
            )
        if qps is not None and qps < 2 * d_qps:
            problems.append(
                f"{path}: {name} qps {qps:g} < 2x direct_ivf qps "
                f"{d_qps:g} (cost-model batching lost the throughput "
                f"win)"
            )
    return problems


def _coarse_serving_problems(
    path: str, rows: "dict[str, dict]"
) -> list[str]:
    """Structural gate for serving rows that measured the symmetric
    int8 first pass against the asymmetric baseline in the same run
    (keyed on the ``qps_asym`` derived field, not row names, so future
    coarse rows inherit it): ``qps`` must reach 1.5x ``qps_asym`` and
    ``recall_at_10`` must stay within 1 point of ``recall_at_10_asym``.
    Full-size runs only (the caller skips quick files): quick corpora
    are small enough that per-call dispatch overhead, not the scan the
    coarse pass shortcuts, dominates the wall clock.

    The throughput half only arms on accelerator rows (``platform``
    stamp present and not ``cpu``): on XLA:CPU both passes lower to
    the same-size f32 BLAS GEMM (the code unpack fuses into the asym
    scan for free), so there is no win to hold — the int8 first pass
    pays off where an integer MXU eats the coarse scan at multiples
    of fp32 throughput.  CPU rows still record qps/qps_asym for the
    trajectory and keep the recall gate, which is
    platform-independent."""
    problems = []
    for name, r in sorted(rows.items()):
        der = r.get("derived") or {}
        qps, asym = _num_of(der, "qps"), _num_of(der, "qps_asym")
        if qps is None or asym is None:
            continue
        platform = der.get("platform")
        if (platform is not None and platform != "cpu"
                and qps < 1.5 * asym):
            problems.append(
                f"{path}: {name} qps {qps:g} < 1.5x asymmetric qps "
                f"{asym:g} (coarse first pass lost its throughput win)"
            )
        rec = _num_of(der, "recall_at_10")
        rec_a = _num_of(der, "recall_at_10_asym")
        if rec is not None and rec_a is not None and rec < rec_a - 0.01:
            problems.append(
                f"{path}: {name} recall_at_10 {rec:g} more than 1 "
                f"point below the asymmetric path's {rec_a:g} (coarse "
                f"shortlist too aggressive)"
            )
    return problems


def _tiered_serving_problems(path: str, rows: "dict[str, dict]") -> list[str]:
    """Structural gate for tiered-IVF serving rows (keyed on the
    ``bitwise_cover`` derived field, not row names): a covering hot-set
    budget must reproduce the HBM-resident results bit for bit
    (``bitwise_cover == 1`` and a saturated cover-pass hit rate), and
    the paged configuration must actually have paged — a payload
    larger than the hot-set budget, cold-pass rows transferred, and
    the cache gauges present to prove it.  These are correctness
    claims, not perf bars, so they hold on quick files too."""
    problems = []
    for name, r in sorted(rows.items()):
        der = r.get("derived") or {}
        bitwise = _num_of(der, "bitwise_cover")
        if bitwise is None:
            continue
        if bitwise != 1:
            problems.append(
                f"{path}: {name} bitwise_cover {bitwise:g} != 1 "
                f"(covering-budget tiered results diverged from the "
                f"HBM-resident index)"
            )
        hot, total = _num_of(der, "hot_bytes"), _num_of(der, "total_bytes")
        if hot is None or total is None:
            problems.append(
                f"{path}: {name} missing hot_bytes/total_bytes cache "
                f"gauges"
            )
        elif total <= hot:
            problems.append(
                f"{path}: {name} total_bytes {total:g} <= hot_bytes "
                f"{hot:g} (paged configuration never exceeded its "
                f"hot-set budget — nothing was tiered)"
            )
        paged = _num_of(der, "paged_rows_cold")
        if paged is None or paged <= 0:
            problems.append(
                f"{path}: {name} paged_rows_cold "
                f"{'missing' if paged is None else '%g' % paged} "
                f"(cold pass transferred no rows)"
            )
        for key in ("hit_rate_warm", "hit_rate_cover"):
            v = _num_of(der, key)
            if v is None or not 0.0 <= v <= 1.0:
                problems.append(
                    f"{path}: {name} {key} "
                    f"{'missing' if v is None else '%g' % v} "
                    f"(expected a rate in [0, 1])"
                )
        cover = _num_of(der, "hit_rate_cover")
        if cover is not None and cover < 0.99:
            problems.append(
                f"{path}: {name} hit_rate_cover {cover:g} < 0.99 "
                f"(covering budget still missing the cache)"
            )
    return problems


def check(path: str) -> list[str]:
    """Problems found in one bench JSON file ([] == healthy)."""
    try:
        doc = _load(path)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    problems = []
    if doc.get("schema_version") != EXPECTED_SCHEMA:
        problems.append(
            f"{path}: schema_version {doc.get('schema_version')!r} "
            f"!= {EXPECTED_SCHEMA}"
        )
    try:
        rows = _rows_of(doc, path)
    except ValueError as e:
        return problems + [str(e)]
    if not rows:
        problems.append(f"{path}: no benchmark rows")
    healthy: "dict[str, dict]" = {}
    for r in rows:
        if not isinstance(r, dict) or not ROW_KEYS <= set(r):
            problems.append(f"{path}: malformed row {r!r}")
        elif r["error"] is not None:
            problems.append(
                f"{path}: ERROR row {r['name']}: {r['error']}"
            )
        else:
            problems.extend(_invariant_problems(path, r))
            healthy[r["name"]] = r
    problems.extend(_tiered_serving_problems(path, healthy))
    if not doc.get("quick"):
        problems.extend(_ivf_cost_problems(path, healthy))
        problems.extend(_coarse_serving_problems(path, healthy))
    return problems


# ---------------------------------------------------------------------------
# Trajectory diffing
# ---------------------------------------------------------------------------


def _healthy_rows(doc: dict, path: str) -> dict[str, dict]:
    """name -> row map of well-formed, non-ERROR rows."""
    out = {}
    for r in _rows_of(doc, path):
        if isinstance(r, dict) and ROW_KEYS <= set(r) and r["error"] is None:
            out[r["name"]] = r
    return out


def _throughput_keys(derived: dict) -> list[str]:
    """Higher-is-better derived metrics: qps and any *_per_s rate
    (adds_per_s / deletes_per_s on the mutation rows).  qps_single is
    a reference point inside the concurrent row, not a trajectory."""
    return [
        k for k in derived
        if k == "qps" or k.endswith("_per_s")
    ]


def _latency_keys(derived: dict) -> list[str]:
    """Lower-is-better derived metrics: any *_ms latency
    (p50_ms / p99_ms / worst_apply_ms / p99_*_compact_ms)."""
    return [k for k in derived if k.endswith("_ms")]


# Corpus-shape metadata stamped on kernel rows (benchmarks stamp
# n/d/b/m via srow); rows carrying it only diff against a baseline row
# of the SAME shape.
SHAPE_KEYS = ("n", "d", "b", "m")


def _shape_of(r: dict):
    """(n, d, b, m) stamp of a row, or None if unstamped."""
    der = r.get("derived") or {}
    vals = tuple(der.get(k) for k in SHAPE_KEYS)
    return vals if any(v is not None for v in vals) else None


def _recall_drops(base: dict, cur: dict) -> list[tuple]:
    """[(metric, absolute_drop)] for every higher-is-better
    ``recall_at_*`` derived metric present on both sides (drop > 0 ==
    quality regressed).  Absolute points, not ratios: recall saturates
    near 1.0 where ratios hide real losses."""
    out = []
    b_der = base.get("derived", {})
    c_der = cur.get("derived", {})
    for key in b_der:
        if not key.startswith("recall_at"):
            continue
        b_v, c_v = b_der.get(key), c_der.get(key)
        if isinstance(b_v, (int, float)) and isinstance(c_v, (int, float)):
            out.append((key, b_v - c_v))
    return out


def _row_regressions(name: str, base: dict, cur: dict) -> list[tuple]:
    """[(metric, ratio)] regression factors for one row (ratio > 1 ==
    slower); us_per_call and *_ms latencies are lower-better, derived
    throughputs (qps, *_per_s) higher-better."""
    out = []
    b_us, c_us = base.get("us_per_call", 0), cur.get("us_per_call", 0)
    if b_us and c_us:  # rows timing nothing (us == 0) carry no signal
        out.append(("us_per_call", c_us / b_us))
    b_der = base.get("derived", {})
    c_der = cur.get("derived", {})
    for key in _throughput_keys(b_der):
        b_v, c_v = b_der.get(key), c_der.get(key)
        if isinstance(b_v, (int, float)) and isinstance(c_v, (int, float)) \
                and b_v > 0 and c_v > 0:
            out.append((key, b_v / c_v))
    for key in _latency_keys(b_der):
        b_v, c_v = b_der.get(key), c_der.get(key)
        if isinstance(b_v, (int, float)) and isinstance(c_v, (int, float)) \
                and b_v > 0 and c_v > 0:
            out.append((key, c_v / b_v))
    return out


def _diffable_keys(r: dict) -> set[str]:
    """Derived metric keys the trajectory diff would compare: the
    throughputs, the latencies and the recall points."""
    der = r.get("derived") or {}
    return {
        k for k in der
        if k == "qps" or k.endswith("_per_s") or k.endswith("_ms")
        or k.startswith("recall_at")
    }


def _one_sided_metrics(base: dict, cur: dict) -> list[tuple[str, str]]:
    """[(metric, side)] for diffable metrics present on only one side
    of a row comparison.  The ratio loops skip these silently, so a
    metric that vanishes (or appears) would otherwise drop out of the
    trajectory without a trace — surface it as a warning instead."""
    b, c = _diffable_keys(base), _diffable_keys(cur)
    return ([(k, "baseline") for k in sorted(b - c)]
            + [(k, "current") for k in sorted(c - b)])


def diff(
    path: str, baseline_dir: str, warn_ratio: float, fail_ratio: float
) -> tuple[list[str], list[str]]:
    """(failures, warnings) from comparing ``path`` against the
    same-named snapshot (or per-group snapshots) under baseline_dir."""
    try:
        doc = _load(path)
    except (OSError, json.JSONDecodeError):
        return [], []  # health check already reported it

    # (current rows, baseline file) pairs to compare
    pairs = []
    if "groups" in doc:
        for group, rows in doc["groups"].items():
            pairs.append((
                {r["name"]: r for r in rows
                 if isinstance(r, dict) and r.get("error") is None},
                os.path.join(baseline_dir, f"BENCH_{group}.json"),
            ))
    else:
        pairs.append((
            _healthy_rows(doc, path),
            os.path.join(baseline_dir, os.path.basename(path)),
        ))

    failures, warnings = [], []
    for cur_rows, base_path in pairs:
        if not os.path.exists(base_path):
            warnings.append(
                f"{path}: no baseline {base_path} (new group?) — skipped"
            )
            continue
        try:
            base_doc = _load(base_path)
        except (OSError, json.JSONDecodeError) as e:
            warnings.append(f"{base_path}: unreadable baseline ({e})")
            continue
        if bool(base_doc.get("quick")) != bool(doc.get("quick")):
            warnings.append(
                f"{path} vs {base_path}: quick/full size mismatch — "
                f"not comparable, diff skipped"
            )
            continue
        base_rows = _healthy_rows(base_doc, base_path)
        for name, base_row in base_rows.items():
            cur = cur_rows.get(name)
            if cur is None:
                warnings.append(
                    f"{path}: row {name} vanished vs {base_path} "
                    f"(trajectory truncation)"
                )
                continue
            b_shape, c_shape = _shape_of(base_row), _shape_of(cur)
            if b_shape is not None and c_shape is not None \
                    and b_shape != c_shape:
                warnings.append(
                    f"{path}: {name} corpus shape "
                    f"{dict(zip(SHAPE_KEYS, c_shape))} != baseline "
                    f"{dict(zip(SHAPE_KEYS, b_shape))} — not "
                    f"comparable, diff refused"
                )
                continue
            for metric, side in _one_sided_metrics(base_row, cur):
                warnings.append(
                    f"{path}: {name} metric {metric} present only in "
                    f"the {side} row vs {base_path} — not diffed"
                )
            for metric, drop in _recall_drops(base_row, cur):
                msg = (
                    f"{path}: {name} {metric} dropped "
                    f"{100 * drop:.1f} points vs {base_path}"
                )
                if drop > 0.02:
                    failures.append(msg)
                elif drop > 0.005:
                    warnings.append(msg)
            for metric, ratio in _row_regressions(name, base_row, cur):
                msg = (
                    f"{path}: {name} {metric} regressed {ratio:.2f}x "
                    f"vs {base_path}"
                )
                if ratio >= fail_ratio:
                    failures.append(msg)
                elif ratio >= warn_ratio:
                    warnings.append(msg)
    return failures, warnings


def main(argv: list[str]) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("paths", nargs="*", default=["bench.json"])
    p.add_argument("--baseline", default=None, metavar="DIR",
                   help="directory of snapshot BENCH_*.json files to "
                        "diff against (same-size runs only)")
    p.add_argument("--warn-ratio", type=float, default=1.5,
                   help="slowdown factor that prints a WARN (default 1.5)")
    p.add_argument("--fail-ratio", type=float, default=3.0,
                   help="slowdown factor that fails the gate (default 3)")
    args = p.parse_args(argv)

    paths = args.paths or ["bench.json"]
    problems, warnings = [], []
    for path in paths:
        problems.extend(check(path))
        if args.baseline is not None:
            f, w = diff(path, args.baseline, args.warn_ratio,
                        args.fail_ratio)
            problems.extend(f)
            warnings.extend(w)
    for w in warnings:
        print(f"WARN {w}")
    for pr in problems:
        print(f"FAIL {pr}")
    if problems:
        return 1
    print(f"OK {len(paths)} file(s) clean"
          + (f", {len(warnings)} warning(s)" if warnings else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
