"""Assemble EXPERIMENTS.md tables from the dry-run / roofline jsonl."""
import json
import sys


def load(path):
    try:
        return [json.loads(l) for l in open(path)]
    except FileNotFoundError:
        return []


def dryrun_table(rows):
    out = [
        "| arch | cell | mesh | peak GiB/dev | TPU-adj GiB | fits 16G "
        "(adj) | AG/AR/RS/CP | async |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        cc = r["collective_counts"]
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {r['peak_gib_per_dev']:.2f} "
            f"| {r['peak_gib_per_dev_tpu_adj']:.2f} "
            f"| {'Y' if r['fits_16g_hbm_tpu_adj'] else 'N'} "
            f"| {cc['all-gather']}/{cc['all-reduce']}"
            f"/{cc['reduce-scatter']}/{cc['collective-permute']} "
            f"| {r['async_collectives']} |"
        )
    return "\n".join(out)


def roofline_table(rows):
    out = [
        "| arch | cell | t_compute (ms) | t_memory (ms) | t_coll (ms) "
        "| bottleneck | useful-FLOP frac | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "t_compute_s" not in r:
            continue
        uf = r.get("useful_flops_frac")
        rf = r.get("roofline_frac")
        out.append(
            f"| {r['arch']} | {r['cell']} "
            f"| {1e3 * r['t_compute_s']:.2f} "
            f"| {1e3 * r['t_memory_s']:.2f} "
            f"| {1e3 * r['t_collective_s']:.2f} "
            f"| **{r['bottleneck']}** "
            f"| {uf if uf is None else round(uf, 3)} "
            f"| {rf if rf is None else round(rf, 4)} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    kind = sys.argv[1]
    rows = load(sys.argv[2])
    if kind == "dryrun":
        print(dryrun_table(rows))
    else:
        print(roofline_table(rows))
