"""Locally-Optimized Product Quantization [Kalantidis & Avrithis 2014].

Coarse k-means into C clusters; for each cluster, residuals are encoded
with a per-cluster rotation (learned by alternating PQ <-> Procrustes,
Eq. 32 of the ASH paper) followed by PQ.  This is the expensive-to-train
additive baseline the paper contrasts with ASH's single shared rotation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines import pq as PQ
from repro.core import learning as L
from repro.core.types import pytree_dataclass


@pytree_dataclass(meta_fields=("M", "b", "C"))
class LOPQState:
    M: int
    b: int
    C: int
    centroids: jax.Array  # (C, D)
    rotations: jax.Array  # (C, D, D)
    codebooks: jax.Array  # (C, M, 2^b, D/M)

    @property
    def bits_per_vector(self) -> int:
        import math

        return self.M * self.b + math.ceil(math.log2(max(self.C, 2)))


def train(
    key: jax.Array,
    X: jax.Array,
    M: int,
    b: int = 8,
    C: int = 8,
    *,
    local_iters: int = 3,
    kmeans_iters: int = 25,
) -> LOPQState:
    X32 = X.astype(jnp.float32)
    D = X32.shape[1]
    k_km, k_pq = jax.random.split(key)
    centroids, assign = L.kmeans(k_km, X32, C, iters=kmeans_iters)
    rotations, codebooks = [], []
    for c in range(C):
        mask = assign == c
        # Static-shape trick: weight rows by mask; k-means on masked rows
        # only.  Simpler: gather via argsort (host-side, training only).
        idx = jnp.nonzero(mask, size=X32.shape[0], fill_value=0)[0]
        count = int(jnp.sum(mask))
        Xc = X32[idx[: max(count, 2 * M)]] - centroids[c]
        st = PQ.train(
            jax.random.fold_in(k_pq, c),
            Xc,
            M,
            b,
            opq_iters=local_iters,
            kmeans_iters=kmeans_iters,
        )
        rotations.append(st.rotation)
        codebooks.append(st.codebooks)
    return LOPQState(
        M=M,
        b=b,
        C=C,
        centroids=centroids,
        rotations=jnp.stack(rotations),
        codebooks=jnp.stack(codebooks),
    )


def encode(state: LOPQState, X: jax.Array):
    """-> (cluster (n,), codes (n, M))."""
    X32 = X.astype(jnp.float32)
    assign = L.assign_clusters(X32, state.centroids)
    resid = X32 - state.centroids[assign]
    rotated = jnp.einsum("nd,nde->ne", resid, state.rotations[assign])
    codes = jax.vmap(
        lambda cb, r: PQ._assign(cb, r[None])[0]
    )(state.codebooks[assign], rotated)
    return assign, codes


def score(state: LOPQState, encoded, Qm: jax.Array) -> jax.Array:
    """<q, mu_c + R_c^T quant(residual)> per vector: (m, n).

    Accumulates per cluster with masking — gathering per-ROW copies of
    the (M, m, 2^b) tables (T[assign]) would materialize an
    (n, M, m, 2^b) tensor (~100 GB at benchmark sizes).
    """
    assign, codes = encoded
    Q32 = Qm.astype(jnp.float32)
    # Rotate the query into every cluster's frame once: (C, m, D)
    Qrot = jnp.einsum("qd,cde->cqe", Q32, state.rotations)
    # Per-cluster segment LUTs: (C, M, m, 2^b)
    M = state.M
    ds = Q32.shape[1] // M
    Qseg = Qrot.reshape(state.C, -1, M, ds).transpose(0, 2, 1, 3)
    T = jnp.einsum("cmqd,cmkd->cmqk", Qseg, state.codebooks)
    n = codes.shape[0]
    resid_dot = jnp.zeros((Q32.shape[0], n), jnp.float32)
    for c in range(state.C):
        # PQ-style gather against cluster c's tables: (M, m, n)
        g = jnp.take_along_axis(
            T[c][:, :, None, :],  # (M, m, 1, 2^b)
            codes.T[:, None, :, None],  # (M, 1, n, 1)
            axis=3,
        )[..., 0]
        resid_dot = jnp.where(
            (assign == c)[None, :], jnp.sum(g, axis=0), resid_dot
        )
    coarse = Q32 @ state.centroids[assign].T  # (m, n)
    return coarse + resid_dot
