"""EDEN [Vargaftik et al. 2022] and TurboQuant [Zandieh et al. 2025].

Both: random rotation R, then per-dimension b-bit Lloyd-Max scalar
quantization (Eq. 30 of the ASH paper).
  * EDEN scale: s = ||x||_2 / ||R^T w_LM(assign(Rx))||_2  (stored fp).
  * TurboQuant (MSE variant): s = 1, Lloyd-Max grid calibrated to the
    coordinate distribution (coordinates of Rx are ~ N(0, ||x||^2/D); a
    single global std is calibrated from data, since TQ stores no
    per-vector scale — noted deviation, see DESIGN.md).

The Lloyd-Max grid for N(0,1) is computed once by 1-D k-means over a
large deterministic Gaussian sample.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import pytree_dataclass

_EPS = 1e-12


@functools.lru_cache(maxsize=None)
def lloyd_max_grid_np(b: int, n_samples: int = 200_000, iters: int = 60):
    """2^b-level Lloyd-Max quantizer grid for N(0,1), as a numpy array."""
    import numpy as np

    rng = np.random.RandomState(0)
    x = np.sort(rng.randn(n_samples).astype(np.float32))
    # quantile init
    qs = (np.arange(2**b) + 0.5) / (2**b)
    grid = np.quantile(x, qs).astype(np.float32)
    for _ in range(iters):
        mids = (grid[1:] + grid[:-1]) / 2
        idx = np.searchsorted(mids, x)
        sums = np.bincount(idx, weights=x, minlength=2**b)
        cnts = np.bincount(idx, minlength=2**b)
        grid = np.where(cnts > 0, sums / np.maximum(cnts, 1), grid).astype(
            np.float32
        )
    return grid


@pytree_dataclass(meta_fields=("b", "variant"))
class EDENState:
    b: int
    variant: str  # "eden" | "turboquant"
    rotation: jax.Array  # (D, D)
    grid: jax.Array  # (2^b,) Lloyd-Max levels (possibly rescaled)

    @property
    def bits_per_vector(self) -> int:
        D = self.rotation.shape[0]
        return D * self.b + (16 if self.variant == "eden" else 0)


def train(
    key: jax.Array, X: jax.Array, b: int, variant: str = "eden"
) -> EDENState:
    X32 = X.astype(jnp.float32)
    D = X32.shape[1]
    g = jax.random.normal(key, (D, D), dtype=jnp.float32)
    qmat, _ = jnp.linalg.qr(g)
    grid = jnp.asarray(lloyd_max_grid_np(b))
    if variant == "turboquant":
        # calibrate the global coordinate std (TQ stores no per-vector s)
        sample = X32[: min(1024, X32.shape[0])] @ qmat
        grid = grid * jnp.std(sample)
    return EDENState(b=b, variant=variant, rotation=qmat, grid=grid)


@jax.jit
def _nearest_level(grid: jax.Array, y: jax.Array) -> jax.Array:
    mids = (grid[1:] + grid[:-1]) / 2.0
    return jnp.searchsorted(mids, y).astype(jnp.int32)


def encode(state: EDENState, X: jax.Array):
    """-> (codes (n, D) int32, scale (n,) fp32)."""
    X32 = X.astype(jnp.float32)
    Y = X32 @ state.rotation  # (n, D)
    if state.variant == "eden":
        norms = jnp.linalg.norm(Y, axis=-1, keepdims=True)
        Yn = Y / jnp.maximum(norms, _EPS) * jnp.sqrt(
            jnp.float32(Y.shape[1])
        )  # unit-variance coords
        codes = _nearest_level(state.grid, Yn)
        recon = state.grid[codes]
        rnorm = jnp.linalg.norm(recon, axis=-1)
        s = norms[:, 0] / jnp.maximum(rnorm, _EPS)
        return codes, s
    else:
        codes = _nearest_level(state.grid, Y)
        return codes, jnp.ones((X32.shape[0],), jnp.float32)


def decode(state: EDENState, encoded) -> jax.Array:
    codes, s = encoded
    return (s[:, None] * state.grid[codes]) @ state.rotation.T


@jax.jit
def score(state: EDENState, encoded, Qm: jax.Array) -> jax.Array:
    """<q, quant(x)> = s * <Rq, grid[codes]>  (m, n)."""
    codes, s = encoded
    Q32 = Qm.astype(jnp.float32)
    Qrot = Q32 @ state.rotation  # (m, D)
    return (Qrot @ state.grid[codes].T) * s[None, :]
