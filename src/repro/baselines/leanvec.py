"""LeanVec (in-distribution) [Tepper et al., TMLR 2024].

SVD/PCA dimensionality reduction to d, then LVQ [Aguerrebere et al. 2023]
per-vector min-max scalar quantization of the reduced vectors.  The
query is projected too; scoring is <P q, LVQ(P x)>.  Quantization is a
post-processing step (the PCA is NOT refined by the quantizer) — the
drawback Section 4 of the ASH paper highlights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import learning as L
from repro.core.types import pytree_dataclass

_EPS = 1e-12


@pytree_dataclass(meta_fields=("b", "d"))
class LeanVecState:
    b: int
    d: int
    P: jax.Array  # (d, D) top-d right singular vectors
    mean: jax.Array  # (D,) centering

    @property
    def bits_per_vector(self) -> int:
        return self.d * self.b + 2 * 16  # codes + (min, delta) fp16 pair


def train(key: jax.Array, X: jax.Array, d: int, b: int = 4) -> LeanVecState:
    X32 = X.astype(jnp.float32)
    mean = jnp.mean(X32, axis=0)
    P = L.pca_topd(X32 - mean, d)
    return LeanVecState(b=b, d=d, P=P, mean=mean)


@jax.jit
def encode(state: LeanVecState, X: jax.Array):
    """LVQ: per-vector [min, max] range, uniform levels.

    -> (codes (n, d) int32, vmin (n,), delta (n,))."""
    U = (X.astype(jnp.float32) - state.mean) @ state.P.T  # (n, d)
    vmin = jnp.min(U, axis=-1)
    vmax = jnp.max(U, axis=-1)
    levels = 2**state.b - 1
    delta = (vmax - vmin) / levels
    codes = jnp.clip(
        jnp.round((U - vmin[:, None]) / jnp.maximum(delta, _EPS)[:, None]),
        0,
        levels,
    ).astype(jnp.int32)
    return codes, vmin, delta


def decode_reduced(state: LeanVecState, encoded) -> jax.Array:
    codes, vmin, delta = encoded
    return vmin[:, None] + codes.astype(jnp.float32) * delta[:, None]


@jax.jit
def score(state: LeanVecState, encoded, Qm: jax.Array) -> jax.Array:
    """<q - mean, recon> + <q, mean-part> approximation of <q, x>.

    LeanVec scores in the reduced space; we add back the mean term so the
    estimate targets <q, x> like the other baselines."""
    Q32 = Qm.astype(jnp.float32)
    Urecon = decode_reduced(state, encoded)  # (n, d)
    qproj = (Q32 - 0.0) @ state.P.T  # project query (in-distribution)
    red = qproj @ Urecon.T  # (m, n)
    mean_term = Q32 @ state.mean  # (m,)
    return red + mean_term[:, None]
