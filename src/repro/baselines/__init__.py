"""Baseline quantizers the paper compares against (Sections 4-5).

All baselines share the same functional API:

    state            = <method>.train(key, X, **cfg)
    encoded          = <method>.encode(state, X)
    scores (m, n)    = <method>.score(state, encoded, Q)
    state.bits_per_vector  -> payload size for iso-compression sweeps
"""
from repro.baselines import pq, lopq, eden, leanvec, rabitq

__all__ = ["pq", "lopq", "eden", "leanvec", "rabitq"]
