"""RaBitQ [Gao & Long 2024] / extended RaBitQ [Gao et al. 2025].

Per Section 2 of the ASH paper these are exact special cases of the ASH
model: D == d, C == 1, W = random orthogonal rotation; b == 1 (RaBitQ) or
b > 1 (extended).  We therefore implement them as thin wrappers over the
ASH encoder with a data-agnostic model — which doubles as the JL-random-W
ablation of Figure 1 when d < D.

Also provides ``expected_dot_1bit(D)``: the closed-form expectation
E_R[<x, quant_1(Rx)>] of Eq. (33), used by benchmarks/fig2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

from repro.core import ash as A
from repro.core.types import ASHConfig, ASHModel


def train(
    key: jax.Array,
    X: jax.Array,
    b: int = 1,
    d: int = 0,
    center: bool = True,
) -> ASHModel:
    """RaBitQ state == data-agnostic ASH model (random W, C=1)."""
    D = X.shape[1]
    cfg = ASHConfig(b=b, d=(d or D), n_landmarks=1, store_fp16=True)
    return A.random_model(
        key, D, cfg, X_for_landmarks=(X if center else None)
    )


encode = A.encode  # identical payload


def score(model: ASHModel, payload, Qm: jax.Array) -> jax.Array:
    from repro.core import scoring as S

    prep = S.prepare_queries(model, Qm)
    return S.score_dot(model, prep, payload)


def expected_dot_1bit(D: int) -> jnp.ndarray:
    """Eq. (33): E_R[<x, quant_1(Rx)>] = 2 sqrt(D/pi) G(D/2) / ((D-1) G((D-1)/2)).

    ~0.798 for D ~ 1000."""
    Df = jnp.float32(D)
    log_ratio = gammaln(Df / 2.0) - gammaln((Df - 1.0) / 2.0)
    return (
        2.0
        * jnp.sqrt(Df / jnp.pi)
        * jnp.exp(log_ratio)
        / (Df - 1.0)
    )
