"""Product Quantization [Jégou et al., TPAMI 2011] + OPQ rotation option.

PQ splits D dims into M segments, k-means with 2^b centroids per segment;
asymmetric ADC scoring via per-segment lookup tables (Eq. 29 of the ASH
paper).  OPQ [Ge et al. 2014] learns a global rotation by alternating PQ
training with an orthogonal Procrustes step.

On TPU the ADC table lookup lowers to a gather HLO — the memory-bound
access pattern the ASH paper contrasts with its matmul-friendly codes
(paper Table 3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import learning as L
from repro.core.types import pytree_dataclass

_EPS = 1e-12


@pytree_dataclass(meta_fields=("M", "b"))
class PQState:
    M: int  # number of segments
    b: int  # bits per segment (2^b centroids)
    codebooks: jax.Array  # (M, 2^b, D/M)
    rotation: Optional[jax.Array]  # (D, D) or None (OPQ)

    @property
    def bits_per_vector(self) -> int:
        return self.M * self.b


def _split(X: jax.Array, M: int) -> jax.Array:
    n, D = X.shape
    return X.reshape(n, M, D // M)


def _train_codebooks(key, X, M, b, iters=25):
    seg = _split(X, M)  # (n, M, ds)
    keys = jax.random.split(key, M)

    def train_one(k, Xm):
        c, _ = L.kmeans(k, Xm, 2**b, iters=iters)
        return c

    return jax.vmap(train_one)(keys, seg.transpose(1, 0, 2))  # (M, 2^b, ds)


def train(
    key: jax.Array,
    X: jax.Array,
    M: int,
    b: int = 8,
    *,
    opq_iters: int = 0,
    kmeans_iters: int = 25,
) -> PQState:
    """Train PQ (opq_iters == 0) or OPQ (alternating rotation)."""
    X32 = X.astype(jnp.float32)
    D = X32.shape[1]
    assert D % M == 0, f"D={D} not divisible by M={M}"
    if opq_iters == 0:
        cb = _train_codebooks(key, X32, M, b, iters=kmeans_iters)
        return PQState(M=M, b=b, codebooks=cb, rotation=None)

    R = jnp.eye(D, dtype=jnp.float32)
    cb = None
    for it in range(opq_iters):
        k_it = jax.random.fold_in(key, it)
        XR = X32 @ R
        cb = _train_codebooks(key, XR, M, b, iters=kmeans_iters)
        codes = _assign(cb, XR, M)
        recon = _decode_rotated(cb, codes)
        # Procrustes: max Tr(R^T X^T recon) -> R = U V^T of X^T recon
        u, _, vt = jnp.linalg.svd(X32.T @ recon, full_matrices=False)
        R = u @ vt
    return PQState(M=M, b=b, codebooks=cb, rotation=R)


@jax.jit
def _assign(codebooks: jax.Array, X: jax.Array, M: int = None) -> jax.Array:
    M_ = codebooks.shape[0]
    seg = _split(X, M_).transpose(1, 0, 2)  # (M, n, ds)

    def one(cb_m, X_m):
        d2 = (
            jnp.sum(X_m * X_m, -1)[:, None]
            - 2 * X_m @ cb_m.T
            + jnp.sum(cb_m * cb_m, -1)[None, :]
        )
        return jnp.argmin(d2, axis=-1)

    return jax.vmap(one)(codebooks, seg).T.astype(jnp.int32)  # (n, M)


def encode(state: PQState, X: jax.Array) -> jax.Array:
    """-> (n, M) int32 centroid indices."""
    X32 = X.astype(jnp.float32)
    if state.rotation is not None:
        X32 = X32 @ state.rotation
    return _assign(state.codebooks, X32)


def _decode_rotated(codebooks, codes):
    # (n, M, ds) gathered -> (n, D) in (possibly rotated) space
    gathered = jnp.take_along_axis(
        codebooks[None], codes[:, :, None, None], axis=2
    )[:, :, 0, :]
    n = codes.shape[0]
    return gathered.reshape(n, -1)


def decode(state: PQState, codes: jax.Array) -> jax.Array:
    recon = _decode_rotated(state.codebooks, codes)
    if state.rotation is not None:
        recon = recon @ state.rotation.T
    return recon


@jax.jit
def score(state: PQState, codes: jax.Array, Qm: jax.Array) -> jax.Array:
    """ADC: <q, quant(x)> via per-segment LUTs (m, n).

    LUT T[m_seg] = q^(seg) @ codebook_seg^T; the per-vector sum of M
    gathers — PQ's hot loop (gather-bound on TPU).
    """
    Q32 = Qm.astype(jnp.float32)
    if state.rotation is not None:
        Q32 = Q32 @ state.rotation
    M = state.M
    qseg = _split(Q32, M).transpose(1, 0, 2)  # (M, m, ds)
    # (M, m, 2^b) tables
    T = jnp.einsum("mqd,mcd->mqc", qseg, state.codebooks)
    # gather per (query, vector, segment): T[s, q, codes[v, s]]
    # -> (m, n) = sum_s T[s, :, codes[:, s]]
    gathered = jnp.take_along_axis(
        T[:, :, None, :],  # (M, m, 1, 2^b)
        codes.T[:, None, :, None],  # (M, 1, n, 1)
        axis=3,
    )[..., 0]
    return jnp.sum(gathered, axis=0)
