"""Model zoo for the assigned architectures."""
from repro.models import common, transformer, moe, nequip, recsys, sasrec

__all__ = ["common", "transformer", "moe", "nequip", "recsys", "sasrec"]
