"""Shared model-building blocks (pure JAX, no flax).

Params are plain nested dicts of jnp arrays; every block is a function
``(params, x, cfg) -> y``.  Layers are stacked along a leading L axis and
driven by ``jax.lax.scan`` so that 80-layer configs compile fast.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Params = dict

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32, scale=1.0):
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # Variance in fp32 (reduction accuracy) but x is rescaled in its own
    # dtype: materializing x.astype(f32) as the first op makes XLA stash
    # the scan-carry residual in f32 — doubling activation memory at
    # 70B+ scale (observed in the dry-run; see EXPERIMENTS.md §Perf).
    var = jnp.mean(
        jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True
    )
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)  # (..., S, 1, dh/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal or full, query-chunked for long prefill)
# ---------------------------------------------------------------------------


def gqa_attention(
    q: jax.Array,  # (B, S, H, dh)
    k: jax.Array,  # (B, T, KV, dh)
    v: jax.Array,  # (B, T, KV, dh)
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    q_chunk: int = 0,
    kv_len: Optional[jax.Array] = None,  # (B,) valid KV prefix lengths
) -> jax.Array:
    """Grouped-query attention; repeats KV heads logically via reshape.

    q_chunk > 0 processes queries in chunks of that size (bounds the
    (Sq, Skv) score tile for 32k prefill).
    """
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(dh)
    qr = q.reshape(B, S, KV, G, dh)

    def chunk_attn(q_c, qpos_c):
        # q_c: (B, Sc, KV, G, dh). Keep operands in their storage dtype
        # and accumulate in f32 — materializing .astype(f32) copies of
        # q/k/v lets XLA hoist the converts into full-size f32 buffers
        # (2x activation / KV-cache memory; observed in the dry-run).
        logits = jnp.einsum(
            "bskgd,btkd->bkgst", q_c, k,
            preferred_element_type=jnp.float32,
        ) * scale  # (B, KV, G, Sc, T) f32
        tpos = jnp.arange(T)
        mask = None
        if causal:
            mask = qpos_c[:, None] >= tpos[None, :]  # (Sc, T)
            mask = mask[None, None, None]
        if kv_len is not None:
            lm = tpos[None, :] < kv_len[:, None]  # (B, T)
            lm = lm[:, None, None, None, :]
            mask = lm if mask is None else (mask & lm)
        if mask is not None:
            logits = jnp.where(mask, logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum(
            "bkgst,btkd->bskgd", p, v,
            preferred_element_type=jnp.float32,
        )
        return out.astype(q.dtype)

    qpos = jnp.arange(S) + q_offset
    if q_chunk and S > q_chunk and S % q_chunk == 0:
        n_chunks = S // q_chunk
        qc = qr.reshape(B, n_chunks, q_chunk, KV, G, dh).transpose(
            1, 0, 2, 3, 4, 5
        )
        pc = qpos.reshape(n_chunks, q_chunk)
        out = jax.lax.map(lambda ab: chunk_attn(ab[0], ab[1]), (qc, pc))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, dh)
    else:
        out = chunk_attn(qr, qpos).reshape(B, S, H, dh)
    return out


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean next-token CE. logits (..., V) fp; labels (...,) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, labels[..., None], axis=-1
    )[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def binary_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# ---------------------------------------------------------------------------
# EmbeddingBag (JAX has no native one — built from take + segment_sum)
# ---------------------------------------------------------------------------


def embedding_bag(
    table: jax.Array,  # (vocab, dim)
    indices: jax.Array,  # (n_lookups,)
    segment_ids: jax.Array,  # (n_lookups,) which bag each lookup joins
    num_bags: int,
    weights: Optional[jax.Array] = None,
    combiner: str = "sum",
) -> jax.Array:
    """Multi-hot embedding lookup + per-bag reduction: (num_bags, dim)."""
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    summed = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if combiner == "sum":
        return summed
    counts = jax.ops.segment_sum(
        jnp.ones_like(segment_ids, dtype=rows.dtype),
        segment_ids,
        num_segments=num_bags,
    )
    if combiner == "mean":
        return summed / jnp.maximum(counts[:, None], 1.0)
    raise ValueError(combiner)
