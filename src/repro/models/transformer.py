"""Decoder-only transformer family (dense + MoE, GQA, RoPE, SwiGLU).

Covers the five assigned LM architectures.  Layers are stacked along a
leading axis and driven by lax.scan; activations can be rematerialized
per layer.  Serving supports a bf16 KV cache and, as the paper-technique
integration, an ASH-compressed KV cache (see ``decode_step`` with
``cfg.kv_quant_bits > 0``): keys/values are projected per head by a
row-orthonormal matrix, scalar-quantized to b bits on the V_b grid and
bit-packed; attention logits use the asymmetric estimator of Eq. (20)
with mu = 0, and the V de-projection is applied once per step after the
probability-weighted reduction (linear-decoder trick, Section 2.2).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import quantization as Q
from repro.models import common as cm
from repro.models.moe import MoEConfig, init_moe, moe_block


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    dtype: Any = jnp.bfloat16  # activation dtype
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 2048  # query chunking for long prefill (0 = off)
    use_scan: bool = True  # lax.scan over layers (False: python unroll,
    # used by the roofline probes — XLA cost_analysis counts loop bodies
    # once, so probes must be loop-free)
    # ASH-KV cache compression (0 = off -> bf16 cache)
    kv_quant_bits: int = 0
    kv_quant_dim: int = 0  # 0 -> d_head (no dim reduction)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        D, H, KV, dh, F, V, L = (
            self.d_model, self.n_heads, self.n_kv_heads, self.head_dim,
            self.d_ff, self.vocab, self.n_layers,
        )
        attn = D * (H * dh) + 2 * D * (KV * dh) + (H * dh) * D
        if self.moe:
            E, Fe = self.moe.n_experts, self.moe.d_ff
            ffn = D * E + E * 3 * D * Fe
        else:
            ffn = 3 * D * F
        return L * (attn + ffn + 2 * D) + 2 * V * D + D

    def active_param_count(self) -> int:
        """6*N_active*D convention for MoE rooflines."""
        if not self.moe:
            return self.param_count()
        D, H, KV, dh, L = (
            self.d_model, self.n_heads, self.n_kv_heads, self.head_dim,
            self.n_layers,
        )
        attn = D * (H * dh) + 2 * D * (KV * dh) + (H * dh) * D
        Fe = self.moe.d_ff
        ffn = D * self.moe.n_experts + self.moe.top_k * 3 * D * Fe
        return L * (attn + ffn + 2 * D) + 2 * self.vocab * D + D


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: TransformerConfig) -> cm.Params:
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    L, F, V = cfg.n_layers, cfg.d_ff, cfg.vocab
    pd = cfg.param_dtype
    keys = jax.random.split(key, 12)

    def stack(initfn, subkey, shape, **kw):
        ks = jax.random.split(subkey, L)
        return jax.vmap(lambda k_: initfn(k_, shape, **kw))(ks)

    layers: dict[str, Any] = {
        "attn_norm": jnp.ones((L, D), pd),
        "ffn_norm": jnp.ones((L, D), pd),
        "wq": stack(cm.dense_init, keys[0], (D, H * dh), dtype=pd),
        "wk": stack(cm.dense_init, keys[1], (D, KV * dh), dtype=pd),
        "wv": stack(cm.dense_init, keys[2], (D, KV * dh), dtype=pd),
        "wo": stack(cm.dense_init, keys[3], (H * dh, D), dtype=pd),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((L, H * dh), pd)
        layers["bk"] = jnp.zeros((L, KV * dh), pd)
        layers["bv"] = jnp.zeros((L, KV * dh), pd)
    if cfg.moe:
        mks = jax.random.split(keys[4], L)
        layers["moe"] = jax.vmap(
            lambda k_: init_moe(k_, cfg.moe, D, dtype=pd)
        )(mks)
    else:
        layers["w_gate"] = stack(cm.dense_init, keys[5], (D, F), dtype=pd)
        layers["w_up"] = stack(cm.dense_init, keys[6], (D, F), dtype=pd)
        layers["w_down"] = stack(cm.dense_init, keys[7], (F, D), dtype=pd)

    params: cm.Params = {
        "embed": cm.embed_init(keys[8], (V, D), dtype=pd),
        "layers": layers,
        "final_norm": jnp.ones((D,), pd),
        "lm_head": cm.dense_init(keys[9], (D, V), dtype=pd),
    }
    if cfg.kv_quant_bits:
        dc = cfg.kv_quant_dim or dh
        # Random row-orthonormal per (layer, kv head): data-agnostic ASH
        # (RaBitQ regime) — learned W can be swapped in post-hoc.
        def ortho(k_):
            g = jax.random.normal(k_, (dh, dh), jnp.float32)
            qm, _ = jnp.linalg.qr(g)
            return qm[:, :dc].T  # (dc, dh)

        ks = jax.random.split(keys[10], L * KV * 2).reshape(L, KV, 2, 2)
        params["kv_quant"] = {
            "Wk": jax.vmap(jax.vmap(lambda kk: ortho(kk[0])))(ks),
            "Wv": jax.vmap(jax.vmap(lambda kk: ortho(kk[1])))(ks),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _layer(
    cfg: TransformerConfig,
    lp: cm.Params,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (S,)
    constrain=lambda a, kind: a,
):
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = cm.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, KV, dh)
    v = v.reshape(B, S, KV, dh)
    q = cm.apply_rope(q, jnp.broadcast_to(positions, (B, S)), cfg.rope_theta)
    k = cm.apply_rope(k, jnp.broadcast_to(positions, (B, S)), cfg.rope_theta)
    # Pin the attention-boundary layouts (q/k/v in, attn out) to the
    # head-sharded form. Without the OUTPUT pin, the backward cotangent
    # arrives in the sequence-parallel layout and GSPMD resolves the
    # clash inside the rematted attention by replicating full (S, S)
    # score tensors — 48 GiB of the 69 GiB per-probe collective traffic
    # on qwen2-72b (EXPERIMENTS.md §Perf iteration 1).
    q = constrain(q, "qkv")
    k = constrain(k, "kv")
    v = constrain(v, "v")
    attn = cm.gqa_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk)
    attn = constrain(attn, "attn_out")
    attn = attn.reshape(B, S, H * dh) @ lp["wo"]
    x = x + constrain(attn, "resid")

    h = cm.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    if cfg.moe:
        flat = h.reshape(B * S, D)
        out, aux = moe_block(lp["moe"], flat, cfg.moe, constrain=constrain)
        ffn = out.reshape(B, S, D)
    else:
        gate = constrain(h @ lp["w_gate"], "ffn_hidden")
        up = constrain(h @ lp["w_up"], "ffn_hidden")
        ffn = cm.swiglu(gate, up) @ lp["w_down"]
        aux = jnp.float32(0.0)
    x = x + constrain(ffn, "resid")
    return x, aux


def forward(
    params: cm.Params,
    tokens: jax.Array,  # (B, S) int32
    cfg: TransformerConfig,
    constrain=lambda a, kind: a,
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V) fp32, aux_loss)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = constrain(x, "resid")
    positions = jnp.arange(S)

    def body(carry, lp):
        x = carry
        lp = constrain(lp, "layer_params")  # keep FSDP gather in-loop
        fn = functools.partial(_layer, cfg, constrain=constrain)
        if cfg.remat:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        x, aux = fn(lp, x, positions)
        return x, aux

    if cfg.use_scan:
        x, auxs = jax.lax.scan(body, x, params["layers"])
    else:
        aux_list = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, aux = body(x, lp)
            aux_list.append(aux)
        auxs = jnp.stack(aux_list)
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return constrain(logits, "logits"), jnp.sum(auxs)


def loss_fn(
    params: cm.Params,
    batch: dict,
    cfg: TransformerConfig,
    constrain=lambda a, kind: a,
) -> jax.Array:
    logits, aux = forward(params, batch["tokens"], cfg, constrain)
    return cm.softmax_cross_entropy(
        logits[:, :-1], batch["labels"][:, 1:]
    ) + aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode with (optionally ASH-compressed) KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    L, KV, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_quant_bits:
        b = cfg.kv_quant_bits
        dc = cfg.kv_quant_dim or dh
        W = Q.packed_width(dc, b)
        return {
            "k_codes": jnp.zeros((L, batch, max_len, KV, W), jnp.uint32),
            "v_codes": jnp.zeros((L, batch, max_len, KV, W), jnp.uint32),
            "k_scale": jnp.zeros((L, batch, max_len, KV), cfg.dtype),
            "v_scale": jnp.zeros((L, batch, max_len, KV), cfg.dtype),
        }
    return {
        "k": jnp.zeros((L, batch, max_len, KV, dh), cfg.dtype),
        "v": jnp.zeros((L, batch, max_len, KV, dh), cfg.dtype),
    }


def _encode_kv(W: jax.Array, vec: jax.Array, b: int):
    """ASH-encode one head vector (mu = 0): -> (codes, scale)."""
    norm = jnp.linalg.norm(vec.astype(jnp.float32), axis=-1, keepdims=True)
    u = (vec.astype(jnp.float32) / jnp.maximum(norm, 1e-12)) @ W.T
    V = Q.quant(u, b, exact=(b <= 4))
    scale = norm[..., 0] / jnp.maximum(Q.code_norms(V), 1e-12)
    return Q.pack_codes(V, b), scale


def decode_step(
    params: cm.Params,
    cache: dict,
    tokens: jax.Array,  # (B,) next input token per sequence
    cache_len: jax.Array,  # scalar int32: current prefix length
    cfg: TransformerConfig,
    constrain=lambda a, kind: a,
):
    """One decode step. Returns (logits (B, V), new_cache)."""
    B = tokens.shape[0]
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    x = params["embed"][tokens].astype(cfg.dtype)  # (B, D)
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    max_len = (
        cache["k"].shape[2] if "k" in cache else cache["k_codes"].shape[2]
    )
    valid = jnp.arange(max_len) <= cache_len  # includes the new slot

    def body(carry, inp):
        x = carry
        lp, layer_cache = inp
        h = cm.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = h @ lp["wq"]
        k = h @ lp["wk"]
        v = h @ lp["wv"]
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
        q = cm.apply_rope(
            q.reshape(B, 1, H, dh), pos, cfg.rope_theta
        )[:, 0]  # (B, H, dh)
        k = cm.apply_rope(
            k.reshape(B, 1, KV, dh), pos, cfg.rope_theta
        )[:, 0]
        v = v.reshape(B, KV, dh)

        if cfg.kv_quant_bits:
            b = cfg.kv_quant_bits
            Wk, Wv = params["kv_quant"]["Wk"], params["kv_quant"]["Wv"]
            lidx = layer_cache["lidx"]
            Wk_l, Wv_l = Wk[lidx], Wv[lidx]  # (KV, dc, dh)
            kc, ks = jax.vmap(
                lambda W_, vec: _encode_kv(W_, vec, b),
                in_axes=(0, 1), out_axes=(1, 1),
            )(Wk_l, k)
            vc, vs = jax.vmap(
                lambda W_, vec: _encode_kv(W_, vec, b),
                in_axes=(0, 1), out_axes=(1, 1),
            )(Wv_l, v)
            k_codes = jax.lax.dynamic_update_slice(
                layer_cache["k_codes"], kc[:, None], (0, cache_len, 0, 0)
            )
            v_codes = jax.lax.dynamic_update_slice(
                layer_cache["v_codes"], vc[:, None], (0, cache_len, 0, 0)
            )
            k_scale = jax.lax.dynamic_update_slice(
                layer_cache["k_scale"], ks[:, None].astype(cfg.dtype),
                (0, cache_len, 0),
            )
            v_scale = jax.lax.dynamic_update_slice(
                layer_cache["v_scale"], vs[:, None].astype(cfg.dtype),
                (0, cache_len, 0),
            )
            # logits: q (B, KV, G, dh) -> project into code space
            qr = q.reshape(B, KV, G, dh)
            qp = jnp.einsum(
                "bkgd,kcd->bkgc", qr.astype(cfg.dtype),
                Wk_l.astype(cfg.dtype),
                preferred_element_type=jnp.float32,
            ).astype(cfg.dtype)
            dc = qp.shape[-1]
            # unpack to bf16 in-loop (the Pallas ash_kv_attn kernel does
            # this tile-wise in VMEM on TPU)
            Kv = Q.unpack_codes(k_codes, dc, b).astype(cfg.dtype)
            # (B, S, KV, dc) x (B, KV, G, dc) -> (B, KV, G, S)
            logits = jnp.einsum(
                "bskc,bkgc->bkgs", Kv, qp,
                preferred_element_type=jnp.float32,
            )
            logits = logits * k_scale.astype(jnp.float32).transpose(
                0, 2, 1
            )[:, :, None, :]
            logits = logits / math.sqrt(dh)
            logits = jnp.where(
                valid[None, None, None, :], logits, -1e30
            )
            p = jax.nn.softmax(logits, axis=-1)
            Vv = Q.unpack_codes(v_codes, dc, b).astype(cfg.dtype)
            pv = (p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[
                :, :, None, :
            ]).astype(cfg.dtype)
            red = jnp.einsum(
                "bkgs,bskc->bkgc", pv, Vv,
                preferred_element_type=jnp.float32,
            )  # reduced space
            attn = jnp.einsum("bkgc,kcd->bkgd", red, Wv_l)  # decode once
            attn = attn.reshape(B, H * dh).astype(cfg.dtype)
            new_layer_cache = {
                "k_codes": k_codes, "v_codes": v_codes,
                "k_scale": k_scale, "v_scale": v_scale,
                "lidx": lidx,
            }
        else:
            kc = jax.lax.dynamic_update_slice(
                layer_cache["k"], k[:, None].astype(cfg.dtype),
                (0, cache_len, 0, 0),
            )
            vc = jax.lax.dynamic_update_slice(
                layer_cache["v"], v[:, None].astype(cfg.dtype),
                (0, cache_len, 0, 0),
            )
            qr = q.reshape(B, KV, G, dh).astype(cfg.dtype)
            # bf16 operands + f32 accumulation: a materialized f32 cast
            # of the cache would be hoisted out of the layer scan into a
            # full-size f32 cache copy (2x HBM) — see common.gqa_attention.
            # The barrier pins any backend-inserted upcast INSIDE the
            # layer loop (per-layer transient, not a whole-cache copy).
            kc_b, vc_b = jax.lax.optimization_barrier((kc, vc))
            logits = jnp.einsum(
                "bkgd,bskd->bkgs", qr, kc_b,
                preferred_element_type=jnp.float32,
            ) / math.sqrt(dh)
            logits = jnp.where(
                valid[None, None, None, :], logits, -1e30
            )
            p = jax.nn.softmax(logits, axis=-1).astype(cfg.dtype)
            attn = jnp.einsum(
                "bkgs,bskd->bkgd", p, vc_b,
                preferred_element_type=jnp.float32,
            ).reshape(B, H * dh).astype(cfg.dtype)
            new_layer_cache = {"k": kc, "v": vc}

        x = x + attn @ lp["wo"]
        h2 = cm.rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
        if cfg.moe:
            out, _ = moe_block(lp["moe"], h2, cfg.moe, constrain=constrain)
            ffn = out
        else:
            ffn = cm.swiglu(h2 @ lp["w_gate"], h2 @ lp["w_up"]) @ lp[
                "w_down"
            ]
        x = x + ffn
        return x, new_layer_cache

    scan_cache = dict(cache)
    if cfg.kv_quant_bits:
        scan_cache["lidx"] = jnp.arange(cfg.n_layers)
    if cfg.use_scan:
        x, new_cache = jax.lax.scan(
            body, x, (params["layers"], scan_cache)
        )
    else:
        caches = []
        for i in range(cfg.n_layers):
            sl = jax.tree_util.tree_map(
                lambda a: a[i], (params["layers"], scan_cache)
            )
            x, lc = body(x, sl)
            caches.append(lc)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *caches
        )
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    if cfg.kv_quant_bits:
        new_cache = {k_: v_ for k_, v_ in new_cache.items() if k_ != "lidx"}
    return logits, new_cache


def prefill(
    params: cm.Params,
    tokens: jax.Array,  # (B, S)
    cfg: TransformerConfig,
    constrain=lambda a, kind: a,
) -> jax.Array:
    """Prefill serve step: full forward, returns last-position logits."""
    logits, _ = forward(params, tokens, cfg, constrain)
    return logits[:, -1]
