"""CTR / ranking recsys architectures: DCN-v2, FM, AutoInt.

The hot path is the sparse-embedding lookup over huge tables (JAX has no
EmbeddingBag — it is built from take + segment_sum in models.common and
used here via per-field single-hot take).  ``retrieval_score`` scores one
user context against a large candidate set by broadcasting the user-side
features and swapping the item field — and, for the ASH-integrated path,
by scoring ASH-compressed candidate embeddings with the fused kernel
(see repro.serving.retrieval).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str  # "dcn_v2" | "fm" | "autoint"
    n_dense: int
    n_sparse: int
    embed_dim: int
    vocab_per_field: int = 1_000_000
    # dcn-v2
    n_cross_layers: int = 3
    mlp_dims: tuple = (1024, 1024, 512)
    cross_rank: int = 0  # 0 = full-rank W
    # autoint
    n_attn_layers: int = 3
    n_attn_heads: int = 2
    d_attn: int = 32
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def interaction_dim(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def init_params(key: jax.Array, cfg: RecSysConfig) -> cm.Params:
    pd = cfg.param_dtype
    keys = jax.random.split(key, 16)
    params: cm.Params = {
        # one big table: field f owns rows [f*V, (f+1)*V)
        "tables": cm.embed_init(
            keys[0], (cfg.n_sparse * cfg.vocab_per_field, cfg.embed_dim),
            dtype=pd,
        ),
    }
    if cfg.kind == "fm":
        params["linear_sparse"] = cm.embed_init(
            keys[1], (cfg.n_sparse * cfg.vocab_per_field, 1), dtype=pd
        )
        if cfg.n_dense:
            params["linear_dense"] = cm.dense_init(
                keys[2], (cfg.n_dense, 1), dtype=pd
            )
            params["dense_emb"] = cm.dense_init(
                keys[3], (cfg.n_dense, cfg.embed_dim), dtype=pd
            )
        params["bias"] = jnp.zeros((), pd)
        return params

    d0 = cfg.interaction_dim
    if cfg.kind == "dcn_v2":
        L = cfg.n_cross_layers
        if cfg.cross_rank:
            params["cross_u"] = jnp.stack([
                cm.dense_init(jax.random.fold_in(keys[4], i),
                              (d0, cfg.cross_rank), dtype=pd)
                for i in range(L)
            ])
            params["cross_v"] = jnp.stack([
                cm.dense_init(jax.random.fold_in(keys[5], i),
                              (cfg.cross_rank, d0), dtype=pd)
                for i in range(L)
            ])
        else:
            params["cross_w"] = jnp.stack([
                cm.dense_init(jax.random.fold_in(keys[4], i), (d0, d0),
                              dtype=pd)
                for i in range(L)
            ])
        params["cross_b"] = jnp.zeros((L, d0), pd)
        dims = (d0,) + cfg.mlp_dims
        params["mlp"] = [
            {
                "w": cm.dense_init(
                    jax.random.fold_in(keys[6], i), (dims[i], dims[i + 1]),
                    dtype=pd,
                ),
                "b": jnp.zeros((dims[i + 1],), pd),
            }
            for i in range(len(dims) - 1)
        ]
        params["head"] = cm.dense_init(
            keys[7], (d0 + cfg.mlp_dims[-1], 1), dtype=pd
        )
        return params

    if cfg.kind == "autoint":
        H, da = cfg.n_attn_heads, cfg.d_attn
        e = cfg.embed_dim
        params["attn"] = []
        d_in = e
        for i in range(cfg.n_attn_layers):
            lk = jax.random.split(jax.random.fold_in(keys[8], i), 4)
            params["attn"].append({
                "wq": cm.dense_init(lk[0], (d_in, H * da), dtype=pd),
                "wk": cm.dense_init(lk[1], (d_in, H * da), dtype=pd),
                "wv": cm.dense_init(lk[2], (d_in, H * da), dtype=pd),
                "wres": cm.dense_init(lk[3], (d_in, H * da), dtype=pd),
            })
            d_in = H * da
        params["head"] = cm.dense_init(
            keys[9], (cfg.n_sparse * d_in, 1), dtype=pd
        )
        if cfg.n_dense:
            params["dense_proj"] = cm.dense_init(
                keys[10], (cfg.n_dense, cfg.embed_dim), dtype=pd
            )
        return params

    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Embedding lookup (the hot path)
# ---------------------------------------------------------------------------


def lookup(params, sparse_ids: jax.Array, cfg: RecSysConfig) -> jax.Array:
    """(B, n_sparse) int32 -> (B, n_sparse, embed_dim).

    Field offsets fold all tables into one row-sharded table so the
    lookup is a single gather (sharded over the vocab axis on the mesh).
    """
    offsets = (
        jnp.arange(cfg.n_sparse, dtype=sparse_ids.dtype)
        * cfg.vocab_per_field
    )
    flat = (sparse_ids + offsets[None, :]).reshape(-1)
    rows = jnp.take(params["tables"], flat, axis=0)
    return rows.reshape(
        sparse_ids.shape[0], cfg.n_sparse, cfg.embed_dim
    )


# ---------------------------------------------------------------------------
# Forwards
# ---------------------------------------------------------------------------


def _fm_forward(params, batch, cfg: RecSysConfig):
    emb = lookup(params, batch["sparse"], cfg)  # (B, F, e)
    if cfg.n_dense:
        dense = batch["dense"].astype(emb.dtype)  # (B, nd)
        demb = dense[:, :, None] * params["dense_emb"][None]  # (B, nd, e)
        emb = jnp.concatenate([emb, demb], axis=1)
    # O(nk) sum-square trick: 0.5 * ((sum v)^2 - sum v^2)
    s = jnp.sum(emb, axis=1)
    s2 = jnp.sum(emb * emb, axis=1)
    pair = 0.5 * jnp.sum(s * s - s2, axis=-1)  # (B,)
    offsets = (
        jnp.arange(cfg.n_sparse, dtype=batch["sparse"].dtype)
        * cfg.vocab_per_field
    )
    lin_rows = jnp.take(
        params["linear_sparse"],
        (batch["sparse"] + offsets[None, :]).reshape(-1),
        axis=0,
    ).reshape(batch["sparse"].shape[0], cfg.n_sparse)
    lin = jnp.sum(lin_rows, axis=1)
    if cfg.n_dense:
        lin = lin + (batch["dense"] @ params["linear_dense"])[:, 0]
    return pair + lin + params["bias"]


def _dcn_forward(params, batch, cfg: RecSysConfig):
    emb = lookup(params, batch["sparse"], cfg).reshape(
        batch["sparse"].shape[0], -1
    )
    x0 = jnp.concatenate(
        [batch["dense"].astype(emb.dtype), emb], axis=-1
    ) if cfg.n_dense else emb  # (B, d0)
    x = x0
    for i in range(cfg.n_cross_layers):
        if cfg.cross_rank:
            wx = (x @ params["cross_u"][i]) @ params["cross_v"][i]
        else:
            wx = x @ params["cross_w"][i]
        x = x0 * (wx + params["cross_b"][i]) + x  # x0 ⊙ (Wx + b) + x
    h = x0
    for layer in params["mlp"]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    both = jnp.concatenate([x, h], axis=-1)
    return (both @ params["head"])[:, 0]


def _autoint_forward(params, batch, cfg: RecSysConfig):
    emb = lookup(params, batch["sparse"], cfg)  # (B, F, e)
    x = emb
    B, F = x.shape[0], x.shape[1]
    H, da = cfg.n_attn_heads, cfg.d_attn
    for lp in params["attn"]:
        q = (x @ lp["wq"]).reshape(B, F, H, da)
        k = (x @ lp["wk"]).reshape(B, F, H, da)
        v = (x @ lp["wv"]).reshape(B, F, H, da)
        logits = jnp.einsum("bfhd,bghd->bhfg", q, k) / jnp.sqrt(
            jnp.float32(da)
        ).astype(x.dtype)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhfg,bghd->bfhd", p, v).reshape(B, F, H * da)
        x = jax.nn.relu(o + x @ lp["wres"])
    return (x.reshape(B, -1) @ params["head"])[:, 0]


def forward(params, batch, cfg: RecSysConfig,
            constrain=lambda a, k: a) -> jax.Array:
    """CTR logit (B,)."""
    if cfg.kind == "fm":
        return _fm_forward(params, batch, cfg)
    if cfg.kind == "dcn_v2":
        return _dcn_forward(params, batch, cfg)
    if cfg.kind == "autoint":
        return _autoint_forward(params, batch, cfg)
    raise ValueError(cfg.kind)


def loss_fn(params, batch, cfg: RecSysConfig,
            constrain=lambda a, k: a) -> jax.Array:
    logits = forward(params, batch, cfg, constrain)
    return cm.binary_cross_entropy(logits, batch["labels"])


def retrieval_score(
    params, user_batch: dict, cand_ids: jax.Array, cfg: RecSysConfig
) -> jax.Array:
    """Score ONE user context against n candidates (retrieval_cand cell).

    Candidates replace sparse field 0 (the item field); user-side fields
    broadcast.  Returns (n_candidates,) logits.
    """
    n = cand_ids.shape[0]
    sparse = jnp.broadcast_to(
        user_batch["sparse"][0][None, :], (n, cfg.n_sparse)
    )
    sparse = sparse.at[:, 0].set(cand_ids)
    batch = {"sparse": sparse}
    if cfg.n_dense:
        batch["dense"] = jnp.broadcast_to(
            user_batch["dense"][0][None, :], (n, cfg.n_dense)
        )
    return forward(params, batch, cfg)
