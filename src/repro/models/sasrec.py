"""SASRec [Kang & McAuley 2018]: self-attentive sequential recommender.

Next-item retrieval is a MIPS problem over the item-embedding table —
the paper's home turf.  ``retrieval_score`` supports (a) exact dot
products and (b) the ASH-compressed path: item embeddings encoded once
offline, queries (the user state h_t) scored with the fused asymmetric
kernel (repro.serving.retrieval wires this up).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    n_neg: int = 128  # sampled-softmax negatives for training
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32


def init_params(key: jax.Array, cfg: SASRecConfig) -> cm.Params:
    pd = cfg.param_dtype
    keys = jax.random.split(key, 4 + 6 * cfg.n_blocks)
    e = cfg.embed_dim
    params: cm.Params = {
        "item_emb": cm.embed_init(keys[0], (cfg.n_items, e), dtype=pd),
        "pos_emb": cm.embed_init(keys[1], (cfg.seq_len, e), dtype=pd),
        "blocks": [],
        "final_ln_s": jnp.ones((e,), pd),
        "final_ln_b": jnp.zeros((e,), pd),
    }
    for i in range(cfg.n_blocks):
        bk = jax.random.split(keys[2 + i], 6)
        params["blocks"].append({
            "ln1_s": jnp.ones((e,), pd), "ln1_b": jnp.zeros((e,), pd),
            "wq": cm.dense_init(bk[0], (e, e), dtype=pd),
            "wk": cm.dense_init(bk[1], (e, e), dtype=pd),
            "wv": cm.dense_init(bk[2], (e, e), dtype=pd),
            "wo": cm.dense_init(bk[3], (e, e), dtype=pd),
            "ln2_s": jnp.ones((e,), pd), "ln2_b": jnp.zeros((e,), pd),
            "ff1": cm.dense_init(bk[4], (e, e), dtype=pd),
            "ff1_b": jnp.zeros((e,), pd),
            "ff2": cm.dense_init(bk[5], (e, e), dtype=pd),
            "ff2_b": jnp.zeros((e,), pd),
        })
    return params


def encode_sequence(params, seq: jax.Array, cfg: SASRecConfig) -> jax.Array:
    """(B, S) item ids (0 = padding) -> (B, S, e) hidden states."""
    B, S = seq.shape
    e = cfg.embed_dim
    x = params["item_emb"][seq] * jnp.sqrt(jnp.float32(e)).astype(
        cfg.dtype
    )
    x = x + params["pos_emb"][None, :S]
    pad_mask = (seq > 0)[:, :, None]
    x = x * pad_mask.astype(x.dtype)
    H = cfg.n_heads
    dh = e // H
    causal = jnp.tril(jnp.ones((S, S), bool))
    for bp in params["blocks"]:
        h = cm.layer_norm(x, bp["ln1_s"], bp["ln1_b"])
        q = (h @ bp["wq"]).reshape(B, S, H, dh)
        k = (h @ bp["wk"]).reshape(B, S, H, dh)
        v = (h @ bp["wv"]).reshape(B, S, H, dh)
        logits = jnp.einsum(
            "bshd,bthd->bhst", q.astype(jnp.float32),
            k.astype(jnp.float32),
        ) / jnp.sqrt(jnp.float32(dh))
        key_mask = (seq > 0)[:, None, None, :]
        logits = jnp.where(causal[None, None] & key_mask, logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
        x = x + (o.reshape(B, S, e) @ bp["wo"]).astype(x.dtype)
        h2 = cm.layer_norm(x, bp["ln2_s"], bp["ln2_b"])
        ff = jax.nn.relu(h2 @ bp["ff1"] + bp["ff1_b"])
        x = x + (ff @ bp["ff2"] + bp["ff2_b"])
        x = x * pad_mask.astype(x.dtype)
    return cm.layer_norm(x, params["final_ln_s"], params["final_ln_b"])


def loss_fn(params, batch, cfg: SASRecConfig,
            constrain=lambda a, k: a) -> jax.Array:
    """Sampled-softmax next-item loss.

    batch: seq (B, S), labels (B, S) next item per position (0 = pad),
    negatives (n_neg,) shared sampled item ids.
    """
    h = encode_sequence(params, batch["seq"], cfg)  # (B, S, e)
    pos_emb = params["item_emb"][batch["labels"]]  # (B, S, e)
    neg_emb = params["item_emb"][batch["negatives"]]  # (n_neg, e)
    pos_logit = jnp.sum(
        h.astype(jnp.float32) * pos_emb.astype(jnp.float32), axis=-1
    )  # (B, S)
    neg_logit = jnp.einsum(
        "bse,ne->bsn", h.astype(jnp.float32),
        neg_emb.astype(jnp.float32),
    )  # (B, S, n_neg)
    logits = jnp.concatenate(
        [pos_logit[..., None], neg_logit], axis=-1
    )
    mask = (batch["labels"] > 0).astype(jnp.float32)
    nll = -jax.nn.log_softmax(logits, axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def user_state(params, seq: jax.Array, cfg: SASRecConfig) -> jax.Array:
    """(B, S) -> (B, e): the query vector for next-item retrieval."""
    h = encode_sequence(params, seq, cfg)
    lengths = jnp.sum((seq > 0).astype(jnp.int32), axis=-1)
    idx = jnp.maximum(lengths - 1, 0)
    return jnp.take_along_axis(
        h, idx[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]


def retrieval_score(
    params, seq: jax.Array, cand_ids: jax.Array, cfg: SASRecConfig
) -> jax.Array:
    """Exact MIPS scores of each user state vs candidate items: (B, n)."""
    u = user_state(params, seq, cfg)  # (B, e)
    cand = params["item_emb"][cand_ids]  # (n, e)
    return u.astype(jnp.float32) @ cand.astype(jnp.float32).T
