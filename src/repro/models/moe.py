"""Mixture-of-Experts FFN block (token-choice top-k, GShard-style).

Dispatch is gather/scatter-based (NOT the one-hot einsum, whose FLOP cost
would dwarf the expert matmuls at E=384): tokens are grouped (a group is
a data-parallel shard's slice, so sorting stays shard-local), each
(token, choice) pair receives a slot in a per-group (E, capacity) buffer
via a stable sort by expert id, and the expert GEMMs run batched over the
buffer.  Overflowing pairs are dropped (capacity_factor controls head
room) — standard GShard semantics.

Sharding intent (constrained via with_sharding_constraint by the caller's
mesh rules):
  buffer (n_groups, E, C, D): groups over data/pod, E over model
  expert weights (E, D, F): E over model, F over data (FSDP'd at rest)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden width
    capacity_factor: float = 1.25
    group_size: int = 4096  # tokens per dispatch group
    router_aux_weight: float = 0.01


def init_moe(key, cfg: MoEConfig, d_model: int, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_ff
    return {
        "router": cm.dense_init(k1, (d_model, E), dtype=jnp.float32),
        "w_gate": cm.dense_init(k2, (E, d_model, F), in_axis=-2, dtype=dtype),
        "w_up": cm.dense_init(k3, (E, d_model, F), in_axis=-2, dtype=dtype),
        "w_down": cm.dense_init(k4, (E, F, d_model), in_axis=-2, dtype=dtype),
    }


def moe_block(
    params,
    x: jax.Array,  # (T, D) flattened tokens
    cfg: MoEConfig,
    constrain=lambda a, kind: a,  # sharding-constraint hook
):
    """Returns (out (T, D), aux_loss scalar)."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = min(cfg.group_size, T)
    assert T % G == 0, (T, G)
    n_groups = T // G
    cap = int((G * k * cfg.capacity_factor) / E) + 1

    logits = x.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(
        jnp.sum(top_p, axis=-1, keepdims=True), 1e-9
    )

    # Load-balance auxiliary loss (Switch/GShard)
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0
    )
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- slot assignment (per group, static shapes) ----
    ge = top_e.reshape(n_groups, G * k)  # expert id per pair
    gp = top_p.reshape(n_groups, G * k).astype(x.dtype)
    order = jnp.argsort(ge, axis=-1, stable=True)  # (n_groups, G*k)
    sorted_e = jnp.take_along_axis(ge, order, axis=-1)
    # position within expert = index - first index of that expert
    first = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="left")
    )(sorted_e)  # (n_groups, E)
    pos_sorted = (
        jnp.arange(G * k)[None, :]
        - jnp.take_along_axis(first, sorted_e, axis=-1)
    )
    inv = jnp.argsort(order, axis=-1)
    pos = jnp.take_along_axis(pos_sorted, inv, axis=-1)  # (n_groups, G*k)
    keep = pos < cap
    slot = jnp.where(keep, ge * cap + pos, E * cap)  # E*cap = drop bin

    # ---- dispatch: scatter rows into (n_groups, E*cap+1, D) ----
    xg = x.reshape(n_groups, G, D)
    rows = jnp.repeat(xg, k, axis=1)  # (n_groups, G*k, D) pair rows

    def scatter_group(slots_g, rows_g):
        buf = jnp.zeros((E * cap + 1, D), rows_g.dtype)
        return buf.at[slots_g].set(rows_g, mode="drop")

    buffer = jax.vmap(scatter_group)(slot, rows)[:, :-1]  # drop bin cut
    buffer = buffer.reshape(n_groups, E, cap, D)
    buffer = constrain(buffer, "moe_buffer")

    # ---- expert GEMMs (batched over E) ----
    gate = jnp.einsum(
        "gecd,edf->gecf", buffer, params["w_gate"].astype(buffer.dtype)
    )
    up = jnp.einsum(
        "gecd,edf->gecf", buffer, params["w_up"].astype(buffer.dtype)
    )
    hidden = cm.swiglu(gate, up)
    out_buf = jnp.einsum(
        "gecf,efd->gecd", hidden, params["w_down"].astype(buffer.dtype)
    )
    out_buf = constrain(out_buf, "moe_buffer")
    out_flat = out_buf.reshape(n_groups, E * cap, D)
    # append a zero row as the drop bin target
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((n_groups, 1, D), out_buf.dtype)], axis=1
    )

    # ---- combine: gather back + weighted sum over k choices ----
    def gather_group(out_g, slots_g, w_g):
        picked = out_g[slots_g]  # (G*k, D) drop bin -> zeros
        return picked * w_g[:, None]

    contrib = jax.vmap(gather_group)(
        out_flat, slot, gp * keep.astype(gp.dtype)
    )  # (n_groups, G*k, D)
    out = jnp.sum(contrib.reshape(n_groups, G, k, D), axis=2)
    return out.reshape(T, D), aux
