"""NequIP [Batzner et al., arXiv:2101.03164] — E(3)-equivariant GNN.

Self-contained implementation (no e3nn):

* node features are irrep blocks {l: (n_nodes, channels, 2l+1)}, l <= l_max
* edge attributes: real spherical harmonics Y_l(r_hat) (explicit formulas
  for l = 0, 1, 2) and a radial Bessel basis with a polynomial cutoff
  envelope
* interaction = tensor-product message passing: neighbor feature irrep l1
  x edge SH irrep l2 -> output irrep l3 contracted through the *Gaunt
  coupling tensor* C[l1 l2 l3]_{m1 m2 m3} = integral of
  Y_{l1 m1} Y_{l2 m2} Y_{l3 m3} over the sphere, the unique (up to scale)
  equivariant bilinear map for each path.  C is computed numerically at import time by Gauss-Legendre
  x trapezoid quadrature, which is EXACT for polynomial integrands of
  the degrees involved (< 7).
* messages are weighted by a radial MLP (per path x channel), aggregated
  with segment_sum (JAX's message-passing primitive — see DESIGN.md),
  followed by self-interaction linears and gated nonlinearities.
* output: scalar (l=0) head -> per-atom energies -> total energy; forces
  come from jax.grad wrt positions (tested for rotation equivariance).

ASH applicability: scalar-quantizing irrep features breaks exact
equivariance, and force-field message passing is not a MIPS problem —
the paper's technique is NOT wired into this arch (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm

# ---------------------------------------------------------------------------
# Real spherical harmonics (explicit, l <= 2) and Gaunt coupling tensors
# ---------------------------------------------------------------------------


def sph_harm_np(l: int, xyz: np.ndarray) -> np.ndarray:
    """Real SH on unit vectors, numpy; xyz (..., 3) -> (..., 2l+1)."""
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    if l == 0:
        return np.full(xyz.shape[:-1] + (1,), 0.5 / math.sqrt(math.pi))
    if l == 1:
        c = math.sqrt(3.0 / (4.0 * math.pi))
        return np.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c = [
            0.5 * math.sqrt(15.0 / math.pi),   # xy
            0.5 * math.sqrt(15.0 / math.pi),   # yz
            0.25 * math.sqrt(5.0 / math.pi),   # 3z^2-1
            0.5 * math.sqrt(15.0 / math.pi),   # xz
            0.25 * math.sqrt(15.0 / math.pi),  # x^2-y^2
        ]
        return np.stack(
            [
                c[0] * x * y,
                c[1] * y * z,
                c[2] * (3.0 * z * z - 1.0),
                c[3] * x * z,
                c[4] * (x * x - y * y),
            ],
            axis=-1,
        )
    raise NotImplementedError(l)


def sph_harm(l: int, xyz: jax.Array) -> jax.Array:
    """Real SH in jnp (same formulas)."""
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    if l == 0:
        return jnp.full(
            xyz.shape[:-1] + (1,), 0.5 / math.sqrt(math.pi), xyz.dtype
        )
    if l == 1:
        c = math.sqrt(3.0 / (4.0 * math.pi))
        return jnp.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c0 = 0.5 * math.sqrt(15.0 / math.pi)
        c2 = 0.25 * math.sqrt(5.0 / math.pi)
        c4 = 0.25 * math.sqrt(15.0 / math.pi)
        return jnp.stack(
            [
                c0 * x * y,
                c0 * y * z,
                c2 * (3.0 * z * z - 1.0),
                c0 * x * z,
                c4 * (x * x - y * y),
            ],
            axis=-1,
        )
    raise NotImplementedError(l)


@functools.lru_cache(maxsize=None)
def gaunt_tensor(l1: int, l2: int, l3: int) -> np.ndarray:
    """C[m1, m2, m3] = ∫ Y_{l1 m1} Y_{l2 m2} Y_{l3 m3} dΩ (exact quadrature)."""
    n_theta, n_phi = 16, 32
    t_nodes, t_weights = np.polynomial.legendre.leggauss(n_theta)
    phi = (np.arange(n_phi) + 0.5) * (2 * np.pi / n_phi)
    w_phi = 2 * np.pi / n_phi
    ct = t_nodes  # cos(theta) in [-1, 1]
    st = np.sqrt(1 - ct**2)
    # grid of unit vectors (n_theta, n_phi, 3)
    xyz = np.stack(
        [
            st[:, None] * np.cos(phi)[None, :],
            st[:, None] * np.sin(phi)[None, :],
            np.broadcast_to(ct[:, None], (n_theta, n_phi)),
        ],
        axis=-1,
    )
    Y1 = sph_harm_np(l1, xyz)  # (T, P, 2l1+1)
    Y2 = sph_harm_np(l2, xyz)
    Y3 = sph_harm_np(l3, xyz)
    w = t_weights[:, None] * w_phi  # (T, 1)
    C = np.einsum("tpa,tpb,tpc,tp->abc", Y1, Y2, Y3, np.broadcast_to(
        w, (n_theta, n_phi)
    ))
    C[np.abs(C) < 1e-12] = 0.0
    return C.astype(np.float32)


def tp_paths(l_max: int) -> list[tuple[int, int, int]]:
    """All (l_in, l_edge, l_out) with non-vanishing Gaunt coupling."""
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if abs(l1 - l2) <= l3 <= l1 + l2 and (l1 + l2 + l3) % 2 == 0:
                    if np.abs(gaunt_tensor(l1, l2, l3)).max() > 1e-10:
                        paths.append((l1, l2, l3))
    return paths


# ---------------------------------------------------------------------------
# Radial basis
# ---------------------------------------------------------------------------


def bessel_basis(r: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """sin(n pi r / rc) / r basis [Klicpera 2020], (E,) -> (E, n_rbf)."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    return (
        jnp.sqrt(2.0 / cutoff)
        * jnp.sin(n[None, :] * jnp.pi * r[:, None] / cutoff)
        / r[:, None]
    )


def poly_cutoff(r: jax.Array, cutoff: float, p: int = 6) -> jax.Array:
    """Smooth polynomial envelope, 1 at r=0, 0 at r>=cutoff."""
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    return (
        1.0
        - ((p + 1) * (p + 2) / 2) * x**p
        + p * (p + 2) * x ** (p + 1)
        - (p * (p + 1) / 2) * x ** (p + 2)
    )


# ---------------------------------------------------------------------------
# Config / init
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat_in: int = 0  # raw node-feature dim (0 -> species one-hot)
    n_species: int = 16
    radial_hidden: int = 64
    dtype: Any = jnp.float32
    # memory controls for 10^7-10^8-edge graphs: rematerialize each
    # interaction layer, and stream edges in chunks (a lax.scan over
    # edge blocks accumulating per-node sums) so edge-wise tensors never
    # exist all at once.
    remat: bool = True
    edge_chunks: int = 1


def _irrep_dims(l_max: int):
    return {l: 2 * l + 1 for l in range(l_max + 1)}


def init_params(key: jax.Array, cfg: NequIPConfig) -> cm.Params:
    C = cfg.channels
    paths = tp_paths(cfg.l_max)
    keys = jax.random.split(key, 6 + cfg.n_layers)
    in_dim = cfg.d_feat_in or cfg.n_species
    params: cm.Params = {
        "embed": cm.dense_init(keys[0], (in_dim, C)),
        "layers": [],
        "out_w1": cm.dense_init(keys[1], (C, C)),
        "out_w2": cm.dense_init(keys[2], (C, 1)),
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[6 + i], 4 + len(paths))
        layer = {
            # radial MLP: n_rbf -> hidden -> (n_paths * C) weights
            "rad_w1": cm.dense_init(lk[0], (cfg.n_rbf, cfg.radial_hidden)),
            "rad_b1": jnp.zeros((cfg.radial_hidden,)),
            "rad_w2": cm.dense_init(
                lk[1], (cfg.radial_hidden, len(paths) * C)
            ),
            # self-interaction per l: (C, C)
            "self": {
                l: cm.dense_init(lk[2 + li], (C, C))
                for li, l in enumerate(range(cfg.l_max + 1))
            },
            # per-l gate scalars produced from l=0 channel
            "gate_w": cm.dense_init(
                lk[3 + cfg.l_max], (C, C * cfg.l_max)
            ),
        }
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _messages(cfg, lp, feats, edge_src, edge_dst, sh, radial, n_nodes,
              constrain):
    """Edge-wise tensor products + scatter: {l3: (N, C, 2l3+1)} sums."""
    C = cfg.channels
    paths = tp_paths(cfg.l_max)
    h = jax.nn.silu(radial @ lp["rad_w1"] + lp["rad_b1"])
    w = (h @ lp["rad_w2"]).reshape(-1, len(paths), C)  # (E, P, C)
    out = {
        l: jnp.zeros((n_nodes, C, 2 * l + 1), feats[0].dtype)
        for l in range(cfg.l_max + 1)
    }
    for pi, (l1, l2, l3) in enumerate(paths):
        Cg = jnp.asarray(gaunt_tensor(l1, l2, l3))  # (m1, m2, m3)
        src_feat = constrain(feats[l1][edge_src], "edge_feats")
        msg = jnp.einsum(
            "eca,eb,abm->ecm", src_feat, sh[l2], Cg
        )  # (E, C, 2l3+1)
        msg = constrain(msg * w[:, pi, :, None], "edge_feats")
        out[l3] = out[l3] + jax.ops.segment_sum(
            msg, edge_dst, num_segments=n_nodes
        )
    return out


def _interaction(
    cfg: NequIPConfig,
    lp: cm.Params,
    feats: dict[int, jax.Array],  # {l: (N, C, 2l+1)}
    edge_src: jax.Array,  # (E,)
    edge_dst: jax.Array,  # (E,)
    sh: dict[int, jax.Array],  # {l: (E, 2l+1)}
    radial: jax.Array,  # (E, n_rbf) already enveloped
    n_nodes: int,
    constrain=lambda a, k: a,
):
    C = cfg.channels
    E = edge_src.shape[0]
    k = cfg.edge_chunks
    msg_fn = _messages
    if cfg.remat:
        # checkpoint the EDGE-WISE work (per chunk): backward recomputes
        # each chunk's messages, so live edge-tensor memory is one chunk
        # regardless of depth. Node-sized residuals are cheap.
        msg_fn = jax.checkpoint(
            _messages, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(0, 7, 8),
        )
    if k > 1 and E % k == 0:
        # stream edges: scan over chunks, accumulate node sums — bounds
        # live edge-tensor memory to E/k rows
        def chunk2(a):
            return constrain(
                a.reshape((k, E // k) + a.shape[1:]), "edge_chunked"
            )

        es, ed = chunk2(edge_src), chunk2(edge_dst)
        shc = {l: chunk2(s) for l, s in sh.items()}
        radc = chunk2(radial)

        def body(acc, xs):
            es_c, ed_c, rad_c, sh_c = xs
            part = msg_fn(
                cfg, lp, feats, es_c, ed_c, sh_c, rad_c, n_nodes,
                constrain,
            )
            return (
                {l: acc[l] + part[l] for l in acc},
                None,
            )

        zero = {
            l: jnp.zeros((n_nodes, C, 2 * l + 1), feats[0].dtype)
            for l in range(cfg.l_max + 1)
        }
        out, _ = jax.lax.scan(body, zero, (es, ed, radc, shc))
    else:
        out = msg_fn(
            cfg, lp, feats, edge_src, edge_dst, sh, radial, n_nodes,
            constrain,
        )

    new = {}
    # self-interaction + residual
    for l in range(cfg.l_max + 1):
        mixed = jnp.einsum("ncm,cd->ndm", out[l], lp["self"][l])
        new[l] = feats[l] + mixed
    # gated nonlinearity: scalars via silu; l>0 scaled by sigmoid(gates)
    scalars = new[0][..., 0]  # (N, C)
    gates = jax.nn.sigmoid(scalars @ lp["gate_w"]).reshape(
        n_nodes, cfg.l_max, C
    )
    act = {0: jax.nn.silu(scalars)[..., None]}
    for l in range(1, cfg.l_max + 1):
        act[l] = new[l] * gates[:, l - 1, :, None]
    return act


def forward(
    params: cm.Params,
    batch: dict,
    cfg: NequIPConfig,
    constrain=lambda a, kind: a,
) -> jax.Array:
    """batch: positions (N,3), node_feats (N,F) or species (N,),
    edge_src/edge_dst (E,), edge_mask (E,), node_mask (N,),
    graph_ids (N,) for batched small graphs (else zeros).
    Returns per-graph energies (n_graphs,).
    """
    pos = batch["positions"].astype(jnp.float32)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n_nodes = pos.shape[0]
    emask = batch.get("edge_mask")
    nmask = batch.get("node_mask")

    rel = pos[dst] - pos[src]  # (E, 3)
    # grad-safe norm (zero-length padding/self edges must not NaN forces)
    r2 = jnp.sum(rel * rel, axis=-1)
    r = jnp.sqrt(jnp.maximum(r2, 1e-12))
    rhat = rel / jnp.maximum(r, 1e-6)[:, None]
    env = poly_cutoff(r, cfg.cutoff)
    if emask is not None:
        env = env * emask.astype(env.dtype)
    radial = bessel_basis(r, cfg.n_rbf, cfg.cutoff) * env[:, None]
    sh = {l: sph_harm(l, rhat) for l in range(cfg.l_max + 1)}

    if "node_feats" in batch:
        x0 = batch["node_feats"].astype(jnp.float32) @ params["embed"]
    else:
        x0 = params["embed"][batch["species"]]
    feats = {0: x0[..., None]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n_nodes, cfg.channels, 2 * l + 1), x0.dtype)

    for lp in params["layers"]:
        feats = _interaction(
            cfg, lp, feats, src, dst, sh, radial, n_nodes, constrain
        )
        feats = {l: constrain(f, "node_feats") for l, f in feats.items()}

    scalars = feats[0][..., 0]  # (N, C)
    atom_e = jax.nn.silu(scalars @ params["out_w1"]) @ params["out_w2"]
    atom_e = atom_e[..., 0]
    if nmask is not None:
        atom_e = atom_e * nmask.astype(atom_e.dtype)
    n_graphs = int(batch.get("n_graphs", 1))
    gid = batch.get("graph_ids")
    if gid is None:
        return jnp.sum(atom_e, keepdims=True)
    return jax.ops.segment_sum(atom_e, gid, num_segments=n_graphs)


def energy_and_forces(params, batch, cfg: NequIPConfig):
    def e_total(pos):
        b = dict(batch)
        b["positions"] = pos
        return jnp.sum(forward(params, b, cfg))

    e, neg_f = jax.value_and_grad(e_total)(batch["positions"])
    return e, -neg_f


def node_output(params, batch, cfg: NequIPConfig,
                constrain=lambda a, k: a) -> jax.Array:
    """Per-node scalar prediction (node-property cells): (N,)."""
    # reuse the trunk, read out per-atom scalars without graph pooling
    b = dict(batch)
    b.pop("graph_ids", None)
    b.pop("n_graphs", None)
    pos = b["positions"].astype(jnp.float32)
    # identical trunk to forward() but returning atom_e pre-pooling
    feats_e = forward(params, dict(b, graph_ids=jnp.arange(
        pos.shape[0], dtype=jnp.int32), n_graphs=pos.shape[0]), cfg,
        constrain)
    return feats_e


def loss_fn(params, batch, cfg: NequIPConfig, constrain=lambda a, k: a):
    """Two regimes:

    * node-property batches (``node_targets`` present — the Cora/
      Products-style feature-graph cells): masked per-node regression.
      FIRST-order AD only, so chunk-remat bounds edge memory.
    * molecular batches (``energy``/``forces``): energy + force matching;
      forces = -dE/dx makes the loss SECOND-order in params (documented:
      memory-intensive, used for the small molecule cell).
    """
    if "node_targets" in batch:
        pred = node_output(params, batch, cfg, constrain)  # (N,)
        mask = batch.get("node_mask")
        err = (pred - batch["node_targets"]) ** 2
        if mask is not None:
            return jnp.sum(err * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.mean(err)
    # ---- energy + forces (second-order) ----
    def e_total(pos):
        b = dict(batch)
        b["positions"] = pos
        e = forward(params, b, cfg, constrain)
        return jnp.sum(e), e

    (_, e), neg_f = jax.value_and_grad(e_total, has_aux=True)(
        batch["positions"]
    )
    loss_e = jnp.mean((e - batch["energy"]) ** 2)
    f = -neg_f
    fm = batch.get("node_mask")
    if fm is not None:
        f = f * fm[:, None]
        tgt = batch["forces"] * fm[:, None]
    else:
        tgt = batch["forces"]
    loss_f = jnp.mean(jnp.sum((f - tgt) ** 2, axis=-1))
    return loss_e + loss_f
