"""All assigned architectures, importable by id (``--arch <id>``)."""
from repro.configs import (
    autoint, dcn_v2, deepseek_7b, fm, granite_moe_3b, kimi_k2_1t,
    llama32_3b, nequip_cfg, qwen2_72b, sasrec_cfg,
)

ARCHS = {
    a.ARCH.arch_id: a.ARCH
    for a in (
        deepseek_7b, qwen2_72b, llama32_3b, granite_moe_3b, kimi_k2_1t,
        nequip_cfg, sasrec_cfg, dcn_v2, fm, autoint,
    )
}


def get(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}"
        )
    return ARCHS[arch_id]


def all_cells(include_skipped: bool = False):
    """Yield (arch, cell) for the official dry-run matrix."""
    for arch in ARCHS.values():
        for cell in arch.cells.values():
            if cell.skip and not include_skipped:
                continue
            yield arch, cell
