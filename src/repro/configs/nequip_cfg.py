"""nequip [arXiv:2101.03164]: O(3)-equivariant interatomic potential."""
import jax.numpy as jnp
from repro.configs.base import Arch, gnn_cells
from repro.models.nequip import NequIPConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = NequIPConfig(
    name="nequip", n_layers=5, channels=32, l_max=2, n_rbf=8,
    cutoff=5.0, n_species=16,
)

ARCH = Arch(
    arch_id="nequip",
    family="nequip",
    cfg=CFG,
    cells=gnn_cells(),
    train_cfg=TrainConfig(opt=OptConfig(name="adamw", lr=1e-3)),
    notes=(
        "E(3)-equivariant tensor products via numerically-exact Gaunt "
        "couplings; message passing = segment_sum over edge lists. "
        "ASH inapplicable (DESIGN.md §4). Graph shapes padded to x512 "
        "multiples with masks; d_feat shapes feed node_feats, molecule "
        "uses species embeddings."
    ),
)
