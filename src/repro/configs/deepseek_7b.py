"""deepseek-7b [arXiv:2401.02954]: dense llama-arch, MHA (GQA kv=32)."""
import jax.numpy as jnp
from repro.configs.base import Arch, lm_cells
from repro.models.transformer import TransformerConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = TransformerConfig(
    name="deepseek-7b", n_layers=30, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=11008, vocab=102400, qkv_bias=False,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    q_chunk=2048,
)

ARCH = Arch(
    arch_id="deepseek-7b",
    family="transformer",
    cfg=CFG,
    cells=lm_cells(full_attention=True),
    train_cfg=TrainConfig(
        opt=OptConfig(name="adamw", lr=3e-4), microbatches=4,
    ),
    notes="llama-arch dense 7B; MHA (kv == heads).",
)
