"""Arch/Cell registry machinery.

Every assigned architecture is an ``Arch`` with its own shape cells.  A
cell knows how to produce (step_fn, abstract args with shardings
attached) for a given mesh + sharding policy — the dry-run lowers and
compiles exactly that.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as SH
from repro.launch.sharding import ShardingPolicy
from repro.train import optim as O
from repro.train.trainer import TrainConfig, TrainState, init_state, make_train_step


def pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class Cell:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval
    shape: dict
    skip: Optional[str] = None  # reason this cell is officially skipped


@dataclasses.dataclass
class Arch:
    arch_id: str
    family: str  # transformer | nequip | recsys | sasrec
    cfg: Any
    cells: dict
    train_cfg: TrainConfig
    notes: str = ""
    # per-arch ShardingPolicy field overrides (size-dependent layout
    # tradeoffs, §Perf): e.g. {"pin_ffn_hidden": False}
    policy_overrides: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------
    def cell(self, name: str) -> Cell:
        return self.cells[name]

    def abstract_params(self):
        from repro import models

        fam = getattr(models, self.family)
        return jax.eval_shape(
            lambda: fam.init_params(jax.random.PRNGKey(0), self.cfg)
        )

    def abstract_state(self):
        params = self.abstract_params()
        return jax.eval_shape(
            lambda p: init_state(jax.random.PRNGKey(0), p, self.train_cfg),
            params,
        )

    def param_rules(self, mesh, pol: ShardingPolicy):
        if self.family == "transformer":
            return SH.transformer_param_rules(mesh, pol)
        if self.family == "nequip":
            return SH.nequip_param_rules(mesh, pol)
        return SH.recsys_param_rules(mesh, pol)

    def loss_fn(self, constrain):
        from repro import models

        fam = getattr(models, self.family)
        return functools.partial(
            fam.loss_fn, cfg=self.cfg, constrain=constrain
        )

    # ------------------------------------------------------------------
    def make_cell_program(self, cell_name: str, mesh, pol: ShardingPolicy):
        """Returns (fn, args) where args are ShapeDtypeStructs with
        NamedShardings attached; jit(fn).lower(*args) is the dry-run."""
        cell = self.cells[cell_name]
        if self.policy_overrides:
            pol = dataclasses.replace(pol, **self.policy_overrides)
        constrain = SH.make_constrain(
            mesh, pol, param_rules=self.param_rules(mesh, pol)
        )
        builder = _CELL_BUILDERS[(self.family, cell.kind)]
        return builder(self, cell, mesh, pol, constrain)


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _sharded_state(arch: Arch, mesh, pol):
    state_sds = arch.abstract_state()
    prules = arch.param_rules(mesh, pol)

    # params / mu / nu / residual share param sharding; scalars replicated
    def spec_for(path, leaf):
        p = _strip_state_prefix(SH._path_str(path))
        if p is None or not leaf.shape:
            return P()
        try:
            spec = prules(p, tuple(leaf.shape))
            return SH.fit_spec(spec, len(leaf.shape))
        except Exception:  # rule indexed a dim the reduced shape lacks
            return P()

    specs = jax.tree_util.tree_map_with_path(spec_for, state_sds)
    return SH.with_shardings(state_sds, specs, mesh), specs


def _strip_state_prefix(path: str):
    """Map TrainState leaf paths onto parameter paths so optimizer
    moments inherit the parameter sharding (critical: mismatched moment
    sharding would reshard every step)."""
    for prefix in ("params/", "opt_state/mu/", "opt_state/nu/",
                   "opt_state/vr/", "opt_state/vc/",
                   "opt_state/v/", "ef_state/residual/"):
        if path.startswith(prefix):
            return path[len(prefix):]
    return None


def _batch_sds(shapes: dict, mesh, pol, rules=None):
    if rules is None:
        rules = SH.batch_rules_leading_dp(mesh, pol)
    sds = {
        k: jax.ShapeDtypeStruct(shape, dtype)
        for k, (shape, dtype) in shapes.items()
    }
    specs = {k: rules(k, tuple(v.shape)) for k, v in sds.items()}
    return SH.with_shardings(sds, specs, mesh)


# ---------------------------------------------------------------------------
# Transformer cells
# ---------------------------------------------------------------------------


def _with_cfg(arch: Arch, cfg):
    import copy

    a = copy.copy(arch)
    a.cfg = cfg
    return a


def make_constrain_grads(arch: Arch, mesh, pol):
    """Pin gradient trees to the parameter sharding."""
    from jax.sharding import NamedSharding

    prules = arch.param_rules(mesh, pol)

    def constrain_grads(grads):
        def f(path, leaf):
            try:
                spec = SH.fit_spec(
                    prules(SH._path_str(path), tuple(leaf.shape)),
                    len(leaf.shape),
                )
                return jax.lax.with_sharding_constraint(
                    leaf, NamedSharding(mesh, spec)
                )
            except Exception:
                return leaf

        return jax.tree_util.tree_map_with_path(f, grads)

    return constrain_grads


def _tfm_train(arch: Arch, cell: Cell, mesh, pol, constrain):
    B, S = cell.shape["global_batch"], cell.shape["seq_len"]
    state, _ = _sharded_state(arch, mesh, pol)
    batch = _batch_sds(
        {
            "tokens": ((B, S), jnp.int32),
            "labels": ((B, S), jnp.int32),
        },
        mesh, pol,
    )
    step = make_train_step(
        arch.loss_fn(constrain), arch.train_cfg,
        constrain_grads=make_constrain_grads(arch, mesh, pol),
    )
    step._donate_argnums = (0,)  # TrainState updated in place
    return step, (state, batch)


def _tfm_prefill(arch: Arch, cell: Cell, mesh, pol, constrain):
    from repro.models import transformer as T

    B, S = cell.shape["global_batch"], cell.shape["seq_len"]
    params_sds = arch.abstract_params()
    prules = arch.param_rules(mesh, pol)
    specs = SH.specs_by_rules(params_sds, prules)
    params = SH.with_shardings(params_sds, specs, mesh)
    batch = _batch_sds({"tokens": ((B, S), jnp.int32)}, mesh, pol)

    def serve_step(params, tokens):
        return T.prefill(params, tokens, arch.cfg, constrain)

    return serve_step, (params, batch["tokens"])


def _tfm_decode(arch: Arch, cell: Cell, mesh, pol, constrain):
    from repro.models import transformer as T

    B, S = cell.shape["global_batch"], cell.shape["seq_len"]
    if cell.shape.get("kv_quant_bits"):
        # ASH-compressed KV cache variant (paper technique applied to
        # serving; extra cell, see EXPERIMENTS.md §Perf)
        arch = _with_cfg(arch, dataclasses.replace(
            arch.cfg,
            kv_quant_bits=cell.shape["kv_quant_bits"],
            kv_quant_dim=cell.shape.get("kv_quant_dim", 0),
        ))
    params_sds = arch.abstract_params()
    prules = arch.param_rules(mesh, pol)
    specs = SH.specs_by_rules(params_sds, prules)
    params = SH.with_shardings(params_sds, specs, mesh)

    cache_sds = jax.eval_shape(lambda: T.init_cache(arch.cfg, B, S))
    crules = SH.kv_cache_rules(mesh, pol)
    cache_specs = SH.specs_by_rules(cache_sds, crules)
    cache = SH.with_shardings(cache_sds, cache_specs, mesh)

    tokens = _batch_sds({"tokens": ((B,), jnp.int32)}, mesh, pol)["tokens"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, tokens, cache_len):
        return T.decode_step(
            params, cache, tokens, cache_len, arch.cfg, constrain
        )

    serve_step._donate_argnums = (1,)  # cache updated in place
    return serve_step, (params, cache, tokens, pos)


# ---------------------------------------------------------------------------
# NequIP cells (all train steps over graph batches)
# ---------------------------------------------------------------------------


def _nequip_train(arch: Arch, cell: Cell, mesh, pol, constrain):
    s = cell.shape
    overrides = {}
    if s.get("d_feat"):
        # feature-graph cells: the embedding consumes d_feat-dim inputs
        overrides["d_feat_in"] = s["d_feat"]
    if s.get("edge_chunks"):
        overrides["edge_chunks"] = s["edge_chunks"]
    if overrides:
        arch = _with_cfg(
            arch, dataclasses.replace(arch.cfg, **overrides)
        )
    N = pad_to(s["n_nodes"], 512)
    E = pad_to(s["n_edges"], 512)
    n_graphs = s.get("n_graphs", 1)
    shapes = {
        "positions": ((N, 3), jnp.float32),
        "edge_src": ((E,), jnp.int32),
        "edge_dst": ((E,), jnp.int32),
        "edge_mask": ((E,), jnp.bool_),
        "node_mask": ((N,), jnp.bool_),
    }
    if s.get("d_feat"):
        # feature-graph cells train node-property regression (1st-order)
        shapes["node_feats"] = ((N, s["d_feat"]), jnp.float32)
        shapes["node_targets"] = ((N,), jnp.float32)
    else:
        # molecular cells train energy + forces (2nd-order AD)
        shapes["species"] = ((N,), jnp.int32)
        shapes["energy"] = ((n_graphs,), jnp.float32)
        shapes["forces"] = ((N, 3), jnp.float32)
    if n_graphs > 1:
        shapes["graph_ids"] = ((N,), jnp.int32)
    state, _ = _sharded_state(arch, mesh, pol)
    batch = _batch_sds(shapes, mesh, pol)
    base_loss = arch.loss_fn(constrain)
    if n_graphs > 1:
        # n_graphs is STATIC (segment_sum num_segments): close over it
        loss = lambda p, b: base_loss(p, dict(b, n_graphs=n_graphs))
    else:
        loss = base_loss
    step = make_train_step(
        loss, arch.train_cfg,
        constrain_grads=make_constrain_grads(arch, mesh, pol),
    )
    step._donate_argnums = (0,)
    return step, (state, batch)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_batch_shapes(arch: Arch, B: int):
    cfg = arch.cfg
    shapes = {
        "sparse": ((B, cfg.n_sparse), jnp.int32),
        "labels": ((B,), jnp.float32),
    }
    if cfg.n_dense:
        shapes["dense"] = ((B, cfg.n_dense), jnp.float32)
    return shapes


def _recsys_train(arch: Arch, cell: Cell, mesh, pol, constrain):
    B = cell.shape["batch"]
    state, _ = _sharded_state(arch, mesh, pol)
    batch = _batch_sds(_recsys_batch_shapes(arch, B), mesh, pol)
    step = make_train_step(
        arch.loss_fn(constrain), arch.train_cfg,
        constrain_grads=make_constrain_grads(arch, mesh, pol),
    )
    step._donate_argnums = (0,)  # TrainState updated in place
    return step, (state, batch)


def _recsys_serve(arch: Arch, cell: Cell, mesh, pol, constrain):
    from repro.models import recsys as R

    B = cell.shape["batch"]
    params_sds = arch.abstract_params()
    specs = SH.specs_by_rules(params_sds, arch.param_rules(mesh, pol))
    params = SH.with_shardings(params_sds, specs, mesh)
    shapes = _recsys_batch_shapes(arch, B)
    shapes.pop("labels")
    batch = _batch_sds(shapes, mesh, pol)

    def serve_step(params, batch):
        return R.forward(params, batch, arch.cfg, constrain)

    return serve_step, (params, batch)


def _recsys_retrieval(arch: Arch, cell: Cell, mesh, pol, constrain):
    from repro.models import recsys as R

    n_cand = cell.shape["n_candidates"]
    params_sds = arch.abstract_params()
    specs = SH.specs_by_rules(params_sds, arch.param_rules(mesh, pol))
    params = SH.with_shardings(params_sds, specs, mesh)
    user_shapes = _recsys_batch_shapes(arch, 1)
    user_shapes.pop("labels")
    user = _batch_sds(user_shapes, mesh, pol)
    cand = _batch_sds(
        {"cand_ids": ((n_cand,), jnp.int32)}, mesh, pol
    )["cand_ids"]

    def serve_step(params, user, cand_ids):
        return R.retrieval_score(params, user, cand_ids, arch.cfg)

    return serve_step, (params, user, cand)


# ---------------------------------------------------------------------------
# SASRec cells
# ---------------------------------------------------------------------------


def _sasrec_batch_shapes(arch: Arch, B: int):
    cfg = arch.cfg
    return {
        "seq": ((B, cfg.seq_len), jnp.int32),
        "labels": ((B, cfg.seq_len), jnp.int32),
        "negatives": ((cfg.n_neg,), jnp.int32),
    }


def _sasrec_train(arch: Arch, cell: Cell, mesh, pol, constrain):
    B = cell.shape["batch"]
    state, _ = _sharded_state(arch, mesh, pol)
    batch = _batch_sds(_sasrec_batch_shapes(arch, B), mesh, pol)
    step = make_train_step(
        arch.loss_fn(constrain), arch.train_cfg,
        constrain_grads=make_constrain_grads(arch, mesh, pol),
    )
    step._donate_argnums = (0,)  # TrainState updated in place
    return step, (state, batch)


def _sasrec_serve(arch: Arch, cell: Cell, mesh, pol, constrain):
    from repro.models import sasrec as SR

    B = cell.shape["batch"]
    params_sds = arch.abstract_params()
    specs = SH.specs_by_rules(params_sds, arch.param_rules(mesh, pol))
    params = SH.with_shardings(params_sds, specs, mesh)
    seq = _batch_sds(
        {"seq": ((B, arch.cfg.seq_len), jnp.int32)}, mesh, pol
    )["seq"]

    def serve_step(params, seq):
        # online inference: user state + full-catalog MIPS scores
        u = SR.user_state(params, seq, arch.cfg)
        return u @ params["item_emb"].astype(jnp.float32).T

    return serve_step, (params, seq)


def _sasrec_retrieval(arch: Arch, cell: Cell, mesh, pol, constrain):
    from repro.models import sasrec as SR

    n_cand = cell.shape["n_candidates"]
    B = cell.shape.get("batch", 1)
    params_sds = arch.abstract_params()
    specs = SH.specs_by_rules(params_sds, arch.param_rules(mesh, pol))
    params = SH.with_shardings(params_sds, specs, mesh)
    seq = _batch_sds(
        {"seq": ((B, arch.cfg.seq_len), jnp.int32)}, mesh, pol
    )["seq"]

    if cell.shape.get("ash_bits"):
        # The paper's technique AS the optimization (§Perf hillclimb):
        # candidates are ASH-encoded offline; the serve step reads
        # packed uint32 codes + fp16 headers instead of the fp32 table.
        from jax.sharding import NamedSharding
        from repro.core import quantization as Q

        b = cell.shape["ash_bits"]
        e = arch.cfg.embed_dim
        d_code = e // cell.shape.get("ash_reduce", 1)
        Wd = Q.packed_width(d_code, b)
        row = SH.batch_rules_leading_dp(mesh, pol)

        def sds(shape, dtype, spec):
            return jax.ShapeDtypeStruct(
                shape, dtype, sharding=NamedSharding(mesh, spec)
            )

        ash_state = {
            "codes": sds((n_cand, Wd), jnp.uint32,
                         row("codes", (n_cand, Wd))),
            "scale": sds((n_cand,), jnp.bfloat16,
                         row("scale", (n_cand,))),
            "offset": sds((n_cand,), jnp.bfloat16,
                          row("offset", (n_cand,))),
            "W": sds((d_code, e), jnp.float32, SH.P()),
            "mu": sds((e,), jnp.float32, SH.P()),
        }

        def serve_step(params, ash, seq):
            u = SR.user_state(params, seq, arch.cfg)  # (B, e)
            q_proj = (u @ ash["W"].T).astype(jnp.bfloat16)  # (B, d)
            V = Q.unpack_codes(ash["codes"], d_code, b).astype(
                jnp.bfloat16
            )
            dot = jnp.einsum(
                "bd,nd->bn", q_proj, V,
                preferred_element_type=jnp.float32,
            )
            bias = (u @ ash["mu"]).astype(jnp.float32)  # (B,)
            return (
                dot * ash["scale"].astype(jnp.float32)[None, :]
                + bias[:, None]
                + ash["offset"].astype(jnp.float32)[None, :]
            )

        return serve_step, (params, ash_state, seq)

    cand = _batch_sds(
        {"cand_ids": ((n_cand,), jnp.int32)}, mesh, pol
    )["cand_ids"]

    def serve_step(params, seq, cand_ids):
        return SR.retrieval_score(params, seq, cand_ids, arch.cfg)

    return serve_step, (params, seq, cand)


_CELL_BUILDERS = {
    ("transformer", "train"): _tfm_train,
    ("transformer", "prefill"): _tfm_prefill,
    ("transformer", "decode"): _tfm_decode,
    ("nequip", "train"): _nequip_train,
    ("recsys", "train"): _recsys_train,
    ("recsys", "serve"): _recsys_serve,
    ("recsys", "retrieval"): _recsys_retrieval,
    ("sasrec", "train"): _sasrec_train,
    ("sasrec", "serve"): _sasrec_serve,
    ("sasrec", "retrieval"): _sasrec_retrieval,
}


# ---------------------------------------------------------------------------
# Standard shape-cell sets
# ---------------------------------------------------------------------------


def lm_cells(full_attention: bool = True) -> dict:
    cells = {
        "train_4k": Cell("train_4k", "train",
                         {"seq_len": 4096, "global_batch": 256}),
        "prefill_32k": Cell("prefill_32k", "prefill",
                            {"seq_len": 32768, "global_batch": 32}),
        "decode_32k": Cell("decode_32k", "decode",
                           {"seq_len": 32768, "global_batch": 128}),
        "long_500k": Cell(
            "long_500k", "decode",
            {"seq_len": 524288, "global_batch": 1},
            skip=(
                "pure full-attention arch: long_500k officially skipped "
                "per brief (runnable via --include-skipped using the "
                "ASH-compressed KV cache)" if full_attention else None
            ),
        ),
        # EXTRA (beyond the 40 assigned cells): decode with the paper's
        # technique applied to the KV cache — 8x cache compression at
        # b=4 with d_code = d_head/2.
        "decode_32k_ashkv": Cell(
            "decode_32k_ashkv", "decode",
            {"seq_len": 32768, "global_batch": 128,
             "kv_quant_bits": 4, "kv_quant_dim": 0},
            skip="extra cell (beyond-paper ASH-KV serving variant)",
        ),
    }
    return cells


def recsys_cells() -> dict:
    return {
        "train_batch": Cell("train_batch", "train", {"batch": 65536}),
        "serve_p99": Cell("serve_p99", "serve", {"batch": 512}),
        "serve_bulk": Cell("serve_bulk", "serve", {"batch": 262144}),
        "retrieval_cand": Cell(
            "retrieval_cand", "retrieval",
            {"batch": 1, "n_candidates": 1_000_000},
        ),
    }


def gnn_cells() -> dict:
    return {
        "full_graph_sm": Cell(
            "full_graph_sm", "train",
            {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433},
        ),
        "minibatch_lg": Cell(
            "minibatch_lg", "train",
            # padded sampled-subgraph sizes for batch_nodes=1024,
            # fanout 15-10 (see data.graphs.neighbor_sample)
            {"n_nodes": 1024 * 16 * 11, "n_edges": 1024 * 150 * 26,
             "d_feat": 602, "edge_chunks": 8},
        ),
        "ogb_products": Cell(
            "ogb_products", "train",
            {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
             "edge_chunks": 16},
        ),
        "molecule": Cell(
            "molecule", "train",
            {"n_nodes": 30 * 128, "n_edges": 64 * 128, "n_graphs": 128},
        ),
    }
