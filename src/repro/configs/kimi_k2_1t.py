"""kimi-k2-1t-a32b [arXiv:2501.kimi2]: trillion-param MoE, 384e top-8."""
import jax.numpy as jnp
from repro.configs.base import Arch, lm_cells
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = TransformerConfig(
    name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
    n_kv_heads=8, d_ff=2048, vocab=163840, qkv_bias=False,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, group_size=4096),
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    q_chunk=2048,
)

ARCH = Arch(
    arch_id="kimi-k2-1t-a32b",
    family="transformer",
    cfg=CFG,
    cells=lm_cells(full_attention=True),
    train_cfg=TrainConfig(
        # 1T params on 512 x 16GB chips: Adafactor (factored 2nd moment,
        # no momentum), bf16 gradient accumulators, 16 microbatches.
        opt=OptConfig(
            name="adafactor", lr=1e-4, b1=0.0,
            moment_dtype=jnp.bfloat16,
        ),
        microbatches=16,
        grad_accum_dtype=jnp.bfloat16,
    ),
    notes=(
        "1T-param MoE: experts sharded E/model x Fe/data x D/pod; "
        "memory budget discussed in EXPERIMENTS.md §Dry-run."
    ),
)
