"""granite-moe-3b-a800m [hf:ibm-granite]: MoE 40 experts top-8."""
import jax.numpy as jnp
from repro.configs.base import Arch, lm_cells
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = TransformerConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab=49155, qkv_bias=False,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512, group_size=4096),
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    q_chunk=2048,
)

ARCH = Arch(
    policy_overrides={
        # <10B models: replicating FFN/attention weights is cheaper than
        # gathering activations (measured; EXPERIMENTS.md §Perf iter 3)
        "pin_ffn_hidden": False, "pin_attn_boundary": False,
    },
    arch_id="granite-moe-3b-a800m",
    family="transformer",
    cfg=CFG,
    cells=lm_cells(full_attention=True),
    train_cfg=TrainConfig(
        opt=OptConfig(name="adamw", lr=3e-4), microbatches=4,
    ),
    notes=(
        "40 experts top-8; E=40 not divisible by model=16 so experts "
        "shard over pod and expert-FFN width over data (see sharding "
        "rules). vocab 49155 is odd -> embed/lm_head replicated."
    ),
)
