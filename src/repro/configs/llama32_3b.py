"""llama3.2-3b [hf:meta-llama/Llama-3.2-3B]: small llama3, GQA kv=8."""
import jax.numpy as jnp
from repro.configs.base import Arch, lm_cells
from repro.models.transformer import TransformerConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = TransformerConfig(
    name="llama3.2-3b", n_layers=28, d_model=3072, n_heads=24,
    n_kv_heads=8, d_ff=8192, vocab=128256, qkv_bias=False,
    rope_theta=500000.0,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    q_chunk=2048,
)

ARCH = Arch(
    policy_overrides={
        # <10B models: replicating FFN/attention weights is cheaper than
        # gathering activations (measured; EXPERIMENTS.md §Perf iter 3)
        "pin_ffn_hidden": False, "pin_attn_boundary": False,
    },
    arch_id="llama3.2-3b",
    family="transformer",
    cfg=CFG,
    cells=lm_cells(full_attention=True),
    train_cfg=TrainConfig(
        opt=OptConfig(name="adamw", lr=3e-4), microbatches=2,
    ),
    notes="small llama3; d_head=128.",
)
