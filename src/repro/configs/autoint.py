"""autoint [arXiv:1810.11921]: self-attentive feature interaction."""
import jax.numpy as jnp
from repro.configs.base import Arch, recsys_cells
from repro.models.recsys import RecSysConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = RecSysConfig(
    name="autoint", kind="autoint", n_dense=0, n_sparse=39,
    embed_dim=16, vocab_per_field=1_048_576, n_attn_layers=3,
    n_attn_heads=2, d_attn=32,
)

ARCH = Arch(
    arch_id="autoint",
    family="recsys",
    cfg=CFG,
    cells=recsys_cells(),
    train_cfg=TrainConfig(opt=OptConfig(name="adamw", lr=1e-3)),
    notes="3-layer 2-head self-attention over 39 field embeddings.",
)
