"""fm [Rendle ICDM'10]: factorization machine, O(nk) sum-square trick."""
import jax.numpy as jnp
from repro.configs.base import Arch, recsys_cells
from repro.models.recsys import RecSysConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = RecSysConfig(
    name="fm", kind="fm", n_dense=0, n_sparse=39, embed_dim=10,
    vocab_per_field=1_048_576,
)

ARCH = Arch(
    arch_id="fm",
    family="recsys",
    cfg=CFG,
    cells=recsys_cells(),
    train_cfg=TrainConfig(opt=OptConfig(name="adamw", lr=1e-3)),
    notes="pairwise interactions via 0.5((sum v)^2 - sum v^2).",
)
