"""sasrec [arXiv:1808.09781]: self-attentive sequential recsys."""
import jax.numpy as jnp
from repro.configs.base import Arch, recsys_cells
from repro.models.sasrec import SASRecConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = SASRecConfig(
    name="sasrec", n_items=1_048_576, embed_dim=50, n_blocks=2,
    n_heads=1, seq_len=50, n_neg=128,
)

from repro.configs.base import Cell

_CELLS = recsys_cells()
# EXTRA cell (beyond the 40): the paper's technique as the serving
# optimization — candidates ASH-encoded (b=4, d=e/2, ~12.5x smaller
# payload), scored asymmetrically. §Perf hillclimb #2.
_CELLS["retrieval_cand_ash"] = Cell(
    "retrieval_cand_ash", "retrieval",
    {"batch": 1, "n_candidates": 1_000_000, "ash_bits": 4,
     "ash_reduce": 2},
    skip="extra cell (paper-technique-optimized retrieval variant)",
)

ARCH = Arch(
    arch_id="sasrec",
    family="sasrec",
    cfg=CFG,
    cells=_CELLS,
    train_cfg=TrainConfig(opt=OptConfig(name="adamw", lr=1e-3)),
    notes=(
        "Next-item retrieval == MIPS over item embeddings: the ASH "
        "technique's natural serving integration (serving.retrieval)."
    ),
)
