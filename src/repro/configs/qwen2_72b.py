"""qwen2-72b [arXiv:2407.10671]: dense, GQA kv=8, QKV bias."""
import jax.numpy as jnp
from repro.configs.base import Arch, lm_cells
from repro.models.transformer import TransformerConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = TransformerConfig(
    name="qwen2-72b", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=29568, vocab=152064, qkv_bias=True,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True,
    q_chunk=2048,
)

ARCH = Arch(
    arch_id="qwen2-72b",
    family="transformer",
    cfg=CFG,
    cells=lm_cells(full_attention=True),
    train_cfg=TrainConfig(
        opt=OptConfig(name="adamw", lr=2e-4, moment_dtype=jnp.bfloat16),
        microbatches=8,
        grad_accum_dtype=jnp.float32,
    ),
    notes="72B dense: FSDP + TP; bf16 Adam moments to fit v5e HBM.",
)
