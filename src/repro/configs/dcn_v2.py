"""dcn-v2 [arXiv:2008.13535]: deep & cross network v2."""
import jax.numpy as jnp
from repro.configs.base import Arch, recsys_cells
from repro.models.recsys import RecSysConfig
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig

CFG = RecSysConfig(
    name="dcn-v2", kind="dcn_v2", n_dense=13, n_sparse=26,
    embed_dim=16, vocab_per_field=1_048_576, n_cross_layers=3,
    mlp_dims=(1024, 1024, 512),
)

ARCH = Arch(
    arch_id="dcn-v2",
    family="recsys",
    cfg=CFG,
    cells=recsys_cells(),
    train_cfg=TrainConfig(opt=OptConfig(name="adamw", lr=1e-3)),
    notes="26 x 1M-row embedding tables row-sharded over all axes.",
)
