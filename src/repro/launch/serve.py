"""ANN serving launcher: build an ASH index over a synthetic embedding
set and serve a request stream through the micro-batching engine — the
paper's end-to-end scenario.

  PYTHONPATH=src python -m repro.launch.serve --n 100000 --dim 256 \
      --bits 2 --reduce 2 --landmarks 64 --queries 1000 --req-batch 8

Requests of ``--req-batch`` rows stream through a ``QueryEngine``
(flush-on-size/timeout, bucketed jit traces, prep cache); the launcher
reports build time, QPS, p50/p99 request latency, engine stats, and
10-recall@{10,100} against exact ground truth.  ``--engine ivf`` serves
through the inverted-file index with coarse routing (the paper's Fig. 9
setup); ``--engine flat`` scans everything; ``--engine sharded``
scatter-gathers over the device mesh.

``--concurrent N`` switches to the concurrent serving subsystem: a
``ServingFrontend`` driver thread owns the flush cadence while N
closed-loop client threads (each: submit, block on the ticket, repeat)
share the batching — with a ``BackgroundCompactor`` attached when
``--auto-compact`` is set, so tombstone eviction happens off the
serving path.  ``--http PORT`` instead serves a minimal JSON API
(stdlib ``http.server`` atop the asyncio facade): POST ``/search`` with
``{"queries": [[...]], "k": 10}``, GET ``/stats`` for the live engine
snapshot.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset, isotropy_diagnostics
from repro.index import AshIndex
from repro.index import metrics as MET
from repro.serving.compactor import BackgroundCompactor
from repro.serving.engine import QueryEngine
from repro.serving.frontend import ServingFrontend
from repro.serving.wal import DurableIndex


def _print_engine_report(engine, mut_tickets=()):
    """The shared observability block: engine snapshot, prep cache,
    flush-reason mix, queue/compaction telemetry."""
    snap = engine.stats.snapshot()
    print(f"[engine] {snap}")
    print(f"[prep-cache] hit_rate={snap['prep_hit_rate']:.3f} "
          f"({snap['prep_hits']}/{snap['prep_hits'] + snap['prep_misses']} "
          f"rows) resident={engine.prep_cache_bytes / 1024:.1f}KiB "
          f"budget={engine.config.prep_cache_bytes / 2**20:.0f}MiB")
    reasons = ", ".join(
        f"{r}={c}" for r, c in snap["flushes"].items() if c
    )
    print(f"[queue] hwm={snap['queue_hwm']} rows "
          f"depth={snap['queue_depth']} "
          f"oldest_ticket={1e3 * snap['oldest_ticket_age_s']:.2f}ms "
          f"deadline_missed={snap['deadline_missed']} "
          f"flushes: {reasons or 'none'}")
    ic = snap.get("ivf_cost", {})
    if ic.get("effective_nprobe") or ic.get("splits"):
        eff = ", ".join(
            f"nprobe={n}:{c}" for n, c in sorted(
                ic["effective_nprobe"].items(), key=lambda kv: int(kv[0])
            )
        )
        print(f"[ivf-cost] rows_per_q={ic['rows_per_query']} "
              f"splits={ic['splits']} degraded={ic['degraded']} "
              f"flushes: {eff or 'none'}")
    comp = snap["compaction"]
    if comp["runs"] or comp["retries"] or snap["compactions"]:
        print(f"[compaction] background runs={comp['runs']} "
              f"retries={comp['retries']} swap={comp['swap_ms']:.2f}ms "
              f"blocked={comp['blocked_ms']:.2f}ms "
              f"synchronous={snap['compactions']}")
    for name, ts in snap.get("tier", {}).items():
        print(f"[tier] index={name} hit_rate={ts['hit_rate']:.3f} "
              f"({ts['hits']}/{ts['hits'] + ts['misses']} lists) "
              f"resident={ts['resident_lists']}/{ts['nlist']} lists "
              f"{ts['resident_bytes'] / 1024:.1f}KiB of "
              f"{ts['hot_bytes'] / 2**20:.0f}MiB budget "
              f"(index {ts['total_bytes'] / 2**20:.1f}MiB) "
              f"paged={ts['paged_rows']} rows "
              f"{ts['paged_bytes'] / 1024:.1f}KiB "
              f"in {ts['transfers']} transfers "
              f"evictions={ts['evictions']}")
    dur = snap.get("durability", {})
    for name, ws in dur.get("indexes", {}).items():
        print(f"[durability] index={name} wal_seq={ws['last_seqno']} "
              f"appends={ws['appends']} "
              f"({ws['appended_bytes'] / 1024:.1f}KiB) "
              f"fsync={ws['fsync']}:{ws['fsyncs']} "
              f"checkpoints={ws['checkpoints']}"
              f"@seq{ws['checkpoint_seqno']} "
              f"failures={dur.get('wal_failures', 0)}")
    sup = snap.get("supervision", {})
    if sup.get("driver_failures") or sup.get("compact_failures"):
        print(f"[supervision] driver_failures="
              f"{sup['driver_failures']} "
              f"(streak {sup['driver_consecutive_failures']}, "
              f"last {sup['driver_last_error']}) "
              f"compact_failures={sup['compact_failures']} "
              f"(last {sup['compact_last_error']})")
    return snap


def _final_checkpoint(engine):
    """Clean-shutdown checkpoint: fold the WAL into a fresh checkpoint
    so the next start replays nothing."""
    durable = engine.durability("default")
    if durable is None:
        return
    seq = durable.checkpoint(barrier=engine.mutation_barrier())
    durable.close()
    print(f"[checkpoint] seq={seq} (wal truncated)")


def _run_concurrent(args, index, engine, Q, search_kw):
    """Closed-loop multi-client serving: N threads each submit one
    request, block on its ticket, and immediately submit the next —
    the frontend driver owns every flush, so concurrent clients share
    buckets that a single caller would underfill."""
    import threading

    compactor = None
    if args.auto_compact is not None:
        compactor = BackgroundCompactor(engine).start()
    n_clients = args.concurrent
    per_client = max(1, args.queries // (n_clients * args.req_batch))
    latencies = [[] for _ in range(n_clients)]
    errors = []
    X_np = np.asarray(Q)  # clients re-serve the query pool

    t0 = time.time()
    with ServingFrontend(engine) as fe:
        def client(cid):
            rng = np.random.RandomState(args.seed + 100 + cid)
            try:
                for _ in range(per_client):
                    lo = rng.randint(0, max(1, len(X_np) - args.req_batch))
                    t_req = time.perf_counter()
                    fe.search(X_np[lo:lo + args.req_batch], k=100,
                              timeout=60.0, **search_kw)
                    latencies[cid].append(time.perf_counter() - t_req)
                    if args.mutate_fraction > 0 and (
                        rng.rand() < args.mutate_fraction
                    ):
                        if rng.rand() < 0.5:
                            fe.submit_add(
                                X_np[lo:lo + args.req_batch]
                            ).result(60.0)
                        else:
                            fe.submit_delete(
                                rng.randint(0, index.n, args.req_batch)
                            ).result(60.0)
            except Exception as e:  # surface, don't hang the join
                errors.append((cid, e))

        threads = [
            threading.Thread(target=client, args=(c,), daemon=True)
            for c in range(n_clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    if compactor is not None:
        compactor.wait_idle(30.0)
        compactor.stop()
    dt = time.time() - t0
    if errors:
        raise errors[0][1]
    lat = np.concatenate([np.asarray(x) for x in latencies])
    served = lat.size * args.req_batch
    p50, p99 = np.percentile(lat, [50, 99])
    print(f"[serve] {served} queries via {n_clients} closed-loop "
          f"clients in {dt:.2f}s ({served / dt:.0f} QPS on this CPU)")
    print(f"[latency] p50={1e3 * p50:.1f}ms p99={1e3 * p99:.1f}ms "
          f"per request")
    _print_engine_report(engine)
    _final_checkpoint(engine)
    return 0


def _run_http(args, index, engine, search_kw):
    """Minimal JSON-over-HTTP demo: a stdlib ``ThreadingHTTPServer``
    whose handlers dispatch into the frontend's asyncio facade — each
    request awaits its ticket on the event loop, so handler threads
    never park inside a flush."""
    import asyncio
    import json
    import threading
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    compactor = None
    if args.auto_compact is not None:
        compactor = BackgroundCompactor(engine).start()
    fe = ServingFrontend(engine).start()
    loop = asyncio.new_event_loop()
    loop_thread = threading.Thread(
        target=loop.run_forever, name="ash-http-loop", daemon=True
    )
    loop_thread.start()

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):  # stay quiet; stats has the counts
            pass

        def _reply(self, code, payload):
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path != "/stats":
                return self._reply(404, {"error": "GET /stats only"})
            snap = engine.stats.snapshot()
            snap["compiled_buckets"] = snap.pop("unique_buckets", 0)
            self._reply(200, snap)

        def do_POST(self):
            if self.path != "/search":
                return self._reply(404, {"error": "POST /search only"})
            try:
                n = int(self.headers.get("Content-Length", 0))
                req = json.loads(self.rfile.read(n))
                q = np.asarray(req["queries"], dtype=np.float32)
                k = int(req.get("k", 10))
                fut = asyncio.run_coroutine_threadsafe(
                    fe.asearch(q, k, **search_kw), loop
                )
                scores, ids = fut.result(timeout=60.0)
                self._reply(200, {"scores": scores.tolist(),
                                  "ids": ids.tolist()})
            except Exception as e:
                self._reply(400, {"error": str(e)})

    server = ThreadingHTTPServer(("127.0.0.1", args.http), Handler)
    print(f"[http] serving {index!r}")
    print(f"[http] POST http://127.0.0.1:{args.http}/search "
          f'{{"queries": [[...x{index.model.landmarks.shape[1]}]], '
          f'"k": 10}} | GET /stats | Ctrl-C to stop')
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        server.server_close()
        loop.call_soon_threadsafe(loop.stop)
        loop_thread.join(timeout=5.0)
        fe.stop()
        if compactor is not None:
            compactor.stop()
        _print_engine_report(engine)
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=100_000)
    p.add_argument("--dim", type=int, default=256)
    p.add_argument("--queries", type=int, default=1000)
    p.add_argument("--req-batch", type=int, default=8,
                   help="rows per request submitted to the engine")
    p.add_argument("--buckets", default="8,32,128",
                   help="engine batch buckets (padded shapes)")
    p.add_argument("--max-wait-ms", type=float, default=2.0,
                   help="engine flush-on-timeout age")
    p.add_argument("--bits", type=int, default=2)
    p.add_argument("--reduce", type=int, default=2,
                   help="dimensionality reduction factor (d = D / r)")
    p.add_argument("--landmarks", type=int, default=64)
    p.add_argument("--engine", choices=("flat", "ivf", "sharded"),
                   default="flat")
    p.add_argument("--tiered", action="store_true",
                   help="serve the IVF index host-tiered "
                        "(backend=tiered_ivf): codes/stats live in "
                        "host memory, only a --hot-bytes LRU of "
                        "inverted lists stays device-resident; probes "
                        "page cold lists in one batched transfer.  "
                        "Results stay bit-identical to --engine ivf "
                        "at equal probe sets (implies --engine ivf)")
    p.add_argument("--hot-bytes", type=int, default=64 << 20,
                   help="device-resident hot-set byte budget for "
                        "--tiered (0 = page every probe)")
    p.add_argument("--metric", choices=("dot", "l2", "cos"),
                   default="dot")
    p.add_argument("--nprobe", type=int, default=8)
    p.add_argument("--row-budget", type=int, default=None,
                   help="IVF cost model: cap the deduped candidate-row "
                        "bill per fused call — over-budget groups "
                        "flush early and split into within-budget "
                        "sub-batches (requires --engine ivf)")
    p.add_argument("--adaptive-nprobe", type=int, default=None,
                   metavar="NPROBE_MIN",
                   help="scale nprobe down a halving ladder toward "
                        "this floor under queue pressure, trading "
                        "recall for tail latency (requires "
                        "--engine ivf)")
    p.add_argument("--rerank", type=int, default=0)
    p.add_argument("--coarse", choices=("int8",), default=None,
                   help="run the symmetric int8 first-pass scan and "
                        "asymmetrically rescore only the top "
                        "--shortlist candidates per query")
    p.add_argument("--shortlist", type=int, default=None,
                   metavar="L",
                   help="coarse first-pass shortlist size (requires "
                        "--coarse; default: kernels.ops."
                        "DEFAULT_SHORTLIST)")
    p.add_argument("--mutate-fraction", type=float, default=0.0,
                   help="fraction of stream slots that carry a "
                        "mutation (engine-queued batched add or "
                        "tombstone delete) alongside the query traffic")
    p.add_argument("--auto-compact", type=float, default=None,
                   help="dead-fraction threshold for automatic "
                        "tombstone eviction after mutation batches "
                        "(off-thread under --concurrent/--http)")
    p.add_argument("--concurrent", type=int, default=0, metavar="N",
                   help="serve through a ServingFrontend driver with "
                        "N closed-loop client threads instead of the "
                        "single-caller stream")
    p.add_argument("--http", type=int, default=0, metavar="PORT",
                   help="serve a minimal JSON API on 127.0.0.1:PORT "
                        "(POST /search, GET /stats) atop the asyncio "
                        "facade until Ctrl-C")
    p.add_argument("--save-dir", default=None,
                   help="persist the built index (npz + JSON) here")
    p.add_argument("--wal", default=None, metavar="DIR",
                   help="durability directory: mutation WAL + atomic "
                        "checkpoints.  If DIR already holds a "
                        "checkpoint the index is RECOVERED from it "
                        "(checkpoint + WAL replay) instead of served "
                        "from the fresh build")
    p.add_argument("--fsync", choices=("always", "interval", "off"),
                   default="interval",
                   help="WAL fsync policy: 'always' makes every "
                        "acknowledged mutation survive power loss, "
                        "'interval' bounds the loss window, 'off' "
                        "leaves it to the OS (process crashes lose "
                        "nothing under any policy)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, args.n, args.dim)
    Q = embedding_dataset(kq, args.queries, args.dim)
    print("[data] isotropy:", isotropy_diagnostics(X))

    cfg = ASHConfig(
        b=args.bits, d=args.dim // args.reduce,
        n_landmarks=args.landmarks,
    )
    print(f"[config] b={cfg.b} d={cfg.d} C={cfg.n_landmarks} "
          f"payload={cfg.payload_bits()} bits/vec "
          f"({32 * args.dim / cfg.payload_bits():.1f}x compression)")

    t0 = time.time()
    opts = {"keep_raw": args.rerank > 0}
    backend = args.engine
    if args.tiered:
        if args.engine not in ("flat", "ivf"):
            p.error("--tiered requires --engine ivf")
        backend = "tiered_ivf"
        opts["hot_bytes"] = args.hot_bytes
    index = AshIndex.build(
        kb, X, cfg, backend=backend, metric=args.metric, **opts
    )
    print(f"[build] {time.time() - t0:.2f}s  {index!r}")
    if args.save_dir:
        index.save(args.save_dir)
        print(f"[save] {args.save_dir}")

    durable = None
    if args.wal:
        if DurableIndex.exists(args.wal):
            durable = DurableIndex.open(args.wal, fsync=args.fsync)
            index = durable.index
            print(f"[recovery] {durable.report.describe()}")
            print(f"[recovery] serving the recovered index "
                  f"(fresh build discarded): {index!r}")
        else:
            durable = DurableIndex.create(
                index, args.wal, fsync=args.fsync
            )
            print(f"[wal] durability at {args.wal} "
                  f"(fsync={args.fsync}, checkpoint 0 written)")

    gt_s, gt_i = MET.exact_topk(Q, X, k=10, metric=args.metric)

    engine_kw = {}
    if args.row_budget is not None:
        engine_kw["row_budget"] = args.row_budget
    if args.adaptive_nprobe is not None:
        engine_kw["nprobe_min"] = args.adaptive_nprobe
    if engine_kw and args.engine != "ivf" and not args.tiered:
        p.error("--row-budget/--adaptive-nprobe require --engine ivf")

    buckets = tuple(int(b) for b in args.buckets.split(","))
    engine = QueryEngine(
        index, batch_buckets=buckets,
        max_wait_s=args.max_wait_ms / 1e3,
        auto_compact=args.auto_compact,
        **engine_kw,
    )
    if durable is not None:
        engine.attach_durability(durable)
    if args.shortlist is not None and args.coarse is None:
        p.error("--shortlist requires --coarse")
    search_kw = dict(nprobe=args.nprobe, rerank=args.rerank)
    if args.coarse is not None:
        search_kw["coarse"] = args.coarse
        if args.shortlist is not None:
            search_kw["shortlist"] = args.shortlist

    if args.http:
        return _run_http(args, index, engine, search_kw)

    # warmup on a throwaway engine: compile EVERY bucket shape the
    # stream can hit (steady-state size flushes AND whatever bucket the
    # final remainder pads to) without pre-warming the timed engine's
    # prep cache or polluting its stats — a trace compiled inside the
    # timed window would be charged to QPS/p99
    warm = QueryEngine(
        index, batch_buckets=buckets,
        max_wait_s=args.max_wait_ms / 1e3,
    )
    for b in buckets:
        warm.search(Q[: min(b, args.queries)], k=100, **search_kw)
    if args.adaptive_nprobe is not None:
        # under pressure flushes walk the halving ladder from --nprobe
        # down to the floor; compile every rung now so a degraded
        # flush never charges a fresh trace to a live ticket
        n_w = args.nprobe
        while n_w > args.adaptive_nprobe:
            n_w = max(args.adaptive_nprobe, n_w // 2)
            for b in buckets:
                warm.search(Q[: min(b, args.queries)], k=100,
                            nprobe=n_w, rerank=args.rerank)

    if args.concurrent:
        return _run_concurrent(args, index, engine, Q, search_kw)

    X_np = np.asarray(X)
    mut_rng = np.random.RandomState(args.seed + 1)
    mut_tickets = []
    t0 = time.time()
    tickets = []
    for i in range(0, args.queries, args.req_batch):
        if args.mutate_fraction > 0 and mut_rng.rand() < args.mutate_fraction:
            # live mutation traffic rides the same engine queue: adds
            # re-ingest existing rows (no re-training), deletes
            # tombstone random live ids; both barrier this index's
            # queued queries and apply batched at the next flush
            if mut_rng.rand() < 0.5:
                rows = X_np[mut_rng.randint(0, args.n, args.req_batch)]
                mut_tickets.append(engine.submit_add(rows))
            else:
                victims = mut_rng.randint(0, index.n, args.req_batch)
                mut_tickets.append(engine.submit_delete(victims))
        tickets.append(
            engine.submit(Q[i:i + args.req_batch], k=100, **search_kw)
        )
    engine.flush()
    dt = time.time() - t0
    ids = np.concatenate([t.result()[1] for t in tickets], axis=0)

    p50, p99 = np.percentile([t.stats.latency_s for t in tickets],
                             [50, 99])
    print(f"[serve] {args.queries} queries "
          f"({len(tickets)} requests x {args.req_batch}) in {dt:.2f}s "
          f"({args.queries / dt:.0f} QPS on this CPU)")
    print(f"[latency] p50={1e3 * p50:.1f}ms "
          f"p99={1e3 * p99:.1f}ms per request")
    snap = _print_engine_report(engine)
    if mut_tickets:
        added = sum(t.n_rows for t in mut_tickets if t.kind == "add")
        removed = sum(t.result() for t in mut_tickets
                      if t.kind == "delete")
        print(f"[mutations] {len(mut_tickets)} submissions "
              f"({added} rows added, {removed} removed) in "
              f"{snap['mutation_batches']} batched applies, "
              f"{snap['compactions']} compactions; index now "
              f"n={index.n} live={index.n_live}")
        print("[recall] skipped (index mutated during the stream; "
              "ground truth is stale)")
    else:
        rec = MET.recall_curve(ids, gt_i, Rs=(10, 100))
        print(f"[recall] 10-recall@10={rec.get(10):.4f} "
              f"10-recall@100={rec.get(100):.4f}")
    _final_checkpoint(engine)
    return 0


if __name__ == "__main__":
    sys.exit(main())
