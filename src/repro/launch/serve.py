"""ANN serving launcher: build an ASH index over a synthetic embedding
set and serve batched queries — the paper's end-to-end scenario.

  PYTHONPATH=src python -m repro.launch.serve --n 100000 --dim 256 \
      --bits 2 --reduce 2 --landmarks 64 --queries 1000 --batch 64

Reports build time, encode time, QPS (this CPU), and 10-recall@{10,100}
against exact ground truth.  ``--engine ivf`` serves through the
inverted-file index with an nprobe sweep (the paper's Fig. 9 setup);
``--engine flat`` scans everything (graph-index regime).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import ASHConfig
from repro.data.synthetic import embedding_dataset, isotropy_diagnostics
from repro.index import AshIndex
from repro.index import metrics as MET


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=100_000)
    p.add_argument("--dim", type=int, default=256)
    p.add_argument("--queries", type=int, default=1000)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--bits", type=int, default=2)
    p.add_argument("--reduce", type=int, default=2,
                   help="dimensionality reduction factor (d = D / r)")
    p.add_argument("--landmarks", type=int, default=64)
    p.add_argument("--engine", choices=("flat", "ivf", "sharded"),
                   default="flat")
    p.add_argument("--metric", choices=("dot", "l2", "cos"),
                   default="dot")
    p.add_argument("--nprobe", type=int, default=8)
    p.add_argument("--rerank", type=int, default=0)
    p.add_argument("--save-dir", default=None,
                   help="persist the built index (npz + JSON) here")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    kx, kq, kb = jax.random.split(key, 3)
    X = embedding_dataset(kx, args.n, args.dim)
    Q = embedding_dataset(kq, args.queries, args.dim)
    print("[data] isotropy:", isotropy_diagnostics(X))

    cfg = ASHConfig(
        b=args.bits, d=args.dim // args.reduce,
        n_landmarks=args.landmarks,
    )
    print(f"[config] b={cfg.b} d={cfg.d} C={cfg.n_landmarks} "
          f"payload={cfg.payload_bits()} bits/vec "
          f"({32 * args.dim / cfg.payload_bits():.1f}x compression)")

    t0 = time.time()
    opts = {}
    if args.engine != "sharded":
        opts["keep_raw"] = args.rerank > 0
    index = AshIndex.build(
        kb, X, cfg, backend=args.engine, metric=args.metric, **opts
    )
    print(f"[build] {time.time() - t0:.2f}s  {index!r}")
    if args.save_dir:
        index.save(args.save_dir)
        print(f"[save] {args.save_dir}")

    gt_s, gt_i = MET.exact_topk(Q, X, k=10, metric=args.metric)

    # warmup + timed batched serving
    def run(queries):
        return index.search(queries, k=100, nprobe=args.nprobe,
                            rerank=args.rerank)

    _ = jax.block_until_ready(run(Q[: args.batch]))
    t0 = time.time()
    ids = []
    for i in range(0, args.queries - args.batch + 1, args.batch):
        s, idx = run(Q[i:i + args.batch])
        ids.append(idx)
    jax.block_until_ready(ids[-1])
    dt = time.time() - t0
    served = len(ids) * args.batch
    ids = jnp.concatenate(ids, axis=0)
    rec = MET.recall_curve(ids, gt_i[:served], Rs=(10, 100))
    print(f"[serve] {served} queries in {dt:.2f}s "
          f"({served / dt:.0f} QPS on this CPU)")
    print(f"[recall] 10-recall@10={rec.get(10):.4f} "
          f"10-recall@100={rec.get(100):.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
