"""Production mesh builders.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis
carries hierarchical data parallelism (reduce-scatter intra-pod,
all-reduce across the DCN/ICI pod link).

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; tests and benches see the single real CPU device).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(
    shape: tuple = None, axes: tuple = ("data", "model")
) -> Mesh:
    """Degenerate mesh over however many devices exist (CPU tests)."""
    n = jax.device_count()
    if shape is None:
        shape = (n,) + (1,) * (len(axes) - 1)
    devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel axes: every axis except the tensor-parallel one."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_size(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
