"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --steps 100 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` scales the architecture down (layers/width/vocab) so any
assigned config trains on this CPU container; the full configs are
exercised through the dry-run.  The loop is fault-tolerant: it resumes
from the latest committed checkpoint (state + data cursor + RNG) and a
``--die-at-step N`` flag exists purely to let tests/demos kill and
resurrect it deterministically.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data.synthetic import (
    ClickStream, IteratorState, SequenceStream, TokenStream,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import TrainConfig, init_state, make_train_step
from repro.models import transformer as TFM


def reduced_arch(arch):
    """Scale an assigned config down to CPU size, same family/topology."""
    import copy

    a = copy.copy(arch)
    cfg = arch.cfg
    if arch.family == "transformer":
        moe = cfg.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, n_experts=min(moe.n_experts, 8), d_ff=64,
                group_size=64,
            )
        a.cfg = dataclasses.replace(
            cfg, n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4), d_head=16,
            d_ff=128, vocab=512, moe=moe, dtype=jnp.float32,
            param_dtype=jnp.float32, q_chunk=0,
        )
    elif arch.family == "nequip":
        a.cfg = dataclasses.replace(cfg, n_layers=2, channels=8)
    elif arch.family == "sasrec":
        a.cfg = dataclasses.replace(
            cfg, n_items=1000, embed_dim=16, seq_len=16, n_neg=32
        )
    else:  # recsys
        kw = dict(vocab_per_field=1000, embed_dim=8)
        if cfg.kind == "dcn_v2":
            kw["mlp_dims"] = (64, 32)
        a.cfg = dataclasses.replace(cfg, **kw)
    a.train_cfg = dataclasses.replace(
        arch.train_cfg, microbatches=1,
        opt=dataclasses.replace(arch.train_cfg.opt, warmup_steps=10,
                                total_steps=1000),
    )
    return a


def make_stream(arch, batch: int, seq: int, seed: int, step: int = 0):
    st = IteratorState(seed=seed, step=step)
    if arch.family == "transformer":
        return TokenStream(st, batch, seq, arch.cfg.vocab)
    if arch.family == "sasrec":
        return SequenceStream(
            st, batch, arch.cfg.seq_len, arch.cfg.n_items,
            arch.cfg.n_neg,
        )
    if arch.family == "recsys":
        return ClickStream(
            st, batch, arch.cfg.n_dense, arch.cfg.n_sparse,
            arch.cfg.vocab_per_field,
        )
    if arch.family == "nequip":
        from repro.data import graphs as G

        class GraphStream:
            n_graphs = max(batch // 8, 1)  # STATIC per stream

            def __init__(self, state):
                self.state = state

            def next(self):
                b = G.batch_small_graphs(
                    self.state.seed * 100003 + self.state.step,
                    n_graphs=self.n_graphs, nodes_per=12,
                    edges_per=32, n_species=arch.cfg.n_species,
                )
                b.pop("n_graphs")  # static: closed over by the loss
                b = {k: jnp.asarray(v) for k, v in b.items()}
                key = jax.random.PRNGKey(self.state.step)
                b["energy"] = jax.random.normal(key, (self.n_graphs,))
                b["forces"] = (
                    jax.random.normal(key, b["positions"].shape) * 0.1
                )
                self.state.step += 1
                return b

        return GraphStream(st)
    raise ValueError(arch.family)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--die-at-step", type=int, default=0,
                   help="simulate a node failure (for FT tests)")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    arch = registry.get(args.arch)
    if args.reduced:
        arch = reduced_arch(arch)

    from repro import models

    fam = getattr(models, arch.family)
    key = jax.random.PRNGKey(args.seed)
    params = fam.init_params(key, arch.cfg)
    state = init_state(key, params, arch.train_cfg)
    loss_fn = arch.loss_fn(lambda a, k: a)
    stream_tmp = make_stream(arch, args.batch, args.seq, args.seed)
    if arch.family == "nequip":
        base = loss_fn
        ng = stream_tmp.n_graphs
        loss_fn = lambda p, b: base(p, dict(b, n_graphs=ng))
    step_fn = jax.jit(make_train_step(loss_fn, arch.train_cfg))

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep_n=3)
        latest = mgr.latest_step()
        if latest is not None:
            state, extra = mgr.restore(state, latest)
            start_step = latest
            args.seed = extra.get("seed", args.seed)
            print(f"[restore] resumed from step {latest}")

    stream = make_stream(arch, args.batch, args.seq, args.seed,
                         step=start_step)

    t0 = time.time()
    for i in range(start_step, args.steps):
        if args.die_at_step and i == args.die_at_step:
            print(f"[failure-sim] dying at step {i}", flush=True)
            sys.exit(42)
        batch = stream.next()
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i == start_step:
            dt = time.time() - t0
            print(
                f"step {i+1:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({dt:.1f}s)", flush=True,
            )
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state, extra={"seed": args.seed})
    if mgr:
        mgr.save(args.steps, state, extra={"seed": args.seed})
        mgr.wait()
    print("[done]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
