"""Sharding policy: DP / FSDP / TP / EP / SP rules for every family.

Everything is divisibility-checked: an axis is only assigned to a dim it
divides, otherwise the next candidate (or replication) is used — so the
same rules compile for 40-expert granite and 384-expert kimi, on the
single-pod and the 2-pod mesh alike.  What ends up replicated is visible
in the dry-run memory analysis.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Knobs the perf hillclimb flips."""

    tp_axis: str = "model"
    seq_parallel: bool = False  # shard activations' seq dim over tp
    fsdp: bool = True  # shard big params over the data axis too
    shard_moe_buffer: bool = True
    # Attention-boundary and FFN-hidden layout pins (§Perf iterations
    # 1-2). Size-dependent tradeoff: pinning swaps weight gathers for
    # activation gathers — a 10x collective win at 72B+ scale, but a
    # regression for <10B models whose FFN weights are cheaper to
    # replicate than their activations are to gather. Per-arch override
    # via Arch.policy_overrides.
    pin_attn_boundary: bool = True
    pin_ffn_hidden: bool = True

    def dp(self, mesh: Mesh) -> tuple:
        return tuple(a for a in mesh.axis_names if a != self.tp_axis)


def _div(n: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    total = 1
    for a in axes:
        if a not in mesh.shape:  # e.g. no "pod" axis on single-pod mesh
            return False
        total *= mesh.shape[a]
    return n % total == 0


def pick(mesh: Mesh, dim: int, *candidates):
    """First candidate axis (or axis tuple) that divides dim; else None."""
    for c in candidates:
        if c is None:
            continue
        if _div(dim, mesh, c):
            return c
    return None


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def fit_spec(spec: P, ndim: int) -> P:
    """Adapt a spec to a lower-rank tensor by dropping trailing Nones
    (adafactor vr/vc reuse the parameter rules on reduced shapes)."""
    entries = list(spec)
    while len(entries) > ndim and entries[-1] is None:
        entries.pop()
    if len(entries) > ndim:
        return P()
    return P(*entries)


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
        for p in path
    )


def specs_by_rules(tree, rules: Callable[[str, tuple], P]):
    """Map a (path, shape) -> PartitionSpec rule over a pytree of
    ShapeDtypeStructs (or arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: rules(_path_str(path), tuple(leaf.shape)), tree
    )


# ---------------------------------------------------------------------------
# Transformer parameter rules
# ---------------------------------------------------------------------------


def transformer_param_rules(mesh: Mesh, pol: ShardingPolicy):
    tp = pol.tp_axis

    def rules(path: str, shape: tuple) -> P:
        nd = len(shape)

        def ax(i, *cands):
            # bounds-safe: reduced shapes (adafactor row/col stats) use
            # the same rules with trailing dims dropped
            if i >= nd or i < -nd:
                return None
            return pick(mesh, shape[i], *cands)

        if path.endswith("embed"):  # (V, D)
            return P(ax(0, tp), ax(1, "data", "pod"))
        if path.endswith("lm_head"):  # (D, V)
            return P(ax(0, "data", "pod"), ax(1, tp))
        if re.search(r"layers/(wq|wk|wv)$", path):  # (L, D, X)
            return P(None, ax(1, "data", "pod") if pol.fsdp else None,
                     ax(2, tp))
        if path.endswith("layers/wo"):  # (L, X, D)
            return P(None, ax(1, tp),
                     ax(2, "data", "pod") if pol.fsdp else None)
        if re.search(r"layers/(w_gate|w_up)$", path):  # (L, D, F)
            return P(None, ax(1, "data", "pod") if pol.fsdp else None,
                     ax(2, tp))
        if path.endswith("layers/w_down"):  # (L, F, D)
            return P(None, ax(1, tp),
                     ax(2, "data", "pod") if pol.fsdp else None)
        if path.endswith("moe/router"):  # (L, D, E)
            return P(None, ax(1, "data", "pod") if pol.fsdp else None,
                     None)
        if re.search(r"moe/(w_gate|w_up)$", path):  # (L, E, D, Fe)
            e_ax = ax(1, tp, "pod")
            d_ax = ax(2, "pod" if e_ax != "pod" else None)
            f_ax = ax(3, "data") if pol.fsdp else None
            return P(None, e_ax, d_ax, f_ax)
        if path.endswith("moe/w_down"):  # (L, E, Fe, D)
            e_ax = ax(1, tp, "pod")
            f_ax = ax(2, "data") if pol.fsdp else None
            d_ax = ax(3, "pod" if e_ax != "pod" else None)
            return P(None, e_ax, f_ax, d_ax)
        # norms, biases, kv_quant projections: replicated
        return P()

    return rules


# ---------------------------------------------------------------------------
# RecSys / SASRec / NequIP parameter rules
# ---------------------------------------------------------------------------


def recsys_param_rules(mesh: Mesh, pol: ShardingPolicy):
    tp = pol.tp_axis

    def rules(path: str, shape: tuple) -> P:
        def ax(i, *cands):
            return pick(mesh, shape[i], *cands)

        if path.endswith("tables") or path.endswith("linear_sparse"):
            # (F*V, e): row-shard the huge table over EVERYTHING possible
            return P(ax(0, ("pod", "data", "model"), ("data", "model"),
                        ("data",)), None)
        if path.endswith("item_emb"):  # (n_items, e)
            return P(ax(0, ("pod", "data", "model"), ("data", "model"),
                        ("data",)), None)
        if "mlp" in path and len(shape) == 2:
            return P(None, ax(1, tp))
        if "cross" in path and len(shape) == 3:
            return P(None, None, None)  # tiny (429 x 429)
        if len(shape) >= 2:
            return P(*([None] * (len(shape) - 1) + [ax(-1, tp)]))
        return P()

    return rules


def nequip_param_rules(mesh: Mesh, pol: ShardingPolicy):
    def rules(path: str, shape: tuple) -> P:
        return P()  # ~100k params: replicate

    return rules


# ---------------------------------------------------------------------------
# Batch / activation specs
# ---------------------------------------------------------------------------


def batch_rules_leading_dp(mesh: Mesh, pol: ShardingPolicy):
    """Shard dim 0 over the DP axes (batch/nodes/edges); rest replicated."""
    dpa = pol.dp(mesh)

    def rules(path: str, shape: tuple) -> P:
        if not shape:
            return P()
        a0 = pick(mesh, shape[0], dpa, dpa[:1], dpa[-1:])
        return P(*([a0] + [None] * (len(shape) - 1)))

    return rules


def kv_cache_rules(mesh: Mesh, pol: ShardingPolicy):
    """Cache (L, B, S, KV, dh) or codes (L, B, S, KV, W):
    B over DP, S over tp (flash-decoding style length splits)."""
    dpa = pol.dp(mesh)
    tp = pol.tp_axis

    def rules(path: str, shape: tuple) -> P:
        if len(shape) < 4:
            return P()
        b_ax = pick(mesh, shape[1], dpa, dpa[:1], dpa[-1:])
        s_ax = pick(mesh, shape[2], tp)
        return P(*([None, b_ax, s_ax] + [None] * (len(shape) - 3)))

    return rules


# ---------------------------------------------------------------------------
# Activation constraint hook (passed into model forwards)
# ---------------------------------------------------------------------------


def make_constrain(mesh: Mesh, pol: ShardingPolicy, param_rules=None):
    dpa = pol.dp(mesh)
    tp = pol.tp_axis

    def constrain(a, kind: str):
        if kind == "layer_params" and param_rules is not None:
            # Per-layer sliced weights inside a scan body: constrain the
            # slice back to its sharded spec so GSPMD cannot hoist the
            # FSDP all-gather out of the loop (which would materialize
            # ALL layers' weights at once — see EXPERIMENTS.md §Perf).
            def f(path, leaf):
                p = "layers/" + _path_str(path)
                try:
                    spec = param_rules(p, (None,) + tuple(leaf.shape))
                    sub = P(*spec[1:len(leaf.shape) + 1])
                    return jax.lax.with_sharding_constraint(
                        leaf, NamedSharding(mesh, sub)
                    )
                except Exception:
                    return leaf

            return jax.tree_util.tree_map_with_path(f, a)
        try:
            if kind == "resid":  # (B, S, D)
                sp = pick(mesh, a.shape[1], tp) if pol.seq_parallel else None
                spec = P(pick(mesh, a.shape[0], dpa, dpa[:1], dpa[-1:]),
                         sp, None)
            elif kind in ("qkv", "kv"):  # (B, S, H, dh)
                spec = P(pick(mesh, a.shape[0], dpa, dpa[:1], dpa[-1:]),
                         None, pick(mesh, a.shape[2], tp), None)
            elif kind == "ffn_hidden":  # (B, S, F): Megatron column-
                # parallel hidden — F over tp; without this pin GSPMD
                # replicates the FFN weights instead (§Perf iteration 2)
                if not pol.pin_ffn_hidden:
                    return a
                spec = P(pick(mesh, a.shape[0], dpa, dpa[:1], dpa[-1:]),
                         None, pick(mesh, a.shape[2], tp))
            elif kind in ("attn_out", "v"):  # (B, S, H|KV, dh)
                if not pol.pin_attn_boundary:
                    return a
                spec = P(pick(mesh, a.shape[0], dpa, dpa[:1], dpa[-1:]),
                         None, pick(mesh, a.shape[2], tp), None)
            elif kind == "logits":  # (B, S, V)
                spec = P(pick(mesh, a.shape[0], dpa, dpa[:1], dpa[-1:]),
                         None, pick(mesh, a.shape[2], tp))
            elif kind == "moe_buffer" and pol.shard_moe_buffer:
                # (n_groups, E, C, D); the expert axis must not reuse an
                # axis already carrying the group dim
                g_ax = pick(mesh, a.shape[0], dpa, dpa[:1], dpa[-1:])
                used = (g_ax,) if isinstance(g_ax, str) else (g_ax or ())
                e_cands = [c for c in (tp, "pod") if c not in used]
                spec = P(g_ax, pick(mesh, a.shape[1], *e_cands) if e_cands
                         else None, None, None)
            elif kind == "node_feats":  # (N, C, m)
                spec = P(pick(mesh, a.shape[0], dpa, dpa[:1], dpa[-1:]),
                         None, None)
            elif kind == "edge_feats":  # (E, ...) edge-wise tensors
                spec = P(*(
                    [pick(mesh, a.shape[0], dpa, dpa[:1], dpa[-1:])]
                    + [None] * (a.ndim - 1)
                ))
            elif kind == "edge_chunked":  # (chunks, E/chunks, ...)
                spec = P(*(
                    [None, pick(mesh, a.shape[1], dpa, dpa[:1],
                                dpa[-1:])]
                    + [None] * (a.ndim - 2)
                ))
            else:
                return a
            return jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, spec)
            )
        except (ValueError, TypeError):
            return a

    return constrain


# ---------------------------------------------------------------------------
# Attach shardings to abstract values
# ---------------------------------------------------------------------------


def with_shardings(tree_sds, specs, mesh: Mesh):
    """Return ShapeDtypeStructs with NamedShardings attached."""
    return jax.tree_util.tree_map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree_sds, specs,
    )
