"""Launchers: mesh builders, dry-run, roofline analysis, train/serve."""
