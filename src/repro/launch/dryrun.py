"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and report memory / cost / collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b \
      --cell train_4k --multi-pod both --json out.json

Single-pod mesh: (data=16, model=16) = 256 chips.
Multi-pod mesh : (pod=2, data=16, model=16) = 512 chips.
"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
).strip()
# ^^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import registry
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh, mesh_size
from repro.launch.sharding import ShardingPolicy


def run_cell(arch, cell, *, multi_pod: bool, policy=None, verbose=True,
             with_probes: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    pol = policy or ShardingPolicy()
    t0 = time.time()
    fn, args = arch.make_cell_program(cell.name, mesh, pol)
    # NamedShardings embed the mesh; no ambient mesh context needed.
    donate = getattr(fn, "_donate_argnums", ())
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()  # PER-DEVICE (see roofline.py)
    text = compiled.as_text()
    chips = mesh_size(mesh)
    coll = RL.parse_collectives(text)
    ghost = min(
        RL.cpu_float_norm_ghost_bytes(text), mem.temp_size_in_bytes
    )
    result = {
        "arch": arch.arch_id,
        "cell": cell.name,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "argument_size_gib_per_dev": _gib(mem.argument_size_in_bytes),
        "output_size_gib_per_dev": _gib(mem.output_size_in_bytes),
        "temp_size_gib_per_dev": _gib(mem.temp_size_in_bytes),
        "peak_gib_per_dev": _gib(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
        ),
        "fits_16g_hbm": bool(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
            < 16 * 2**30
        ),
        # CPU-backend bf16->f32 normalization inflation (absent on TPU)
        "cpu_f32_ghost_gib": _gib(ghost),
        "peak_gib_per_dev_tpu_adj": _gib(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes - ghost
        ),
        "fits_16g_hbm_tpu_adj": bool(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes - ghost
            < 16 * 2**30
        ),
        "collective_counts": coll.count_by_kind,
        "async_collectives": coll.async_pairs,
    }
    if with_probes:
        from repro.launch import analysis as AN

        roof = AN.corrected_roofline(arch, cell, mesh, pol)
        result.update({
            "flops_per_dev": roof.flops,
            "hbm_bytes_per_dev": roof.hbm_bytes,
            "collective_bytes_per_dev": roof.collective_bytes,
            **{k: (round(v, 6) if isinstance(v, float) else v)
               for k, v in roof.row().items()
               if k.startswith("t_") or k in (
                   "bottleneck", "useful_flops_frac", "roofline_frac")},
        })
    if verbose:
        print(json.dumps(result, indent=None, default=_jsonify))
        print("--- memory_analysis:", mem)
    return result


def _gib(b):
    return round(b / 2**30, 3)


def _jsonify(x):
    try:
        return float(x)
    except Exception:
        return str(x)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="arch id (default: all)")
    p.add_argument("--cell", default=None, help="cell name (default: all)")
    p.add_argument("--multi-pod", choices=("single", "multi", "both"),
                   default="both")
    p.add_argument("--include-skipped", action="store_true")
    p.add_argument("--json", default=None, help="append results to file")
    p.add_argument("--seq-parallel", action="store_true")
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--with-probes", action="store_true",
                   help="add loop-corrected roofline terms (slower)")
    args = p.parse_args(argv)

    pol = ShardingPolicy(
        seq_parallel=args.seq_parallel, fsdp=not args.no_fsdp
    )
    pods = {"single": (False,), "multi": (True,), "both": (False, True)}[
        args.multi_pod
    ]
    results, failures = [], []
    for arch, cell in registry.all_cells(args.include_skipped):
        if args.arch and arch.arch_id != args.arch:
            continue
        if args.cell and cell.name != args.cell:
            continue
        for mp in pods:
            tag = f"{arch.arch_id}/{cell.name}/{'2x16x16' if mp else '16x16'}"
            print(f"=== {tag} ===", flush=True)
            try:
                results.append(
                    run_cell(arch, cell, multi_pod=mp, policy=pol,
                             with_probes=args.with_probes and not mp)
                )
            except Exception as e:
                traceback.print_exc()
                failures.append((tag, repr(e)))
    print(f"\n==== dry-run done: {len(results)} ok, "
          f"{len(failures)} failed ====")
    for tag, err in failures:
        print(f"FAILED {tag}: {err[:200]}")
    if args.json:
        mode = "a" if os.path.exists(args.json) else "w"
        with open(args.json, mode) as f:
            for r in results:
                f.write(json.dumps(r, default=_jsonify) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
