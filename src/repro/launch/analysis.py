"""Loop-corrected cost analysis via probe programs.

XLA's cost_analysis counts lax.scan/while bodies ONCE (verified — see
roofline.py docstring).  The production cells use scan over layers (and
over gradient-accumulation microbatches, and lax.map for query
chunking), so their reported FLOPs/bytes/collective-bytes must be
corrected.  Rather than guessing multipliers, we lower LOOP-FREE probe
programs (layers python-unrolled, one microbatch, q_chunk off — probes
are never executed, so their transient memory is irrelevant) and solve
for the per-layer / fixed / optimizer components:

  train: F(L) = e + L*l (probe at L=1,2)  +  O (optimizer-only probe)
         total = k_micro * (e + L_full*l) + O
  prefill/decode: total = e + L_full*l

RecSys / SASRec / NequIP programs are loop-free already (python-level
layer loops) and are reported directly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch import roofline as RL
from repro.launch import sharding as SH
from repro.launch.mesh import mesh_size
from repro.train import optim as O
from repro.train.trainer import TrainConfig


@dataclasses.dataclass
class CostVec:
    flops: float
    hbm_bytes: float
    coll_bytes: float

    def __add__(self, o):
        return CostVec(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes,
                       self.coll_bytes + o.coll_bytes)

    def __sub__(self, o):
        return CostVec(self.flops - o.flops, self.hbm_bytes - o.hbm_bytes,
                       self.coll_bytes - o.coll_bytes)

    def __mul__(self, s):
        return CostVec(self.flops * s, self.hbm_bytes * s,
                       self.coll_bytes * s)

    __rmul__ = __mul__


def _cost_of(fn, args) -> CostVec:
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    text = compiled.as_text()
    coll = RL.parse_collectives(text)
    return CostVec(
        float(ca.get("flops", 0.0)),
        float(ca.get("bytes accessed", 0.0)),
        float(coll.total_bytes),
    )


def _probe_arch(arch, n_layers: int, micro: bool):
    """Clone of the arch with a loop-free model config."""
    import copy

    a = copy.copy(arch)
    a.cfg = dataclasses.replace(
        arch.cfg, n_layers=n_layers, use_scan=False, q_chunk=0
    )
    if micro:
        a.train_cfg = dataclasses.replace(arch.train_cfg, microbatches=1)
    return a


def _probe_cell(cell, batch_div: int):
    """Cell with the per-microbatch batch size."""
    shape = dict(cell.shape)
    if "global_batch" in shape:
        shape["global_batch"] = max(
            shape["global_batch"] // batch_div, 1
        )
    return dataclasses.replace(cell, shape=shape)


def transformer_corrected_cost(arch, cell, mesh, pol) -> CostVec:
    """Probe-corrected per-device cost for an LM cell."""
    k = arch.train_cfg.microbatches if cell.kind == "train" else 1
    L_full = arch.cfg.n_layers
    if arch.policy_overrides:
        pol = dataclasses.replace(pol, **arch.policy_overrides)
    constrain = SH.make_constrain(
        mesh, pol, param_rules=arch.param_rules(mesh, pol)
    )

    def probe_grads(n_layers: int) -> CostVec:
        a = _probe_arch(arch, n_layers, micro=True)
        c = _probe_cell(cell, k)
        if cell.kind == "train":
            # loss+grads only (optimizer probed separately)
            from repro.configs.base import _sharded_state, _batch_sds

            params_sds = a.abstract_params()
            specs = SH.specs_by_rules(params_sds, a.param_rules(mesh, pol))
            params = SH.with_shardings(params_sds, specs, mesh)
            batch = _batch_sds(
                {
                    "tokens": ((c.shape["global_batch"],
                                c.shape["seq_len"]), jnp.int32),
                    "labels": ((c.shape["global_batch"],
                                c.shape["seq_len"]), jnp.int32),
                },
                mesh, pol,
            )
            loss = a.loss_fn(constrain)

            def grads_fn(p, b):
                return jax.value_and_grad(loss)(p, b)

            return _cost_of(grads_fn, (params, batch))
        fn, args = a.make_cell_program(cell.name, mesh, pol)
        return _cost_of(fn, args)

    f1 = probe_grads(1)
    f2 = probe_grads(2)
    layer = f2 - f1
    fixed = f1 - layer
    fwd_bwd = fixed + L_full * layer

    if cell.kind != "train":
        return fwd_bwd

    # optimizer-only probe on the FULL-depth abstract params
    params_sds = arch.abstract_params()
    prules = arch.param_rules(mesh, pol)
    specs = SH.specs_by_rules(params_sds, prules)
    params = SH.with_shardings(params_sds, specs, mesh)
    opt_init, opt_update = O.make_optimizer(arch.train_cfg.opt)
    opt_sds = jax.eval_shape(opt_init, params_sds)

    # moments inherit the parameter sharding (path-prefix strip)
    from jax.sharding import PartitionSpec as P

    def opt_spec_for(path, leaf):
        ps = SH._path_str(path)
        for pref in ("mu/", "nu/", "vr/", "vc/", "v/", "residual/"):
            if ps.startswith(pref):
                try:
                    return SH.fit_spec(
                        prules(ps[len(pref):], tuple(leaf.shape)),
                        len(leaf.shape),
                    )
                except Exception:
                    return P()
        return P()

    opt_specs = jax.tree_util.tree_map_with_path(opt_spec_for, opt_sds)
    opt_sharded = SH.with_shardings(opt_sds, opt_specs, mesh)
    grads = params  # same shapes/shardings as params

    def opt_fn(grads, opt_state, params):
        upd, new_state = opt_update(grads, opt_state, params)
        return O.apply_updates(params, upd), new_state

    opt_cost = _cost_of(opt_fn, (grads, opt_sharded, params))
    return k * fwd_bwd + opt_cost


def direct_cost(arch, cell, mesh, pol) -> CostVec:
    """Loop-free families: report the real program's cost directly."""
    fn, args = arch.make_cell_program(cell.name, mesh, pol)
    return _cost_of(fn, args)


def corrected_roofline(arch, cell, mesh, pol) -> RL.Roofline:
    chips = mesh_size(mesh)
    if arch.family == "transformer":
        cv = transformer_corrected_cost(arch, cell, mesh, pol)
    else:
        cv = direct_cost(arch, cell, mesh, pol)
    mf = RL.model_flops_for(arch, cell)
    return RL.Roofline(
        flops=cv.flops,
        hbm_bytes=cv.hbm_bytes,
        collective_bytes=cv.coll_bytes,
        n_chips=chips,
        model_flops=(mf / chips if mf is not None else None),
    )
