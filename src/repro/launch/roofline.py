"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e constants:
  compute   = HLO_FLOPs_per_device  / 197e12 FLOP/s bf16
  memory    = HLO_bytes_per_device  / 819e9  B/s HBM
  collective= collective_bytes_per_device / 50e9 B/s ICI

MEASURED SEMANTICS of the XLA analyses (verified empirically, see
EXPERIMENTS.md §Dry-run): cost_analysis() and memory_analysis() on an
SPMD-partitioned module report PER-DEVICE quantities, and while-loop
(lax.scan) bodies are counted ONCE, not x trip-count.  All Roofline
fields here are therefore per-device; scan undercounting is corrected by
the loop-free probe programs in launch.analysis.

cost_analysis() has no collective statistics, so collective bytes come
from parsing the optimized HLO text and summing output-shape bytes of
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per-device shard shapes — consistent).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12  # bf16 per chip, TPU v5e
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-chip injection, 1 link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> byte count; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict
    async_pairs: int  # number of *-start ops (compute/comm overlap)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in optimized HLO.

    Counts each logical collective once (start/done pairs dedup'd), and
    reports how many are async (-start form) — evidence XLA scheduled
    them to overlap with compute.
    """
    bytes_by_kind: dict = {k: 0 for k in _COLLECTIVES}
    count_by_kind: dict = {k: 0 for k in _COLLECTIVES}
    async_pairs = 0
    op_alt = "|".join(_COLLECTIVES)
    pat = re.compile(
        r"%?[\w.\-]+\s*=\s*(\S+)\s+(" + op_alt + r")(-start|-done)?\("
    )
    for line in hlo_text.splitlines():
        ls = line.strip()
        # "%name = TYPE[dims] all-gather(...)" (or async -start/-done)
        m = pat.match(ls)
        if not m:
            continue
        shape_str, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # counted at -start
        if suffix == "-start":
            async_pairs += 1
        count_by_kind[op] += 1
        bytes_by_kind[op] += _shape_bytes(shape_str)
    return CollectiveStats(bytes_by_kind, count_by_kind, async_pairs)


def cpu_float_norm_ghost_bytes(hlo_text: str, min_bytes: int = 2**26) -> int:
    """Estimate CPU-pipeline-only f32 'ghost' buffers.

    The CPU XLA backend has no native bf16 arithmetic: float
    normalization upcasts bf16 loop carries/stacks to f32, materializing
    full-size f32 copies of bf16 buffers (verified in the dry-run HLO:
    ``f32[S] convert(bf16[S])`` feeding while-loop dus stacks).  The TPU
    backend computes bf16 natively and does not allocate these.  We sum
    distinct large f32 convert-results whose operand shape also exists
    in bf16 — reported as a separate diagnostic so 'fits on 16 GB v5e'
    can be judged net of the CPU-only inflation (see EXPERIMENTS.md).
    """
    bf16_shapes = set(re.findall(r"bf16\[([\d,]+)\]", hlo_text))
    ghosts: dict = {}
    for m in re.finditer(
        r"%(\S+) = f32\[([\d,]+)\]\S* (?:convert|fusion)\(", hlo_text
    ):
        name, dims = m.group(1), m.group(2)
        if dims not in bf16_shapes:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if 4 * n >= min_bytes:
            # one ghost per distinct shape per producer kind — convert
            # chains alias, so count each shape once
            ghosts[dims] = 4 * n
    return sum(ghosts.values())


@dataclasses.dataclass
class Roofline:
    """All quantities PER DEVICE. model_flops = useful (6ND-convention)
    flops for the whole step divided by chip count."""

    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int
    model_flops: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(ts, key=ts.get)

    @property
    def useful_flops_frac(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_frac(self) -> float:
        """Fraction of peak implied by the dominant term for USEFUL model
        flops: (useful-flops time at peak) / (dominant bound time) — the
        'MFU the roofline allows', the §Perf score."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        if bound == 0:
            return 0.0
        useful = (self.model_flops if self.model_flops is not None
                  else self.flops) / PEAK_FLOPS
        return useful / bound

    def row(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def from_compiled(compiled, n_chips: int, model_flops=None,
                  hlo_text: Optional[str] = None) -> Roofline:
    """model_flops argument: GLOBAL useful flops (divided here)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=float(coll.total_bytes),
        n_chips=n_chips,
        model_flops=(model_flops / n_chips
                     if model_flops is not None else None),
    )


def model_flops_for(arch, cell) -> Optional[float]:
    """MODEL_FLOPS: 6*N*D for dense LM train, 6*N_active*D for MoE;
    2*N*D for LM forward-only; analytic estimates for others."""
    if arch.family == "transformer":
        tokens = cell.shape["global_batch"] * (
            cell.shape["seq_len"] if cell.kind != "decode" else 1
        )
        n_params = (
            arch.cfg.active_param_count()
            if arch.cfg.moe else arch.cfg.param_count()
        )
        if cell.kind == "train":
            return 6.0 * n_params * tokens
        if cell.kind == "prefill":
            return 2.0 * n_params * tokens
        # decode: fwd flops + attention over the cache
        L, KV, dh = arch.cfg.n_layers, arch.cfg.n_kv_heads, arch.cfg.head_dim
        H = arch.cfg.n_heads
        attn = (
            2.0 * 2.0 * cell.shape["global_batch"] * H * dh
            * cell.shape["seq_len"] * L
        )
        return 2.0 * n_params * tokens + attn
    if arch.family == "sasrec":
        e = arch.cfg.embed_dim
        if cell.kind == "retrieval":
            return 2.0 * cell.shape["n_candidates"] * e
        if cell.kind == "serve":
            # user encoder + full-catalog MIPS
            S = arch.cfg.seq_len
            enc = 2.0 * arch.cfg.n_blocks * (4 * e * e * S + 2 * S * S * e)
            return cell.shape["batch"] * (
                enc + 2.0 * arch.cfg.n_items * e
            )
        S = arch.cfg.seq_len
        enc = 2.0 * arch.cfg.n_blocks * (4 * e * e * S + 2 * S * S * e)
        return 3.0 * cell.shape["batch"] * (
            enc + 2.0 * S * arch.cfg.n_neg * e
        )
    if arch.family == "recsys":
        cfg = arch.cfg
        B = cell.shape.get("n_candidates", cell.shape.get("batch", 1))
        d0 = cfg.interaction_dim
        if cfg.kind == "dcn_v2":
            per = 2.0 * cfg.n_cross_layers * d0 * d0
            dims = (d0,) + cfg.mlp_dims
            for i in range(len(dims) - 1):
                per += 2.0 * dims[i] * dims[i + 1]
        elif cfg.kind == "fm":
            per = 4.0 * cfg.n_sparse * cfg.embed_dim
        else:  # autoint
            F, H, da = cfg.n_sparse, cfg.n_attn_heads, cfg.d_attn
            e = cfg.embed_dim
            per = 0.0
            d_in = e
            for _ in range(cfg.n_attn_layers):
                per += 2.0 * F * (4 * d_in * H * da) + 4.0 * F * F * H * da
                d_in = H * da
        mult = 3.0 if cell.kind == "train" else 1.0
        return mult * B * per
    if arch.family == "nequip":
        E = cell.shape["n_edges"]
        C = arch.cfg.channels
        # per edge: radial MLP + tensor-product paths (~9 paths, m<=5)
        per_edge = 2.0 * (arch.cfg.n_rbf * 64 + 64 * 9 * C) + 9 * 2.0 * C * 15
        return 3.0 * arch.cfg.n_layers * E * per_edge
    return None
