"""Host-memory tiered IVF backend: beyond-HBM indexes.

Every other backend keeps the whole payload device-resident, so index
size — not code size — caps the corpus.  ``TieredIVFBackend`` keeps
only the model (landmarks == IVF centroids) and a byte-bounded hot set
of inverted lists on the device; packed codes, the ``ASHStats``
columns, the ``CoarseCodes`` values and the raw rerank rows live
per-list in host memory, sliced along the contiguous-list row order
``ivf._assemble`` produces.

A search lowers through ``common.plan_paged_probe``: resolve the probe
set (the same coarse top-k expression the HBM backend jits, run as its
own tiny jit so the probed lists are host-visible), look each probed
list up in the device-resident block cache (the shared
:class:`repro.serving.cache.ByteLRU`), batch all misses into ONE
host→device transfer, and concatenate the resident blocks into an
ascending-list union ``IVFIndex`` whose inverted lists are rebased to
union-local rows.  Scoring then calls the SAME jitted entry points the
HBM backend compiles — ``ivf._score_probed`` for partial probes,
``ivf._full_scan`` for covering ones — so the traced graph is
identical and only the gather-source length differs.  That is the
load-bearing choice for bit-identity: the union preserves the global
row order restricted to the probed lists (ascending contiguous slices
→ a monotone index shift), so the in-graph ``invlists[probe]`` gather
produces slot-for-slot the same candidate values, and reusing the HBM
backend's own jit (rather than a lookalike graph) keeps XLA's fusion
and rounding decisions aligned — a separately-jitted clone of the same
math has been observed to drift by one ulp under some XLA host
configurations.  Results are bit-identical to ``backend="ivf"`` at
equal probe sets for every option combination (rerank,
``coarse="int8"``, m=1 padding, covering nprobe, tombstones).

``nprobe >= nlist`` mirrors the HBM backend's dense full scan: the
union of ALL lists reproduces the global payload exactly (no pad
rows), scored under a dense plan with the tombstone bitmap as the
kernel mask operand.

Mutations delegate to the HBM IVF implementation: add/compact
materialize the host mirrors into an ``IVFIndex``, run ``IV._add`` /
``IV._compact`` (literally the same code, hence bitwise-identical
assembly), and re-host.  Deletes are host-side bitmap updates — cached
device blocks stay valid because tombstones live in a separate bitmap
(sliced per-union from a lazily refreshed device copy), masked
in-graph exactly like the HBM backend masks them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring as S
from repro.core.types import (
    ASHPayload, ASHStats, CoarseCodes,
)
from repro.index import common as C
from repro.index import ivf as IV
from repro.index.api import (
    IVFBackend, _model_arrays, register_backend,
)
from repro.serving.cache import ByteLRU

DEFAULT_HOT_BYTES = 64 << 20

# host mirror columns, in block order; "raw" rides last when present
_FIELDS = (
    "codes", "scale", "offset", "cluster",
    "res_norm", "ip_x_mu", "x_sq", "cvalues", "ids",
)


class TieredState:
    """Host mirrors + device hot set of one tiered IVF index.

    NOT a pytree: the host arrays never enter a jit trace — per-list
    blocks are device_put on demand and cached in ``cache`` (list id →
    tuple of device arrays in ``_FIELDS`` order, + raw).  ``counts`` /
    ``starts`` give each list's contiguous global row range;
    ``invlists`` / ``live`` are exposed host-side so the serving
    engine's IVF cost model (probe sets, live list sizes, nprobe
    clamping) works on this state unchanged.
    """

    def __init__(self):  # populated by from_ivf
        raise TypeError("use TieredState.from_ivf()")

    @classmethod
    def from_ivf(
        cls, index: IV.IVFIndex, hot_bytes: int, carry=None
    ) -> "TieredState":
        """Host an ``IVFIndex``.  ``carry`` threads the lifetime cache
        and paging counters through a mutation re-host so gauges stay
        monotonic (the block cache itself is dropped: a re-sort moves
        rows between lists)."""
        st = object.__new__(cls)
        st.metric = index.metric
        st.max_list_len = int(index.max_list_len)
        st.next_id = index.next_id
        st.hot_bytes = int(hot_bytes)
        st.model = index.model  # device-resident, with the landmarks
        st.coarse_mean = index.coarse.mean  # GLOBAL corpus mean
        st.b = index.payload.b
        st.d = index.payload.d
        st.nlist = int(index.model.landmarks.shape[0])
        st.codes = np.asarray(index.payload.codes)
        st.scale = np.asarray(index.payload.scale)
        st.offset = np.asarray(index.payload.offset)
        st.cluster = np.asarray(index.payload.cluster)
        st.res_norm = np.asarray(index.stats.res_norm)
        st.ip_x_mu = np.asarray(index.stats.ip_x_mu)
        st.x_sq = np.asarray(index.stats.x_sq)
        st.cvalues = np.asarray(index.coarse.values)
        st.ids = np.asarray(index.ids)
        st.raw = None if index.raw is None else np.asarray(index.raw)
        st.live = (
            None if index.live is None
            else np.asarray(index.live).astype(bool)
        )
        st.counts, st.starts = IV.list_geometry(st.cluster, st.nlist)
        st._invlists = None
        st._invlists_dev = None
        st._live_dev = None
        st.cache = ByteLRU(st.hot_bytes)
        st.paged_rows = 0
        st.paged_bytes = 0
        st.transfers = 0
        st.total_bytes = sum(
            int(getattr(st, f).nbytes) for f in _FIELDS
        ) + (0 if st.raw is None else int(st.raw.nbytes))
        if carry is not None:
            st.cache.hits = carry.cache.hits
            st.cache.misses = carry.cache.misses
            st.cache.evictions = carry.cache.evictions
            st.paged_rows = carry.paged_rows
            st.paged_bytes = carry.paged_bytes
            st.transfers = carry.transfers
        return st

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    @property
    def invlists(self) -> np.ndarray:
        """Padded inverted lists, host numpy — derived lazily from the
        contiguous geometry for engine compatibility (probe clamping,
        live list sizes); searches never touch it."""
        if self._invlists is None:
            self._invlists = IV.build_invlists(
                self.counts, self.starts, self.max_list_len
            )
        return self._invlists

    @property
    def live_dev(self):
        """Device copy of the tombstone bitmap (the in-graph mask
        operand), rebuilt lazily after each delete."""
        if self.live is None:
            return None
        if self._live_dev is None:
            self._live_dev = jnp.asarray(self.live)
        return self._live_dev

    @property
    def invlists_dev(self):
        """Device copy of the padded inverted lists (global rows) —
        the operand union searches rebase per probe set."""
        if self._invlists_dev is None:
            self._invlists_dev = jnp.asarray(self.invlists)
        return self._invlists_dev

    def materialize(self) -> IV.IVFIndex:
        """Device-resident ``IVFIndex`` with identical contents — the
        mutation path runs the HBM implementation on it and re-hosts,
        so assembly stays bitwise-equal to the HBM backend's."""
        return IV.IVFIndex(
            metric=self.metric,
            max_list_len=self.max_list_len,
            model=self.model,
            payload=ASHPayload(
                b=self.b, d=self.d,
                codes=jnp.asarray(self.codes),
                scale=jnp.asarray(self.scale),
                offset=jnp.asarray(self.offset),
                cluster=jnp.asarray(self.cluster),
            ),
            ids=jnp.asarray(self.ids),
            invlists=jnp.asarray(self.invlists),
            raw=None if self.raw is None else jnp.asarray(self.raw),
            stats=ASHStats(
                res_norm=jnp.asarray(self.res_norm),
                ip_x_mu=jnp.asarray(self.ip_x_mu),
                x_sq=jnp.asarray(self.x_sq),
            ),
            live=(
                None if self.live is None else jnp.asarray(self.live)
            ),
            next_id=self.next_id,
            coarse=CoarseCodes(
                values=jnp.asarray(self.cvalues), mean=self.coarse_mean
            ),
        )

    # -- the paging core ----------------------------------------------

    def _host_block(self, c: int) -> tuple:
        s = int(self.starts[c])
        e = s + int(self.counts[c])
        blk = tuple(getattr(self, f)[s:e] for f in _FIELDS)
        if self.raw is not None:
            blk += (self.raw[s:e],)
        return blk

    def fetch_blocks(self, lists) -> dict:
        """Resolve every list in ``lists`` to its device block: cache
        hits first, then ONE batched ``device_put`` for all misses.
        Blocks larger than the whole budget still serve this call —
        the cache just evicts them immediately (paging, not OOM)."""
        out = {}
        miss = []
        for c in lists:
            blk = self.cache.get(c)
            if blk is None:
                miss.append(c)
            else:
                out[c] = blk
        if miss:
            dev = jax.device_put([self._host_block(c) for c in miss])
            for c, blk in zip(miss, dev):
                blk = tuple(blk)
                out[c] = blk
                self.cache.put(c, blk)
                self.paged_rows += int(self.counts[c])
                self.paged_bytes += sum(int(a.nbytes) for a in blk)
            self.transfers += 1
        return out

    def union_index(self, lists, pad_rows: int) -> IV.IVFIndex:
        """Device-resident ``IVFIndex`` over the union of ``lists``
        (ascending ids) plus ``pad_rows`` zero rows.

        Ascending-list concatenation of contiguous slices reproduces
        the global row order restricted to the union, so the union's
        inverted lists are the global ones shifted by a per-list
        constant (rebased on device; non-union lists keep their global
        rows, which is fine — a probe set is always a subset of the
        union built from it).  Pad rows are never gathered (candidate
        entries are real union rows or -1), so they cannot perturb
        results.  The tombstone bitmap is sliced per-union from the
        device copy — NOT stored in the cached blocks — so deletes
        never invalidate the hot set."""
        blocks = self.fetch_blocks(lists)
        names = _FIELDS + (("raw",) if self.raw is not None else ())
        parts = {
            f: [blocks[c][i] for c in lists]
            for i, f in enumerate(names)
        }
        live = self.live_dev
        live_parts = None
        if live is not None:
            live_parts = [
                live[int(self.starts[c]):
                     int(self.starts[c]) + int(self.counts[c])]
                for c in lists
            ]
        if pad_rows:
            for f in names:
                host = getattr(self, f)
                fill = -1 if f == "ids" else 0
                parts[f].append(jnp.full(
                    (pad_rows,) + host.shape[1:], fill,
                    dtype=host.dtype,
                ))
            if live_parts is not None:
                live_parts.append(jnp.zeros(pad_rows, dtype=bool))
        u = {f: jnp.concatenate(parts[f], axis=0) for f in names}
        c_u = self.counts[np.asarray(lists, dtype=np.int64)]
        local_starts = np.concatenate(
            [[0], np.cumsum(c_u)[:-1]]
        ).astype(np.int64)
        if len(lists) == self.nlist:
            # all-lists union: local rows ARE global rows
            inv = self.invlists_dev
        else:
            delta = np.zeros(self.nlist, dtype=np.int32)
            delta[np.asarray(lists, dtype=np.int64)] = (
                local_starts - self.starts[np.asarray(lists)]
            ).astype(np.int32)
            inv = self.invlists_dev
            inv = jnp.where(
                inv >= 0, inv + jnp.asarray(delta)[:, None], -1
            )
        return IV.IVFIndex(
            metric=self.metric,
            max_list_len=self.max_list_len,
            model=self.model,
            payload=ASHPayload(
                b=self.b, d=self.d, codes=u["codes"],
                scale=u["scale"], offset=u["offset"],
                cluster=u["cluster"],
            ),
            ids=u["ids"],
            invlists=inv,
            raw=u.get("raw"),
            stats=ASHStats(
                res_norm=u["res_norm"], ip_x_mu=u["ip_x_mu"],
                x_sq=u["x_sq"],
            ),
            live=(
                None if live_parts is None
                else jnp.concatenate(live_parts, axis=0)
            ),
            next_id=None,
            coarse=CoarseCodes(
                values=u["cvalues"], mean=self.coarse_mean
            ),
        )


@functools.partial(jax.jit, static_argnames=("nprobe",))
def _probe_paged(ip_q_landmarks, landmark_sq_norms, nprobe: int):
    """Coarse assignment — the exact ``ivf._probe_lists`` expression
    (0.5 * ||mu||^2 is a power-of-two scale, so this is FMA-stable:
    fused and unfused lowerings round identically, and the
    host-visible probe set equals the one the HBM backend computes
    in-jit)."""
    coarse = ip_q_landmarks - 0.5 * landmark_sq_norms[None, :]
    return jax.lax.top_k(coarse, nprobe)[1]


@register_backend
class TieredIVFBackend:
    """Host-memory tiered inverted-file backend (see module doc)."""

    name = "tiered_ivf"
    default_nprobe = IVFBackend.default_nprobe

    @staticmethod
    def build(key, X, config, *, metric,
              hot_bytes: int = DEFAULT_HOT_BYTES, **opts):
        return TieredState.from_ivf(
            IV._build(key, X, config, metric=metric, **opts),
            hot_bytes,
        )

    @staticmethod
    def from_parts(model, payload, *, metric, raw=None,
                   hot_bytes: int = DEFAULT_HOT_BYTES):
        return TieredState.from_ivf(
            IVFBackend.from_parts(model, payload, metric=metric,
                                  raw=raw),
            hot_bytes,
        )

    @staticmethod
    def resolve_nprobe(state, nprobe):
        """Same normalization as the HBM backend (shared default, so
        requests group identically across the two)."""
        if nprobe is None:
            nprobe = TieredIVFBackend.default_nprobe
        return min(nprobe, state.nlist)

    # -- search -------------------------------------------------------

    @staticmethod
    def search(state, queries, *, k, nprobe=None, rerank=0, **opts):
        prep = S.prepare_queries(state.model, queries)
        return TieredIVFBackend.search_prepped(
            state, prep, k=k, nprobe=nprobe, rerank=rerank, **opts
        )

    @staticmethod
    def search_prepped(state, prep, *, k, nprobe=None, rerank=0,
                       coarse=None, shortlist=None):
        nprobe = TieredIVFBackend.resolve_nprobe(state, nprobe)
        if nprobe >= state.nlist:
            return TieredIVFBackend._full_scan(
                state, prep, k, rerank, coarse, shortlist
            )
        if prep.q.shape[0] == 1:
            # the HBM backend's m=1 -> 2 zero-row pad (bit-identity
            # between per-request and bucketed engine calls); the pad
            # row's probed lists join the union exactly like they join
            # the HBM gather
            s, i = TieredIVFBackend._gathered(
                state, IV._pad_single(prep), k, nprobe, rerank,
                coarse, shortlist,
            )
            return s[:1], i[:1]
        return TieredIVFBackend._gathered(
            state, prep, k, nprobe, rerank, coarse, shortlist
        )

    @staticmethod
    def _gathered(state, prep, k, nprobe, rerank, coarse, shortlist):
        probe = np.asarray(_probe_paged(
            prep.ip_q_landmarks, state.model.landmark_sq_norms, nprobe
        ))
        return TieredIVFBackend._execute_probe(
            state, prep, probe, k, rerank, coarse, shortlist
        )

    @staticmethod
    def _execute_probe(state, prep, probe, k, rerank, coarse,
                       shortlist):
        # plan the union on the host (which lists, padded length) ...
        pp = C.plan_paged_probe(
            probe, state.counts, state.starts, None,
            state.max_list_len, metric=state.metric, k=k,
            rerank=rerank, coarse=coarse, shortlist=shortlist,
        )
        uidx = state.union_index(
            pp.union_lists, pp.n_pad - pp.n_union
        )
        # ... then execute through the HBM backend's OWN jitted
        # gather (in-graph invlists[probe] + tombstone drop): same
        # traced graph, so same fusion/rounding — see module doc
        return IV._score_probed(
            uidx, prep, jnp.asarray(probe, dtype=jnp.int32), k,
            rerank, coarse=coarse, shortlist=shortlist,
        )

    @staticmethod
    def _full_scan(state, prep, k, rerank, coarse, shortlist):
        # the all-lists union IS the global cluster-sorted payload
        # (contiguous lists, ascending, no pad); ivf._full_scan's
        # dense plan then matches the HBM route bit for bit
        uidx = state.union_index(tuple(range(state.nlist)), 0)
        return IV._full_scan(
            uidx, prep, k, rerank, coarse=coarse, shortlist=shortlist
        )

    @staticmethod
    def probe_sets(state, prep, nprobe=None):
        """Host-visible coarse assignment (the engine cost model's
        contract; see ``IVFBackend.probe_sets``)."""
        nprobe = TieredIVFBackend.resolve_nprobe(state, nprobe)
        return np.asarray(_probe_paged(
            prep.ip_q_landmarks, state.model.landmark_sq_norms, nprobe
        ))

    @staticmethod
    def search_probed(state, prep, probe, *, k, rerank=0, coarse=None,
                      shortlist=None):
        """Top-k over an explicit probed-list set; mirrors
        ``IVFBackend.search_probed`` including the m=1 pad-row probe."""
        probe = np.asarray(probe)
        if prep.q.shape[0] == 1:
            prep = IV._pad_single(prep)
            pad_probe = np.asarray(_probe_paged(
                prep.ip_q_landmarks, state.model.landmark_sq_norms,
                probe.shape[1],
            ))[1:]
            probe = np.concatenate([probe, pad_probe], axis=0)
            s, i = TieredIVFBackend._execute_probe(
                state, prep, probe, k, rerank, coarse, shortlist
            )
            return s[:1], i[:1]
        return TieredIVFBackend._execute_probe(
            state, prep, probe, k, rerank, coarse, shortlist
        )

    @staticmethod
    def list_sizes(state):
        """Live rows per list, host numpy (nlist,) — the engine's
        probe-cost bill.  Segment sums over the contiguous geometry
        (equivalent to ``IVFBackend.list_sizes`` on the padded
        invlists, without materializing them)."""
        if state.live is None:
            return state.counts.astype(np.int64)
        csum = np.concatenate(
            [[0], np.cumsum(state.live.astype(np.int64))]
        )
        ends = state.starts + state.counts
        return (csum[ends] - csum[state.starts]).astype(np.int64)

    # -- mutations (delegated to the HBM implementation) ---------------

    @staticmethod
    def add(state, X_new):
        return TieredState.from_ivf(
            IV._add(state.materialize(), X_new),
            state.hot_bytes, carry=state,
        )

    @staticmethod
    def delete(state, del_ids):
        # host-side bitmap update; cached device blocks stay valid —
        # tombstones are dropped to -1 in the candidate rows pre-DMA
        # (plan_paged_probe), never read out of the blocks
        new_live, removed = C.mark_deleted(
            state.ids, state.live, del_ids, state.n
        )
        if removed == 0:
            return state, 0
        state.live = np.asarray(new_live).astype(bool)
        state._live_dev = None
        return state, removed

    @staticmethod
    def compact(state):
        if state.live is None:
            return state
        return TieredState.from_ivf(
            IV._compact(state.materialize()),
            state.hot_bytes, carry=state,
        )

    # -- introspection / persistence ----------------------------------

    @staticmethod
    def model_of(state):
        return state.model

    @staticmethod
    def payload_of(state):
        return ASHPayload(
            b=state.b, d=state.d, codes=state.codes,
            scale=state.scale, offset=state.offset,
            cluster=state.cluster,
        )

    @staticmethod
    def stats_of(state):
        return ASHStats(
            res_norm=state.res_norm, ip_x_mu=state.ip_x_mu,
            x_sq=state.x_sq,
        )

    @staticmethod
    def live_of(state):
        return state.live

    @staticmethod
    def ids_of(state):
        return state.ids

    @staticmethod
    def next_id_of(state):
        return C.effective_next_id(
            state.next_id, state.ids, state.n
        )

    @staticmethod
    def resident_mask(state) -> np.ndarray:
        """(nlist,) bool: which lists are device-resident right now —
        the engine bills non-resident lists at the paging surcharge."""
        mask = np.zeros(state.nlist, dtype=bool)
        keys = list(state.cache.keys())
        if keys:
            mask[np.asarray(keys, dtype=np.int64)] = True
        return mask

    @staticmethod
    def tier_stats(state) -> dict:
        """Gauge snapshot for ``snapshot()["tier"]`` (lifetime
        counters, carried across mutation re-hosts)."""
        cs = state.cache.stats()
        return {
            "hits": cs["hits"],
            "misses": cs["misses"],
            "hit_rate": round(cs["hit_rate"], 4),
            "evictions": cs["evictions"],
            "resident_lists": cs["entries"],
            "nlist": state.nlist,
            "resident_bytes": cs["nbytes"],
            "hot_bytes": state.hot_bytes,
            "total_bytes": state.total_bytes,
            "paged_rows": state.paged_rows,
            "paged_bytes": state.paged_bytes,
            "transfers": state.transfers,
        }

    @staticmethod
    def to_arrays(state):
        # identical layout to IVFBackend.to_arrays (the host mirrors
        # ARE the arrays), plus the hot-set budget in the meta so a
        # load reconstructs the same tier shape
        arrays = {
            **_model_arrays(state.model),
            "payload.codes": state.codes,
            "payload.scale": state.scale,
            "payload.offset": state.offset,
            "payload.cluster": state.cluster,
            "stats.res_norm": state.res_norm,
            "stats.ip_x_mu": state.ip_x_mu,
            "stats.x_sq": state.x_sq,
            "ids": state.ids,
            "invlists": state.invlists,
        }
        if state.raw is not None:
            arrays["raw"] = state.raw
        if state.live is not None:
            arrays["live"] = state.live
        meta = {
            "max_list_len": state.max_list_len,
            "hot_bytes": state.hot_bytes,
        }
        if state.next_id is not None:
            meta["next_id"] = int(state.next_id)
        return arrays, meta

    @staticmethod
    def from_arrays(arrays, meta, config, metric, *, hot_bytes=None,
                    **opts):
        ivf = IVFBackend.from_arrays(
            arrays, meta, config, metric, **opts
        )
        if hot_bytes is None:
            hot_bytes = meta.get("hot_bytes", DEFAULT_HOT_BYTES)
        return TieredState.from_ivf(ivf, hot_bytes)
