"""Flat (exhaustive-scan) ASH index with optional exact re-ranking."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import ASHConfig, ASHModel, ASHPayload, pytree_dataclass


@pytree_dataclass(meta_fields=("metric",))
class FlatIndex:
    metric: str  # "dot" | "l2" | "cos"
    model: ASHModel
    payload: ASHPayload
    # Optional raw vectors for exact re-ranking of a shortlist (kept in
    # bf16 to bound memory; None for pure-compressed deployments).
    raw: Optional[jax.Array]


def build(
    key: jax.Array,
    X: jax.Array,
    config: ASHConfig,
    *,
    metric: str = "dot",
    learned: bool = True,
    keep_raw: bool = False,
    **train_kw,
) -> FlatIndex:
    if learned:
        model, _ = A.train(key, X, config, **train_kw)
    else:
        model = A.random_model(key, X.shape[1], config, X_for_landmarks=X)
    payload = A.encode(model, X)
    raw = X.astype(jnp.bfloat16) if keep_raw else None
    return FlatIndex(metric=metric, model=model, payload=payload, raw=raw)


def _scores(index: FlatIndex, prep) -> jax.Array:
    if index.metric == "dot":
        return S.score_dot(index.model, prep, index.payload)
    if index.metric == "l2":
        return -S.score_l2(index.model, prep, index.payload)
    if index.metric == "cos":
        return S.score_cosine(index.model, prep, index.payload)
    raise ValueError(index.metric)


@functools.partial(jax.jit, static_argnames=("k", "rerank"))
def search(
    index: FlatIndex, queries: jax.Array, k: int = 10, rerank: int = 0
) -> tuple[jax.Array, jax.Array]:
    """Top-k search. Returns (scores, indices), each (m, k).

    rerank > 0: retrieve a shortlist of that size by ASH scores and
    re-rank it with exact (bf16) dot products (requires raw vectors).
    """
    prep = S.prepare_queries(index.model, queries)
    approx = _scores(index, prep)
    if rerank and index.raw is not None:
        short_s, short_i = jax.lax.top_k(approx, max(rerank, k))
        cand = index.raw[short_i].astype(jnp.float32)  # (m, R, D)
        exact = jnp.einsum("md,mrd->mr", prep.q, cand)
        rs, ri = jax.lax.top_k(exact, k)
        return rs, jnp.take_along_axis(short_i, ri, axis=1)
    return jax.lax.top_k(approx, k)
