"""Flat (exhaustive-scan) ASH index with optional exact re-ranking.

Entry point is ``repro.index.AshIndex`` with ``backend="flat"``; the
``_search_prepped`` path lets the serving engine reuse cached
``QueryPrep`` projections.  Metric dispatch and the rerank pipeline live
in ``repro.index.common`` (shared with the IVF and sharded backends).

Scan strategy: every metric routes through the fused kernel family by
default (``use_pallas=None`` → Pallas on TPU, the identical-semantics
jnp oracle on CPU; ``use_pallas=False`` forces the pure-jnp reference
scorers).  Whenever the requested top-k/shortlist fits the fused
selection budget (``common.fused_topk_limit()``), the scan and the
selection fuse — on TPU the (m, n) score matrix never reaches HBM.
The l2/cos epilogues read the encode-time ``ASHStats`` carried on the
index (built at build/add, persisted by save/load).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import (
    ASHConfig, ASHModel, ASHPayload, ASHStats, CoarseCodes, QueryPrep,
    pytree_dataclass,
)
from repro.index import common as C


@pytree_dataclass(meta_fields=("metric", "next_id"))
class FlatIndex:
    metric: str  # "dot" | "l2" | "cos"
    model: ASHModel
    payload: ASHPayload
    # Optional raw vectors for exact re-ranking of a shortlist (kept in
    # bf16 to bound memory; None for pure-compressed deployments).
    raw: Optional[jax.Array]
    # Encode-time row statistics consumed by the fused l2/cos epilogues
    # (None → rebuilt per scoring call, decompressing the database).
    stats: Optional[ASHStats] = None
    # User-facing id of each payload row; None = identity (row == id),
    # which holds until a compaction retires tombstoned ids.  Always
    # strictly increasing (appends continue past every retired id).
    ids: Optional[jax.Array] = None
    # Row-validity bitmap: False rows are tombstoned (deleted) and can
    # never surface in results (the ScanPlan threads this into the
    # kernels' runtime mask operand).  None = all rows live.
    live: Optional[jax.Array] = None
    # Meta: id the next added row receives (None = derived; see
    # ``common.effective_next_id``).  Only set once mutations happen.
    next_id: Optional[int] = None
    # Dequantized-code cache for the symmetric int8 coarse first pass
    # (``search(coarse="int8")``); derived from ``payload`` — rebuilt
    # at build/add/compact, never persisted (save/load reconstructs).
    # None → ``execute_plan`` rebuilds per call (decompressing).
    coarse: Optional[CoarseCodes] = None


def _build(
    key: jax.Array,
    X: jax.Array,
    config: ASHConfig,
    *,
    metric: str = "dot",
    learned: bool = True,
    keep_raw: bool = False,
    model: Optional[ASHModel] = None,
    **train_kw,
) -> FlatIndex:
    C.validate_metric(metric)
    if model is None:
        if learned:
            model, _ = A.train(key, X, config, **train_kw)
        else:
            model = A.random_model(
                key, X.shape[1], config, X_for_landmarks=X
            )
    payload = A.encode(model, X)
    raw = X.astype(jnp.bfloat16) if keep_raw else None
    return FlatIndex(
        metric=metric, model=model, payload=payload, raw=raw,
        stats=S.payload_stats(model, payload),
        coarse=S.coarse_codes(payload),
    )


@functools.partial(
    jax.jit,
    static_argnames=("k", "rerank", "use_pallas", "coarse", "shortlist"),
)
def _search_prepped(
    index: FlatIndex,
    prep: QueryPrep,
    k: int = 10,
    rerank: int = 0,
    use_pallas: Optional[bool] = None,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Top-k search from precomputed query projections.

    Returns (scores, indices), each (m, k).  rerank > 0: retrieve a
    shortlist of that size by ASH scores and re-rank it with exact
    (bf16) metric-aware scores (requires raw vectors).

    The shortlist/top-k selection fuses into the scan kernel whenever
    its size fits ``common.fused_topk_limit()``; the fallback
    materializes scores and runs ``lax.top_k`` — both return identical
    results, so the routing choice is invisible to callers (the ladder
    itself lives in ``common.execute_plan``, shared with the IVF and
    sharded backends).

    coarse="int8" runs the symmetric int8 first-pass scan over the
    persisted ``index.coarse`` value cache, keeping the top
    ``shortlist`` (default ``common.default_shortlist()``) rows per
    query for the asymmetric refine (then the usual exact rerank).
    """
    plan = C.ScanPlan(
        metric=index.metric, k=k, rerank=rerank, row_valid=index.live,
        ids=index.ids, use_pallas=use_pallas,
        coarse=coarse, shortlist=shortlist,
    )
    return C.execute_plan(
        index.model, prep, index.payload, plan,
        stats=index.stats, raw=index.raw, coarse_cache=index.coarse,
    )


def _search(
    index: FlatIndex,
    queries: jax.Array,
    k: int = 10,
    rerank: int = 0,
    use_pallas: Optional[bool] = None,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Top-k search; composition of ``prepare_queries`` and
    :func:`_search_prepped` so the batched engine path and the direct
    path share the exact same compiled arithmetic (bit-identical)."""
    prep = S.prepare_queries(index.model, queries)
    return _search_prepped(
        index, prep, k=k, rerank=rerank, use_pallas=use_pallas,
        coarse=coarse, shortlist=shortlist,
    )


def _add(index: FlatIndex, X_new: jax.Array) -> FlatIndex:
    """Encode new rows under the existing model and append them.  New
    rows get the next ``n_new`` user ids (see ``effective_next_id``)."""
    payload_new = A.encode(index.model, X_new)
    n_new = payload_new.n
    nid = C.effective_next_id(index.next_id, index.ids, index.payload.n)
    ids = index.ids
    if ids is not None:
        ids = jnp.concatenate(
            [ids, nid + jnp.arange(n_new, dtype=jnp.int32)]
        )
    live = index.live
    if live is not None:
        live = jnp.concatenate([live, jnp.ones((n_new,), bool)])
    raw = index.raw
    if raw is not None:
        raw = jnp.concatenate(
            [raw, X_new.astype(jnp.bfloat16)], axis=0
        )
    payload = C.concat_payloads(index.payload, payload_new)
    return FlatIndex(
        metric=index.metric,
        model=index.model,
        payload=payload,
        raw=raw,
        stats=C.concat_stats(
            index.stats, S.payload_stats(index.model, payload_new)
        ),
        ids=ids,
        live=live,
        next_id=None if index.next_id is None else nid + n_new,
        # full rebuild, not an incremental concat: CoarseCodes.mean
        # spans ALL rows, and an incremental mean update would drift
        # from a fresh build's (breaking add == rebuild bit-identity)
        coarse=None if index.coarse is None else S.coarse_codes(payload),
    )


def _delete(index: FlatIndex, del_ids) -> tuple[FlatIndex, int]:
    """Tombstone rows by user id: (index, rows newly removed).  Rows
    stay in the payload (scored ``-inf`` via the kernel mask operand)
    until :func:`_compact` evicts them."""
    new_live, removed = C.mark_deleted(
        index.ids, index.live, del_ids, index.payload.n
    )
    if removed == 0:
        return index, 0
    return dataclasses.replace(index, live=jnp.asarray(new_live)), removed


def _compact(index: FlatIndex) -> FlatIndex:
    """Rewrite codes/stats/raw/ids to evict tombstoned rows.  Search
    afterwards is bit-identical to a fresh build over the survivors
    (same model): encode/stats are row-independent and survivors keep
    their payload rows and relative order, so values and tie order
    match (survivor ids map monotonically onto the rebuild's rows)."""
    if index.live is None:
        return index
    live_np = np.asarray(index.live).astype(bool)
    if live_np.all():
        return dataclasses.replace(index, live=None)
    if not live_np.any():
        raise ValueError(
            "compact() would evict every row; an empty index cannot "
            "be searched — keep at least one live row or rebuild"
        )
    nid = C.effective_next_id(index.next_id, index.ids, index.payload.n)
    keep = jnp.asarray(np.nonzero(live_np)[0].astype(np.int32))
    ids = keep if index.ids is None else index.ids[keep]
    payload = C.gather_payload(index.payload, keep)
    return FlatIndex(
        metric=index.metric,
        model=index.model,
        payload=payload,
        raw=None if index.raw is None else index.raw[keep],
        stats=C.take_stats(index.stats, keep),
        ids=ids.astype(jnp.int32),
        live=None,
        next_id=nid,
        coarse=None if index.coarse is None else S.coarse_codes(payload),
    )
