"""Flat (exhaustive-scan) ASH index with optional exact re-ranking.

Entry point is ``repro.index.AshIndex`` with ``backend="flat"``; the
``_search_prepped`` path lets the serving engine reuse cached
``QueryPrep`` projections.  Metric dispatch and the rerank pipeline live
in ``repro.index.common`` (shared with the IVF and sharded backends).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import ASHConfig, ASHModel, ASHPayload, QueryPrep, pytree_dataclass
from repro.index import common as C


@pytree_dataclass(meta_fields=("metric",))
class FlatIndex:
    metric: str  # "dot" | "l2" | "cos"
    model: ASHModel
    payload: ASHPayload
    # Optional raw vectors for exact re-ranking of a shortlist (kept in
    # bf16 to bound memory; None for pure-compressed deployments).
    raw: Optional[jax.Array]


def _build(
    key: jax.Array,
    X: jax.Array,
    config: ASHConfig,
    *,
    metric: str = "dot",
    learned: bool = True,
    keep_raw: bool = False,
    model: Optional[ASHModel] = None,
    **train_kw,
) -> FlatIndex:
    C.validate_metric(metric)
    if model is None:
        if learned:
            model, _ = A.train(key, X, config, **train_kw)
        else:
            model = A.random_model(
                key, X.shape[1], config, X_for_landmarks=X
            )
    payload = A.encode(model, X)
    raw = X.astype(jnp.bfloat16) if keep_raw else None
    return FlatIndex(metric=metric, model=model, payload=payload, raw=raw)


@functools.partial(
    jax.jit, static_argnames=("k", "rerank", "use_pallas")
)
def _search_prepped(
    index: FlatIndex,
    prep: QueryPrep,
    k: int = 10,
    rerank: int = 0,
    use_pallas: Optional[bool] = False,
) -> tuple[jax.Array, jax.Array]:
    """Top-k search from precomputed query projections.

    Returns (scores, indices), each (m, k).  rerank > 0: retrieve a
    shortlist of that size by ASH scores and re-rank it with exact
    (bf16) metric-aware scores (requires raw vectors).
    """
    approx = C.approx_scores(
        index.model, prep, index.payload, index.metric,
        use_pallas=use_pallas,
    )
    if rerank and index.raw is not None:
        R = min(max(rerank, k), approx.shape[-1])
        short_s, short_i = jax.lax.top_k(approx, R)
        return C.exact_rerank(
            prep, index.raw, short_s, short_i, index.metric, k
        )
    return jax.lax.top_k(approx, k)


def _search(
    index: FlatIndex,
    queries: jax.Array,
    k: int = 10,
    rerank: int = 0,
    use_pallas: Optional[bool] = False,
) -> tuple[jax.Array, jax.Array]:
    """Top-k search; composition of ``prepare_queries`` and
    :func:`_search_prepped` so the batched engine path and the direct
    path share the exact same compiled arithmetic (bit-identical)."""
    prep = S.prepare_queries(index.model, queries)
    return _search_prepped(
        index, prep, k=k, rerank=rerank, use_pallas=use_pallas
    )


def _add(index: FlatIndex, X_new: jax.Array) -> FlatIndex:
    """Encode new rows under the existing model and append them."""
    payload_new = A.encode(index.model, X_new)
    raw = index.raw
    if raw is not None:
        raw = jnp.concatenate(
            [raw, X_new.astype(jnp.bfloat16)], axis=0
        )
    return FlatIndex(
        metric=index.metric,
        model=index.model,
        payload=C.concat_payloads(index.payload, payload_new),
        raw=raw,
    )
