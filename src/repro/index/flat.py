"""Flat (exhaustive-scan) ASH index with optional exact re-ranking.

The module-level ``build``/``search`` functions are deprecation shims
kept for one release; new code goes through ``repro.index.AshIndex``
with ``backend="flat"``.  Metric dispatch and the rerank pipeline live
in ``repro.index.common`` (shared with the IVF and sharded backends).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import ASHConfig, ASHModel, ASHPayload, pytree_dataclass
from repro.index import common as C


@pytree_dataclass(meta_fields=("metric",))
class FlatIndex:
    metric: str  # "dot" | "l2" | "cos"
    model: ASHModel
    payload: ASHPayload
    # Optional raw vectors for exact re-ranking of a shortlist (kept in
    # bf16 to bound memory; None for pure-compressed deployments).
    raw: Optional[jax.Array]


def _build(
    key: jax.Array,
    X: jax.Array,
    config: ASHConfig,
    *,
    metric: str = "dot",
    learned: bool = True,
    keep_raw: bool = False,
    model: Optional[ASHModel] = None,
    **train_kw,
) -> FlatIndex:
    C.validate_metric(metric)
    if model is None:
        if learned:
            model, _ = A.train(key, X, config, **train_kw)
        else:
            model = A.random_model(
                key, X.shape[1], config, X_for_landmarks=X
            )
    payload = A.encode(model, X)
    raw = X.astype(jnp.bfloat16) if keep_raw else None
    return FlatIndex(metric=metric, model=model, payload=payload, raw=raw)


@functools.partial(
    jax.jit, static_argnames=("k", "rerank", "use_pallas")
)
def _search(
    index: FlatIndex,
    queries: jax.Array,
    k: int = 10,
    rerank: int = 0,
    use_pallas: Optional[bool] = False,
) -> tuple[jax.Array, jax.Array]:
    """Top-k search. Returns (scores, indices), each (m, k).

    rerank > 0: retrieve a shortlist of that size by ASH scores and
    re-rank it with exact (bf16) metric-aware scores (requires raw
    vectors).
    """
    prep = S.prepare_queries(index.model, queries)
    approx = C.approx_scores(
        index.model, prep, index.payload, index.metric,
        use_pallas=use_pallas,
    )
    if rerank and index.raw is not None:
        R = min(max(rerank, k), approx.shape[-1])
        short_s, short_i = jax.lax.top_k(approx, R)
        return C.exact_rerank(
            prep, index.raw, short_s, short_i, index.metric, k
        )
    return jax.lax.top_k(approx, k)


def _add(index: FlatIndex, X_new: jax.Array) -> FlatIndex:
    """Encode new rows under the existing model and append them."""
    payload_new = A.encode(index.model, X_new)
    raw = index.raw
    if raw is not None:
        raw = jnp.concatenate(
            [raw, X_new.astype(jnp.bfloat16)], axis=0
        )
    return FlatIndex(
        metric=index.metric,
        model=index.model,
        payload=C.concat_payloads(index.payload, payload_new),
        raw=raw,
    )


def build(key, X, config, **kw) -> FlatIndex:
    """Deprecated: use ``AshIndex.build(..., backend="flat")``."""
    C.warn_deprecated(
        "repro.index.flat.build",
        'repro.index.AshIndex.build(..., backend="flat")',
    )
    return _build(key, X, config, **kw)


def search(index, queries, k: int = 10, rerank: int = 0):
    """Deprecated: use ``AshIndex.search``."""
    C.warn_deprecated(
        "repro.index.flat.search", "repro.index.AshIndex.search"
    )
    return _search(index, queries, k=k, rerank=rerank)
