"""Unified ``AshIndex`` facade: one build/search/persist surface over
the flat, IVF and sharded backends.

The paper's value proposition is a single encoder-decoder payload
(Table 1) serving dot/L2/cosine search at every scale; this module is
the single entry point over it::

    index = AshIndex.build(key, X, ASHConfig(b=2, d=64, n_landmarks=64),
                           backend="ivf", metric="l2", keep_raw=True)
    scores, ids = index.search(queries, k=10, nprobe=16, rerank=100)
    index.add(X_new)                    # incremental ingestion
    index.delete([3, 17])               # tombstone rows by user id
    index.compact(max_dead_fraction=0.2)  # evict tombstones past 20%
    ids = index.stage_add(X_more)       # buffer for batched ingestion
    index.apply_pending()               # one re-sort for the batch
    index.save("/tmp/idx")              # npz arrays + JSON config
    index = AshIndex.load("/tmp/idx")   # bit-identical search results

Backends are pluggable via :func:`register_backend`; all share the
metric dispatcher and exact-rerank pipeline of ``repro.index.common``,
so every backend returns higher-is-better scores and id ``-1`` for
missing candidates.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
import shutil
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import (
    ASHConfig, ASHModel, ASHPayload, ASHStats, QueryPrep,
)
from repro.index import common as C
from repro.index import distributed as DX
from repro.index import flat as F
from repro.index import ivf as IV
from repro.testing import faults

FORMAT_VERSION = 1


class CorruptIndexError(ValueError):
    """A saved index failed an integrity check on load.

    Subclasses ``ValueError`` so pre-existing ``except ValueError``
    callers keep working; carries *where* and *which check* so an
    operator can tell a half-written save from bit rot."""

    def __init__(self, path, check: str):
        self.path = str(path)
        self.check = check
        super().__init__(f"corrupt index at {self.path}: {check}")


_BACKENDS: dict[str, type] = {}


def register_backend(cls):
    """Class decorator: register an index backend under ``cls.name``."""
    _BACKENDS[cls.name] = cls
    return cls


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def _get_backend(name: str):
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


# ---------------------------------------------------------------------------
# Array (de)serialization — numpy .npz with bf16 stored as uint16 views
# ---------------------------------------------------------------------------

_MODEL_FIELDS = (
    "W", "landmarks", "W_landmarks", "landmark_sq_norms",
    "bias_rho", "bias_beta",
)
_PAYLOAD_FIELDS = ("codes", "scale", "offset", "cluster")
_STATS_FIELDS = ("res_norm", "ip_x_mu", "x_sq")


_BF16 = np.dtype(jnp.bfloat16)


def _encode_array(a) -> tuple[np.ndarray, str]:
    """jax/numpy array -> (savez-safe numpy array, dtype tag).

    numpy can't serialize the ml_dtypes bfloat16 descr, so bf16 arrays
    are stored as uint16 bit patterns and tagged for exact restore."""
    a = np.asarray(a)
    if a.dtype == _BF16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _decode_array(a: np.ndarray, tag: str) -> jax.Array:
    if tag == "bfloat16":
        return jnp.asarray(a.view(_BF16))
    return jnp.asarray(a)


# -- crash-safe on-disk layout ----------------------------------------
#
# A saved index is two files under one directory: arrays.npz and
# config.json (the manifest).  The manifest carries a crc32 per npz
# entry, computed over the encoded bytes, so load() can refuse bit rot
# before deserializing garbage.  Writes are atomic at every boundary:
#
#   fresh target   — write into a dot-prefixed temp dir next to it,
#                    fsync files + dirs, one os.replace of the dir;
#   existing target — write arrays.new.npz + config.new.json, fsync,
#                    then os.replace each (arrays first).  A crash
#                    between the two renames leaves new arrays under
#                    the old manifest; load() detects the checksum
#                    mismatch and rolls FORWARD from config.new.json
#                    (both .new files were durable before any rename).

_FAULT_SAVE_REPLACE = faults.point("save.replace")
_FAULT_SAVE_BETWEEN = faults.point("save.between_replace")


def _fsync_file(path: pathlib.Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: pathlib.Path) -> None:
    try:
        fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    except OSError:
        return  # platform without directory fsync
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_npz(path: pathlib.Path, encoded: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        np.savez(f, **encoded)  # file object: no .npz suffix games
        f.flush()
        os.fsync(f.fileno())


def _write_manifest(path: pathlib.Path, meta: dict[str, Any]) -> None:
    with open(path, "w") as f:
        f.write(json.dumps(meta, indent=2))
        f.flush()
        os.fsync(f.fileno())


def _save_fresh(p: pathlib.Path, encoded, meta) -> None:
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.parent / f".{p.name}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    _write_npz(tmp / "arrays.npz", encoded)
    _write_manifest(tmp / "config.json", meta)
    _fsync_dir(tmp)
    faults.fire(_FAULT_SAVE_REPLACE)
    os.replace(tmp, p)
    _fsync_dir(p.parent)


def _save_over(p: pathlib.Path, encoded, meta) -> None:
    _write_npz(p / "arrays.new.npz", encoded)
    _write_manifest(p / "config.new.json", meta)
    _fsync_dir(p)
    os.replace(p / "arrays.new.npz", p / "arrays.npz")
    faults.fire(_FAULT_SAVE_BETWEEN)
    os.replace(p / "config.new.json", p / "config.json")
    _fsync_dir(p)


def _read_index_files(
    p: pathlib.Path, manifest: str = "config.json"
) -> tuple[dict[str, Any], dict[str, np.ndarray]]:
    """Read + integrity-check one (manifest, arrays.npz) pair; returns
    (meta, still-encoded arrays).  Every failure mode — missing file,
    bad JSON, unreadable zip, missing entries, checksum mismatch —
    raises :class:`CorruptIndexError` naming the failed check."""
    mpath = p / manifest
    if not mpath.is_file():
        raise CorruptIndexError(p, f"{manifest} missing")
    try:
        meta = json.loads(mpath.read_text())
    except (ValueError, OSError) as e:
        raise CorruptIndexError(p, f"{manifest} unreadable: {e}") from e
    if not isinstance(meta, dict) or "format_version" not in meta:
        raise CorruptIndexError(p, f"{manifest} is not an index manifest")
    if meta["format_version"] != FORMAT_VERSION:
        raise CorruptIndexError(
            p,
            f"format_version {meta['format_version']} != {FORMAT_VERSION}",
        )
    apath = p / "arrays.npz"
    if not apath.is_file():
        raise CorruptIndexError(p, "arrays.npz missing")
    try:
        with np.load(apath) as npz:
            encoded = {name: np.asarray(npz[name]) for name in npz.files}
    except CorruptIndexError:
        raise
    except Exception as e:  # BadZipFile / ValueError / zlib / EOF / OS
        raise CorruptIndexError(p, f"arrays.npz unreadable: {e}") from e
    for name in encoded:
        if name not in meta.get("dtypes", {}):
            raise CorruptIndexError(
                p, f"arrays.npz entry {name!r} missing from manifest dtypes"
            )
    checksums = meta.get("checksums")
    if checksums is not None:  # pre-manifest saves have none
        missing = set(checksums) - set(encoded)
        if missing:
            raise CorruptIndexError(
                p, f"arrays.npz missing entries {sorted(missing)}"
            )
        extra = set(encoded) - set(checksums)
        if extra:
            raise CorruptIndexError(
                p, f"arrays.npz has unmanifested entries {sorted(extra)}"
            )
        for name, want in checksums.items():
            got = zlib.crc32(np.ascontiguousarray(encoded[name]).tobytes())
            if got != want:
                raise CorruptIndexError(
                    p,
                    f"checksum mismatch for {name!r}: "
                    f"crc32 {got:#010x} != manifest {want:#010x}",
                )
    return meta, encoded


def _read_index_dir(
    p: pathlib.Path,
) -> tuple[dict[str, Any], dict[str, np.ndarray]]:
    """:func:`_read_index_files` + roll-forward: if the live pair is
    inconsistent but a durable ``config.new.json`` matches the arrays
    (crash between an over-save's two renames), finish that save and
    load it; otherwise re-raise the original corruption error."""
    try:
        return _read_index_files(p)
    except CorruptIndexError as err:
        if not (p / "config.new.json").is_file():
            raise
        try:
            meta, encoded = _read_index_files(p, "config.new.json")
        except CorruptIndexError:
            raise err from None
        os.replace(p / "config.new.json", p / "config.json")
        (p / "arrays.new.npz").unlink(missing_ok=True)
        _fsync_dir(p)
        return meta, encoded


def _model_arrays(model: ASHModel) -> dict[str, Any]:
    return {f"model.{f}": getattr(model, f) for f in _MODEL_FIELDS}


def _model_from_arrays(
    arrays: dict[str, jax.Array], config: ASHConfig
) -> ASHModel:
    return ASHModel(
        config=config,
        **{f: arrays[f"model.{f}"] for f in _MODEL_FIELDS},
    )


def _payload_arrays(payload: ASHPayload) -> dict[str, Any]:
    return {f"payload.{f}": getattr(payload, f) for f in _PAYLOAD_FIELDS}


def _payload_from_arrays(
    arrays: dict[str, jax.Array], config: ASHConfig
) -> ASHPayload:
    return ASHPayload(
        b=config.b,
        d=config.d,
        **{f: arrays[f"payload.{f}"] for f in _PAYLOAD_FIELDS},
    )


def _stats_arrays(stats: Optional[ASHStats]) -> dict[str, Any]:
    if stats is None:
        return {}
    return {f"stats.{f}": getattr(stats, f) for f in _STATS_FIELDS}


def _stats_from_arrays(
    arrays: dict[str, jax.Array], model: ASHModel, payload: ASHPayload
) -> ASHStats:
    """Restore persisted stats bit-identically; rebuild from the
    payload when loading a pre-stats save."""
    if all(f"stats.{f}" in arrays for f in _STATS_FIELDS):
        return ASHStats(
            **{f: arrays[f"stats.{f}"] for f in _STATS_FIELDS}
        )
    return S.payload_stats(model, payload)


def _train_or_reuse(
    key, X, config, *, model=None, learned=True, **train_kw
) -> ASHModel:
    if model is not None:
        return model
    if learned:
        model, _ = A.train(key, X, config, **train_kw)
        return model
    return A.random_model(key, X.shape[1], config, X_for_landmarks=X)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


@register_backend
class FlatBackend:
    """Exhaustive scan over the whole payload."""

    name = "flat"

    @staticmethod
    def build(key, X, config, *, metric, **opts):
        return F._build(key, X, config, metric=metric, **opts)

    @staticmethod
    def from_parts(model, payload, *, metric, raw=None):
        return F.FlatIndex(
            metric=metric, model=model, payload=payload, raw=raw,
            stats=S.payload_stats(model, payload),
            coarse=S.coarse_codes(payload),
        )

    @staticmethod
    def search(state, queries, *, k, nprobe=None, rerank=0, **opts):
        del nprobe  # no coarse routing in a flat scan
        return F._search(state, queries, k=k, rerank=rerank, **opts)

    @staticmethod
    def search_prepped(state, prep, *, k, nprobe=None, rerank=0, **opts):
        del nprobe
        return F._search_prepped(state, prep, k=k, rerank=rerank, **opts)

    @staticmethod
    def add(state, X_new):
        return F._add(state, X_new)

    @staticmethod
    def delete(state, ids):
        return F._delete(state, ids)

    @staticmethod
    def compact(state):
        return F._compact(state)

    @staticmethod
    def model_of(state):
        return state.model

    @staticmethod
    def payload_of(state):
        return state.payload

    @staticmethod
    def stats_of(state):
        return state.stats

    @staticmethod
    def live_of(state):
        return state.live

    @staticmethod
    def ids_of(state):
        return state.ids

    @staticmethod
    def next_id_of(state):
        return C.effective_next_id(
            state.next_id, state.ids, state.payload.n
        )

    @staticmethod
    def to_arrays(state):
        arrays = {
            **_model_arrays(state.model),
            **_payload_arrays(state.payload),
            **_stats_arrays(state.stats),
        }
        if state.raw is not None:
            arrays["raw"] = state.raw
        if state.ids is not None:
            arrays["ids"] = state.ids
        if state.live is not None:
            arrays["live"] = state.live
        meta = {}
        if state.next_id is not None:
            meta["next_id"] = int(state.next_id)
        return arrays, meta

    @staticmethod
    def from_arrays(arrays, meta, config, metric, **opts):
        model = _model_from_arrays(arrays, config)
        payload = _payload_from_arrays(arrays, config)
        return F.FlatIndex(
            metric=metric,
            model=model,
            payload=payload,
            raw=arrays.get("raw"),
            stats=_stats_from_arrays(arrays, model, payload),
            ids=arrays.get("ids"),
            live=arrays.get("live"),
            next_id=meta.get("next_id"),
            # derived from the payload deterministically, so rebuild
            # on load (== the saved index's cache) instead of persisting
            coarse=S.coarse_codes(payload),
        )


@register_backend
class IVFBackend:
    """Inverted-file routing over the landmark coarse quantizer."""

    name = "ivf"
    default_nprobe = 8

    @staticmethod
    def build(key, X, config, *, metric, **opts):
        return IV._build(key, X, config, metric=metric, **opts)

    @staticmethod
    def from_parts(model, payload, *, metric, raw=None):
        ids = jnp.arange(payload.n, dtype=jnp.int32)
        return IV._assemble(metric, model, payload, ids, raw)

    @staticmethod
    def resolve_nprobe(state, nprobe):
        """Effective nprobe: default applied, clamped to the invlist
        count.  Public so the serving engine can normalize request
        nprobe before grouping (distinct values above nlist route
        identically and must share one group/trace)."""
        if nprobe is None:
            nprobe = IVFBackend.default_nprobe
        return min(nprobe, state.invlists.shape[0])

    @staticmethod
    def search(state, queries, *, k, nprobe=None, rerank=0, **opts):
        nprobe = IVFBackend.resolve_nprobe(state, nprobe)
        return IV._search(
            state, queries, k=k, nprobe=nprobe, rerank=rerank, **opts
        )

    @staticmethod
    def search_prepped(state, prep, *, k, nprobe=None, rerank=0, **opts):
        nprobe = IVFBackend.resolve_nprobe(state, nprobe)
        return IV._search_prepped(
            state, prep, k=k, nprobe=nprobe, rerank=rerank, **opts
        )

    @staticmethod
    def probe_sets(state, prep, nprobe=None):
        """Host-visible coarse assignment: (m, nprobe) int32 probed
        list ids per query, best-first — exactly the lists the
        gathered search scans at that nprobe (a smaller nprobe's set
        is a column prefix).  The serving engine's candidate-row cost
        model consumes these to dedup lists shared across a batch
        group before splitting it against a row budget."""
        nprobe = IVFBackend.resolve_nprobe(state, nprobe)
        return np.asarray(IV._probe_lists(state, prep, nprobe))

    @staticmethod
    def search_probed(state, prep, probe, *, k, rerank=0, **opts):
        """Top-k over an explicit probed-list set (budgeted gather
        entry point); ``probe`` as returned by :meth:`probe_sets`."""
        return IV._search_probed(
            state, prep, jnp.asarray(probe, dtype=jnp.int32),
            k=k, rerank=rerank, **opts,
        )

    @staticmethod
    def list_sizes(state):
        """Live row count per inverted list, host numpy (nlist,):
        what probing a list costs the gathered scan.  Tombstoned rows
        are dropped pre-DMA, so they bill as zero."""
        inv = np.asarray(state.invlists)
        valid = inv >= 0
        if state.live is not None:
            valid &= np.asarray(state.live)[np.maximum(inv, 0)]
        return valid.sum(axis=1).astype(np.int64)

    @staticmethod
    def add(state, X_new):
        return IV._add(state, X_new)

    @staticmethod
    def delete(state, ids):
        return IV._delete(state, ids)

    @staticmethod
    def compact(state):
        return IV._compact(state)

    @staticmethod
    def model_of(state):
        return state.model

    @staticmethod
    def payload_of(state):
        return state.payload

    @staticmethod
    def stats_of(state):
        return state.stats

    @staticmethod
    def live_of(state):
        return state.live

    @staticmethod
    def ids_of(state):
        return state.ids

    @staticmethod
    def next_id_of(state):
        return C.effective_next_id(
            state.next_id, state.ids, state.payload.n
        )

    @staticmethod
    def to_arrays(state):
        arrays = {
            **_model_arrays(state.model),
            **_payload_arrays(state.payload),
            **_stats_arrays(state.stats),
            "ids": state.ids,
            "invlists": state.invlists,
        }
        if state.raw is not None:
            arrays["raw"] = state.raw
        if state.live is not None:
            arrays["live"] = state.live
        meta = {"max_list_len": state.max_list_len}
        if state.next_id is not None:
            meta["next_id"] = int(state.next_id)
        return arrays, meta

    @staticmethod
    def from_arrays(arrays, meta, config, metric, **opts):
        model = _model_from_arrays(arrays, config)
        payload = _payload_from_arrays(arrays, config)
        return IV.IVFIndex(
            metric=metric,
            max_list_len=int(meta["max_list_len"]),
            model=model,
            payload=payload,
            ids=arrays["ids"],
            invlists=arrays["invlists"],
            raw=arrays.get("raw"),
            stats=_stats_from_arrays(arrays, model, payload),
            live=arrays.get("live"),
            next_id=meta.get("next_id"),
            coarse=S.coarse_codes(payload),  # derived; never persisted
        )


@dataclasses.dataclass
class ShardedState:
    """Host copy of the payload + its device-sharded placement.

    The host copies (unpadded) are kept for add()/delete()/save(); the
    padded, row-sharded copies are what searches scan: the payload, its
    encode-time ``ASHStats`` (fused l2/cos epilogue inputs), — when
    built with ``keep_raw`` — a bf16 raw-vector shard enabling
    shard-local exact rerank, and — once rows are deleted — a validity
    bitmap shard feeding the kernels' runtime mask operand.  Compiled
    searchers are cached per (k, rerank) and invalidated when the
    placement changes; deletes only re-shard the (tiny) bitmap.
    """

    metric: str
    model: ASHModel
    payload: ASHPayload  # unpadded, host-side source of truth
    mesh: Any
    axes: tuple[str, ...]
    raw: Optional[jax.Array] = None  # unpadded bf16 rows (rerank)
    stats: Optional[ASHStats] = None  # unpadded; built when missing
    ids: Optional[jax.Array] = None  # user ids; None = identity
    live: Optional[jax.Array] = None  # validity bitmap; None = all live
    next_id: Optional[int] = None  # id of the next added row
    sharded: ASHPayload = dataclasses.field(init=False)
    sharded_stats: ASHStats = dataclasses.field(init=False)
    sharded_raw: Optional[jax.Array] = dataclasses.field(init=False)
    sharded_valid: Optional[jax.Array] = dataclasses.field(init=False)
    searchers: dict = dataclasses.field(init=False, default_factory=dict)

    def __post_init__(self):
        # the unpadded payload is the gather-safe source of truth: the
        # pad sentinel (cluster == -1) must only ever exist on the
        # device-side padded copy, where row masking precedes use.
        # Validated once here — add() only appends encode() output,
        # whose cluster assignments are always valid
        cluster = np.asarray(self.payload.cluster)
        if cluster.size and int(cluster.min()) < 0:
            raise ValueError(
                "pad-sentinel cluster ids in the host payload; "
                "construct ShardedState from an unpadded payload"
            )
        if self.stats is None:
            self.stats = S.payload_stats(self.model, self.payload)
        self.place()

    def _pad(self) -> int:
        mult = math.prod(self.mesh.shape[a] for a in self.axes)
        return (-self.payload.n) % mult

    def place(self):
        mult = math.prod(self.mesh.shape[a] for a in self.axes)
        padded = DX.pad_to_multiple(self.payload, mult)
        pad = padded.n - self.payload.n
        self.sharded = DX.shard_rows(self.mesh, padded, self.axes)
        self.sharded_stats = DX.shard_rows(
            self.mesh, DX.pad_stats(self.stats, pad), self.axes
        )
        self.sharded_raw = None if self.raw is None else DX.shard_rows(
            self.mesh,
            jnp.pad(self.raw, ((0, pad), (0, 0))),
            self.axes,
        )
        self.place_valid()
        self.searchers = {}

    def place_valid(self):
        """(Re-)shard just the validity bitmap — the only placement a
        delete touches (payload/stats/raw shards and cached searcher
        traces survive; the mask is a runtime kernel operand)."""
        if self.live is None:
            self.sharded_valid = None
            return
        self.sharded_valid = DX.shard_rows(
            self.mesh,
            jnp.pad(jnp.asarray(self.live).astype(bool),
                    (0, self._pad())),
            self.axes,
        )

    def searcher(self, k: int, rerank: int = 0,
                 coarse: Optional[str] = None,
                 shortlist: Optional[int] = None):
        """(payload, QueryPrep) -> (scores, ids) searcher, cached per
        (k, rerank shortlist, coarse mode, coarse shortlist).

        Prep-based so the direct and engine paths share one compiled
        function (queries are prepped outside the shard_map, once,
        instead of redundantly on every shard)."""
        key = (k, rerank, coarse, shortlist)
        if key not in self.searchers:
            self.searchers[key] = DX.make_sharded_search_prepped(
                self.mesh, self.model, self.axes, k,
                metric=self.metric, n_real=self.payload.n,
                rerank=rerank, coarse=coarse, shortlist=shortlist,
            )
        return self.searchers[key]


def _default_mesh(axes: tuple[str, ...]):
    from jax.sharding import Mesh

    devs = np.array(jax.devices())
    shape = (len(devs),) + (1,) * (len(axes) - 1)
    return Mesh(devs.reshape(shape), axes)


@register_backend
class ShardedBackend:
    """Scatter-gather search over a device mesh (wraps
    ``distributed.make_sharded_search`` behind the common signature)."""

    name = "sharded"

    @staticmethod
    def _resolve_mesh(mesh, axes):
        axes = tuple(axes) if axes is not None else ("data",)
        if mesh is None:
            mesh = _default_mesh(axes)
        return mesh, axes

    @staticmethod
    def build(key, X, config, *, metric, mesh=None, axes=None,
              model=None, learned=True, keep_raw=False, **train_kw):
        mesh, axes = ShardedBackend._resolve_mesh(mesh, axes)
        model = _train_or_reuse(
            key, X, config, model=model, learned=learned, **train_kw
        )
        return ShardedState(
            metric=metric, model=model, payload=A.encode(model, X),
            mesh=mesh, axes=axes,
            raw=X.astype(jnp.bfloat16) if keep_raw else None,
        )

    @staticmethod
    def from_parts(model, payload, *, metric, raw=None, mesh=None,
                   axes=None):
        mesh, axes = ShardedBackend._resolve_mesh(mesh, axes)
        return ShardedState(
            metric=metric, model=model, payload=payload,
            mesh=mesh, axes=axes, raw=raw,
        )

    @staticmethod
    def search(state, queries, *, k, nprobe=None, rerank=0,
               coarse=None, shortlist=None):
        prep = S.prepare_queries(state.model, queries)
        return ShardedBackend.search_prepped(
            state, prep, k=k, nprobe=nprobe, rerank=rerank,
            coarse=coarse, shortlist=shortlist,
        )

    @staticmethod
    def search_prepped(state, prep, *, k, nprobe=None, rerank=0,
                       coarse=None, shortlist=None):
        del nprobe  # no list routing in the scatter-gather scan
        if rerank and state.raw is None:
            raise ValueError(
                "rerank on the sharded backend requires keep_raw=True "
                "(bf16 raw shards are distributed with the payload)"
            )
        s, rows = state.searcher(k, rerank, coarse, shortlist)(
            state.sharded, prep,
            stats=state.sharded_stats, raw=state.sharded_raw,
            valid=state.sharded_valid,
        )
        if state.ids is None:
            return s, rows
        # map global payload rows to user ids after the merge (a (m, k)
        # gather; monotonic ids keep the merge's tie order intact)
        return s, jnp.where(
            rows < 0, -1, state.ids[jnp.maximum(rows, 0)]
        )

    @staticmethod
    def add(state, X_new):
        # mirror build: encode, then recompute stats AND raw for the
        # appended rows before any re-placement — a partial update
        # (e.g. raw missing for the tail) would silently break
        # shard-local rerank after the next place()
        payload_new = A.encode(state.model, X_new)
        n_new = payload_new.n
        nid = C.effective_next_id(
            state.next_id, state.ids, state.payload.n
        )
        state.payload = C.concat_payloads(state.payload, payload_new)
        # __post_init__ guarantees stats is never None, so the concat
        # always yields the full stats block
        state.stats = C.concat_stats(
            state.stats, S.payload_stats(state.model, payload_new)
        )
        if state.raw is not None:
            state.raw = jnp.concatenate(
                [state.raw, X_new.astype(jnp.bfloat16)], axis=0
            )
        if state.ids is not None:
            state.ids = jnp.concatenate(
                [state.ids, nid + jnp.arange(n_new, dtype=jnp.int32)]
            )
        if state.live is not None:
            state.live = jnp.concatenate(
                [state.live, jnp.ones((n_new,), bool)]
            )
        if state.next_id is not None:
            state.next_id = nid + n_new
        state.place()
        return state

    @staticmethod
    def delete(state, ids):
        new_live, removed = C.mark_deleted(
            state.ids, state.live, ids, state.payload.n
        )
        if removed:
            state.live = jnp.asarray(new_live)
            state.place_valid()  # payload/raw/stats shards untouched
        return state, removed

    @staticmethod
    def compact(state):
        if state.live is None:
            return state
        live_np = np.asarray(state.live).astype(bool)
        if live_np.all():
            state.live = None
            state.place_valid()
            return state
        if not live_np.any():
            raise ValueError(
                "compact() would evict every row; an empty index "
                "cannot be searched — keep at least one live row or "
                "rebuild"
            )
        nid = C.effective_next_id(
            state.next_id, state.ids, state.payload.n
        )
        keep = jnp.asarray(np.nonzero(live_np)[0].astype(np.int32))
        state.ids = (
            keep if state.ids is None else state.ids[keep]
        ).astype(jnp.int32)
        state.next_id = nid
        state.payload = C.gather_payload(state.payload, keep)
        state.stats = C.take_stats(state.stats, keep)
        if state.raw is not None:
            state.raw = state.raw[keep]
        state.live = None
        state.place()
        return state

    @staticmethod
    def model_of(state):
        return state.model

    @staticmethod
    def payload_of(state):
        return state.payload

    @staticmethod
    def stats_of(state):
        return state.stats

    @staticmethod
    def live_of(state):
        return state.live

    @staticmethod
    def ids_of(state):
        return state.ids

    @staticmethod
    def next_id_of(state):
        return C.effective_next_id(
            state.next_id, state.ids, state.payload.n
        )

    @staticmethod
    def to_arrays(state):
        arrays = {
            **_model_arrays(state.model),
            **_payload_arrays(state.payload),
            **_stats_arrays(state.stats),
        }
        if state.raw is not None:
            arrays["raw"] = state.raw
        if state.ids is not None:
            arrays["ids"] = state.ids
        if state.live is not None:
            arrays["live"] = state.live
        meta = {"axes": list(state.axes)}
        if state.next_id is not None:
            meta["next_id"] = int(state.next_id)
        return arrays, meta

    @staticmethod
    def from_arrays(arrays, meta, config, metric, *, mesh=None,
                    axes=None):
        axes = tuple(axes or meta.get("axes") or ("data",))
        mesh, axes = ShardedBackend._resolve_mesh(mesh, axes)
        model = _model_from_arrays(arrays, config)
        payload = _payload_from_arrays(arrays, config)
        return ShardedState(
            metric=metric,
            model=model,
            payload=payload,
            mesh=mesh,
            axes=axes,
            raw=arrays.get("raw"),
            stats=_stats_from_arrays(arrays, model, payload),
            ids=arrays.get("ids"),
            live=arrays.get("live"),
            next_id=meta.get("next_id"),
        )


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------


class AshIndex:
    """One lifecycle — build / search / add / delete / compact / save /
    load — over every backend.  See the module docstring for the
    canonical usage.

    Mutation model: :meth:`delete` tombstones rows in place (a validity
    bitmap threaded into the scan kernels' runtime mask operand — no
    recompilation, deleted ids can never surface); :meth:`compact`
    rewrites codes/stats/raw to evict tombstones past a dead-fraction
    threshold; :meth:`stage_add` buffers rows host-side (ids assigned
    immediately) until :meth:`apply_pending` ingests them in ONE
    backend add — the serving engine's batched-mutation path, which
    amortizes the IVF re-sort / sharded re-placement across a batch.
    Tombstones and the pending-add buffer both survive save/load.
    """

    def __init__(self, backend: str, metric: str, state):
        self._backend = _get_backend(backend)
        self._backend_name = backend
        self._metric = C.validate_metric(metric)
        self._state = state
        self._pending_add: list[np.ndarray] = []
        # bumped on every state rewrite (add / delete that removed
        # rows / apply_pending that ingested rows / compact); the
        # background compactor compares epochs to detect mutations
        # landing between its snapshot and its atomic swap
        self._mutation_epoch = 0

    # -- construction -------------------------------------------------

    @classmethod
    def build(
        cls,
        key: jax.Array,
        X: jax.Array,
        config: ASHConfig,
        *,
        backend: str = "flat",
        metric: str = "dot",
        **opts,
    ) -> "AshIndex":
        """Train (or reuse ``model=``), encode ``X`` and assemble the
        backend structure.  Backend-specific ``opts``: ``keep_raw``,
        ``learned``, ``model``, ``train_sample``, ``mesh``, ``axes``
        and any ``repro.core.ash.train`` keyword."""
        impl = _get_backend(backend)
        C.validate_metric(metric)
        state = impl.build(key, X, config, metric=metric, **opts)
        return cls(backend, metric, state)

    @classmethod
    def from_parts(
        cls,
        model: ASHModel,
        payload: ASHPayload,
        *,
        backend: str = "flat",
        metric: str = "dot",
        raw: Optional[jax.Array] = None,
        **opts,
    ) -> "AshIndex":
        """Wrap an already-encoded (model, payload) pair."""
        impl = _get_backend(backend)
        C.validate_metric(metric)
        state = impl.from_parts(
            model, payload, metric=metric, raw=raw, **opts
        )
        return cls(backend, metric, state)

    # -- lifecycle ----------------------------------------------------

    def search(
        self,
        queries: jax.Array,
        k: int = 10,
        *,
        nprobe: Optional[int] = None,
        rerank: int = 0,
        **opts,
    ) -> tuple[jax.Array, jax.Array]:
        """Top-k search: (scores, ids), each (m, k), higher-is-better
        scores for every metric; id -1 marks a missing candidate.

        ``coarse="int8"`` (every backend) runs the symmetric int8
        first-pass scan and asymmetrically rescores only the top
        ``shortlist`` candidates per query — faster on big scans, and
        exact (bit-identical to ``coarse=None``) whenever the
        shortlist covers the scanned rows."""
        return self._backend.search(
            self._state, queries, k=k, nprobe=nprobe, rerank=rerank,
            **opts,
        )

    def prepare(self, queries: jax.Array) -> QueryPrep:
        """Precompute the QUERY-COMPUTE projections (Eq. 20) for
        ``queries``; feed to :meth:`search_prepped`.  Row i of the prep
        depends only on row i of ``queries``, so prep rows are cacheable
        and batchable across requests (the serving engine does both)."""
        return S.prepare_queries(self.model, queries)

    def search_prepped(
        self,
        prep: QueryPrep,
        k: int = 10,
        *,
        nprobe: Optional[int] = None,
        rerank: int = 0,
        **opts,
    ) -> tuple[jax.Array, jax.Array]:
        """:meth:`search` from precomputed projections — bit-identical
        to ``search(queries, ...)`` for the same query rows."""
        return self._backend.search_prepped(
            self._state, prep, k=k, nprobe=nprobe, rerank=rerank,
            **opts,
        )

    def add(self, X_new: jax.Array) -> "AshIndex":
        """Encode new vectors under the existing model and ingest them
        immediately (ids continue past every id ever assigned,
        including retired ones).  Flushes any staged rows first so id
        assignment stays in submission order.  Returns self."""
        self.apply_pending()
        self._state = self._backend.add(self._state, X_new)
        self._mutation_epoch += 1
        return self

    # -- mutations ----------------------------------------------------

    def stage_add(self, X_new) -> np.ndarray:
        """Buffer rows for a later batched ingestion; returns the user
        ids they WILL carry (assigned now, in submission order).

        Staged rows are invisible to search until
        :meth:`apply_pending` ingests the whole buffer in one backend
        ``add`` — one IVF re-sort / sharded re-placement per batch
        instead of per call (the serving engine's
        ``submit_add`` path).  The buffer persists through
        :meth:`save`/:meth:`load`.
        """
        X = np.ascontiguousarray(np.asarray(X_new), dtype=np.float32)
        if X.ndim == 1:
            X = X[None, :]
        dim = self.model.landmarks.shape[1]
        if X.ndim != 2 or X.shape[1] != dim:
            raise ValueError(
                f"stage_add rows must be (n, {dim}): got {X.shape}"
            )
        start = self.next_id + sum(
            p.shape[0] for p in self._pending_add
        )
        if X.shape[0] == 0:  # nothing to stage; no empty buffer entry
            return np.arange(start, start, dtype=np.int64)
        self._pending_add.append(X)
        return np.arange(start, start + X.shape[0], dtype=np.int64)

    def apply_pending(self) -> int:
        """Ingest every staged row in one backend add; returns the row
        count applied (0 = nothing staged)."""
        if not self._pending_add:
            return 0
        rows = np.concatenate(self._pending_add, axis=0)
        self._pending_add = []
        self._state = self._backend.add(self._state, jnp.asarray(rows))
        self._mutation_epoch += 1
        return rows.shape[0]

    def delete(self, ids) -> int:
        """Tombstone rows by user id; returns the number of rows newly
        removed (unknown / already-deleted ids are ignored — FAISS
        ``remove_ids`` semantics).  Deleted ids can never surface in
        results: the validity bitmap feeds the scan kernels' runtime
        mask operand (dense paths) and drops candidates pre-DMA
        (gathered paths).  Applies staged adds first, so deleting a
        just-staged id works."""
        self.apply_pending()
        self._state, removed = self._backend.delete(self._state, ids)
        if removed:
            self._mutation_epoch += 1
        return removed

    def compact(self, max_dead_fraction: float = 0.0) -> "AshIndex":
        """Evict tombstoned rows by rewriting codes/stats/raw when the
        dead fraction exceeds ``max_dead_fraction`` (default: any
        tombstone triggers a rewrite).  Search afterwards is
        bit-identical to a fresh build over the surviving rows (same
        model); user ids are stable across compaction and never
        reused.  No-op below the threshold.  Returns self."""
        self.apply_pending()
        if self.dead_fraction > max_dead_fraction:
            self._state = self._backend.compact(self._state)
            self._mutation_epoch += 1
        return self

    # -- persistence --------------------------------------------------

    def save(self, path, *, extra_meta: Optional[dict] = None) -> None:
        """Write ``arrays.npz`` + ``config.json`` under ``path/``
        atomically: a crash at any instant leaves either the previous
        save or the new one, never a torn mix (fresh targets go
        through a temp dir + one ``os.replace``; existing targets
        through durable ``.new`` files that :meth:`load` can roll
        forward).  The manifest carries a crc32 per array that
        :meth:`load` verifies.  ``extra_meta`` entries are merged into
        the manifest (the durability layer stores its WAL high-water
        mark this way)."""
        p = pathlib.Path(path)
        arrays, backend_meta = self._backend.to_arrays(self._state)
        if self._pending_add:
            # staged-but-unapplied rows ride along so a batched
            # ingestion in flight is never lost to a save/load cycle
            arrays = dict(arrays)
            arrays["pending_add"] = np.concatenate(
                self._pending_add, axis=0
            )
        encoded, dtypes, checksums = {}, {}, {}
        for name, a in arrays.items():
            encoded[name], dtypes[name] = _encode_array(a)
            checksums[name] = zlib.crc32(
                np.ascontiguousarray(encoded[name]).tobytes()
            )
        cfg = self.config
        meta = {
            "format_version": FORMAT_VERSION,
            "backend": self._backend_name,
            "metric": self._metric,
            "config": {
                "b": cfg.b,
                "d": cfg.d,
                "n_landmarks": cfg.n_landmarks,
                "store_fp16": cfg.store_fp16,
            },
            "dtypes": dtypes,
            "backend_meta": backend_meta,
            "checksums": checksums,
        }
        if extra_meta:
            meta.update(extra_meta)
        if p.exists():
            _save_over(p, encoded, meta)
        else:
            _save_fresh(p, encoded, meta)

    @classmethod
    def load(cls, path, **opts) -> "AshIndex":
        """Inverse of :meth:`save`; search results are bit-identical to
        the saved index.  ``opts`` (e.g. ``mesh=``/``axes=`` for the
        sharded backend) override the backend placement.  Every
        integrity failure — missing files, truncated or bit-flipped
        ``arrays.npz``, checksum mismatch — raises
        :class:`CorruptIndexError` naming the failed check."""
        p = pathlib.Path(path)
        meta, encoded = _read_index_dir(p)
        try:
            arrays = {
                name: _decode_array(a, meta["dtypes"][name])
                for name, a in encoded.items()
            }
        except Exception as e:
            raise CorruptIndexError(p, f"array decode failed: {e}") from e
        pending = arrays.pop("pending_add", None)
        config = ASHConfig(**meta["config"])
        impl = _get_backend(meta["backend"])
        state = impl.from_arrays(
            arrays, meta["backend_meta"], config, meta["metric"], **opts
        )
        index = cls(meta["backend"], meta["metric"], state)
        if pending is not None:
            index._pending_add = [
                np.asarray(pending, dtype=np.float32)
            ]
        return index

    # -- introspection ------------------------------------------------

    @property
    def backend(self) -> str:
        return self._backend_name

    @property
    def metric(self) -> str:
        return self._metric

    @property
    def model(self) -> ASHModel:
        return self._backend.model_of(self._state)

    @property
    def payload(self) -> ASHPayload:
        return self._backend.payload_of(self._state)

    @property
    def stats(self) -> Optional[ASHStats]:
        """Encode-time row statistics (fused l2/cos epilogue inputs);
        carried by every built-in backend, None only for custom
        backends without a ``stats_of``."""
        stats_of = getattr(self._backend, "stats_of", None)
        return None if stats_of is None else stats_of(self._state)

    @property
    def config(self) -> ASHConfig:
        return self.model.config

    @property
    def n(self) -> int:
        """Payload rows, INCLUDING tombstones (excluding staged adds)."""
        return self.payload.n

    @property
    def n_dead(self) -> int:
        """Tombstoned rows awaiting compaction."""
        live = getattr(self._backend, "live_of", lambda s: None)(
            self._state
        )
        if live is None:
            return 0
        return self.n - int(np.asarray(live).sum())

    @property
    def n_live(self) -> int:
        """Searchable rows (``n`` minus tombstones)."""
        return self.n - self.n_dead

    @property
    def dead_fraction(self) -> float:
        """Tombstoned fraction of the payload — compare against
        ``compact(max_dead_fraction=...)``."""
        return self.n_dead / max(1, self.n)

    @property
    def pending_rows(self) -> int:
        """Rows staged by :meth:`stage_add`, not yet ingested."""
        return sum(p.shape[0] for p in self._pending_add)

    @property
    def mutation_epoch(self) -> int:
        """Monotonic counter of state rewrites (adds applied, deletes
        that removed rows, compactions).  Equal epochs guarantee the
        searchable state is unchanged — the background compactor's
        swap-if-unchanged check."""
        return self._mutation_epoch

    @property
    def next_id(self) -> int:
        """User id the next added row receives (monotonic; retired ids
        are never reused).  Staged rows already hold theirs."""
        next_id_of = getattr(self._backend, "next_id_of", None)
        return self.n if next_id_of is None else next_id_of(self._state)

    def __len__(self) -> int:
        return self.n

    def __repr__(self) -> str:
        cfg = self.config
        mut = ""
        if self.n_dead or self.pending_rows:
            mut = (
                f", dead={self.n_dead}, pending={self.pending_rows}"
            )
        return (
            f"AshIndex(backend={self._backend_name!r}, "
            f"metric={self._metric!r}, n={self.n}{mut}, b={cfg.b}, "
            f"d={cfg.d}, C={cfg.n_landmarks}, "
            f"payload={cfg.payload_bits()} bits/vec)"
        )
