"""Unified ``AshIndex`` facade: one build/search/persist surface over
the flat, IVF and sharded backends.

The paper's value proposition is a single encoder-decoder payload
(Table 1) serving dot/L2/cosine search at every scale; this module is
the single entry point over it::

    index = AshIndex.build(key, X, ASHConfig(b=2, d=64, n_landmarks=64),
                           backend="ivf", metric="l2", keep_raw=True)
    scores, ids = index.search(queries, k=10, nprobe=16, rerank=100)
    index.add(X_new)                    # incremental ingestion
    index.save("/tmp/idx")              # npz arrays + JSON config
    index = AshIndex.load("/tmp/idx")   # bit-identical search results

Backends are pluggable via :func:`register_backend`; all share the
metric dispatcher and exact-rerank pipeline of ``repro.index.common``,
so every backend returns higher-is-better scores and id ``-1`` for
missing candidates.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import (
    ASHConfig, ASHModel, ASHPayload, ASHStats, QueryPrep,
)
from repro.index import common as C
from repro.index import distributed as DX
from repro.index import flat as F
from repro.index import ivf as IV

FORMAT_VERSION = 1

_BACKENDS: dict[str, type] = {}


def register_backend(cls):
    """Class decorator: register an index backend under ``cls.name``."""
    _BACKENDS[cls.name] = cls
    return cls


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def _get_backend(name: str):
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


# ---------------------------------------------------------------------------
# Array (de)serialization — numpy .npz with bf16 stored as uint16 views
# ---------------------------------------------------------------------------

_MODEL_FIELDS = (
    "W", "landmarks", "W_landmarks", "landmark_sq_norms",
    "bias_rho", "bias_beta",
)
_PAYLOAD_FIELDS = ("codes", "scale", "offset", "cluster")
_STATS_FIELDS = ("res_norm", "ip_x_mu", "x_sq")


_BF16 = np.dtype(jnp.bfloat16)


def _encode_array(a) -> tuple[np.ndarray, str]:
    """jax/numpy array -> (savez-safe numpy array, dtype tag).

    numpy can't serialize the ml_dtypes bfloat16 descr, so bf16 arrays
    are stored as uint16 bit patterns and tagged for exact restore."""
    a = np.asarray(a)
    if a.dtype == _BF16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _decode_array(a: np.ndarray, tag: str) -> jax.Array:
    if tag == "bfloat16":
        return jnp.asarray(a.view(_BF16))
    return jnp.asarray(a)


def _model_arrays(model: ASHModel) -> dict[str, Any]:
    return {f"model.{f}": getattr(model, f) for f in _MODEL_FIELDS}


def _model_from_arrays(
    arrays: dict[str, jax.Array], config: ASHConfig
) -> ASHModel:
    return ASHModel(
        config=config,
        **{f: arrays[f"model.{f}"] for f in _MODEL_FIELDS},
    )


def _payload_arrays(payload: ASHPayload) -> dict[str, Any]:
    return {f"payload.{f}": getattr(payload, f) for f in _PAYLOAD_FIELDS}


def _payload_from_arrays(
    arrays: dict[str, jax.Array], config: ASHConfig
) -> ASHPayload:
    return ASHPayload(
        b=config.b,
        d=config.d,
        **{f: arrays[f"payload.{f}"] for f in _PAYLOAD_FIELDS},
    )


def _stats_arrays(stats: Optional[ASHStats]) -> dict[str, Any]:
    if stats is None:
        return {}
    return {f"stats.{f}": getattr(stats, f) for f in _STATS_FIELDS}


def _stats_from_arrays(
    arrays: dict[str, jax.Array], model: ASHModel, payload: ASHPayload
) -> ASHStats:
    """Restore persisted stats bit-identically; rebuild from the
    payload when loading a pre-stats save."""
    if all(f"stats.{f}" in arrays for f in _STATS_FIELDS):
        return ASHStats(
            **{f: arrays[f"stats.{f}"] for f in _STATS_FIELDS}
        )
    return S.payload_stats(model, payload)


def _train_or_reuse(
    key, X, config, *, model=None, learned=True, **train_kw
) -> ASHModel:
    if model is not None:
        return model
    if learned:
        model, _ = A.train(key, X, config, **train_kw)
        return model
    return A.random_model(key, X.shape[1], config, X_for_landmarks=X)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


@register_backend
class FlatBackend:
    """Exhaustive scan over the whole payload."""

    name = "flat"

    @staticmethod
    def build(key, X, config, *, metric, **opts):
        return F._build(key, X, config, metric=metric, **opts)

    @staticmethod
    def from_parts(model, payload, *, metric, raw=None):
        return F.FlatIndex(
            metric=metric, model=model, payload=payload, raw=raw,
            stats=S.payload_stats(model, payload),
        )

    @staticmethod
    def search(state, queries, *, k, nprobe=None, rerank=0, **opts):
        del nprobe  # no coarse routing in a flat scan
        return F._search(state, queries, k=k, rerank=rerank, **opts)

    @staticmethod
    def search_prepped(state, prep, *, k, nprobe=None, rerank=0, **opts):
        del nprobe
        return F._search_prepped(state, prep, k=k, rerank=rerank, **opts)

    @staticmethod
    def add(state, X_new):
        return F._add(state, X_new)

    @staticmethod
    def model_of(state):
        return state.model

    @staticmethod
    def payload_of(state):
        return state.payload

    @staticmethod
    def stats_of(state):
        return state.stats

    @staticmethod
    def to_arrays(state):
        arrays = {
            **_model_arrays(state.model),
            **_payload_arrays(state.payload),
            **_stats_arrays(state.stats),
        }
        if state.raw is not None:
            arrays["raw"] = state.raw
        return arrays, {}

    @staticmethod
    def from_arrays(arrays, meta, config, metric, **opts):
        model = _model_from_arrays(arrays, config)
        payload = _payload_from_arrays(arrays, config)
        return F.FlatIndex(
            metric=metric,
            model=model,
            payload=payload,
            raw=arrays.get("raw"),
            stats=_stats_from_arrays(arrays, model, payload),
        )


@register_backend
class IVFBackend:
    """Inverted-file routing over the landmark coarse quantizer."""

    name = "ivf"
    default_nprobe = 8

    @staticmethod
    def build(key, X, config, *, metric, **opts):
        return IV._build(key, X, config, metric=metric, **opts)

    @staticmethod
    def from_parts(model, payload, *, metric, raw=None):
        ids = jnp.arange(payload.n, dtype=jnp.int32)
        return IV._assemble(metric, model, payload, ids, raw)

    @staticmethod
    def resolve_nprobe(state, nprobe):
        """Effective nprobe: default applied, clamped to the invlist
        count.  Public so the serving engine can normalize request
        nprobe before grouping (distinct values above nlist route
        identically and must share one group/trace)."""
        if nprobe is None:
            nprobe = IVFBackend.default_nprobe
        return min(nprobe, state.invlists.shape[0])

    @staticmethod
    def search(state, queries, *, k, nprobe=None, rerank=0, **opts):
        nprobe = IVFBackend.resolve_nprobe(state, nprobe)
        return IV._search(
            state, queries, k=k, nprobe=nprobe, rerank=rerank, **opts
        )

    @staticmethod
    def search_prepped(state, prep, *, k, nprobe=None, rerank=0, **opts):
        nprobe = IVFBackend.resolve_nprobe(state, nprobe)
        return IV._search_prepped(
            state, prep, k=k, nprobe=nprobe, rerank=rerank, **opts
        )

    @staticmethod
    def add(state, X_new):
        return IV._add(state, X_new)

    @staticmethod
    def model_of(state):
        return state.model

    @staticmethod
    def payload_of(state):
        return state.payload

    @staticmethod
    def stats_of(state):
        return state.stats

    @staticmethod
    def to_arrays(state):
        arrays = {
            **_model_arrays(state.model),
            **_payload_arrays(state.payload),
            **_stats_arrays(state.stats),
            "ids": state.ids,
            "invlists": state.invlists,
        }
        if state.raw is not None:
            arrays["raw"] = state.raw
        return arrays, {"max_list_len": state.max_list_len}

    @staticmethod
    def from_arrays(arrays, meta, config, metric, **opts):
        model = _model_from_arrays(arrays, config)
        payload = _payload_from_arrays(arrays, config)
        return IV.IVFIndex(
            metric=metric,
            max_list_len=int(meta["max_list_len"]),
            model=model,
            payload=payload,
            ids=arrays["ids"],
            invlists=arrays["invlists"],
            raw=arrays.get("raw"),
            stats=_stats_from_arrays(arrays, model, payload),
        )


@dataclasses.dataclass
class ShardedState:
    """Host copy of the payload + its device-sharded placement.

    The host copies (unpadded) are kept for add()/save(); the padded,
    row-sharded copies are what searches scan: the payload, its
    encode-time ``ASHStats`` (fused l2/cos epilogue inputs) and — when
    built with ``keep_raw`` — a bf16 raw-vector shard enabling
    shard-local exact rerank.  Compiled searchers are cached per
    (k, rerank) and invalidated when the placement changes.
    """

    metric: str
    model: ASHModel
    payload: ASHPayload  # unpadded, host-side source of truth
    mesh: Any
    axes: tuple[str, ...]
    raw: Optional[jax.Array] = None  # unpadded bf16 rows (rerank)
    stats: Optional[ASHStats] = None  # unpadded; built when missing
    sharded: ASHPayload = dataclasses.field(init=False)
    sharded_stats: ASHStats = dataclasses.field(init=False)
    sharded_raw: Optional[jax.Array] = dataclasses.field(init=False)
    searchers: dict = dataclasses.field(init=False, default_factory=dict)

    def __post_init__(self):
        # the unpadded payload is the gather-safe source of truth: the
        # pad sentinel (cluster == -1) must only ever exist on the
        # device-side padded copy, where row masking precedes use.
        # Validated once here — add() only appends encode() output,
        # whose cluster assignments are always valid
        cluster = np.asarray(self.payload.cluster)
        if cluster.size and int(cluster.min()) < 0:
            raise ValueError(
                "pad-sentinel cluster ids in the host payload; "
                "construct ShardedState from an unpadded payload"
            )
        if self.stats is None:
            self.stats = S.payload_stats(self.model, self.payload)
        self.place()

    def place(self):
        mult = math.prod(self.mesh.shape[a] for a in self.axes)
        padded = DX.pad_to_multiple(self.payload, mult)
        pad = padded.n - self.payload.n
        self.sharded = DX.shard_rows(self.mesh, padded, self.axes)
        self.sharded_stats = DX.shard_rows(
            self.mesh, DX.pad_stats(self.stats, pad), self.axes
        )
        self.sharded_raw = None if self.raw is None else DX.shard_rows(
            self.mesh,
            jnp.pad(self.raw, ((0, pad), (0, 0))),
            self.axes,
        )
        self.searchers = {}

    def searcher(self, k: int, rerank: int = 0):
        """(payload, QueryPrep) -> (scores, ids) searcher, cached per
        (k, rerank shortlist).

        Prep-based so the direct and engine paths share one compiled
        function (queries are prepped outside the shard_map, once,
        instead of redundantly on every shard)."""
        key = (k, rerank)
        if key not in self.searchers:
            self.searchers[key] = DX.make_sharded_search_prepped(
                self.mesh, self.model, self.axes, k,
                metric=self.metric, n_real=self.payload.n,
                rerank=rerank,
            )
        return self.searchers[key]


def _default_mesh(axes: tuple[str, ...]):
    from jax.sharding import Mesh

    devs = np.array(jax.devices())
    shape = (len(devs),) + (1,) * (len(axes) - 1)
    return Mesh(devs.reshape(shape), axes)


@register_backend
class ShardedBackend:
    """Scatter-gather search over a device mesh (wraps
    ``distributed.make_sharded_search`` behind the common signature)."""

    name = "sharded"

    @staticmethod
    def _resolve_mesh(mesh, axes):
        axes = tuple(axes) if axes is not None else ("data",)
        if mesh is None:
            mesh = _default_mesh(axes)
        return mesh, axes

    @staticmethod
    def build(key, X, config, *, metric, mesh=None, axes=None,
              model=None, learned=True, keep_raw=False, **train_kw):
        mesh, axes = ShardedBackend._resolve_mesh(mesh, axes)
        model = _train_or_reuse(
            key, X, config, model=model, learned=learned, **train_kw
        )
        return ShardedState(
            metric=metric, model=model, payload=A.encode(model, X),
            mesh=mesh, axes=axes,
            raw=X.astype(jnp.bfloat16) if keep_raw else None,
        )

    @staticmethod
    def from_parts(model, payload, *, metric, raw=None, mesh=None,
                   axes=None):
        mesh, axes = ShardedBackend._resolve_mesh(mesh, axes)
        return ShardedState(
            metric=metric, model=model, payload=payload,
            mesh=mesh, axes=axes, raw=raw,
        )

    @staticmethod
    def search(state, queries, *, k, nprobe=None, rerank=0):
        prep = S.prepare_queries(state.model, queries)
        return ShardedBackend.search_prepped(
            state, prep, k=k, nprobe=nprobe, rerank=rerank
        )

    @staticmethod
    def search_prepped(state, prep, *, k, nprobe=None, rerank=0):
        del nprobe  # no coarse routing in the scatter-gather scan
        if rerank and state.raw is None:
            raise ValueError(
                "rerank on the sharded backend requires keep_raw=True "
                "(bf16 raw shards are distributed with the payload)"
            )
        return state.searcher(k, rerank)(
            state.sharded, prep,
            stats=state.sharded_stats, raw=state.sharded_raw,
        )

    @staticmethod
    def add(state, X_new):
        payload_new = A.encode(state.model, X_new)
        state.payload = C.concat_payloads(state.payload, payload_new)
        state.stats = C.concat_stats(
            state.stats, S.payload_stats(state.model, payload_new)
        )
        if state.raw is not None:
            state.raw = jnp.concatenate(
                [state.raw, X_new.astype(jnp.bfloat16)], axis=0
            )
        state.place()
        return state

    @staticmethod
    def model_of(state):
        return state.model

    @staticmethod
    def payload_of(state):
        return state.payload

    @staticmethod
    def stats_of(state):
        return state.stats

    @staticmethod
    def to_arrays(state):
        arrays = {
            **_model_arrays(state.model),
            **_payload_arrays(state.payload),
            **_stats_arrays(state.stats),
        }
        if state.raw is not None:
            arrays["raw"] = state.raw
        return arrays, {"axes": list(state.axes)}

    @staticmethod
    def from_arrays(arrays, meta, config, metric, *, mesh=None,
                    axes=None):
        axes = tuple(axes or meta.get("axes") or ("data",))
        mesh, axes = ShardedBackend._resolve_mesh(mesh, axes)
        model = _model_from_arrays(arrays, config)
        payload = _payload_from_arrays(arrays, config)
        return ShardedState(
            metric=metric,
            model=model,
            payload=payload,
            mesh=mesh,
            axes=axes,
            raw=arrays.get("raw"),
            stats=_stats_from_arrays(arrays, model, payload),
        )


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------


class AshIndex:
    """One lifecycle — build / search / add / save / load — over every
    backend.  See the module docstring for the canonical usage."""

    def __init__(self, backend: str, metric: str, state):
        self._backend = _get_backend(backend)
        self._backend_name = backend
        self._metric = C.validate_metric(metric)
        self._state = state

    # -- construction -------------------------------------------------

    @classmethod
    def build(
        cls,
        key: jax.Array,
        X: jax.Array,
        config: ASHConfig,
        *,
        backend: str = "flat",
        metric: str = "dot",
        **opts,
    ) -> "AshIndex":
        """Train (or reuse ``model=``), encode ``X`` and assemble the
        backend structure.  Backend-specific ``opts``: ``keep_raw``,
        ``learned``, ``model``, ``train_sample``, ``mesh``, ``axes``
        and any ``repro.core.ash.train`` keyword."""
        impl = _get_backend(backend)
        C.validate_metric(metric)
        state = impl.build(key, X, config, metric=metric, **opts)
        return cls(backend, metric, state)

    @classmethod
    def from_parts(
        cls,
        model: ASHModel,
        payload: ASHPayload,
        *,
        backend: str = "flat",
        metric: str = "dot",
        raw: Optional[jax.Array] = None,
        **opts,
    ) -> "AshIndex":
        """Wrap an already-encoded (model, payload) pair."""
        impl = _get_backend(backend)
        C.validate_metric(metric)
        state = impl.from_parts(
            model, payload, metric=metric, raw=raw, **opts
        )
        return cls(backend, metric, state)

    # -- lifecycle ----------------------------------------------------

    def search(
        self,
        queries: jax.Array,
        k: int = 10,
        *,
        nprobe: Optional[int] = None,
        rerank: int = 0,
        **opts,
    ) -> tuple[jax.Array, jax.Array]:
        """Top-k search: (scores, ids), each (m, k), higher-is-better
        scores for every metric; id -1 marks a missing candidate."""
        return self._backend.search(
            self._state, queries, k=k, nprobe=nprobe, rerank=rerank,
            **opts,
        )

    def prepare(self, queries: jax.Array) -> QueryPrep:
        """Precompute the QUERY-COMPUTE projections (Eq. 20) for
        ``queries``; feed to :meth:`search_prepped`.  Row i of the prep
        depends only on row i of ``queries``, so prep rows are cacheable
        and batchable across requests (the serving engine does both)."""
        return S.prepare_queries(self.model, queries)

    def search_prepped(
        self,
        prep: QueryPrep,
        k: int = 10,
        *,
        nprobe: Optional[int] = None,
        rerank: int = 0,
        **opts,
    ) -> tuple[jax.Array, jax.Array]:
        """:meth:`search` from precomputed projections — bit-identical
        to ``search(queries, ...)`` for the same query rows."""
        return self._backend.search_prepped(
            self._state, prep, k=k, nprobe=nprobe, rerank=rerank,
            **opts,
        )

    def add(self, X_new: jax.Array) -> "AshIndex":
        """Encode new vectors under the existing model and ingest them
        (ids continue from the current size).  Returns self."""
        self._state = self._backend.add(self._state, X_new)
        return self

    # -- persistence --------------------------------------------------

    def save(self, path) -> None:
        """Write ``arrays.npz`` + ``config.json`` under ``path/``."""
        p = pathlib.Path(path)
        p.mkdir(parents=True, exist_ok=True)
        arrays, backend_meta = self._backend.to_arrays(self._state)
        encoded, dtypes = {}, {}
        for name, a in arrays.items():
            encoded[name], dtypes[name] = _encode_array(a)
        np.savez(p / "arrays.npz", **encoded)
        cfg = self.config
        meta = {
            "format_version": FORMAT_VERSION,
            "backend": self._backend_name,
            "metric": self._metric,
            "config": {
                "b": cfg.b,
                "d": cfg.d,
                "n_landmarks": cfg.n_landmarks,
                "store_fp16": cfg.store_fp16,
            },
            "dtypes": dtypes,
            "backend_meta": backend_meta,
        }
        (p / "config.json").write_text(json.dumps(meta, indent=2))

    @classmethod
    def load(cls, path, **opts) -> "AshIndex":
        """Inverse of :meth:`save`; search results are bit-identical to
        the saved index.  ``opts`` (e.g. ``mesh=``/``axes=`` for the
        sharded backend) override the backend placement."""
        p = pathlib.Path(path)
        meta = json.loads((p / "config.json").read_text())
        if meta["format_version"] != FORMAT_VERSION:
            raise ValueError(
                f"index format {meta['format_version']} != "
                f"{FORMAT_VERSION}"
            )
        with np.load(p / "arrays.npz") as npz:
            arrays = {
                name: _decode_array(npz[name], meta["dtypes"][name])
                for name in npz.files
            }
        config = ASHConfig(**meta["config"])
        impl = _get_backend(meta["backend"])
        state = impl.from_arrays(
            arrays, meta["backend_meta"], config, meta["metric"], **opts
        )
        return cls(meta["backend"], meta["metric"], state)

    # -- introspection ------------------------------------------------

    @property
    def backend(self) -> str:
        return self._backend_name

    @property
    def metric(self) -> str:
        return self._metric

    @property
    def model(self) -> ASHModel:
        return self._backend.model_of(self._state)

    @property
    def payload(self) -> ASHPayload:
        return self._backend.payload_of(self._state)

    @property
    def stats(self) -> Optional[ASHStats]:
        """Encode-time row statistics (fused l2/cos epilogue inputs);
        carried by every built-in backend, None only for custom
        backends without a ``stats_of``."""
        stats_of = getattr(self._backend, "stats_of", None)
        return None if stats_of is None else stats_of(self._state)

    @property
    def config(self) -> ASHConfig:
        return self.model.config

    @property
    def n(self) -> int:
        return self.payload.n

    def __len__(self) -> int:
        return self.n

    def __repr__(self) -> str:
        cfg = self.config
        return (
            f"AshIndex(backend={self._backend_name!r}, "
            f"metric={self._metric!r}, n={self.n}, b={cfg.b}, "
            f"d={cfg.d}, C={cfg.n_landmarks}, "
            f"payload={cfg.payload_bits()} bits/vec)"
        )
