"""ANN index structures over ASH payloads."""
from repro.index import flat, ivf, metrics, distributed
from repro.index.metrics import exact_topk, recall_at, recall_curve

__all__ = ["flat", "ivf", "metrics", "distributed",
           "exact_topk", "recall_at", "recall_curve"]
