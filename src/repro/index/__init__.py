"""ANN index structures over ASH payloads.

``AshIndex`` is the unified build/search/persist surface over the
flat, IVF and sharded backends; ``repro.serving.engine`` batches
requests on top of it.
"""
from repro.index import common, flat, ivf, metrics, distributed
from repro.index.api import (
    AshIndex, CorruptIndexError, available_backends, register_backend,
)
from repro.index import tiered
from repro.index.metrics import exact_topk, recall_at, recall_curve

__all__ = ["AshIndex", "CorruptIndexError", "available_backends",
           "register_backend",
           "common", "flat", "ivf", "metrics", "distributed", "tiered",
           "exact_topk", "recall_at", "recall_curve"]
