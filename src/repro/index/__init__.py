"""ANN index structures over ASH payloads.

``AshIndex`` is the unified build/search/persist surface; the
``flat``/``ivf`` module-level builders are deprecated shims kept for
one release.
"""
from repro.index import common, flat, ivf, metrics, distributed
from repro.index.api import AshIndex, available_backends, register_backend
from repro.index.metrics import exact_topk, recall_at, recall_curve

__all__ = ["AshIndex", "available_backends", "register_backend",
           "common", "flat", "ivf", "metrics", "distributed",
           "exact_topk", "recall_at", "recall_curve"]
