"""Inverted-file (IVF) ASH index.

The ASH landmarks ARE the IVF centroids (Section 2 of the paper): the
coarse quantizer used for residual centering doubles as the routing
structure, so OFFSET/SCALE come for free per list.

JAX needs static shapes, so inverted lists are stored padded to the
longest list; search gathers ``nprobe`` padded lists per query, scores
them with the asymmetric estimator, masks padding, and top-k's.
Queries with fewer than k valid candidates pad results with score
``-inf`` / id ``-1`` (never aliased to row 0).

Entry point is ``repro.index.AshIndex`` with ``backend="ivf"``; the
``_search_prepped`` path lets the serving engine reuse cached
``QueryPrep`` projections.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import (
    ASHConfig, ASHModel, ASHPayload, ASHStats, QueryPrep, pytree_dataclass,
)
from repro.index import common as C

NEG_INF = C.NEG_INF


@pytree_dataclass(meta_fields=("metric", "max_list_len"))
class IVFIndex:
    metric: str
    max_list_len: int
    model: ASHModel  # landmarks == IVF centroids (nlist, D)
    payload: ASHPayload  # rows sorted by list
    ids: jax.Array  # (n,) original ids, sorted by list
    invlists: jax.Array  # (nlist, max_list_len) int32 row indices, -1 pad
    raw: Optional[jax.Array]  # optional bf16 vectors (sorted) for rerank
    # Encode-time row statistics for the fused l2/cos epilogues on the
    # full-probe (dense-scan) path; row-aligned with ``payload``.
    stats: Optional[ASHStats] = None


def _assemble(
    metric: str,
    model: ASHModel,
    payload: ASHPayload,
    ids: jax.Array,
    raw: Optional[jax.Array],
) -> IVFIndex:
    """Sort rows by cluster and build the padded inverted lists.

    payload/ids/raw are row-aligned in any order; ``ids`` holds the
    original (user-facing) id of each row.  Used by both build and
    incremental add — a stable sort keeps add() results identical to a
    from-scratch assembly over the concatenated rows.
    """
    import numpy as np

    cluster = np.asarray(payload.cluster)
    # the -1 pad sentinel (distributed.pad_to_multiple) must never be
    # gathered into inverted lists — it aliases under wrapped indexing
    if cluster.size and cluster.min() < 0:
        raise ValueError(
            "payload contains pad-sentinel cluster ids (-1); assemble "
            "inverted lists from an unpadded payload"
        )
    nlist = model.landmarks.shape[0]
    order = np.argsort(cluster, kind="stable")
    counts = np.bincount(cluster[order], minlength=nlist)
    max_len = int(counts.max())
    invlists = np.full((nlist, max_len), -1, dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for c in range(nlist):
        invlists[c, : counts[c]] = np.arange(
            starts[c], starts[c] + counts[c], dtype=np.int32
        )

    perm = jnp.asarray(order)
    sorted_payload = C.permute_payload(payload, perm)
    return IVFIndex(
        metric=metric,
        max_list_len=max_len,
        model=model,
        payload=sorted_payload,
        ids=jnp.asarray(ids)[perm].astype(jnp.int32),
        invlists=jnp.asarray(invlists),
        raw=None if raw is None else raw[perm],
        stats=S.payload_stats(model, sorted_payload),
    )


def _build(
    key: jax.Array,
    X: jax.Array,
    config: ASHConfig,
    *,
    metric: str = "dot",
    keep_raw: bool = False,
    model: Optional[ASHModel] = None,
    train_sample: Optional[int] = None,
    **train_kw,
) -> IVFIndex:
    """nlist = config.n_landmarks."""
    C.validate_metric(metric)
    if model is None:
        model, _ = A.train(
            key, X, config, train_sample=train_sample, **train_kw
        )
    payload = A.encode(model, X)
    raw = X.astype(jnp.bfloat16) if keep_raw else None
    ids = jnp.arange(payload.n, dtype=jnp.int32)
    return _assemble(metric, model, payload, ids, raw)


def _add(index: IVFIndex, X_new: jax.Array) -> IVFIndex:
    """Encode new rows under the existing model and merge them into the
    inverted lists.  New rows get ids ``n, ..., n + n_new - 1``."""
    payload_new = A.encode(index.model, X_new)
    n_old = index.ids.shape[0]
    ids = jnp.concatenate(
        [index.ids,
         n_old + jnp.arange(payload_new.n, dtype=jnp.int32)]
    )
    raw = index.raw
    if raw is not None:
        raw = jnp.concatenate(
            [raw, X_new.astype(jnp.bfloat16)], axis=0
        )
    return _assemble(
        index.metric,
        index.model,
        C.concat_payloads(index.payload, payload_new),
        ids,
        raw,
    )


@functools.partial(jax.jit, static_argnames=("k", "nprobe", "rerank"))
def _search_prepped(
    index: IVFIndex,
    prep: QueryPrep,
    k: int = 10,
    nprobe: int = 8,
    rerank: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Top-k from precomputed query projections: (scores, ids), (m,k).

    nprobe >= nlist probes every list — coarse routing degenerates to
    an exhaustive scan, so the query skips the gather entirely and runs
    the flat fused-kernel scan over the (list-sorted) payload, mapping
    rows back through ``index.ids``.  Partial probes lower to a
    gathered ``ScanPlan`` served by the masked-gather kernel family
    (batch-shape-invariant rowwise oracle on CPU)."""
    if nprobe >= index.invlists.shape[0]:
        return _full_scan(index, prep, k, rerank)
    if prep.q.shape[0] == 1:
        # XLA lowers the degenerate single-query batch differently from
        # every m >= 2 (last-ulp score drift), which would break the
        # serving engine's bit-identity guarantee between per-request
        # and bucketed calls; compute at m=2 and slice.
        prep = jax.tree_util.tree_map(
            lambda a: jnp.concatenate([a, jnp.zeros_like(a)], axis=0),
            prep,
        )
        s, i = _score_gathered(index, prep, k, nprobe, rerank)
        return s[:1], i[:1]
    return _score_gathered(index, prep, k, nprobe, rerank)


def _full_scan(
    index: IVFIndex,
    prep: QueryPrep,
    k: int,
    rerank: int,
    use_pallas: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Exhaustive fused-kernel scan (the nprobe == nlist case): the
    flat backend's routing ladder (a dense ``common.ScanPlan``) with
    payload rows mapped to user ids via ``index.ids``."""
    plan = C.ScanPlan(
        metric=index.metric, k=k, rerank=rerank, ids=index.ids,
        use_pallas=use_pallas,
    )
    return C.execute_plan(
        index.model, prep, index.payload, plan,
        stats=index.stats, raw=index.raw,
    )


def _score_gathered(
    index: IVFIndex,
    prep: QueryPrep,
    k: int,
    nprobe: int,
    rerank: int,
) -> tuple[jax.Array, jax.Array]:
    """Partial probes: gather each query's candidate lists and lower to
    a gathered ``ScanPlan`` — the masked-gather kernel family scores
    straight off the packed codes (pad ids mask to ``-inf``) and fuses
    the selection; no (m, nprobe*L) score matrix reaches HBM on TPU."""
    m = prep.q.shape[0]
    # coarse routing: nearest centroids by L2 (== max <q,mu> - ||mu||^2/2)
    coarse = (
        prep.ip_q_landmarks
        - 0.5 * index.model.landmark_sq_norms[None, :]
    )
    _, probe = jax.lax.top_k(coarse, nprobe)  # (m, nprobe)
    cand_rows = index.invlists[probe].reshape(m, -1)  # (m, nprobe*L)
    plan = C.ScanPlan(
        metric=index.metric, k=k, rerank=rerank, rows=cand_rows,
        ids=index.ids,
    )
    return C.execute_plan(
        index.model, prep, index.payload, plan,
        stats=index.stats, raw=index.raw,
    )


def _search(
    index: IVFIndex,
    queries: jax.Array,
    k: int = 10,
    nprobe: int = 8,
    rerank: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Composition of ``prepare_queries`` and :func:`_search_prepped`,
    so engine (prep-cached) and direct paths share compiled arithmetic."""
    prep = S.prepare_queries(index.model, queries)
    return _search_prepped(index, prep, k=k, nprobe=nprobe, rerank=rerank)
