"""Inverted-file (IVF) ASH index.

The ASH landmarks ARE the IVF centroids (Section 2 of the paper): the
coarse quantizer used for residual centering doubles as the routing
structure, so OFFSET/SCALE come for free per list.

JAX needs static shapes, so inverted lists are stored padded to the
longest list; search gathers ``nprobe`` padded lists per query, scores
them with the asymmetric estimator, masks padding, and top-k's.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import ASHConfig, ASHModel, ASHPayload, pytree_dataclass

NEG_INF = -jnp.inf


@pytree_dataclass(meta_fields=("metric", "max_list_len"))
class IVFIndex:
    metric: str
    max_list_len: int
    model: ASHModel  # landmarks == IVF centroids (nlist, D)
    payload: ASHPayload  # rows sorted by list
    ids: jax.Array  # (n,) original ids, sorted by list
    invlists: jax.Array  # (nlist, max_list_len) int32 row indices, -1 pad
    raw: Optional[jax.Array]  # optional bf16 vectors (sorted) for rerank


def build(
    key: jax.Array,
    X: jax.Array,
    config: ASHConfig,
    *,
    metric: str = "dot",
    keep_raw: bool = False,
    train_sample: Optional[int] = None,
    **train_kw,
) -> IVFIndex:
    """nlist = config.n_landmarks."""
    model, _ = A.train(key, X, config, train_sample=train_sample, **train_kw)
    payload = A.encode(model, X)
    import numpy as np

    cluster = np.asarray(payload.cluster)
    n = cluster.shape[0]
    nlist = model.landmarks.shape[0]
    order = np.argsort(cluster, kind="stable")
    sorted_cluster = cluster[order]
    counts = np.bincount(sorted_cluster, minlength=nlist)
    max_len = int(counts.max())
    invlists = np.full((nlist, max_len), -1, dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for c in range(nlist):
        invlists[c, : counts[c]] = np.arange(
            starts[c], starts[c] + counts[c], dtype=np.int32
        )

    perm = jnp.asarray(order)
    payload_sorted = jax.tree_util.tree_map(
        lambda a: a[perm] if hasattr(a, "shape") and a.ndim >= 1
        and a.shape[0] == n else a,
        payload,
    )
    raw = X.astype(jnp.bfloat16)[perm] if keep_raw else None
    return IVFIndex(
        metric=metric,
        max_list_len=max_len,
        model=model,
        payload=payload_sorted,
        ids=perm.astype(jnp.int32),
        invlists=jnp.asarray(invlists),
        raw=raw,
    )


def _gather_payload(payload: ASHPayload, rows: jax.Array) -> ASHPayload:
    """Gather payload rows (any leading batch shape); -1 rows read row 0
    (masked later)."""
    safe = jnp.maximum(rows, 0)
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=payload.codes[safe],
        scale=payload.scale[safe],
        offset=payload.offset[safe],
        cluster=payload.cluster[safe],
    )


@functools.partial(jax.jit, static_argnames=("k", "nprobe", "rerank"))
def search(
    index: IVFIndex,
    queries: jax.Array,
    k: int = 10,
    nprobe: int = 8,
    rerank: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Returns (scores (m,k), original ids (m,k))."""
    m = queries.shape[0]
    prep = S.prepare_queries(index.model, queries)
    # coarse routing: nearest centroids by L2 (== max <q,mu> - ||mu||^2/2)
    coarse = (
        prep.ip_q_landmarks
        - 0.5 * index.model.landmark_sq_norms[None, :]
    )
    _, probe = jax.lax.top_k(coarse, nprobe)  # (m, nprobe)
    cand_rows = index.invlists[probe].reshape(m, -1)  # (m, nprobe*L)
    valid = cand_rows >= 0

    def score_one(prep_q, rows_q, valid_q):
        sub = _gather_payload(index.payload, rows_q)
        one = jax.tree_util.tree_map(
            lambda a: a[None] if hasattr(a, "ndim") else a, prep_q
        )
        if index.metric == "dot":
            sc = S.score_dot(index.model, one, sub)[0]
        elif index.metric == "l2":
            sc = -S.score_l2(index.model, one, sub)[0]
        else:
            sc = S.score_cosine(index.model, one, sub)[0]
        return jnp.where(valid_q, sc, NEG_INF)

    scores = jax.vmap(score_one)(prep, cand_rows, valid)  # (m, nprobe*L)
    if rerank and index.raw is not None:
        R = max(rerank, k)
        ss, si = jax.lax.top_k(scores, R)
        rows = jnp.take_along_axis(cand_rows, si, axis=1)
        cand = index.raw[jnp.maximum(rows, 0)].astype(jnp.float32)
        exact = jnp.einsum("md,mrd->mr", prep.q, cand)
        exact = jnp.where(ss > NEG_INF, exact, NEG_INF)
        rs, ri = jax.lax.top_k(exact, k)
        rows_k = jnp.take_along_axis(rows, ri, axis=1)
        return rs, index.ids[jnp.maximum(rows_k, 0)]
    ts, ti = jax.lax.top_k(scores, k)
    rows_k = jnp.take_along_axis(cand_rows, ti, axis=1)
    return ts, index.ids[jnp.maximum(rows_k, 0)]
