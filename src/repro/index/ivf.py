"""Inverted-file (IVF) ASH index.

The ASH landmarks ARE the IVF centroids (Section 2 of the paper): the
coarse quantizer used for residual centering doubles as the routing
structure, so OFFSET/SCALE come for free per list.

JAX needs static shapes, so inverted lists are stored padded to the
longest list; search gathers ``nprobe`` padded lists per query, scores
them with the asymmetric estimator, masks padding, and top-k's.
Queries with fewer than k valid candidates pad results with score
``-inf`` / id ``-1`` (never aliased to row 0).

Entry point is ``repro.index.AshIndex`` with ``backend="ivf"``; the
``_search_prepped`` path lets the serving engine reuse cached
``QueryPrep`` projections.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ash as A
from repro.core import scoring as S
from repro.core.types import (
    ASHConfig, ASHModel, ASHPayload, ASHStats, CoarseCodes, QueryPrep,
    pytree_dataclass,
)
from repro.index import common as C

NEG_INF = C.NEG_INF


@pytree_dataclass(meta_fields=("metric", "max_list_len", "next_id"))
class IVFIndex:
    metric: str
    max_list_len: int
    model: ASHModel  # landmarks == IVF centroids (nlist, D)
    payload: ASHPayload  # rows sorted by list
    ids: jax.Array  # (n,) original ids, sorted by list
    invlists: jax.Array  # (nlist, max_list_len) int32 row indices, -1 pad
    raw: Optional[jax.Array]  # optional bf16 vectors (sorted) for rerank
    # Encode-time row statistics for the fused l2/cos epilogues on the
    # full-probe (dense-scan) path; row-aligned with ``payload``.
    stats: Optional[ASHStats] = None
    # Row-validity bitmap, row-aligned with ``payload``: False rows are
    # tombstoned (deleted).  Dense full-probe scans mask them via the
    # kernel mask operand; partial probes drop them from the candidate
    # lists before the gather kernel DMAs anything.  None = all live.
    live: Optional[jax.Array] = None
    # Meta: id the next added row receives (see effective_next_id).
    next_id: Optional[int] = None
    # Dequantized-code cache for the symmetric int8 coarse first pass,
    # row-aligned with the (list-sorted) ``payload``; derived, rebuilt
    # by ``_assemble`` on every mutation, never persisted.
    coarse: Optional[CoarseCodes] = None


def list_geometry(cluster, nlist: int):
    """Contiguous-list geometry of a (cluster-sorted or unsorted)
    cluster column: ``(counts, starts)``, each (nlist,) int64.  In the
    cluster-sorted row order list ``c`` occupies the contiguous global
    row range ``[starts[c], starts[c] + counts[c])`` — the invariant
    the padded inverted lists AND the host-tiered paged gather
    (``common.plan_paged_probe``) are built on."""
    import numpy as np

    counts = np.bincount(
        np.asarray(cluster), minlength=nlist
    ).astype(np.int64)
    starts = np.concatenate(
        [[0], np.cumsum(counts)[:-1]]
    ).astype(np.int64)
    return counts, starts


def build_invlists(counts, starts, max_len: int):
    """Padded inverted lists from the contiguous geometry: (nlist,
    max_len) int32 global rows, ``-1`` beyond each list's count."""
    import numpy as np

    t = np.arange(max_len, dtype=np.int64)
    rows = starts[:, None] + t[None, :]
    return np.where(
        t[None, :] < counts[:, None], rows, -1
    ).astype(np.int32)


def _assemble(
    metric: str,
    model: ASHModel,
    payload: ASHPayload,
    ids: jax.Array,
    raw: Optional[jax.Array],
    live: Optional[jax.Array] = None,
    next_id: Optional[int] = None,
) -> IVFIndex:
    """Sort rows by cluster and build the padded inverted lists.

    payload/ids/raw/live are row-aligned in any order; ``ids`` holds
    the original (user-facing) id of each row.  Used by build,
    incremental add and compaction — a stable sort keeps add() results
    identical to a from-scratch assembly over the concatenated rows.
    """
    import numpy as np

    cluster = np.asarray(payload.cluster)
    # the -1 pad sentinel (distributed.pad_to_multiple) must never be
    # gathered into inverted lists — it aliases under wrapped indexing
    if cluster.size and cluster.min() < 0:
        raise ValueError(
            "payload contains pad-sentinel cluster ids (-1); assemble "
            "inverted lists from an unpadded payload"
        )
    nlist = model.landmarks.shape[0]
    order = np.argsort(cluster, kind="stable")
    counts, starts = list_geometry(cluster, nlist)
    max_len = int(counts.max())
    invlists = build_invlists(counts, starts, max_len)

    perm = jnp.asarray(order)
    sorted_payload = C.permute_payload(payload, perm)
    return IVFIndex(
        metric=metric,
        max_list_len=max_len,
        model=model,
        payload=sorted_payload,
        ids=jnp.asarray(ids)[perm].astype(jnp.int32),
        invlists=jnp.asarray(invlists),
        raw=None if raw is None else raw[perm],
        stats=S.payload_stats(model, sorted_payload),
        live=None if live is None else jnp.asarray(live)[perm],
        next_id=next_id,
        coarse=S.coarse_codes(sorted_payload),
    )


def _build(
    key: jax.Array,
    X: jax.Array,
    config: ASHConfig,
    *,
    metric: str = "dot",
    keep_raw: bool = False,
    model: Optional[ASHModel] = None,
    train_sample: Optional[int] = None,
    **train_kw,
) -> IVFIndex:
    """nlist = config.n_landmarks."""
    C.validate_metric(metric)
    if model is None:
        model, _ = A.train(
            key, X, config, train_sample=train_sample, **train_kw
        )
    payload = A.encode(model, X)
    raw = X.astype(jnp.bfloat16) if keep_raw else None
    ids = jnp.arange(payload.n, dtype=jnp.int32)
    return _assemble(metric, model, payload, ids, raw)


def _add(index: IVFIndex, X_new: jax.Array) -> IVFIndex:
    """Encode new rows under the existing model and merge them into the
    inverted lists.  New rows get the next ``n_new`` user ids (past any
    retired ones; see ``effective_next_id``)."""
    payload_new = A.encode(index.model, X_new)
    n_new = payload_new.n
    nid = C.effective_next_id(index.next_id, index.ids, index.payload.n)
    ids = jnp.concatenate(
        [index.ids, nid + jnp.arange(n_new, dtype=jnp.int32)]
    )
    live = index.live
    if live is not None:
        live = jnp.concatenate([live, jnp.ones((n_new,), bool)])
    raw = index.raw
    if raw is not None:
        raw = jnp.concatenate(
            [raw, X_new.astype(jnp.bfloat16)], axis=0
        )
    return _assemble(
        index.metric,
        index.model,
        C.concat_payloads(index.payload, payload_new),
        ids,
        raw,
        live=live,
        next_id=None if index.next_id is None else nid + n_new,
    )


def _delete(index: IVFIndex, del_ids) -> tuple[IVFIndex, int]:
    """Tombstone rows by user id: (index, rows newly removed).  The
    inverted lists are untouched — tombstoned rows are dropped from
    gathered candidate lists at search time and masked in full scans —
    so delete never pays the re-sort; :func:`_compact` does."""
    import dataclasses

    new_live, removed = C.mark_deleted(
        index.ids, index.live, del_ids, index.payload.n
    )
    if removed == 0:
        return index, 0
    return dataclasses.replace(index, live=jnp.asarray(new_live)), removed


def _compact(index: IVFIndex) -> IVFIndex:
    """Evict tombstoned rows and rebuild the inverted lists.  Survivors
    keep their relative (stable cluster-sorted) order, so search after
    compaction is bit-identical to a fresh build over the surviving
    rows under the same model."""
    import dataclasses

    import numpy as np

    if index.live is None:
        return index
    live_np = np.asarray(index.live).astype(bool)
    if live_np.all():
        return dataclasses.replace(index, live=None)
    if not live_np.any():
        raise ValueError(
            "compact() would evict every row; an empty index cannot "
            "be searched — keep at least one live row or rebuild"
        )
    nid = C.effective_next_id(index.next_id, index.ids, index.payload.n)
    keep = jnp.asarray(np.nonzero(live_np)[0].astype(np.int32))
    return _assemble(
        index.metric,
        index.model,
        C.gather_payload(index.payload, keep),
        index.ids[keep],
        None if index.raw is None else index.raw[keep],
        next_id=nid,
    )


@jax.jit
def _pad_single(prep: QueryPrep) -> QueryPrep:
    """m=1 -> m=2 by appending an all-zero query row.

    XLA lowers the degenerate single-query batch differently from
    every m >= 2 (last-ulp score drift), which would break the serving
    engine's bit-identity guarantee between per-request and bucketed
    calls.  The pad runs as its OWN jit program — never fused into the
    scoring trace — so the padded call dispatches the exact m=2
    executable real two-query batches use; padding inside the scoring
    trace would compile a third program ("pad then score") that XLA
    again fuses its own way.  (Concatenation is pure data movement, so
    a jitted pad emits bit-identical arrays to an eager one at a
    fraction of the dispatch cost.)"""
    return jax.tree_util.tree_map(
        lambda a: jnp.concatenate([a, jnp.zeros_like(a)], axis=0), prep
    )


def _search_prepped(
    index: IVFIndex,
    prep: QueryPrep,
    k: int = 10,
    nprobe: int = 8,
    rerank: int = 0,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Top-k from precomputed query projections: (scores, ids), (m,k).

    nprobe >= nlist probes every list — coarse routing degenerates to
    an exhaustive scan, so the query skips the gather entirely and runs
    the flat fused-kernel scan over the (list-sorted) payload, mapping
    rows back through ``index.ids``.  Partial probes lower to a
    gathered ``ScanPlan`` served by the masked-gather kernel family
    (batch-shape-invariant rowwise oracle on CPU).  ``coarse="int8"``
    inserts the symmetric int8 first pass on either route (see
    ``common.ScanPlan``)."""
    if nprobe >= index.invlists.shape[0]:
        return _full_scan(
            index, prep, k, rerank, coarse=coarse, shortlist=shortlist
        )
    if prep.q.shape[0] == 1:
        s, i = _score_gathered(
            index, _pad_single(prep), k, nprobe, rerank,
            coarse=coarse, shortlist=shortlist,
        )
        return s[:1], i[:1]
    return _score_gathered(
        index, prep, k, nprobe, rerank,
        coarse=coarse, shortlist=shortlist,
    )


@functools.partial(
    jax.jit,
    static_argnames=("k", "rerank", "use_pallas", "coarse", "shortlist"),
)
def _full_scan(
    index: IVFIndex,
    prep: QueryPrep,
    k: int,
    rerank: int,
    use_pallas: Optional[bool] = None,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Exhaustive fused-kernel scan (the nprobe == nlist case): the
    flat backend's routing ladder (a dense ``common.ScanPlan``) with
    payload rows mapped to user ids via ``index.ids``."""
    plan = C.ScanPlan(
        metric=index.metric, k=k, rerank=rerank, row_valid=index.live,
        ids=index.ids, use_pallas=use_pallas,
        coarse=coarse, shortlist=shortlist,
    )
    return C.execute_plan(
        index.model, prep, index.payload, plan,
        stats=index.stats, raw=index.raw, coarse_cache=index.coarse,
    )


def _probe_lists(
    index: IVFIndex, prep: QueryPrep, nprobe: int
) -> jax.Array:
    """Coarse assignment: the ``nprobe`` nearest centroids per query,
    best-first.  Nearest by L2 == max <q,mu> - ||mu||^2/2, computed
    from the prep's landmark inner products (already materialized for
    residual centering), so exposing it costs one top-k."""
    coarse = (
        prep.ip_q_landmarks
        - 0.5 * index.model.landmark_sq_norms[None, :]
    )
    return jax.lax.top_k(coarse, nprobe)[1]  # (m, nprobe)


@functools.partial(
    jax.jit,
    static_argnames=("k", "nprobe", "rerank", "coarse", "shortlist"),
)
def _score_gathered(
    index: IVFIndex,
    prep: QueryPrep,
    k: int,
    nprobe: int,
    rerank: int,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Partial probes: coarse-route, then score the probed lists."""
    probe = _probe_lists(index, prep, nprobe)
    return _score_probed_impl(
        index, prep, probe, k, rerank,
        coarse=coarse, shortlist=shortlist,
    )


def _search_probed(
    index: IVFIndex,
    prep: QueryPrep,
    probe: jax.Array,
    k: int = 10,
    rerank: int = 0,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Top-k over an explicit probed-list set (budgeted gather).

    ``probe`` is (m, nprobe) int32 list ids per query — callers that
    already hold the coarse assignment (the serving engine's
    candidate-row cost model computes it host-side to plan row
    budgets) skip the in-jit coarse top-k and land on the same
    gathered ``ScanPlan`` lowering as ``_search_prepped``.
    Bit-identical to it when ``probe`` equals the coarse assignment."""
    if prep.q.shape[0] == 1:
        # mirror _search_prepped's eager m=1 -> 2 padding (see
        # _pad_single); the pad row's probe must be the zero-query's
        # coarse assignment — not an arbitrary filler — for the padded
        # batch to match _search_prepped's bit-for-bit
        prep = _pad_single(prep)
        pad_probe = _probe_lists(index, prep, probe.shape[1])[1:]
        probe = jnp.concatenate([probe, pad_probe], axis=0)
        s, i = _score_probed(
            index, prep, probe, k, rerank,
            coarse=coarse, shortlist=shortlist,
        )
        return s[:1], i[:1]
    return _score_probed(
        index, prep, probe, k, rerank,
        coarse=coarse, shortlist=shortlist,
    )


@functools.partial(
    jax.jit, static_argnames=("k", "rerank", "coarse", "shortlist")
)
def _score_probed(
    index: IVFIndex,
    prep: QueryPrep,
    probe: jax.Array,
    k: int = 10,
    rerank: int = 0,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Jit entry over :func:`_score_probed_impl` for explicit probes."""
    return _score_probed_impl(
        index, prep, probe, k, rerank,
        coarse=coarse, shortlist=shortlist,
    )


def _score_probed_impl(
    index: IVFIndex,
    prep: QueryPrep,
    probe: jax.Array,
    k: int,
    rerank: int,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Gather each query's candidate lists and lower to a gathered
    ``ScanPlan`` — the masked-gather kernel family scores straight off
    the packed codes (pad ids mask to ``-inf``) and fuses the
    selection; no (m, nprobe*L) score matrix reaches HBM on TPU."""
    m = prep.q.shape[0]
    cand_rows = index.invlists[probe].reshape(m, -1)  # (m, nprobe*L)
    if index.live is not None:
        # drop tombstoned rows pre-DMA: mapped to the -1 pad id, the
        # gather kernel never issues a copy for them and the epilogue
        # masks the slot to -inf — identical to list padding
        cand_rows = jnp.where(
            index.live[jnp.maximum(cand_rows, 0)], cand_rows, -1
        )
    plan = C.ScanPlan(
        metric=index.metric, k=k, rerank=rerank, rows=cand_rows,
        ids=index.ids, coarse=coarse, shortlist=shortlist,
    )
    return C.execute_plan(
        index.model, prep, index.payload, plan,
        stats=index.stats, raw=index.raw, coarse_cache=index.coarse,
    )


def _search(
    index: IVFIndex,
    queries: jax.Array,
    k: int = 10,
    nprobe: int = 8,
    rerank: int = 0,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Composition of ``prepare_queries`` and :func:`_search_prepped`,
    so engine (prep-cached) and direct paths share compiled arithmetic."""
    prep = S.prepare_queries(index.model, queries)
    return _search_prepped(
        index, prep, k=k, nprobe=nprobe, rerank=rerank,
        coarse=coarse, shortlist=shortlist,
    )
