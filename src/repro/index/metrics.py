"""Ground truth + recall metrics for ANN evaluation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "metric", "block"))
def exact_topk(
    Qm: jax.Array,
    X: jax.Array,
    k: int = 10,
    metric: str = "dot",
    block: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Brute-force exact top-k. Returns (scores, indices) each (m, k).

    metric: "dot" (MIPS), "l2" (returns -distance so that larger=better),
    "cos".
    """
    Q32 = Qm.astype(jnp.float32)
    X32 = X.astype(jnp.float32)
    if metric == "dot":
        s = Q32 @ X32.T
    elif metric == "l2":
        s = -(
            jnp.sum(Q32 * Q32, -1)[:, None]
            - 2 * Q32 @ X32.T
            + jnp.sum(X32 * X32, -1)[None, :]
        )
    elif metric == "cos":
        s = (Q32 @ X32.T) / (
            jnp.linalg.norm(Q32, axis=-1)[:, None]
            * jnp.maximum(jnp.linalg.norm(X32, axis=-1), 1e-12)[None, :]
        )
    else:
        raise ValueError(metric)
    return jax.lax.top_k(s, k)


def recall_at(
    retrieved: jax.Array, ground_truth: jax.Array, k_gt: int = 10
) -> jax.Array:
    """k_gt-recall@R: |retrieved_R  ∩ gt_{k_gt}| / k_gt, averaged over queries.

    retrieved: (m, R) indices; ground_truth: (m, >=k_gt) indices.
    """
    gt = ground_truth[:, :k_gt]
    hit = (retrieved[:, :, None] == gt[:, None, :]).any(axis=1)
    return jnp.mean(jnp.sum(hit, axis=-1) / k_gt)


def recall_curve(retrieved, ground_truth, Rs=(10, 20, 50, 100), k_gt=10):
    """10-recall@R for several R (the paper's accuracy metric)."""
    return {
        R: float(recall_at(retrieved[:, :R], ground_truth, k_gt))
        for R in Rs
        if R <= retrieved.shape[1]
    }
