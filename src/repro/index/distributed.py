"""Distributed (sharded) ASH search over a device mesh.

The database payload is sharded row-wise across every mesh axis; queries
are replicated.  Each shard lowers its local scan to a dense
``common.ScanPlan`` — the same fused metric epilogues and fused local
top-k (or shard-local exact rerank) as the flat backend, with the
per-shard pad-row mask folded into the kernel's id masking — converts
local row ids to global ids, all-gathers the k-per-shard candidates,
and re-top-k's: the classic scatter-gather ANN serving pattern,
expressed with shard_map + jax.lax collectives so XLA can overlap the
local scan with the gather.

The encode-time ``ASHStats`` (fused l2/cos epilogue inputs) and an
optional bf16 raw-vector copy (shard-local exact rerank) are sharded
row-aligned with the payload and threaded through the shard_map
alongside it.

This module is mesh-shape agnostic: it works on the single-host CPU test
mesh and on the (pod, data, model) = (2, 16, 16) production mesh of
launch/mesh.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import scoring as S
from repro.core.types import ASHModel, ASHPayload, ASHStats
from repro.index import common as C

PAD_CLUSTER = -1  # cluster id of pad rows; never a valid landmark


def shard_rows(mesh: Mesh, tree, axes: tuple[str, ...]):
    """Place every array leaf of ``tree`` row-sharded over the given
    mesh axes (remaining dims replicated).  Leaf row counts must divide
    the product of axis sizes."""
    sharding = NamedSharding(mesh, P(axes))
    return jax.tree_util.tree_map(
        lambda a: jax.device_put(a, sharding), tree
    )


def shard_payload(
    mesh: Mesh, payload: ASHPayload, axes: tuple[str, ...]
) -> ASHPayload:
    """Row-shard a payload (see :func:`shard_rows`)."""
    return shard_rows(mesh, payload, axes)


def pad_to_multiple(payload: ASHPayload, multiple: int) -> ASHPayload:
    """Pad rows with sentinel entries so sharding divides evenly.

    Pad rows carry ``scale=0, offset=-inf`` (they never win a top-k)
    and ``cluster=PAD_CLUSTER`` (-1) — a sentinel no real row uses, so
    search paths can derive the valid-row count from the payload itself
    and list assembly can assert the sentinel never reaches a gather
    (``ivf._assemble``; under jit, negative ids would silently alias by
    wrapping).  Scores of pad rows are additionally masked by the
    per-shard ``n_valid`` row mask before any aliased landmark lookup
    can surface.
    """
    n = payload.n
    pad = (-n) % multiple
    if pad == 0:
        return payload
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=jnp.pad(payload.codes, ((0, pad), (0, 0))),
        scale=jnp.pad(payload.scale, (0, pad)),
        offset=jnp.pad(
            payload.offset, (0, pad), constant_values=jnp.finfo(
                payload.offset.dtype
            ).min
        ),
        cluster=jnp.pad(
            payload.cluster, (0, pad), constant_values=PAD_CLUSTER
        ),
    )


def pad_stats(stats: Optional[ASHStats], pad: int) -> Optional[ASHStats]:
    """Zero-pad stats rows to match a padded payload (pad rows are
    masked before their garbage epilogue terms can surface)."""
    if stats is None or pad == 0:
        return stats
    return ASHStats(
        res_norm=jnp.pad(stats.res_norm, (0, pad)),
        ip_x_mu=jnp.pad(stats.ip_x_mu, (0, pad)),
        x_sq=jnp.pad(stats.x_sq, (0, pad)),
    )


def _make_searcher(
    mesh: Mesh,
    model: ASHModel,
    axes: tuple[str, ...],
    k: int,
    *,
    metric: str,
    n_real: int | None,
    from_prep: bool,
    rerank: int = 0,
    fused: bool | None = None,
    coarse: str | None = None,
    shortlist: int | None = None,
):
    C.validate_metric(metric)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    def local_then_merge(payload: ASHPayload, stats, raw, valid, queries):
        # ---- local scan (per shard): one dense ScanPlan ----
        prep = (
            queries if from_prep
            else S.prepare_queries(model, queries)
        )
        n_local = payload.codes.shape[0]
        # global row ids: shard linear index * n_local + local id
        shard_lin = jnp.int32(0)
        mul = 1
        for a in reversed(axes):
            shard_lin = shard_lin + jax.lax.axis_index(a) * mul
            mul *= mesh.shape[a]
        if n_real is None:
            # rows padded by pad_to_multiple carry the -1 cluster
            # sentinel (always contiguous at the end of the last
            # shards), so the valid-row count is derivable per shard —
            # l2/cos callers can no longer forget the mask
            n_valid = jnp.sum(
                (payload.cluster != PAD_CLUSTER).astype(jnp.int32)
            )
        else:
            n_valid = jnp.clip(
                n_real - shard_lin * n_local, 0, n_local
            )
        # a shard can hold fewer rows than k (small indexes, deep
        # meshes): clamp the LOCAL top-k to the shard size — the
        # all-gather still collects n_shards * k_loc >= min(k, n_p)
        # candidates, so the global top-k below is unaffected
        k_loc = min(k, n_local)
        # coarse="int8": the int8 first pass + shortlist runs PER
        # SHARD (each shard keeps its own top-L before refining), so
        # the merged result equals the flat backend's only when every
        # shard's shortlist covers its true top-k_loc.  The value
        # cache is rebuilt inside the shard_map trace (CoarseCodes is
        # derived data; no persisted row-sharded copy yet).
        plan = C.ScanPlan(
            metric=metric, k=k_loc, rerank=rerank, n_valid=n_valid,
            row_valid=valid, use_pallas=fused,
            coarse=coarse, shortlist=shortlist,
        )
        ls, li = C.execute_plan(
            model, prep, payload, plan, stats=stats, raw=raw
        )  # (m, k) fused local top-k (exact scores under rerank)
        gi = li + shard_lin * n_local
        # ---- merge: gather k-per-shard along every sharded axis ----
        for a in axes:
            ls = jax.lax.all_gather(ls, a, axis=1, tiled=True)
            gi = jax.lax.all_gather(gi, a, axis=1, tiled=True)
        fs, fi = jax.lax.top_k(ls, k)
        gids = jnp.take_along_axis(gi, fi, axis=1)
        return fs, jnp.where(jnp.isneginf(fs), -1, gids)

    # pytree prefixes: payload/stats/raw/valid leaves row-sharded,
    # queries replicated (stats/raw/valid may be None — empty pytrees,
    # spec unused)
    specs = dict(
        in_specs=(P(axes), P(axes), P(axes), P(axes), P()),
        out_specs=(P(), P()),
    )
    if hasattr(jax, "shard_map"):  # jax >= 0.6: top-level, check_vma
        fn = jax.shard_map(
            local_then_merge, mesh=mesh, check_vma=False, **specs
        )
    else:
        from jax.experimental.shard_map import shard_map

        fn = shard_map(
            local_then_merge, mesh=mesh, check_rep=False, **specs
        )
    jitted = jax.jit(fn)

    def search(payload, queries, stats=None, raw=None, valid=None):
        if rerank and raw is None:
            # loud, not a silent fall-back to un-reranked ASH scores
            raise ValueError(
                "this searcher was built with rerank > 0; pass raw= "
                "(row-sharded bf16 vectors aligned with the payload)"
            )
        return jitted(payload, stats, raw, valid, queries)

    return search


def make_sharded_search(
    mesh: Mesh,
    model: ASHModel,
    axes: tuple[str, ...],
    k: int = 10,
    *,
    metric: str = "dot",
    n_real: int | None = None,
    rerank: int = 0,
    fused: bool | None = None,
    coarse: str | None = None,
    shortlist: int | None = None,
):
    """Build a jitted (payload, queries) -> (scores, global_ids) searcher.

    ``axes``: mesh axes the database rows are sharded over (e.g.
    ("pod", "data", "model") shards over all 512 devices).

    The searcher also accepts ``stats=`` (row-sharded ``ASHStats``, so
    the fused l2/cos epilogues skip the per-call stats rebuild),
    ``raw=`` (row-sharded bf16 vectors enabling shard-local exact
    rerank when ``rerank > 0``) and ``valid=`` (a row-sharded bool
    validity bitmap — tombstoned rows score ``-inf`` / id -1 via the
    kernels' runtime mask operand, no recompile per mutation), all
    aligned with the padded payload.

    ``n_real``: rows beyond this global index are padding (from
    :func:`pad_to_multiple`) and are masked to score ``-inf`` / id -1.
    Optional override — by default the mask is derived per shard from
    the pad rows' ``cluster == -1`` sentinel, for every metric.

    ``fused``: None = auto (Pallas kernels on TPU, the
    identical-semantics jnp oracle on CPU); False = the retained
    pure-jnp reference scorers + materialize-then-``top_k`` (the
    bit-identity oracle for the fused local scan).

    ``coarse``/``shortlist``: opt into the symmetric int8 first pass
    on each shard's local scan (see ``common.ScanPlan``); the
    shortlist is per shard.
    """
    return _make_searcher(
        mesh, model, axes, k, metric=metric, n_real=n_real,
        from_prep=False, rerank=rerank, fused=fused,
        coarse=coarse, shortlist=shortlist,
    )


def make_sharded_search_prepped(
    mesh: Mesh,
    model: ASHModel,
    axes: tuple[str, ...],
    k: int = 10,
    *,
    metric: str = "dot",
    n_real: int | None = None,
    rerank: int = 0,
    fused: bool | None = None,
    coarse: str | None = None,
    shortlist: int | None = None,
):
    """Like :func:`make_sharded_search` but takes a precomputed
    ``QueryPrep`` (replicated) instead of raw queries, so the
    QUERY-COMPUTE projections run once on the host instead of
    redundantly on every shard — and so the serving engine's prep cache
    can feed this backend too."""
    return _make_searcher(
        mesh, model, axes, k, metric=metric, n_real=n_real,
        from_prep=True, rerank=rerank, fused=fused,
        coarse=coarse, shortlist=shortlist,
    )
