"""Distributed (sharded) ASH search over a device mesh.

The database payload is sharded row-wise across every mesh axis; queries
are replicated.  Each shard computes local asymmetric scores + a local
top-k, converts local row ids to global ids, all-gathers the k-per-shard
candidates, and re-top-k's — the classic scatter-gather ANN serving
pattern, here expressed with shard_map + jax.lax collectives so XLA can
overlap the local scan with the gather.

This module is mesh-shape agnostic: it works on the single-host CPU test
mesh and on the (pod, data, model) = (2, 16, 16) production mesh of
launch/mesh.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import scoring as S
from repro.core.types import ASHModel, ASHPayload
from repro.index import common as C


def shard_payload(
    mesh: Mesh, payload: ASHPayload, axes: tuple[str, ...]
) -> ASHPayload:
    """Place payload row-sharded over the given mesh axes (replicated on
    the rest).  Rows must divide the product of axis sizes."""
    spec = P(axes)
    put = lambda a: jax.device_put(a, NamedSharding(mesh, spec))
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=put(payload.codes),
        scale=put(payload.scale),
        offset=put(payload.offset),
        cluster=put(payload.cluster),
    )


def pad_to_multiple(payload: ASHPayload, multiple: int) -> ASHPayload:
    """Pad rows with sentinel entries (scale=0, offset=-inf) so sharding
    divides evenly; sentinels never win a top-k."""
    n = payload.n
    pad = (-n) % multiple
    if pad == 0:
        return payload
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=jnp.pad(payload.codes, ((0, pad), (0, 0))),
        scale=jnp.pad(payload.scale, (0, pad)),
        offset=jnp.pad(
            payload.offset, (0, pad), constant_values=jnp.finfo(
                payload.offset.dtype
            ).min
        ),
        cluster=jnp.pad(payload.cluster, (0, pad)),
    )


def _make_searcher(
    mesh: Mesh,
    model: ASHModel,
    axes: tuple[str, ...],
    k: int,
    *,
    metric: str,
    n_real: int | None,
    from_prep: bool,
):
    C.validate_metric(metric)
    if metric != "dot" and n_real is None:
        raise ValueError(
            "n_real is required for metric != 'dot': the l2/cos "
            "estimators don't respect the pad sentinel"
        )
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    def local_then_merge(payload: ASHPayload, queries):
        # ---- local scan (per shard) ----
        prep = (
            queries if from_prep
            else S.prepare_queries(model, queries)
        )
        local_scores = C.approx_scores(
            model, prep, payload, metric
        )  # (m, n_local)
        n_local = payload.codes.shape[0]
        # global row ids: shard linear index * n_local + local id
        shard_lin = jnp.int32(0)
        mul = 1
        for a in reversed(axes):
            shard_lin = shard_lin + jax.lax.axis_index(a) * mul
            mul *= mesh.shape[a]
        if n_real is not None:
            gid = shard_lin * n_local + jnp.arange(n_local)
            local_scores = jnp.where(
                (gid < n_real)[None, :], local_scores, C.NEG_INF
            )
        ls, li = jax.lax.top_k(local_scores, k)  # (m, k)
        gi = li + shard_lin * n_local
        # ---- merge: gather k-per-shard along every sharded axis ----
        for a in axes:
            ls = jax.lax.all_gather(ls, a, axis=1, tiled=True)
            gi = jax.lax.all_gather(gi, a, axis=1, tiled=True)
        fs, fi = jax.lax.top_k(ls, k)
        gids = jnp.take_along_axis(gi, fi, axis=1)
        return fs, jnp.where(jnp.isneginf(fs), -1, gids)

    # pytree prefix: all payload leaves row-sharded
    specs = dict(in_specs=(P(axes), P()), out_specs=(P(), P()))
    if hasattr(jax, "shard_map"):  # jax >= 0.6: top-level, check_vma
        fn = jax.shard_map(
            local_then_merge, mesh=mesh, check_vma=False, **specs
        )
    else:
        from jax.experimental.shard_map import shard_map

        fn = shard_map(
            local_then_merge, mesh=mesh, check_rep=False, **specs
        )
    return jax.jit(fn)


def make_sharded_search(
    mesh: Mesh,
    model: ASHModel,
    axes: tuple[str, ...],
    k: int = 10,
    *,
    metric: str = "dot",
    n_real: int | None = None,
):
    """Build a jitted (payload, queries) -> (scores, global_ids) searcher.

    ``axes``: mesh axes the database rows are sharded over (e.g.
    ("pod", "data", "model") shards over all 512 devices).

    ``n_real``: rows beyond this global index are padding (from
    :func:`pad_to_multiple`) and are masked to score ``-inf`` / id -1.
    Required for ``metric != "dot"`` — the l2/cos estimators don't
    respect the dot-only ``offset=-inf`` pad sentinel.
    """
    return _make_searcher(
        mesh, model, axes, k, metric=metric, n_real=n_real,
        from_prep=False,
    )


def make_sharded_search_prepped(
    mesh: Mesh,
    model: ASHModel,
    axes: tuple[str, ...],
    k: int = 10,
    *,
    metric: str = "dot",
    n_real: int | None = None,
):
    """Like :func:`make_sharded_search` but takes a precomputed
    ``QueryPrep`` (replicated) instead of raw queries, so the
    QUERY-COMPUTE projections run once on the host instead of
    redundantly on every shard — and so the serving engine's prep cache
    can feed this backend too."""
    return _make_searcher(
        mesh, model, axes, k, metric=metric, n_real=n_real,
        from_prep=True,
    )
