"""Shared machinery for every index backend.

One metric dispatcher, one scan-plan executor and one exact-rerank
pipeline, used by the flat, IVF and sharded backends (and the serving
layer) instead of each re-implementing score selection and shortlist
rerank by hand.

Every backend lowers its search to a :class:`ScanPlan` — a declarative
description of WHAT to score (a dense row range, optionally truncated
by ``n_valid``, or per-query gathered candidate lists via ``rows``)
plus metric / top-k / rerank — and :func:`execute_plan` picks the
kernel: the fused dense scan family for dense plans, the masked-gather
family for gathered plans, with materialize-then-``top_k`` fallbacks
beyond the fused-selection budget.  The fused and fallback routes
return identical results, so the routing boundary is invisible to
callers.

Score convention: **higher is better** for every metric — L2 scores are
negated squared distances.  Invalid candidates carry ``NEG_INF`` scores
and are reported with id ``-1`` (FAISS convention) rather than being
silently aliased to row 0.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import scoring as S
from repro.core.types import (
    ASHModel, ASHPayload, ASHStats, CoarseCodes, QueryPrep,
)

NEG_INF = -jnp.inf
METRICS = ("dot", "l2", "cos")
COARSE_MODES = ("int8",)
_EPS = 1e-12


def validate_metric(metric: str) -> str:
    if metric not in METRICS:
        raise ValueError(
            f"unknown metric {metric!r}; expected one of {METRICS}"
        )
    return metric


# ---------------------------------------------------------------------------
# Approximate (payload) scoring — the single metric dispatcher
# ---------------------------------------------------------------------------


def approx_scores(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    metric: str,
    *,
    use_pallas: Optional[bool] = False,
    stats: Optional[ASHStats] = None,
) -> jax.Array:
    """ASH scores of all payload rows, (m, n), higher-is-better.

    use_pallas: ``False`` → the pure-jnp reference scorers (retained as
    oracles; ``scoring.score_*`` keep a ``rowwise`` mode for
    batch-invariance cross-checks); ``True`` / ``None`` → route EVERY
    metric through the fused kernel family (``None`` = auto: Pallas on
    TPU, the identical-semantics jnp oracle on CPU).  The l2/cos
    epilogues consume the encode-time ``stats``
    (``scoring.payload_stats``); when absent they are rebuilt on the
    fly, which unpacks the database once.
    """
    if use_pallas is False:
        if metric == "dot":
            return S.score_dot(model, prep, payload)
        if metric == "l2":
            return -S.score_l2(model, prep, payload)
        if metric == "cos":
            return S.score_cosine(model, prep, payload)
        raise ValueError(metric)
    validate_metric(metric)
    from repro.kernels import ops as K

    return K.ash_score(
        model, prep, payload, metric=metric, stats=stats,
        use_pallas=use_pallas,
    )


def approx_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    metric: str,
    k: int,
    *,
    use_pallas: Optional[bool] = None,
    stats: Optional[ASHStats] = None,
    n_valid: Any = None,
    row_valid: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused-selection top-k over all payload rows: (scores, rows).

    Equal to ``top_k(approx_scores(..., use_pallas=use_pallas), k)`` —
    but on TPU the (m, n) score matrix never reaches HBM (each kernel
    tile emits a partial top-k̃; see ``kernels.ash_score``).  Callers
    must keep ``k <= fused_topk_limit()`` and ``k <= payload.n``.
    ``n_valid`` (int or traced scalar) masks rows at/beyond it inside
    the scan (sharded pad-row masking); ``row_valid`` ((n,) bool) masks
    tombstoned rows the same way.
    """
    validate_metric(metric)
    from repro.kernels import ops as K

    return K.ash_score_topk(
        model, prep, payload, k, metric=metric, stats=stats,
        use_pallas=use_pallas, n_valid=n_valid, row_valid=row_valid,
    )


def fused_topk_limit() -> int:
    """Largest k the fused-selection path serves (see kernels.ops)."""
    from repro.kernels import ops as K

    return K.FUSED_TOPK_MAX_K


def default_shortlist() -> int:
    """Default coarse-shortlist size L (see kernels.ops, picked by the
    kernel-bench recall sweep)."""
    from repro.kernels import ops as K

    return K.DEFAULT_SHORTLIST


# ---------------------------------------------------------------------------
# ScanPlan — the single scoring path every backend lowers to
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanPlan:
    """Declarative description of one top-k scan.

    WHAT to score:
      * dense (``rows is None``): every payload row, optionally
        truncated by ``n_valid`` (an int or traced scalar; rows
        at/beyond it are padding and score ``-inf`` — the sharded
        backend's per-shard pad masking) and/or filtered by
        ``row_valid`` (a (n,) bool validity bitmap; False rows are
        tombstones and score ``-inf`` — the mutation layer's deletes,
        folded into the same kernel mask operand so no plan variant
        recompiles).
      * gathered (``rows`` = (m, R) int32): query i scores its own
        candidate list ``rows[i]`` (IVF partial probes); pad entries
        carry id -1 and score ``-inf``.  Tombstones must be dropped
        from the candidate lists (mapped to -1) BEFORE planning — the
        gather kernel then never DMAs a deleted row (``row_valid`` on a
        gathered plan is an error, not a silent no-op).

    HOW to select: top-``k`` per query; ``rerank > 0`` retrieves a
    ``max(rerank, k)`` shortlist by ASH scores and re-ranks it with
    exact scores over the ``raw`` vectors handed to
    :func:`execute_plan`.  ``ids`` maps payload rows to user-facing ids
    (IVF stores rows sorted by list).  ``use_pallas``: None = auto
    (Pallas on TPU, the bit-identical-semantics jnp oracle on CPU),
    False = the retained pure-jnp reference scorers.

    FIRST PASS: ``coarse="int8"`` inserts a symmetric int8 coarse scan
    ahead of the asymmetric path — the bulk scan runs integer MXU
    products over per-query-quantized queries, only the top
    ``shortlist`` (L) coarse candidates are rescored asymmetrically
    (then optionally exact-reranked as usual).  ``shortlist=None``
    takes the benchmark-picked default.  Coarse search changes results
    BY DESIGN (the query side is quantized); exception: whenever L
    covers the whole candidate set (L >= n dense / L >= R gathered) the
    coarse stage is skipped outright and results are bit-identical to
    the pure asymmetric plan.  ``shortlist`` without ``coarse`` is an
    error, as is an unknown coarse mode.
    """

    metric: str
    k: int
    rerank: int = 0
    rows: Optional[jax.Array] = None
    n_valid: Any = None
    row_valid: Optional[jax.Array] = None
    ids: Optional[jax.Array] = None
    use_pallas: Optional[bool] = None
    coarse: Optional[str] = None
    shortlist: Optional[int] = None


def _map_ids(rows: jax.Array, ids: Optional[jax.Array]) -> jax.Array:
    """Map payload rows to user-facing ids, preserving the -1
    missing-candidate sentinel (shared tail of every plan route)."""
    if ids is None:
        return rows
    return jnp.where(rows < 0, -1, ids[jnp.maximum(rows, 0)])


def execute_plan(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    plan: ScanPlan,
    *,
    stats: Optional[ASHStats] = None,
    raw: Optional[jax.Array] = None,
    coarse_cache: Optional[CoarseCodes] = None,
) -> tuple[jax.Array, jax.Array]:
    """Lower a :class:`ScanPlan` onto the fused kernel family.

    Returns (scores, ids), each (m, k).  The scan and the selection
    fuse whenever the requested top-k / rerank shortlist fits
    :func:`fused_topk_limit`, falling back to materialize +
    ``lax.top_k`` beyond it — the two return identical results, so the
    routing boundary is invisible to callers.

    ``coarse_cache`` is the backend's persisted :class:`CoarseCodes`
    for coarse plans; when absent it is rebuilt per call (one database
    unpack — backends should pass it, shard-local plans may not).
    """
    validate_metric(plan.metric)
    if plan.coarse is not None and plan.coarse not in COARSE_MODES:
        raise ValueError(
            f"unknown coarse mode {plan.coarse!r}; expected one of "
            f"{COARSE_MODES} (or None)"
        )
    if plan.shortlist is not None and plan.coarse is None:
        raise ValueError(
            "shortlist= sets the coarse first-pass size and requires "
            "coarse='int8'"
        )
    if plan.rows is None:
        return _execute_dense(
            model, prep, payload, plan, stats=stats, raw=raw,
            coarse_cache=coarse_cache,
        )
    if plan.n_valid is not None or plan.row_valid is not None:
        raise ValueError(
            "n_valid/row_valid apply to dense plans only; gathered "
            "plans mask by pad id (drop tombstoned rows to -1 in "
            "`rows` before planning)"
        )
    return _execute_gather(
        model, prep, payload, plan, stats=stats, raw=raw,
        coarse_cache=coarse_cache,
    )


def _execute_dense(model, prep, payload, plan, *, stats, raw,
                   coarse_cache=None):
    """Dense-scan lowering (flat, IVF full probe, sharded local scan)."""
    n = payload.n
    fused = plan.use_pallas is not False
    cap = fused_topk_limit()
    masked = plan.n_valid is not None or plan.row_valid is not None

    if plan.coarse is not None:
        from repro.kernels import ops as K

        want_rerank = bool(plan.rerank) and raw is not None
        refine_k = (
            min(max(plan.rerank, plan.k), n) if want_rerank else plan.k
        )
        L = max(plan.shortlist or default_shortlist(), refine_k)
        if L < n:
            ss, srows = K.coarse_refine_topk(
                model, prep, payload, refine_k, shortlist=L,
                metric=plan.metric, stats=stats, coarse=coarse_cache,
                n_valid=plan.n_valid, row_valid=plan.row_valid,
                use_pallas=plan.use_pallas,
            )
            if want_rerank:
                return exact_rerank(
                    prep, raw, ss, srows, plan.metric, plan.k,
                    ids=plan.ids,
                )
            ss, srows = ss[:, : plan.k], srows[:, : plan.k]
            srows = jnp.where(jnp.isneginf(ss), -1, srows)
            return ss, _map_ids(srows, plan.ids)
        # L >= n: the shortlist covers every row, so the coarse pass
        # cannot change the candidate set — run the pure asymmetric
        # path outright (bit-identical to coarse=None by construction)

    def materialized():
        s = approx_scores(
            model, prep, payload, plan.metric,
            use_pallas=plan.use_pallas, stats=stats,
        )
        if not masked:
            return s
        from repro.kernels import ops as K

        return K.mask_valid_rows(s, plan.n_valid, plan.row_valid)

    if plan.rerank and raw is not None:
        R = min(max(plan.rerank, plan.k), n)
        if fused and R <= cap:
            short_s, short_rows = approx_topk(
                model, prep, payload, plan.metric, R,
                use_pallas=plan.use_pallas, stats=stats,
                n_valid=plan.n_valid, row_valid=plan.row_valid,
            )
        else:
            short_s, short_rows = jax.lax.top_k(materialized(), R)
        return exact_rerank(
            prep, raw, short_s, short_rows, plan.metric, plan.k,
            ids=plan.ids,
        )
    if fused and plan.k <= min(cap, n):
        s, rows = approx_topk(
            model, prep, payload, plan.metric, plan.k,
            use_pallas=plan.use_pallas, stats=stats,
            n_valid=plan.n_valid, row_valid=plan.row_valid,
        )
    else:
        s, rows = jax.lax.top_k(materialized(), plan.k)
    if masked:
        # -inf slots carry route-dependent ids under row masking (the
        # fused kernel emits sentinels, lax.top_k the masked rows);
        # normalize both routes to the repo-wide -1 convention so the
        # routing boundary stays invisible
        rows = jnp.where(jnp.isneginf(s), -1, rows)
    return s, _map_ids(rows, plan.ids)


def _execute_gather(model, prep, payload, plan, *, stats, raw,
                    coarse_cache=None):
    """Gathered-candidate lowering (IVF partial probes)."""
    from repro.kernels import ops as K

    R = plan.rows.shape[1]
    fused = plan.use_pallas is not False
    cap = fused_topk_limit()

    if plan.coarse is not None:
        want_rerank = bool(plan.rerank) and raw is not None
        refine_k = (
            min(max(plan.rerank, plan.k), R) if want_rerank else plan.k
        )
        L = max(plan.shortlist or default_shortlist(), refine_k)
        if L < R:
            ss, srows = K.coarse_refine_gather_topk(
                model, prep, payload, plan.rows, refine_k,
                shortlist=L, metric=plan.metric, stats=stats,
                coarse=coarse_cache, use_pallas=plan.use_pallas,
            )
            if want_rerank:
                return exact_rerank(
                    prep, raw, ss, srows, plan.metric, plan.k,
                    ids=plan.ids,
                )
            ss, srows = ss[:, : plan.k], srows[:, : plan.k]
            return ss, _map_ids(srows, plan.ids)
        # L >= R: shortlist covers the whole candidate list — pure
        # asymmetric gathered path, bit-identical to coarse=None

    def shortlist(size):
        if fused and size <= cap:
            return K.ash_score_gather_topk(
                model, prep, payload, plan.rows, size,
                metric=plan.metric, stats=stats,
                use_pallas=plan.use_pallas,
            )
        sc = K.ash_score_gather(
            model, prep, payload, plan.rows, metric=plan.metric,
            stats=stats, use_pallas=plan.use_pallas,
        )
        s, pos = jax.lax.top_k(sc, size)
        return s, jnp.take_along_axis(plan.rows, pos, axis=1)

    if plan.rerank and raw is not None:
        ss, srows = shortlist(min(max(plan.rerank, plan.k), R))
        return exact_rerank(
            prep, raw, ss, srows, plan.metric, plan.k, ids=plan.ids
        )
    s, rows_out = shortlist(plan.k)
    return s, _map_ids(rows_out, plan.ids)


# ---------------------------------------------------------------------------
# Paged scan planning — the host-tiered ScanPlan variant
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PagedScanPlan:
    """A gathered :class:`ScanPlan` whose candidate rows index a
    device-assembled UNION of probed inverted lists instead of the
    global payload (the host-tiered IVF backend, where codes live in
    host memory per list and only the probed lists are resident).

    Built host-side by :func:`plan_paged_probe` from the probe set and
    the index's contiguous-list geometry.  ``union_lists`` names the
    probed lists in ascending id order; concatenating their row blocks
    in that order reproduces the global (cluster-sorted) row order
    restricted to the union, so ``rows`` — global candidate rows
    remapped through a monotone shift into the union — preserves the
    candidate ORDER of the HBM-resident gathered plan exactly:
    per-candidate scoring arithmetic, top-k tie resolution and id
    mapping all come out bitwise identical.  ``n_pad - n_union``
    zero rows pad the union to a bounded set of trace shapes; they are
    never gathered (every ``rows`` entry is a real row or ``-1``).
    """

    metric: str
    k: int
    rerank: int
    coarse: Optional[str]
    shortlist: Optional[int]
    rows: Any  # (m, nprobe * max_list_len) int32 numpy, union-local
    union_lists: tuple  # ascending probed list ids
    n_union: int  # real rows in the union
    n_pad: int  # union rows after padding (multiple of pad_multiple)

    def to_scan_plan(self, rows, ids) -> ScanPlan:
        """Lower onto the gathered :class:`ScanPlan` executor; ``rows``
        is the device copy of ``self.rows``, ``ids`` the union's
        user-id column."""
        return ScanPlan(
            metric=self.metric, k=self.k, rerank=self.rerank,
            rows=rows, ids=ids, coarse=self.coarse,
            shortlist=self.shortlist,
        )


def plan_paged_probe(
    probe,
    counts,
    starts,
    live,
    max_list_len: int,
    *,
    metric: str,
    k: int,
    rerank: int = 0,
    coarse: Optional[str] = None,
    shortlist: Optional[int] = None,
    pad_multiple: int = 256,
) -> PagedScanPlan:
    """Plan a paged gathered scan over a probe set, host-side.

    ``probe`` is (m, nprobe) int32 probed list ids per query (any
    order, duplicates allowed); ``counts``/``starts`` the contiguous
    list geometry (:func:`repro.index.ivf.list_geometry`); ``live`` an
    optional (n,) row-validity bitmap — tombstoned rows are dropped to
    the ``-1`` pad id here, pre-DMA, exactly like the HBM gathered
    path.  The candidate layout matches ``invlists[probe]`` slot for
    slot (list-id probe order, each list's tail padded with ``-1``),
    with global rows shifted into the ascending-list union.
    """
    import numpy as np

    probe = np.asarray(probe)
    m = probe.shape[0]
    counts = np.asarray(counts, dtype=np.int64)
    starts = np.asarray(starts, dtype=np.int64)
    union = np.unique(probe.ravel())
    union = union[(union >= 0) & (union < counts.size)]
    c_u = counts[union]
    local_starts = np.concatenate(
        [[0], np.cumsum(c_u)[:-1]]
    ).astype(np.int64)
    n_union = int(c_u.sum())
    # per-list shift mapping a global row of list c into the union
    delta = np.zeros(counts.size, dtype=np.int64)
    delta[union] = local_starts - starts[union]
    t = np.arange(max_list_len, dtype=np.int64)
    g = starts[probe][:, :, None] + t[None, None, :]  # global rows
    valid = t[None, None, :] < counts[probe][:, :, None]
    if live is not None:
        live = np.asarray(live).astype(bool)
        valid &= live[np.minimum(g, max(live.size - 1, 0))]
    loc = g + delta[probe][:, :, None]
    cand = np.where(valid, loc, -1).reshape(m, -1).astype(np.int32)
    n_pad = max(
        pad_multiple, -(-n_union // pad_multiple) * pad_multiple
    )
    return PagedScanPlan(
        metric=metric, k=k, rerank=rerank, coarse=coarse,
        shortlist=shortlist, rows=cand,
        union_lists=tuple(int(c) for c in union),
        n_union=n_union, n_pad=n_pad,
    )


# ---------------------------------------------------------------------------
# Exact scoring + the shared rerank pipeline
# ---------------------------------------------------------------------------


def exact_scores(
    prep: QueryPrep, cand: jax.Array, metric: str
) -> jax.Array:
    """Metric-aware exact scores of raw candidates.

    cand: (m, R, D) candidate vectors per query.  Returns (m, R),
    higher-is-better (same convention as :func:`approx_scores`).

    The inner products use a broadcast-multiply + last-axis reduce
    rather than a batched matmul: XLA's batched-dot lowering varies
    with m, and rerank scores must be bit-identical whether a query is
    served alone or inside an engine bucket.
    """
    ip = jnp.sum(prep.q[:, None, :] * cand, axis=-1)
    if metric == "dot":
        return ip
    if metric == "l2":
        return -(
            prep.q_sq_norm[:, None]
            - 2.0 * ip
            + jnp.sum(cand * cand, axis=-1)
        )
    if metric == "cos":
        q_norm = jnp.sqrt(jnp.maximum(prep.q_sq_norm, _EPS))[:, None]
        c_norm = jnp.maximum(
            jnp.sqrt(jnp.sum(cand * cand, axis=-1)), _EPS
        )
        return ip / (q_norm * c_norm)
    raise ValueError(metric)


def exact_rerank(
    prep: QueryPrep,
    raw: jax.Array,
    shortlist_scores: jax.Array,
    shortlist_rows: jax.Array,
    metric: str,
    k: int,
    ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Re-rank a shortlist with exact scores on the raw vectors.

    shortlist_scores/rows: (m, R) approximate scores and row indices
    into ``raw``; invalid entries must carry ``NEG_INF`` scores (their
    rows may be ``-1``).  ``ids`` optionally maps raw rows to returned
    ids (IVF stores rows sorted by list).  Returns (scores, ids) each
    (m, k); entries without a valid candidate get score ``NEG_INF`` and
    id ``-1``.
    """
    cand = raw[jnp.maximum(shortlist_rows, 0)].astype(jnp.float32)
    exact = exact_scores(prep, cand, metric)
    exact = jnp.where(jnp.isneginf(shortlist_scores), NEG_INF, exact)
    rs, ri = jax.lax.top_k(exact, k)
    rows_k = jnp.take_along_axis(shortlist_rows, ri, axis=1)
    out = rows_k if ids is None else ids[jnp.maximum(rows_k, 0)]
    return rs, jnp.where(jnp.isneginf(rs), -1, out)


# ---------------------------------------------------------------------------
# Payload manipulation shared by backends
# ---------------------------------------------------------------------------


def gather_payload(payload: ASHPayload, rows: jax.Array) -> ASHPayload:
    """Gather payload rows (any leading batch shape); -1 rows read row 0
    (callers mask them by score).  Serving no longer routes through
    payload gathers — gathered plans feed the masked-gather kernels —
    but the rowwise reference path (tests, benchmarks) still scores
    per-query sub-payloads built with this."""
    safe = jnp.maximum(rows, 0)
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=payload.codes[safe],
        scale=payload.scale[safe],
        offset=payload.offset[safe],
        cluster=payload.cluster[safe],
    )


def take_stats(
    stats: Optional[ASHStats], rows: jax.Array
) -> Optional[ASHStats]:
    """Gather stats rows (compaction: survivors keep their encode-time
    statistics bit-identically instead of being recomputed)."""
    if stats is None:
        return None
    return ASHStats(
        res_norm=stats.res_norm[rows],
        ip_x_mu=stats.ip_x_mu[rows],
        x_sq=stats.x_sq[rows],
    )


# ---------------------------------------------------------------------------
# Tombstone (delete) bookkeeping shared by backends
# ---------------------------------------------------------------------------


def effective_next_id(next_id, ids, n: int) -> int:
    """The user-facing id the next added row receives.

    ``next_id`` (persisted once mutations happen) wins; otherwise it is
    derived — identity-id states (``ids is None``) continue at ``n``,
    and explicit id arrays at ``max(ids) + 1`` (equal to ``n`` for any
    pre-mutation save, so old snapshots keep their add() semantics).
    Ids are never reused: a deleted-and-compacted id stays retired.
    """
    if next_id is not None:
        return int(next_id)
    if ids is None or n == 0:
        return int(n)
    import numpy as np

    return int(np.asarray(ids).max()) + 1


def mark_deleted(
    ids: Optional[jax.Array],
    live: Optional[jax.Array],
    del_ids,
    n: int,
) -> tuple[Any, int]:
    """Tombstone payload rows by user id: (new live bitmap (n,) bool
    numpy, rows newly removed).

    ``ids`` maps payload rows to user ids (None = identity); ``live``
    is the current bitmap (None = all live).  Ids that don't exist or
    are already tombstoned are ignored (FAISS ``remove_ids``
    semantics), so the removed count is the true live-row delta.
    """
    import numpy as np

    del_ids = np.unique(np.asarray(del_ids).reshape(-1).astype(np.int64))
    row_ids = (
        np.arange(n, dtype=np.int64) if ids is None
        else np.asarray(ids).astype(np.int64)
    )
    hit = np.isin(row_ids, del_ids)
    if live is not None:
        old = np.asarray(live).astype(bool)
        hit &= old  # only count rows that were still live
        new_live = old & ~hit
    else:
        new_live = ~hit
    return new_live, int(hit.sum())


def concat_stats(
    a: Optional[ASHStats], b: Optional[ASHStats]
) -> Optional[ASHStats]:
    """Row-concatenate two stats blocks (None if either side is
    missing — callers then rebuild via ``scoring.payload_stats``)."""
    if a is None or b is None:
        return None
    return ASHStats(
        res_norm=jnp.concatenate([a.res_norm, b.res_norm], axis=0),
        ip_x_mu=jnp.concatenate([a.ip_x_mu, b.ip_x_mu], axis=0),
        x_sq=jnp.concatenate([a.x_sq, b.x_sq], axis=0),
    )


def concat_payloads(a: ASHPayload, b: ASHPayload) -> ASHPayload:
    """Row-concatenate two payloads encoded under the same model."""
    if (a.b, a.d) != (b.b, b.d):
        raise ValueError(
            f"payload mismatch: (b={a.b}, d={a.d}) vs (b={b.b}, d={b.d})"
        )
    return ASHPayload(
        b=a.b,
        d=a.d,
        codes=jnp.concatenate([a.codes, b.codes], axis=0),
        scale=jnp.concatenate([a.scale, b.scale], axis=0),
        offset=jnp.concatenate([a.offset, b.offset], axis=0),
        cluster=jnp.concatenate([a.cluster, b.cluster], axis=0),
    )


def permute_payload(payload: ASHPayload, perm: jax.Array) -> ASHPayload:
    """Reorder payload rows by ``perm`` (a permutation of arange(n))."""
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=payload.codes[perm],
        scale=payload.scale[perm],
        offset=payload.offset[perm],
        cluster=payload.cluster[perm],
    )
