"""Shared machinery for every index backend.

One metric dispatcher and one exact-rerank pipeline, used by the flat,
IVF and sharded backends (and the serving layer) instead of each
re-implementing score selection and shortlist rerank by hand.

Score convention: **higher is better** for every metric — L2 scores are
negated squared distances.  Invalid candidates carry ``NEG_INF`` scores
and are reported with id ``-1`` (FAISS convention) rather than being
silently aliased to row 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import scoring as S
from repro.core.types import ASHModel, ASHPayload, ASHStats, QueryPrep

NEG_INF = -jnp.inf
METRICS = ("dot", "l2", "cos")
_EPS = 1e-12


def validate_metric(metric: str) -> str:
    if metric not in METRICS:
        raise ValueError(
            f"unknown metric {metric!r}; expected one of {METRICS}"
        )
    return metric


# ---------------------------------------------------------------------------
# Approximate (payload) scoring — the single metric dispatcher
# ---------------------------------------------------------------------------


def approx_scores(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    metric: str,
    *,
    use_pallas: Optional[bool] = False,
    rowwise: bool = False,
    stats: Optional[ASHStats] = None,
) -> jax.Array:
    """ASH scores of all payload rows, (m, n), higher-is-better.

    use_pallas: ``False`` → the pure-jnp reference scorers; ``True`` /
    ``None`` → route EVERY metric through the fused kernel family
    (``None`` = auto: Pallas on TPU, the identical-semantics jnp oracle
    on CPU).  The l2/cos epilogues consume the encode-time ``stats``
    (``scoring.payload_stats``); when absent they are rebuilt on the
    fly, which unpacks the database once.

    rowwise: batch-size-invariant reduction order for the DOT-PROD term
    (see ``scoring.score_dot``) — required on gathered/vmapped candidate
    sets so scores stay bit-identical across serving batch shapes;
    incompatible with the fused kernel, so it forces the reference
    scorers regardless of ``use_pallas``.
    """
    if use_pallas is False or rowwise:
        if metric == "dot":
            return S.score_dot(model, prep, payload, rowwise=rowwise)
        if metric == "l2":
            return -S.score_l2(model, prep, payload, rowwise=rowwise)
        if metric == "cos":
            return S.score_cosine(model, prep, payload, rowwise=rowwise)
        raise ValueError(metric)
    validate_metric(metric)
    from repro.kernels import ops as K

    return K.ash_score(
        model, prep, payload, metric=metric, stats=stats,
        use_pallas=use_pallas,
    )


def approx_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    metric: str,
    k: int,
    *,
    use_pallas: Optional[bool] = None,
    stats: Optional[ASHStats] = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused-selection top-k over all payload rows: (scores, rows).

    Equal to ``top_k(approx_scores(..., use_pallas=use_pallas), k)`` —
    but on TPU the (m, n) score matrix never reaches HBM (each kernel
    tile emits a partial top-k̃; see ``kernels.ash_score``).  Callers
    must keep ``k <= fused_topk_limit()`` and ``k <= payload.n``.
    """
    validate_metric(metric)
    from repro.kernels import ops as K

    return K.ash_score_topk(
        model, prep, payload, k, metric=metric, stats=stats,
        use_pallas=use_pallas,
    )


def fused_topk_limit() -> int:
    """Largest k the fused-selection path serves (see kernels.ops)."""
    from repro.kernels import ops as K

    return K.FUSED_TOPK_MAX_K


def scan_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    metric: str,
    k: int,
    *,
    rerank: int = 0,
    raw: Optional[jax.Array] = None,
    stats: Optional[ASHStats] = None,
    use_pallas: Optional[bool] = None,
    ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Dense-scan top-k routing shared by the flat backend and the IVF
    full-probe (nprobe == nlist) path.

    Fuses the scan with on-chip selection whenever the requested top-k
    or rerank shortlist fits :func:`fused_topk_limit`, falling back to
    materialize + ``lax.top_k`` beyond it — the two return identical
    results, so the routing boundary is invisible to callers.  ``raw``
    enables the exact-rerank pipeline; ``ids`` maps payload rows to
    user-facing ids (IVF stores rows sorted by list).
    """
    n = payload.n
    fused = use_pallas is not False
    cap = fused_topk_limit()
    if rerank and raw is not None:
        R = min(max(rerank, k), n)
        if fused and R <= cap:
            short_s, short_rows = approx_topk(
                model, prep, payload, metric, R,
                use_pallas=use_pallas, stats=stats,
            )
        else:
            approx = approx_scores(
                model, prep, payload, metric,
                use_pallas=use_pallas, stats=stats,
            )
            short_s, short_rows = jax.lax.top_k(approx, R)
        return exact_rerank(
            prep, raw, short_s, short_rows, metric, k, ids=ids
        )
    if fused and k <= min(cap, n):
        s, rows = approx_topk(
            model, prep, payload, metric, k,
            use_pallas=use_pallas, stats=stats,
        )
    else:
        approx = approx_scores(
            model, prep, payload, metric,
            use_pallas=use_pallas, stats=stats,
        )
        s, rows = jax.lax.top_k(approx, k)
    if ids is None:
        return s, rows
    return s, jnp.where(rows < 0, -1, ids[jnp.maximum(rows, 0)])


# ---------------------------------------------------------------------------
# Exact scoring + the shared rerank pipeline
# ---------------------------------------------------------------------------


def exact_scores(
    prep: QueryPrep, cand: jax.Array, metric: str
) -> jax.Array:
    """Metric-aware exact scores of raw candidates.

    cand: (m, R, D) candidate vectors per query.  Returns (m, R),
    higher-is-better (same convention as :func:`approx_scores`).

    The inner products use a broadcast-multiply + last-axis reduce
    rather than a batched matmul: XLA's batched-dot lowering varies
    with m, and rerank scores must be bit-identical whether a query is
    served alone or inside an engine bucket.
    """
    ip = jnp.sum(prep.q[:, None, :] * cand, axis=-1)
    if metric == "dot":
        return ip
    if metric == "l2":
        return -(
            prep.q_sq_norm[:, None]
            - 2.0 * ip
            + jnp.sum(cand * cand, axis=-1)
        )
    if metric == "cos":
        q_norm = jnp.sqrt(jnp.maximum(prep.q_sq_norm, _EPS))[:, None]
        c_norm = jnp.maximum(
            jnp.sqrt(jnp.sum(cand * cand, axis=-1)), _EPS
        )
        return ip / (q_norm * c_norm)
    raise ValueError(metric)


def exact_rerank(
    prep: QueryPrep,
    raw: jax.Array,
    shortlist_scores: jax.Array,
    shortlist_rows: jax.Array,
    metric: str,
    k: int,
    ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Re-rank a shortlist with exact scores on the raw vectors.

    shortlist_scores/rows: (m, R) approximate scores and row indices
    into ``raw``; invalid entries must carry ``NEG_INF`` scores (their
    rows may be ``-1``).  ``ids`` optionally maps raw rows to returned
    ids (IVF stores rows sorted by list).  Returns (scores, ids) each
    (m, k); entries without a valid candidate get score ``NEG_INF`` and
    id ``-1``.
    """
    cand = raw[jnp.maximum(shortlist_rows, 0)].astype(jnp.float32)
    exact = exact_scores(prep, cand, metric)
    exact = jnp.where(jnp.isneginf(shortlist_scores), NEG_INF, exact)
    rs, ri = jax.lax.top_k(exact, k)
    rows_k = jnp.take_along_axis(shortlist_rows, ri, axis=1)
    out = rows_k if ids is None else ids[jnp.maximum(rows_k, 0)]
    return rs, jnp.where(jnp.isneginf(rs), -1, out)


def masked_topk(
    scores: jax.Array, ids: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Top-k of (m, n) scores; ``NEG_INF`` entries come back as id -1."""
    ts, ti = jax.lax.top_k(scores, k)
    out = jnp.take_along_axis(ids, ti, axis=1)
    return ts, jnp.where(jnp.isneginf(ts), -1, out)


# ---------------------------------------------------------------------------
# Payload manipulation shared by backends
# ---------------------------------------------------------------------------


def gather_payload(payload: ASHPayload, rows: jax.Array) -> ASHPayload:
    """Gather payload rows (any leading batch shape); -1 rows read row 0
    (callers mask them by score)."""
    safe = jnp.maximum(rows, 0)
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=payload.codes[safe],
        scale=payload.scale[safe],
        offset=payload.offset[safe],
        cluster=payload.cluster[safe],
    )


def concat_stats(
    a: Optional[ASHStats], b: Optional[ASHStats]
) -> Optional[ASHStats]:
    """Row-concatenate two stats blocks (None if either side is
    missing — callers then rebuild via ``scoring.payload_stats``)."""
    if a is None or b is None:
        return None
    return ASHStats(
        res_norm=jnp.concatenate([a.res_norm, b.res_norm], axis=0),
        ip_x_mu=jnp.concatenate([a.ip_x_mu, b.ip_x_mu], axis=0),
        x_sq=jnp.concatenate([a.x_sq, b.x_sq], axis=0),
    )


def concat_payloads(a: ASHPayload, b: ASHPayload) -> ASHPayload:
    """Row-concatenate two payloads encoded under the same model."""
    if (a.b, a.d) != (b.b, b.d):
        raise ValueError(
            f"payload mismatch: (b={a.b}, d={a.d}) vs (b={b.b}, d={b.d})"
        )
    return ASHPayload(
        b=a.b,
        d=a.d,
        codes=jnp.concatenate([a.codes, b.codes], axis=0),
        scale=jnp.concatenate([a.scale, b.scale], axis=0),
        offset=jnp.concatenate([a.offset, b.offset], axis=0),
        cluster=jnp.concatenate([a.cluster, b.cluster], axis=0),
    )


def permute_payload(payload: ASHPayload, perm: jax.Array) -> ASHPayload:
    """Reorder payload rows by ``perm`` (a permutation of arange(n))."""
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=payload.codes[perm],
        scale=payload.scale[perm],
        offset=payload.offset[perm],
        cluster=payload.cluster[perm],
    )
