"""Shared machinery for every index backend.

One metric dispatcher and one exact-rerank pipeline, used by the flat,
IVF and sharded backends (and the serving layer) instead of each
re-implementing score selection and shortlist rerank by hand.

Score convention: **higher is better** for every metric — L2 scores are
negated squared distances.  Invalid candidates carry ``NEG_INF`` scores
and are reported with id ``-1`` (FAISS convention) rather than being
silently aliased to row 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import scoring as S
from repro.core.types import ASHModel, ASHPayload, QueryPrep

NEG_INF = -jnp.inf
METRICS = ("dot", "l2", "cos")
_EPS = 1e-12


def validate_metric(metric: str) -> str:
    if metric not in METRICS:
        raise ValueError(
            f"unknown metric {metric!r}; expected one of {METRICS}"
        )
    return metric


# ---------------------------------------------------------------------------
# Approximate (payload) scoring — the single metric dispatcher
# ---------------------------------------------------------------------------


def approx_scores(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    metric: str,
    *,
    use_pallas: Optional[bool] = False,
    rowwise: bool = False,
) -> jax.Array:
    """ASH scores of all payload rows, (m, n), higher-is-better.

    use_pallas: ``False`` → the pure-jnp reference scorers; ``True`` /
    ``None`` → route the dot path through the fused kernel (``None`` =
    auto: Pallas on TPU, oracle on CPU).  Only ``metric="dot"`` has a
    fused kernel; other metrics always use the reference path.

    rowwise: batch-size-invariant reduction order for the DOT-PROD term
    (see ``scoring.score_dot``) — required on gathered/vmapped candidate
    sets so scores stay bit-identical across serving batch shapes;
    incompatible with the fused kernel.
    """
    if metric == "dot":
        if use_pallas is False or rowwise:
            return S.score_dot(model, prep, payload, rowwise=rowwise)
        from repro.kernels import ops as K

        return K.ash_score(model, prep, payload, use_pallas=use_pallas)
    if metric == "l2":
        return -S.score_l2(model, prep, payload, rowwise=rowwise)
    if metric == "cos":
        return S.score_cosine(model, prep, payload, rowwise=rowwise)
    raise ValueError(metric)


# ---------------------------------------------------------------------------
# Exact scoring + the shared rerank pipeline
# ---------------------------------------------------------------------------


def exact_scores(
    prep: QueryPrep, cand: jax.Array, metric: str
) -> jax.Array:
    """Metric-aware exact scores of raw candidates.

    cand: (m, R, D) candidate vectors per query.  Returns (m, R),
    higher-is-better (same convention as :func:`approx_scores`).

    The inner products use a broadcast-multiply + last-axis reduce
    rather than a batched matmul: XLA's batched-dot lowering varies
    with m, and rerank scores must be bit-identical whether a query is
    served alone or inside an engine bucket.
    """
    ip = jnp.sum(prep.q[:, None, :] * cand, axis=-1)
    if metric == "dot":
        return ip
    if metric == "l2":
        return -(
            prep.q_sq_norm[:, None]
            - 2.0 * ip
            + jnp.sum(cand * cand, axis=-1)
        )
    if metric == "cos":
        q_norm = jnp.sqrt(jnp.maximum(prep.q_sq_norm, _EPS))[:, None]
        c_norm = jnp.maximum(
            jnp.sqrt(jnp.sum(cand * cand, axis=-1)), _EPS
        )
        return ip / (q_norm * c_norm)
    raise ValueError(metric)


def exact_rerank(
    prep: QueryPrep,
    raw: jax.Array,
    shortlist_scores: jax.Array,
    shortlist_rows: jax.Array,
    metric: str,
    k: int,
    ids: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Re-rank a shortlist with exact scores on the raw vectors.

    shortlist_scores/rows: (m, R) approximate scores and row indices
    into ``raw``; invalid entries must carry ``NEG_INF`` scores (their
    rows may be ``-1``).  ``ids`` optionally maps raw rows to returned
    ids (IVF stores rows sorted by list).  Returns (scores, ids) each
    (m, k); entries without a valid candidate get score ``NEG_INF`` and
    id ``-1``.
    """
    cand = raw[jnp.maximum(shortlist_rows, 0)].astype(jnp.float32)
    exact = exact_scores(prep, cand, metric)
    exact = jnp.where(jnp.isneginf(shortlist_scores), NEG_INF, exact)
    rs, ri = jax.lax.top_k(exact, k)
    rows_k = jnp.take_along_axis(shortlist_rows, ri, axis=1)
    out = rows_k if ids is None else ids[jnp.maximum(rows_k, 0)]
    return rs, jnp.where(jnp.isneginf(rs), -1, out)


def masked_topk(
    scores: jax.Array, ids: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Top-k of (m, n) scores; ``NEG_INF`` entries come back as id -1."""
    ts, ti = jax.lax.top_k(scores, k)
    out = jnp.take_along_axis(ids, ti, axis=1)
    return ts, jnp.where(jnp.isneginf(ts), -1, out)


# ---------------------------------------------------------------------------
# Payload manipulation shared by backends
# ---------------------------------------------------------------------------


def gather_payload(payload: ASHPayload, rows: jax.Array) -> ASHPayload:
    """Gather payload rows (any leading batch shape); -1 rows read row 0
    (callers mask them by score)."""
    safe = jnp.maximum(rows, 0)
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=payload.codes[safe],
        scale=payload.scale[safe],
        offset=payload.offset[safe],
        cluster=payload.cluster[safe],
    )


def concat_payloads(a: ASHPayload, b: ASHPayload) -> ASHPayload:
    """Row-concatenate two payloads encoded under the same model."""
    if (a.b, a.d) != (b.b, b.d):
        raise ValueError(
            f"payload mismatch: (b={a.b}, d={a.d}) vs (b={b.b}, d={b.d})"
        )
    return ASHPayload(
        b=a.b,
        d=a.d,
        codes=jnp.concatenate([a.codes, b.codes], axis=0),
        scale=jnp.concatenate([a.scale, b.scale], axis=0),
        offset=jnp.concatenate([a.offset, b.offset], axis=0),
        cluster=jnp.concatenate([a.cluster, b.cluster], axis=0),
    )


def permute_payload(payload: ASHPayload, perm: jax.Array) -> ASHPayload:
    """Reorder payload rows by ``perm`` (a permutation of arange(n))."""
    return ASHPayload(
        b=payload.b,
        d=payload.d,
        codes=payload.codes[perm],
        scale=payload.scale[perm],
        offset=payload.offset[perm],
        cluster=payload.cluster[perm],
    )
