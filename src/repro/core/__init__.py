"""ASH core: the paper's contribution as a composable JAX module."""
from repro.core.types import (
    ASHConfig, ASHModel, ASHPayload, ASHStats, CoarseCodes,
    CoarseQueryPrep, QueryPrep,
)
from repro.core import quantization
from repro.core import learning
from repro.core import ash
from repro.core import scoring
from repro.core.ash import train, encode, decode, random_model
from repro.core.scoring import (
    coarse_codes,
    payload_stats,
    prepare_coarse_queries,
    prepare_queries,
    score_dot,
    score_dot_1bit,
    score_l2,
    score_cosine,
    score_symmetric_dot,
)

__all__ = [
    "ASHConfig", "ASHModel", "ASHPayload", "ASHStats", "CoarseCodes",
    "CoarseQueryPrep", "QueryPrep",
    "quantization", "learning", "ash", "scoring",
    "train", "encode", "decode", "random_model",
    "coarse_codes", "payload_stats", "prepare_coarse_queries",
    "prepare_queries", "score_dot", "score_dot_1bit",
    "score_l2", "score_cosine", "score_symmetric_dot",
]
