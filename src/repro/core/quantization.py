"""Scalar quantization onto the odd-integer grid V_b (Eq. 4/7 of the paper).

V_b = {2c - 2^b + 1 | c = 0..2^b-1} = {-(2^b-1), ..., -3, -1, 1, 3, ..., 2^b-1}

``quant_b(u) = argmax_{v in V_b^d} cosSim(v, u)`` is solved EXACTLY by a
sorted breakpoint sweep: as a scale t grows from 0+, the grid-rounded
vector v(t) (with |v_j| = 2*floor(t*|u_j|/2) + 1 clipped to 2^b-1) changes
one coordinate magnitude at a time at breakpoints t = 2m/|u_j|
(m = 1..2^(b-1)-1).  Every candidate maximizer of cosSim is one of those
K = d*(2^(b-1)-1) states, so we sort the breakpoints, sweep with running
<v,u> and ||v||^2 (cumsums), and pick the best state.  O(K log K), exact.

A cheaper ``quant_grid`` fast path evaluates a fixed set of candidate
scales; it is used inside very large encode jobs for b >= 8 and validated
against the exact sweep in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_EPS = 1e-30


def grid_values(b: int) -> jnp.ndarray:
    """The 2^b odd-integer grid values of V_b."""
    c = jnp.arange(2**b, dtype=jnp.int32)
    return 2 * c - (2**b - 1)


def levels_to_values(levels: jax.Array, b: int) -> jax.Array:
    """uint levels in [0, 2^b) -> grid values in V_b (int32)."""
    return (2 * levels.astype(jnp.int32) - (2**b - 1)).astype(jnp.int32)


def values_to_levels(values: jax.Array, b: int) -> jax.Array:
    """grid values in V_b -> uint levels in [0, 2^b)."""
    return ((values.astype(jnp.int32) + (2**b - 1)) // 2).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# Exact quantizer (breakpoint sweep)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("b",))
def quant_exact(u: jax.Array, b: int) -> jax.Array:
    """Exact quant_b for a batch of vectors.

    Args:
      u: (..., d) real vectors (any scale; cosSim is scale-invariant).
      b: bits per dimension.

    Returns:
      (..., d) int32 values in V_b maximizing cosSim with u.
    """
    if b == 1:
        return jnp.where(u >= 0, 1, -1).astype(jnp.int32)

    def one(uv):
        d = uv.shape[0]
        a = jnp.abs(uv)
        sgn = jnp.where(uv >= 0, 1, -1).astype(jnp.int32)
        n_bp = 2 ** (b - 1) - 1  # breakpoints per dimension
        m = jnp.arange(1, n_bp + 1, dtype=jnp.float32)  # (n_bp,)
        # t_{j,m} = 2m / a_j ; dims with a_j ~ 0 never upgrade.
        t = (2.0 * m[None, :]) / jnp.maximum(a[:, None], _EPS)  # (d, n_bp)
        dS1 = jnp.broadcast_to(2.0 * a[:, None], t.shape)
        dS2 = jnp.broadcast_to(8.0 * m[None, :], t.shape)
        t_flat = t.reshape(-1)
        order = jnp.argsort(t_flat)
        S1 = jnp.cumsum(dS1.reshape(-1)[order]) + jnp.sum(a)
        S2 = jnp.cumsum(dS2.reshape(-1)[order]) + d
        # state 0 = all-ones vector
        obj0 = jnp.sum(a) / jnp.sqrt(jnp.float32(d))
        obj = jnp.concatenate([obj0[None], S1 / jnp.sqrt(S2)])
        k_star = jnp.argmax(obj)  # number of breakpoints taken
        # rank of each flat breakpoint in the sorted order
        ranks = jnp.argsort(order)
        taken = (ranks < k_star).reshape(d, n_bp)
        mag = 1 + 2 * jnp.sum(taken.astype(jnp.int32), axis=1)
        return sgn * mag

    batch_shape = u.shape[:-1]
    flat = u.reshape((-1, u.shape[-1]))
    out = jax.vmap(one)(flat)
    return out.reshape(batch_shape + (u.shape[-1],))


# ---------------------------------------------------------------------------
# Fast-path quantizer (candidate-scale grid)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("b", "n_scales"))
def quant_grid(u: jax.Array, b: int, n_scales: int = 64) -> jax.Array:
    """Approximate quant_b via a log-spaced candidate-scale search.

    For each candidate t, v(t)_j = round-to-grid(t * u_j); pick the t whose
    v maximizes cosSim(v, u).  With ~64 scales this is within float
    round-off of the exact sweep in practice (validated in tests).
    """
    if b == 1:
        return jnp.where(u >= 0, 1, -1).astype(jnp.int32)

    gmax = 2**b - 1

    def one(uv):
        a = jnp.abs(uv)
        a_max = jnp.maximum(jnp.max(a), _EPS)
        # Breakpoints live at t = 2m/a_j, m <= 2^(b-1)-1: the scan must
        # reach the largest breakpoint of the smallest *relevant*
        # coordinate or small-|u_j| dims can never upgrade past mag 1.
        # Near-zero dims are ignored (their breakpoints sit at absurd
        # scales and contribute ~nothing to cosSim).
        a_min = jnp.min(jnp.where(a > 1e-4 * a_max, a, a_max))
        lo = 0.5 / a_max
        hi = (gmax + 1.0) / jnp.maximum(a_min, _EPS)
        ts = jnp.logspace(jnp.log10(lo), jnp.log10(hi), n_scales)
        def eval_t(t):
            scaled = uv * t
            mag = jnp.clip(
                2 * jnp.floor(jnp.abs(scaled) / 2.0) + 1, 1, gmax
            )
            v = jnp.where(uv >= 0, mag, -mag)
            num = jnp.sum(v * uv)
            den = jnp.sqrt(jnp.sum(v * v))
            return num / jnp.maximum(den, _EPS), v
        objs, vs = jax.vmap(eval_t)(ts)
        best = jnp.argmax(objs)
        return vs[best].astype(jnp.int32)

    batch_shape = u.shape[:-1]
    flat = u.reshape((-1, u.shape[-1]))
    out = jax.vmap(one)(flat)
    return out.reshape(batch_shape + (u.shape[-1],))


def quant(u: jax.Array, b: int, exact: bool = True) -> jax.Array:
    """quant_b dispatcher. Exact sweep for b <= 6, grid search beyond."""
    if b == 1:
        return quant_exact(u, 1)
    if exact and b <= 6:
        return quant_exact(u, b)
    return quant_grid(u, b)


# ---------------------------------------------------------------------------
# Bit packing (payload layout)
# ---------------------------------------------------------------------------


def codes_per_word(b: int) -> int:
    assert b in (1, 2, 4, 8, 16, 32), f"unsupported bitrate {b}"
    return 32 // b


def packed_width(d: int, b: int) -> int:
    k = codes_per_word(b)
    return (d + k - 1) // k


def pack_codes(values: jax.Array, b: int) -> jax.Array:
    """Pack grid values (..., d) int32 -> (..., ceil(d/k)) uint32 words.

    Little-endian within a word: code j of a group occupies bits
    [j*b, (j+1)*b).  Stored as unsigned *levels* (value+2^b-1)/2.
    """
    levels = values_to_levels(values, b)
    k = codes_per_word(b)
    d = levels.shape[-1]
    n_words = packed_width(d, b)
    pad = n_words * k - d
    if pad:
        levels = jnp.pad(
            levels, [(0, 0)] * (levels.ndim - 1) + [(0, pad)]
        )
    grouped = levels.reshape(levels.shape[:-1] + (n_words, k))
    shifts = (jnp.arange(k, dtype=jnp.uint32) * b).astype(jnp.uint32)
    # Non-overlapping bit fields: bitwise-or == sum.
    words = jnp.sum(
        grouped.astype(jnp.uint32) << shifts, axis=-1, dtype=jnp.uint32
    )
    return words


def unpack_codes(words: jax.Array, d: int, b: int) -> jax.Array:
    """Inverse of pack_codes -> (..., d) int32 grid values."""
    k = codes_per_word(b)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * b).astype(jnp.uint32)
    mask = jnp.uint32(2**b - 1)
    grouped = (words[..., None] >> shifts) & mask  # (..., n_words, k)
    levels = grouped.reshape(words.shape[:-1] + (-1,))[..., :d]
    return levels_to_values(levels, b)


def code_norms(values: jax.Array) -> jax.Array:
    """||v||_2 per vector for grid-valued codes (..., d)."""
    v = values.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(v * v, axis=-1))
