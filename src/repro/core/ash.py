"""ASH encoder/decoder and end-to-end training (Sections 2-3).

Encoder  g(x):  c* = nearest landmark; x~ = (x-mu*)/||x-mu*||;
                v = quant_b(W x~);  payload = (codes, SCALE, OFFSET, c*).
Decoder  f(v):  x^ = ||x-mu*|| * ||v||^-1 W^T v + mu*.

The SCALE/OFFSET headers are exactly Eq. (20):
  SCALE  = ||v||^-1 ||x - mu*||
  OFFSET = <x, mu*> - SCALE * <W mu*, v> - ||mu*||^2
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import learning as L
from repro.core import quantization as Q
from repro.core.types import ASHConfig, ASHModel, ASHPayload

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Training (Section 3)
# ---------------------------------------------------------------------------


def train(
    key: jax.Array,
    X: jax.Array,
    config: ASHConfig,
    *,
    train_sample: Optional[int] = None,
    landmark_sample: Optional[int] = None,
    max_iters: int = 25,
    use_newton_schulz: bool = False,
    kmeans_iters: int = 25,
) -> tuple[ASHModel, list[float]]:
    """Learn landmarks + W = R P from data.

    Follows the paper: W is learned on a subsample of ~10*D vectors
    (10x oversampling of the covariance), PCA init for P,
    random-rotation init for R, <= 25 alternation iterations with early
    stopping.  The landmark k-means runs on the full set by default
    (``landmark_sample`` caps it for very large corpora) — landmark
    quality bounds the residual norms every downstream bit quantizes,
    and Lloyd iterations are cheap relative to encoding.
    """
    n, D = X.shape
    d = config.d if config.d > 0 else D
    assert d <= D, f"target dim {d} exceeds input dim {D}"
    config = ASHConfig(
        b=config.b, d=d, n_landmarks=config.n_landmarks,
        store_fp16=config.store_fp16,
    )
    k_sub, k_lm, k_km, k_rot = jax.random.split(key, 4)

    # Subsample BEFORE casting so a capped run on a huge low-precision
    # corpus never materializes a full fp32 copy.
    X32 = None  # full fp32 view, created lazily
    if landmark_sample is not None and landmark_sample < n:
        idx_lm = jax.random.choice(
            k_lm, n, shape=(landmark_sample,), replace=False
        )
        X_lm = X[idx_lm].astype(jnp.float32)
    else:
        X32 = X.astype(jnp.float32)
        X_lm = X32
    centroids, _ = L.kmeans(
        k_km, X_lm, config.n_landmarks, iters=kmeans_iters
    )

    if train_sample is None:
        # 10x covariance oversampling per the paper, but never
        # subsample tiny corpora — the cap exists to bound training
        # cost, and below ~4k rows there is no cost to bound.
        train_sample = min(n, max(10 * D, 4096))
    if train_sample < n:
        idx = jax.random.choice(
            k_sub, n, shape=(train_sample,), replace=False
        )
        Xt = X[idx].astype(jnp.float32)
    else:
        Xt = X32 if X32 is not None else X.astype(jnp.float32)
    x_tilde, _, _ = L.normalized_residuals(Xt, centroids)
    P = L.pca_topd(x_tilde, d)  # (d, D)
    Z = x_tilde @ P.T  # (n_t, d)
    R, history = L.learn_rotation(
        k_rot, Z, config.b,
        max_iters=max_iters, use_newton_schulz=use_newton_schulz,
    )
    W = (R @ P).astype(jnp.float32)  # (d, D), row-orthonormal
    model = ASHModel(
        config=config,
        W=W,
        landmarks=centroids,
        W_landmarks=centroids @ W.T,
        landmark_sq_norms=jnp.sum(centroids * centroids, axis=-1),
        bias_rho=jnp.float32(1.0),
        bias_beta=jnp.float32(0.0),
    )
    return model, history


def random_model(
    key: jax.Array, D: int, config: ASHConfig, X_for_landmarks=None
) -> ASHModel:
    """Data-agnostic ASH: W = random row-orthonormal (JL baseline; also the
    RaBitQ regime when d == D and C == 1)."""
    d = config.d if config.d > 0 else D
    config = ASHConfig(
        b=config.b, d=d, n_landmarks=config.n_landmarks,
        store_fp16=config.store_fp16,
    )
    k_w, k_km = jax.random.split(key)
    g = jax.random.normal(k_w, (D, D), dtype=jnp.float32)
    qmat, _ = jnp.linalg.qr(g)
    W = qmat[:, :d].T  # (d, D) rows orthonormal
    if X_for_landmarks is not None and config.n_landmarks > 1:
        centroids, _ = L.kmeans(
            k_km, X_for_landmarks.astype(jnp.float32), config.n_landmarks
        )
    elif X_for_landmarks is not None:
        centroids = jnp.mean(
            X_for_landmarks.astype(jnp.float32), axis=0, keepdims=True
        )
    else:
        centroids = jnp.zeros((config.n_landmarks, D), jnp.float32)
    return ASHModel(
        config=config,
        W=W,
        landmarks=centroids,
        W_landmarks=centroids @ W.T,
        landmark_sq_norms=jnp.sum(centroids * centroids, axis=-1),
        bias_rho=jnp.float32(1.0),
        bias_beta=jnp.float32(0.0),
    )


# ---------------------------------------------------------------------------
# Encode / decode
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("exact",))
def encode(model: ASHModel, X: jax.Array, exact: bool = True) -> ASHPayload:
    """Encode database vectors into the ASH payload (Table 1)."""
    cfg = model.config
    X32 = X.astype(jnp.float32)
    x_tilde, res_norm, assign = L.normalized_residuals(X32, model.landmarks)
    U = x_tilde @ model.W.T  # (n, d)
    V = Q.quant(U, cfg.b, exact=exact)  # (n, d) int32 grid values
    vnorm = jnp.maximum(Q.code_norms(V), _EPS)
    scale = res_norm / vnorm
    ip_x_mu = jnp.sum(X32 * model.landmarks[assign], axis=-1)
    ip_Wmu_v = jnp.sum(
        model.W_landmarks[assign] * V.astype(jnp.float32), axis=-1
    )
    offset = (
        ip_x_mu - scale * ip_Wmu_v - model.landmark_sq_norms[assign]
    )
    # IEEE fp16 (10-bit mantissa), matching Table 1's 16-bit header;
    # bf16 would cost ~3 bits of SCALE/OFFSET precision.  Clip into the
    # fp16-finite range so extreme-norm corpora degrade in precision
    # instead of overflowing to inf (which would poison every score of
    # the affected rows).
    hdr_dtype = jnp.float16 if cfg.store_fp16 else jnp.float32
    if cfg.store_fp16:
        lim = float(jnp.finfo(jnp.float16).max)
        scale = jnp.clip(scale, 0.0, lim)
        offset = jnp.clip(offset, -lim, lim)
    return ASHPayload(
        b=cfg.b,
        d=cfg.d,
        codes=Q.pack_codes(V, cfg.b),
        scale=scale.astype(hdr_dtype),
        offset=offset.astype(hdr_dtype),
        cluster=assign,
    )


@jax.jit
def decode(model: ASHModel, payload: ASHPayload) -> jax.Array:
    """Reconstruct x^ = ||x-mu*|| ||v||^-1 W^T v + mu* from the payload.

    ||x-mu*|| is recovered as SCALE * ||v||; this is the full (lossy)
    inverse of encode.
    """
    V = Q.unpack_codes(payload.codes, payload.d, payload.b).astype(
        jnp.float32
    )
    x_tilde_hat = (V / jnp.maximum(Q.code_norms(V), _EPS)[:, None]) @ model.W
    res_norm = payload.scale.astype(jnp.float32) * Q.code_norms(V)
    return res_norm[:, None] * x_tilde_hat + model.landmarks[payload.cluster]


def reconstruction_error(model: ASHModel, X: jax.Array) -> jax.Array:
    """Mean squared reconstruction error of the *normalized residuals*
    (Eq. 5/14) — the quantity the learning minimizes."""
    X32 = X.astype(jnp.float32)
    x_tilde, _, _ = L.normalized_residuals(X32, model.landmarks)
    U = x_tilde @ model.W.T
    V = Q.quant(U, model.config.b).astype(jnp.float32)
    vnorm = jnp.maximum(Q.code_norms(V), _EPS)
    x_hat = (V / vnorm[:, None]) @ model.W
    return jnp.mean(jnp.sum((x_tilde - x_hat) ** 2, axis=-1))
