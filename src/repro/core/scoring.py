"""Similarity computations from ASH payloads.

Implements the asymmetric dot product (Eq. 20), the 1-bit masked-add
specialization (Eq. 22), Euclidean distance and cosine similarity
(Appendix A), and the symmetric case (Appendix B).  These are the pure-jnp
reference paths; the Pallas fused kernels in ``repro.kernels`` are bit-for
-bit validated against them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quantization as Q
from repro.core.types import (
    ASHModel, ASHPayload, ASHStats, CoarseCodes, CoarseQueryPrep,
    QueryPrep,
)

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Per-query precompute (QUERY-COMPUTE of Eq. 20)
# ---------------------------------------------------------------------------


@jax.jit
def prepare_queries(model: ASHModel, q: jax.Array) -> QueryPrep:
    """One-time per-query work: q_breve = W q, <q, mu_c>, ||q||^2."""
    q32 = q.astype(jnp.float32)
    return QueryPrep(
        q=q32,
        q_proj=q32 @ model.W.T,
        ip_q_landmarks=q32 @ model.landmarks.T,
        q_sq_norm=jnp.sum(q32 * q32, axis=-1),
    )


# ---------------------------------------------------------------------------
# Recoverable Table-1 quantities
# ---------------------------------------------------------------------------


def _recovered_full(model: ASHModel, payload: ASHPayload, V=None):
    """One decompression pass -> every Table-1 recovery, including the
    <W mu*, v> inner products (which several quantities reuse)."""
    if V is None:
        V = Q.unpack_codes(payload.codes, payload.d, payload.b).astype(
            jnp.float32
        )
    vnorm = Q.code_norms(V)
    scale = payload.scale.astype(jnp.float32)
    offset = payload.offset.astype(jnp.float32)
    res_norm = scale * vnorm
    ip_Wmu_v = jnp.sum(model.W_landmarks[payload.cluster] * V, axis=-1)
    ip_x_mu = (
        offset + scale * ip_Wmu_v
        + model.landmark_sq_norms[payload.cluster]
    )
    return V, vnorm, res_norm, ip_x_mu, ip_Wmu_v


def recovered_terms(model: ASHModel, payload: ASHPayload, V=None):
    """Recover (V float, ||v||, ||x-mu*||, <x, mu*>) from the payload.

    ``V`` optionally passes already-unpacked codes so callers that need
    both the recovered terms and the code matrix decompress the payload
    once instead of twice.
    """
    return _recovered_full(model, payload, V)[:4]


def _x_sq_estimate(model, payload, vnorm, res_norm, ip_Wmu_v):
    """||x||^2 estimate of Eq. (A.5) — the single definition shared by
    :func:`payload_stats` (fused cos epilogue) and :func:`score_cosine`
    (reference scorer), so the two can never desynchronize."""
    return (
        res_norm**2
        + 2.0 * (res_norm / jnp.maximum(vnorm, _EPS)) * ip_Wmu_v
        + model.landmark_sq_norms[payload.cluster]
    )


@jax.jit
def payload_stats(model: ASHModel, payload: ASHPayload) -> ASHStats:
    """Build the :class:`ASHStats` row statistics for a payload.

    One decompression pass at encode/build time; afterwards the fused
    l2/cos kernels score straight off the packed codes + these vectors
    (see ``repro.kernels.ops``).  ``x_sq`` is the Eq. (A.5) squared-norm
    estimate used by cosine search — identical to the quantity
    :func:`score_cosine` derives on the fly.
    """
    _, vnorm, res_norm, ip_x_mu, ip_Wmu_v = _recovered_full(model, payload)
    x_sq = _x_sq_estimate(model, payload, vnorm, res_norm, ip_Wmu_v)
    return ASHStats(
        res_norm=res_norm.astype(jnp.float32),
        ip_x_mu=ip_x_mu.astype(jnp.float32),
        x_sq=x_sq.astype(jnp.float32),
    )


# ---------------------------------------------------------------------------
# Symmetric int8 coarse pass (query quantizer + dequantized-code cache)
# ---------------------------------------------------------------------------

# int8 query grid half-width; paired with the |code| <= 255 (b=8) bound
# this keeps every coarse partial sum under 2^24 for d_pad <= 512, so
# fp32 accumulation of the integer products is EXACT — the jnp coarse
# path (one BLAS matmul over CoarseCodes.values) is bitwise equal to
# the Pallas kernel's int32 MXU accumulation.
COARSE_QMAX = 127


def coarse_codes(payload: ASHPayload) -> CoarseCodes:
    """Build the :class:`CoarseCodes` cache for a payload.

    One decompression pass at build/add/compact/load time (like
    :func:`payload_stats`); afterwards the coarse jnp scan is a single
    fp32 BLAS matmul over exact-integer values — no per-call unpack.
    """
    d_pad = payload.codes.shape[1] * Q.codes_per_word(payload.b)
    V = Q.unpack_codes(payload.codes, d_pad, payload.b).astype(
        jnp.float32
    )
    scale = payload.scale.astype(jnp.float32)
    return CoarseCodes(
        values=V, mean=jnp.mean(scale[:, None] * V, axis=0)
    )


@jax.jit
def prepare_coarse_queries(
    prep: QueryPrep, mean: jax.Array
) -> CoarseQueryPrep:
    """Symmetric int8 quantization of the projected queries.

    Per-query scale ``s = max|q_proj| / 127`` (eps-guarded), codes
    ``round(q_proj / s)`` clipped to [-127, 127].  The correction term
    ``q_corr = <q_proj - s * q_int8, mean>`` (``mean`` from
    :func:`coarse_codes`) folds the average residual contribution into
    the Eq. (20) base score, making the coarse score an unbiased
    estimate of the asymmetric score against the corpus mean.
    """
    qp = prep.q_proj.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(qp), axis=-1), _EPS) / COARSE_QMAX
    qi = jnp.clip(
        jnp.round(qp / s[..., None]), -COARSE_QMAX, COARSE_QMAX
    )
    resid = qp - s[..., None] * qi
    # mean is (d_pad,) from the packed-code width; q_proj is (…, d) with
    # d <= d_pad.  A zero-padded residual column contributes nothing, so
    # slicing mean to the query width is exact.
    return CoarseQueryPrep(
        q_int8=qi.astype(jnp.int8),
        q_scale=s,
        q_corr=resid @ mean.astype(jnp.float32)[: qp.shape[-1]],
    )


# ---------------------------------------------------------------------------
# Asymmetric scoring (Eq. 20)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("rowwise",))
def score_dot(
    model: ASHModel, prep: QueryPrep, payload: ASHPayload,
    *, rowwise: bool = False,
) -> jax.Array:
    """<q, x_i> approximation, Eq. (20), for a batch of queries against
    all payload rows.  Returns (n_queries, n_db).

    rowwise=True swaps the dense matmul for a broadcast-multiply +
    last-axis reduce.  Same values up to reduction order — but the
    reduction order no longer depends on the query-batch size, so row i
    is bit-identical whether scored alone or inside any batch.  Used by
    the gathered (IVF) and shortlist paths, where XLA's batched-matmul
    lowering is batch-size dependent; the dense scan keeps the
    MXU-friendly matmul.
    """
    V = Q.unpack_codes(payload.codes, payload.d, payload.b).astype(
        jnp.float32
    )
    return _score_dot_from_V(prep, payload, V, rowwise)


def _score_dot_from_V(
    prep: QueryPrep, payload: ASHPayload, V: jax.Array, rowwise: bool
) -> jax.Array:
    """Eq. (20) from already-unpacked codes — lets the l2/cos reference
    scorers reuse one decompression instead of unpacking twice."""
    if rowwise:
        dot = jnp.sum(prep.q_proj[..., None, :] * V, axis=-1)
    else:
        dot = prep.q_proj @ V.T  # (m, n) — DOT-PROD term (MXU on TPU)
    scale = payload.scale.astype(jnp.float32)[None, :]
    offset = payload.offset.astype(jnp.float32)[None, :]
    query_compute = prep.ip_q_landmarks[..., payload.cluster]  # (m, n)
    return scale * dot + query_compute + offset


@jax.jit
def score_dot_1bit(
    model: ASHModel, prep: QueryPrep, payload: ASHPayload
) -> jax.Array:
    """1-bit masked-add formulation, Eq. (22). Numerically identical to
    score_dot for b == 1 (tested); mirrors the masked-load kernel."""
    assert payload.b == 1
    d = payload.d
    V = Q.unpack_codes(payload.codes, d, 1)
    Bmat = ((V + 1) // 2).astype(jnp.float32)  # bin() in {0,1}
    res_norm = payload.scale.astype(jnp.float32) * jnp.sqrt(
        jnp.float32(d)
    )  # ||v|| = sqrt(d) for b=1
    inv_sqrt_d = 1.0 / jnp.sqrt(jnp.float32(d))
    masked_add = prep.q_proj @ Bmat.T  # (m, n): sum of q_j where bit set
    sum_q = jnp.sum(prep.q_proj, axis=-1, keepdims=True)  # <q, 1>
    scale = 2.0 * inv_sqrt_d * res_norm[None, :]
    query_compute = (
        -inv_sqrt_d * res_norm[None, :] * sum_q
        + prep.ip_q_landmarks[..., payload.cluster]
    )
    ip_Wmu_2b1 = jnp.sum(
        model.W_landmarks[payload.cluster]
        * (2.0 * Bmat - 1.0),
        axis=-1,
    )
    offset_terms = (
        # <x, mu*> recovered
        payload.offset.astype(jnp.float32)
        + payload.scale.astype(jnp.float32)
        * jnp.sqrt(jnp.float32(d))
        * inv_sqrt_d
        * ip_Wmu_2b1
        + model.landmark_sq_norms[payload.cluster]
        # minus d^-1/2 ||x-mu|| <W mu, 2b-1> - ||mu||^2  (Eq. 22 OFFSET)
        - inv_sqrt_d * res_norm * ip_Wmu_2b1
        - model.landmark_sq_norms[payload.cluster]
    )
    return scale * masked_add + query_compute + offset_terms[None, :]


@functools.partial(jax.jit, static_argnames=("rowwise",))
def score_l2(
    model: ASHModel, prep: QueryPrep, payload: ASHPayload,
    *, rowwise: bool = False,
) -> jax.Array:
    """||q - x_i||^2 approximation (Appendix A), (m, n)."""
    V, _, res_norm, ip_x_mu = recovered_terms(model, payload)
    ip_qx = _score_dot_from_V(prep, payload, V, rowwise)
    mu_sq = model.landmark_sq_norms[payload.cluster]  # (n,)
    ip_q_mu = prep.ip_q_landmarks[..., payload.cluster]  # (m, n)
    q_sq_mu = (
        prep.q_sq_norm[..., None] - 2.0 * ip_q_mu + mu_sq[None, :]
    )  # ||q - mu*||^2
    return (
        q_sq_mu
        + (res_norm**2)[None, :]
        - 2.0 * (ip_qx - ip_x_mu[None, :] - ip_q_mu + mu_sq[None, :])
    )


@functools.partial(jax.jit, static_argnames=("rowwise",))
def score_cosine(
    model: ASHModel, prep: QueryPrep, payload: ASHPayload,
    *, rowwise: bool = False,
) -> jax.Array:
    """cosSim(q, x_i) using the norm estimate of Eq. (A.5), (m, n)."""
    V, vnorm, res_norm, _, ip_Wmu_v = _recovered_full(model, payload)
    ip_qx = _score_dot_from_V(prep, payload, V, rowwise)
    x_sq = _x_sq_estimate(model, payload, vnorm, res_norm, ip_Wmu_v)
    x_norm = jnp.sqrt(jnp.maximum(x_sq, _EPS))
    q_norm = jnp.sqrt(jnp.maximum(prep.q_sq_norm, _EPS))
    return ip_qx / (q_norm[..., None] * x_norm[None, :])


# ---------------------------------------------------------------------------
# Symmetric scoring (Appendix B) — for graph-index construction
# ---------------------------------------------------------------------------


@jax.jit
def score_symmetric_dot(
    model: ASHModel, pa: ASHPayload, pb: ASHPayload
) -> jax.Array:
    """<x, y> for two encoded sets (C == 1 assumed per Appendix B).

    (n_a, n_b) matrix; Eq. (B.2) with cosSim(quant(Wx~), quant(Wy~))."""
    Va, va_n, ra_n, ip_a_mu = recovered_terms(model, pa)
    Vb, vb_n, rb_n, ip_b_mu = recovered_terms(model, pb)
    cos = (Va @ Vb.T) / jnp.maximum(
        va_n[:, None] * vb_n[None, :], _EPS
    )
    mu_sq = model.landmark_sq_norms[0]
    return (
        ra_n[:, None] * rb_n[None, :] * cos
        + ip_a_mu[:, None]
        + ip_b_mu[None, :]
        - mu_sq
    )


# ---------------------------------------------------------------------------
# Bias correction (Eq. 34)
# ---------------------------------------------------------------------------


def fit_bias(
    model: ASHModel,
    payload: ASHPayload,
    X: jax.Array,
    queries: jax.Array,
    sample: int = 100,
) -> ASHModel:
    """Least-squares (rho, beta) so that rho*<q,x> + beta ~ <q, x^>.

    Per the paper, a ~100-sample regression; the correction divides the
    estimate by rho (and subtracts beta) for L2-faithful scores.
    """
    qs = queries[:sample].astype(jnp.float32)
    xs = X[:sample].astype(jnp.float32)
    sub = jax.tree_util.tree_map(
        lambda a: a[:sample] if a.ndim >= 1 and a.shape[0] == payload.n else a,
        payload,
    )
    prep = prepare_queries(model, qs)
    est = score_dot(model, prep, sub).reshape(-1)
    true = (qs @ xs.T).reshape(-1)
    A = jnp.stack([true, jnp.ones_like(true)], axis=1)
    coef, *_ = jnp.linalg.lstsq(A, est, rcond=None)
    rho, beta = coef[0], coef[1]
    return ASHModel(
        config=model.config,
        W=model.W,
        landmarks=model.landmarks,
        W_landmarks=model.W_landmarks,
        landmark_sq_norms=model.landmark_sq_norms,
        bias_rho=rho,
        bias_beta=beta,
    )


def debias(model: ASHModel, scores: jax.Array) -> jax.Array:
    """Apply the inverse linear correction to estimated dot products."""
    return (scores - model.bias_beta) / jnp.maximum(model.bias_rho, _EPS)
