"""Learning the ASH parameters (Section 3 of the paper).

W = R @ P:
  * P (d, D): top-d eigenvectors of sum_i x~_i x~_i^T (PCA on normalized
    residuals).
  * R in SO(d): refined by ITQ-style alternation —
      1. v_i <- quant_b(R P x~_i)
      2. R <- argmax_{R in SO(d)} Tr(R M),  M = P (sum_i ||v_i||^-1 x~_i v_i^T)
    Step 2 is an orthogonal Procrustes problem: M = U S V^T  =>  R = V U^T.
    (Derivation: Tr(RM) = Tr(R U S V^T) is maximized over the orthogonal
    group when V^T R U = I.)  The Newton-Schulz polar iteration is an
    SVD-free alternative (the polar factor of M^T equals V U^T).

Landmarks: k-means (kmeans++ seeding + Lloyd), Section 2 / Eq. (13).

Early stopping follows the paper's Section 5 experimental setup: at most
25 iterations, patience 3, absolute loss-improvement threshold 1e-4 and
relative threshold 2.5e-3.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantization as Q

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Orthogonal linear algebra
# ---------------------------------------------------------------------------


def random_rotation(key: jax.Array, d: int) -> jax.Array:
    """R(0): orthogonal polar factor of a standard normal matrix."""
    g = jax.random.normal(key, (d, d), dtype=jnp.float32)
    u, _, vt = jnp.linalg.svd(g, full_matrices=False)
    return u @ vt


def procrustes_svd(M: jax.Array) -> jax.Array:
    """argmax_{R orthogonal} Tr(R M) = V U^T for M = U S V^T."""
    u, _, vt = jnp.linalg.svd(M, full_matrices=False)
    return vt.T @ u.T


def newton_schulz(M: jax.Array, steps: int = 12) -> jax.Array:
    """Polar factor of M^T via the quintic Newton-Schulz iteration.

    Returns the same maximizer as procrustes_svd (up to convergence
    tolerance) without an SVD — the TPU/GPU-friendly path popularized by
    Muon [Jordan et al., 2024], cited by the paper as an alternative.
    """
    X = M.T  # polar(M^T) = U' V'^T with M^T = U' S V'^T == (V U^T) of M
    X = X / (jnp.linalg.norm(X) + _EPS)
    a, b, c = 3.4445, -4.7750, 2.0315  # Muon's quintic coefficients

    def body(_, X):
        A = X @ X.T
        B = b * A + c * (A @ A)
        return a * X + B @ X

    return jax.lax.fori_loop(0, steps, body, X)


def pca_topd(X: jax.Array, d: int) -> jax.Array:
    """Top-d principal directions (rows) of X (n, D): P in St(d, D)."""
    cov = (X.T @ X).astype(jnp.float32)
    eigvals, eigvecs = jnp.linalg.eigh(cov)  # ascending
    P = eigvecs[:, ::-1][:, :d].T  # (d, D)
    return P


# ---------------------------------------------------------------------------
# k-means landmarks
# ---------------------------------------------------------------------------


def _kmeanspp_init(key: jax.Array, X: jax.Array, C: int) -> jax.Array:
    """kmeans++ seeding (D^2 sampling)."""
    n = X.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centroids0 = jnp.zeros((C, X.shape[1]), X.dtype).at[0].set(X[first])
    d2_0 = jnp.sum((X - X[first]) ** 2, axis=-1)

    def body(carry, ki):
        centroids, d2 = carry
        i, k = ki
        p = d2 / jnp.maximum(jnp.sum(d2), _EPS)
        idx = jax.random.choice(k, n, p=p)
        c_new = X[idx]
        centroids = jax.lax.dynamic_update_index_in_dim(
            centroids, c_new, i, axis=0
        )
        d2 = jnp.minimum(d2, jnp.sum((X - c_new) ** 2, axis=-1))
        return (centroids, d2), None

    keys = jax.random.split(key, C - 1) if C > 1 else jnp.zeros((0, 2), jnp.uint32)
    idxs = jnp.arange(1, C)
    (centroids, _), _ = jax.lax.scan(body, (centroids0, d2_0), (idxs, keys))
    return centroids


def assign_clusters(X: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest centroid per row (Eq. 13)."""
    # ||x - mu||^2 = ||x||^2 - 2 <x, mu> + ||mu||^2 ; ||x||^2 constant in mu
    d2 = (
        -2.0 * X @ centroids.T
        + jnp.sum(centroids * centroids, axis=-1)[None, :]
    )
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("C", "iters"))
def kmeans(
    key: jax.Array, X: jax.Array, C: int, iters: int = 25
) -> tuple[jax.Array, jax.Array]:
    """Lloyd's k-means. Returns (centroids (C, D), assignment (n,))."""
    if C == 1:
        mu = jnp.mean(X, axis=0, keepdims=True)
        return mu, jnp.zeros((X.shape[0],), jnp.int32)

    centroids = _kmeanspp_init(key, X, C)

    def body(_, centroids):
        assign = assign_clusters(X, centroids)
        sums = jax.ops.segment_sum(X, assign, num_segments=C)
        counts = jax.ops.segment_sum(
            jnp.ones((X.shape[0],), X.dtype), assign, num_segments=C
        )
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep old centroid for empty clusters
        return jnp.where(counts[:, None] > 0, new, centroids)

    centroids = jax.lax.fori_loop(0, iters, body, centroids)
    return centroids, assign_clusters(X, centroids)


# ---------------------------------------------------------------------------
# Residual normalization (Eq. 12)
# ---------------------------------------------------------------------------


def normalized_residuals(
    X: jax.Array, centroids: jax.Array, assign: Optional[jax.Array] = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x~_i = (x_i - mu*_i) / ||x_i - mu*_i||.

    Returns (x_tilde (n,D), residual_norm (n,), assign (n,)).
    """
    if assign is None:
        assign = assign_clusters(X, centroids)
    resid = X - centroids[assign]
    norms = jnp.linalg.norm(resid, axis=-1)
    x_tilde = resid / jnp.maximum(norms, _EPS)[:, None]
    return x_tilde, norms, assign


# ---------------------------------------------------------------------------
# ITQ-style alternation (Section 3)
# ---------------------------------------------------------------------------


class ITQState(NamedTuple):
    R: jax.Array  # (d, d)
    loss: jax.Array  # scalar: negated objective of Eq. (24), normalized


@functools.partial(jax.jit, static_argnames=("b", "use_newton_schulz"))
def itq_step(
    R: jax.Array,
    Z: jax.Array,  # (n, d) = x~ @ P^T, precomputed once
    *,
    b: int,
    use_newton_schulz: bool = False,
) -> ITQState:
    """One alternation step. Z = P x~ stacked row-wise.

    v_i = quant_b(R z_i);  M = sum_i ||v_i||^-1 z_i v_i^T  (d, d)
    (M here is the paper's P (sum ||v||^-1 x~ v^T) since Z = X~ P^T.)
    """
    U = Z @ R.T  # (n, d) = (R P x~)^T rows
    V = Q.quant(U, b).astype(jnp.float32)
    vnorm = jnp.maximum(jnp.linalg.norm(V, axis=-1), _EPS)
    Vn = V / vnorm[:, None]
    M = Z.T @ Vn  # (d, d)
    R_new = newton_schulz(M) if use_newton_schulz else procrustes_svd(M)
    # Objective (Eq. 24): sum_i ||v_i||^-1 <P x~_i, R^T v_i> = Tr(R M).
    # Normalized per sample; loss = -objective (so smaller is better).
    obj = jnp.trace(R_new @ M) / Z.shape[0]
    return ITQState(R=R_new, loss=-obj)


def learn_rotation(
    key: jax.Array,
    Z: jax.Array,
    b: int,
    *,
    max_iters: int = 25,
    patience: int = 3,
    abs_tol: float = 1e-4,
    rel_tol: float = 2.5e-3,
    use_newton_schulz: bool = False,
) -> tuple[jax.Array, list[float]]:
    """Full alternation with the paper's early-stopping rule.

    Host-side loop (training is offline and tiny: d x d SVDs); each step
    is jitted.  Returns (R, loss_history).
    """
    d = Z.shape[1]
    R = random_rotation(key, d)
    history: list[float] = []
    best = float("inf")
    bad = 0
    for _ in range(max_iters):
        state = itq_step(R, Z, b=b, use_newton_schulz=use_newton_schulz)
        R = state.R
        loss = float(state.loss)
        history.append(loss)
        if best == float("inf"):
            improved = True
        else:
            improved = (best - loss) > max(abs_tol, rel_tol * abs(best))
        if improved:
            best, bad = loss, 0
        else:
            bad += 1
            if bad >= patience:
                break
    return R, history
