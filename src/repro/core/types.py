"""Core datatypes for the ASH library.

Everything is a registered JAX pytree so models/payloads flow through
``jax.jit`` / ``shard_map`` / checkpointing without special casing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


def pytree_dataclass(cls=None, *, meta_fields: tuple = ()):
    """Dataclass registered as a JAX pytree. ``meta_fields`` are static."""

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(
            c, data_fields=list(data_fields), meta_fields=list(meta_fields)
        )
        return c

    if cls is None:
        return wrap
    return wrap(cls)


@pytree_dataclass(meta_fields=("b", "d", "n_landmarks", "store_fp16"))
class ASHConfig:
    """Static configuration of an ASH quantizer.

    Attributes:
      b: bitrate per dimension (1, 2, 4, 8).
      d: target (reduced) dimensionality, d <= D.
      n_landmarks: number of landmark (coarse-quantizer) vectors C.
      store_fp16: downcast per-vector headers (SCALE/OFFSET) to IEEE
        fp16, matching the paper's 16-bit header payload (Table 1).
    """

    b: int = 2
    d: int = 0  # 0 == "same as input D" (resolved at train time)
    n_landmarks: int = 1
    store_fp16: bool = True

    @property
    def grid_max(self) -> int:
        return 2**self.b - 1

    def payload_bits(self, with_log2c: bool = True) -> int:
        """Total bits per encoded vector, per Table 1 of the paper."""
        import math

        header = 2 * 16
        if with_log2c and self.n_landmarks > 1:
            header += math.ceil(math.log2(self.n_landmarks))
        return header + self.b * self.d


@pytree_dataclass(meta_fields=("config",))
class ASHModel:
    """Learned global parameters of an ASH quantizer.

    W = R @ P with P the top-d PCA basis (d, D) and R in SO(d); the
    landmarks are the coarse quantizer centroids (C, D).
    """

    config: ASHConfig
    W: jax.Array  # (d, D) row-orthonormal projection
    landmarks: jax.Array  # (C, D)
    # Pre-computed W @ mu_c for all landmarks (C, d): used by OFFSET and
    # the symmetric path; tiny, stored with the model.
    W_landmarks: jax.Array  # (C, d)
    landmark_sq_norms: jax.Array  # (C,)
    # Optional linear-bias correction (rho, beta) from Eq. (34); identity
    # by default. Only affects L2 search ordering, not MIPS.
    bias_rho: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.float32(1.0)
    )
    bias_beta: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.float32(0.0)
    )

    @property
    def D(self) -> int:
        return self.W.shape[1]

    @property
    def d(self) -> int:
        return self.W.shape[0]


@pytree_dataclass(meta_fields=("b", "d"))
class ASHPayload:
    """Encoded database vectors (the per-vector payload of Table 1).

    codes are bit-packed little-endian into uint32 words,
    ``32 // b`` codes per word. scale/offset are the SCALE / OFFSET
    terms of Eq. (20); cluster is c*_i. The extra fields of Table 1
    (residual norm, <x, mu*>) are *recoverable* from scale/offset:
      ||x - mu*||   = scale * ||v||          (||v|| from codes)
      <x, mu*>      = offset + scale * <W mu*, v> + ||mu*||^2
    so dot/L2/cosine search all run off this payload.
    """

    b: int
    d: int
    codes: jax.Array  # (n, n_words) uint32 bit-packed
    scale: jax.Array  # (n,) fp32 or bf16
    offset: jax.Array  # (n,) fp32 or bf16
    cluster: jax.Array  # (n,) int32

    @property
    def n(self) -> int:
        return self.codes.shape[0]


@pytree_dataclass
class ASHStats:
    """Query-independent per-row payload statistics (Table 1 recoveries).

    Everything the l2/cos scoring epilogues need beyond the payload
    itself, recovered ONCE at encode/build time (from ``<W mu*, v>``)
    instead of re-unpacking the whole database per search call:

      res_norm = ||x - mu*||         = SCALE * ||v||
      ip_x_mu  = <x, mu*>            = OFFSET + SCALE <W mu*, v> + ||mu*||^2
      x_sq     = ||x||^2 estimate    (Eq. A.5, via the cosine-norm identity)

    Rows are aligned with the owning :class:`ASHPayload`; build with
    ``scoring.payload_stats``.  Persisted with the index (save/load is
    bit-identical) so the fused kernels never touch unpacked codes.
    """

    res_norm: jax.Array  # (n,) fp32
    ip_x_mu: jax.Array  # (n,) fp32
    x_sq: jax.Array  # (n,) fp32

    @property
    def n(self) -> int:
        return self.res_norm.shape[0]


@pytree_dataclass
class QueryPrep:
    """Per-query precomputed terms (QUERY-COMPUTE of Eq. (20)).

    Computed once per query; thousands of per-vector scores reuse it.
    """

    q: jax.Array  # (..., D) original query
    q_proj: jax.Array  # (..., d)  q-breve = W q
    ip_q_landmarks: jax.Array  # (..., C) <q, mu_c>
    q_sq_norm: jax.Array  # (...,) ||q||^2  (for L2)


@pytree_dataclass
class CoarseCodes:
    """Pre-dequantized code matrix for the symmetric int8 coarse scan.

    ``values`` holds the payload's grid values as EXACT small integers
    in fp32 (``2*level - (2^b - 1)``, at most +-255) so the coarse jnp
    path runs one BLAS matmul per call with no per-call ``unpack_codes``
    pass — the unpack the asymmetric jnp scan pays every search.  All
    partial sums stay below 2^24, so fp32 accumulation of these integer
    products is exact and bitwise equal to the Pallas kernel's int32
    MXU accumulation.

    ``mean`` is the scale-weighted corpus mean of the dequantized rows,
    ``mean_j(SCALE_j * v_j)`` (d_pad,) — the correction operand that
    makes coarse scores corpus-mean-unbiased estimates of the
    asymmetric score (see ``scoring.prepare_coarse_queries``).

    Derived from the payload (never persisted): rebuilt at build / add
    / compact / load alongside ``ASHStats``.
    """

    values: jax.Array  # (n, d_pad) fp32 exact grid values
    mean: jax.Array  # (d_pad,) fp32 mean_j(scale_j * v_j)

    @property
    def n(self) -> int:
        return self.values.shape[0]


@pytree_dataclass
class CoarseQueryPrep:
    """Per-query int8 symmetric quantization of ``QueryPrep.q_proj``.

    q_int8 = round(q_proj / q_scale) with a per-query symmetric scale
    q_scale = max|q_proj| / 127, so the coarse first pass accumulates
    int8 x int8 dot products on the MXU.  ``q_corr`` is the
    ``ASHStats``-style correction ``<q_proj - q_scale * q_int8,
    mean_j(scale_j * v_j)>`` folded into the Eq. (20) base score so the
    coarse estimate is unbiased against the corpus mean (it cancels the
    average quantization-residual contribution).
    """

    q_int8: jax.Array  # (m, d_pad) int8
    q_scale: jax.Array  # (m,) fp32 per-query symmetric scale
    q_corr: jax.Array  # (m,) fp32 residual correction term
