"""Pallas TPU kernels for the ASH scoring hot paths.

ash_score    — fused unpack + MXU matmul + Eq. (20) epilogue
                (dense scans and masked-gather candidate lists, each
                with fused on-chip top-k selection)
ash_kv_attn  — decode attention over an ASH-compressed KV cache
ref          — pure-jnp oracles (bit-exact semantics)
ops          — public jit'd wrappers with CPU-interpret fallback
"""
from repro.kernels import ref, ops
from repro.kernels.ops import (
    ash_score,
    ash_score_coarse,
    ash_score_coarse_gather,
    ash_score_coarse_topk,
    ash_score_gather,
    ash_score_gather_topk,
    ash_score_topk,
    ash_kv_attention,
    coarse_refine_gather_topk,
    coarse_refine_topk,
)

__all__ = ["ref", "ops", "ash_score", "ash_score_topk",
           "ash_score_coarse", "ash_score_coarse_topk",
           "ash_score_coarse_gather", "ash_score_gather",
           "ash_score_gather_topk", "ash_kv_attention",
           "coarse_refine_topk", "coarse_refine_gather_topk"]
