"""Pallas TPU kernels for the ASH scoring hot paths.

ash_score    — fused unpack + MXU matmul + Eq. (20) epilogue
ash_kv_attn  — decode attention over an ASH-compressed KV cache
ref          — pure-jnp oracles (bit-exact semantics)
ops          — public jit'd wrappers with CPU-interpret fallback
"""
from repro.kernels import ref, ops
from repro.kernels.ops import ash_score, ash_kv_attention

__all__ = ["ref", "ops", "ash_score", "ash_kv_attention"]
