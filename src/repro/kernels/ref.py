"""Pure-jnp oracles for the Pallas kernels.

These define bit-exact semantics; the kernels are validated against them
(interpret mode on CPU, compiled on TPU) across shape/dtype/bit sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantization as Q


def ash_score_ref(
    codes: jax.Array,  # (n, Wd) uint32 packed
    q_proj: jax.Array,  # (m, d_pad) query projections (zero-padded cols)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,) int32
    ip_q_landmarks: jax.Array,  # (m, C)
    b: int,
) -> jax.Array:
    """Asymmetric ASH scores (Eq. 20): (m, n) fp32.

    d is implied by the packed width: d_pad = Wd * (32 // b); q_proj must
    be zero-padded to d_pad so padding lanes contribute nothing.
    """
    d_pad = codes.shape[1] * Q.codes_per_word(b)
    V = Q.unpack_codes(codes, d_pad, b).astype(jnp.float32)
    dot = q_proj.astype(jnp.float32) @ V.T  # (m, n)
    bias = ip_q_landmarks.astype(jnp.float32)[:, cluster]  # (m, n)
    return (
        dot * scale.astype(jnp.float32)[None, :]
        + bias
        + offset.astype(jnp.float32)[None, :]
    )


def ash_score_metric_ref(
    codes: jax.Array,  # (n, Wd) uint32 packed
    q_proj: jax.Array,  # (m, d_pad)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,) int32
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None,  # (m,) metric query term (None for dot)
    rowterm: jax.Array | None,  # (n,) metric row term (None for dot)
    b: int,
    metric: str = "dot",
) -> jax.Array:
    """Metric-epilogue scores, higher-is-better: the oracle for the
    fused kernel family.

    Applies the same epilogue op order as the kernel's
    ``_epilogue_scores`` over the Eq. (20) base score:
      dot: base;  l2: 2*base - qterm - rowterm (== -||q - x||^2);
      cos: base * qterm * rowterm.
    ``qterm``/``rowterm`` come from ``ops._metric_operands``.
    """
    base = ash_score_ref(
        codes, q_proj, scale, offset, cluster, ip_q_landmarks, b
    )
    if metric == "dot":
        return base
    qcol = qterm.astype(jnp.float32)[:, None]
    rrow = rowterm.astype(jnp.float32)[None, :]
    if metric == "l2":
        return (2.0 * base - qcol) - rrow
    if metric == "cos":
        return (base * qcol) * rrow
    raise ValueError(metric)


def mask_rows_ref(
    scores: jax.Array,  # (m, n)
    n_valid: jax.Array | None = None,  # scalar; cols >= it are masked
    row_valid: jax.Array | None = None,  # (n,) bool/int; 0 = masked
) -> jax.Array:
    """Oracle for the dense kernel's row-validity mask operand.

    Forces masked columns to ``-inf``: columns at/beyond ``n_valid``
    (the sharded backend's per-shard pad truncation) and columns whose
    ``row_valid`` entry is falsy (the index layers' tombstone bitmap).
    The fused selection kernel folds the same combined mask into its id
    masking, so materialize-then-``top_k`` and fused selection agree.
    """
    if n_valid is None and row_valid is None:
        return scores
    ok = jnp.ones((scores.shape[-1],), bool)
    if row_valid is not None:
        ok = ok & row_valid.astype(bool)
    if n_valid is not None:
        cols = jnp.arange(scores.shape[-1])
        ok = ok & (cols < n_valid)
    return jnp.where(ok[None, :], scores, -jnp.inf)


def ash_score_gather_ref(
    codes: jax.Array,  # (n, Wd) uint32 packed
    rows: jax.Array,  # (m, R) int32 candidate row ids, -1 = padding
    q_proj: jax.Array,  # (m, d_pad)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,) int32
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None,  # (m,) metric query term (None for dot)
    rowterm: jax.Array | None,  # (n,) metric row term (None for dot)
    b: int,
    metric: str = "dot",
) -> jax.Array:
    """Masked-gather metric scores: (m, R) fp32, higher-is-better — the
    oracle for the masked-gather kernel.

    Query i is scored against its own candidate list ``rows[i]``; pad
    entries (id -1) come back ``-inf``.  The DOT-PROD term is a
    broadcast-multiply + last-axis reduce (not a batched matmul), so row
    i's scores are identical whatever the query-batch size — the
    bit-identity invariant the serving engine's bucketing relies on.
    The epilogue applies the same op order as the dense kernel's
    ``_epilogue_scores`` (the landmark bias has a single non-zero
    one-hot term, so gather and one-hot matmul agree bitwise).
    """
    m, R = rows.shape
    d_pad = codes.shape[1] * Q.codes_per_word(b)
    safe = jnp.maximum(rows, 0)
    V = Q.unpack_codes(
        codes[safe.reshape(-1)], d_pad, b
    ).astype(jnp.float32).reshape(m, R, d_pad)
    dot = jnp.sum(q_proj.astype(jnp.float32)[:, None, :] * V, axis=-1)
    cl = cluster[safe]  # (m, R)
    bias = jnp.take_along_axis(
        ip_q_landmarks.astype(jnp.float32), cl, axis=1
    )
    base = (
        dot * scale.astype(jnp.float32)[safe]
        + bias
        + offset.astype(jnp.float32)[safe]
    )
    if metric == "dot":
        out = base
    elif metric == "l2":
        qcol = qterm.astype(jnp.float32)[:, None]
        out = (2.0 * base - qcol) - rowterm.astype(jnp.float32)[safe]
    elif metric == "cos":
        qcol = qterm.astype(jnp.float32)[:, None]
        out = (base * qcol) * rowterm.astype(jnp.float32)[safe]
    else:
        raise ValueError(metric)
    return jnp.where(rows >= 0, out, -jnp.inf)


def _coarse_base(dot_int, q_scale, q_corr, scale, offset, bias):
    """Shared Eq. (20) base for the coarse oracles — the exact op order
    the coarse kernel's epilogue mirrors.  ``dot_int`` is the integer
    int8 x code accumulation (exact in fp32: every partial sum of the
    integer products stays below 2^24 for d_pad <= 512)."""
    dotc = dot_int.astype(jnp.float32) * q_scale.astype(jnp.float32)[
        ..., None
    ]
    biasq = bias + q_corr.astype(jnp.float32)[..., None]
    return (
        dotc * scale.astype(jnp.float32)
        + biasq
        + offset.astype(jnp.float32)
    )


def ash_score_coarse_ref(
    codes: jax.Array,  # (n, Wd) uint32 packed
    q_int8: jax.Array,  # (m, d_pad) int8 quantized query projections
    q_scale: jax.Array,  # (m,) per-query symmetric scale
    q_corr: jax.Array,  # (m,) residual correction term
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,) int32
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None,  # (m,) metric query term (None for dot)
    rowterm: jax.Array | None,  # (n,) metric row term (None for dot)
    b: int,
    metric: str = "dot",
    values: jax.Array | None = None,  # (n, d_pad) pre-dequantized codes
) -> jax.Array:
    """Symmetric int8 coarse scores: (m, n) fp32, higher-is-better —
    the oracle for ``ash_score_coarse[_topk]_pallas``.

    The DOT-PROD term is the integer accumulation
    ``<q_int8, v>`` scaled back by the per-query ``q_scale``; the
    correction ``q_corr`` rides the bias so the coarse score is an
    unbiased (corpus-mean) estimate of the asymmetric Eq. (20) score.
    Integer accumulation is order-invariant and exact in fp32 below
    2^24, so this matmul is BITWISE equal to the kernel's int32 MXU
    accumulation — and to the ``values``-cache fast path (pass
    ``CoarseCodes.values`` to skip the unpack).  The metric epilogues
    apply the same op order as ``ash_score_metric_ref`` over the coarse
    base.
    """
    if values is None:
        d_pad = codes.shape[1] * Q.codes_per_word(b)
        values = Q.unpack_codes(codes, d_pad, b).astype(jnp.float32)
    dot = q_int8.astype(jnp.float32) @ values.T  # (m, n) exact ints
    bias = ip_q_landmarks.astype(jnp.float32)[:, cluster]
    base = _coarse_base(
        dot, q_scale, q_corr, scale[None, :], offset[None, :], bias
    )
    if metric == "dot":
        return base
    qcol = qterm.astype(jnp.float32)[:, None]
    rrow = rowterm.astype(jnp.float32)[None, :]
    if metric == "l2":
        return (2.0 * base - qcol) - rrow
    if metric == "cos":
        return (base * qcol) * rrow
    raise ValueError(metric)


def ash_score_coarse_gather_ref(
    codes: jax.Array,  # (n, Wd) uint32 packed
    rows: jax.Array,  # (m, R) int32 candidate row ids, -1 = padding
    q_int8: jax.Array,  # (m, d_pad) int8
    q_scale: jax.Array,  # (m,)
    q_corr: jax.Array,  # (m,)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,) int32
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None,
    rowterm: jax.Array | None,
    b: int,
    metric: str = "dot",
    values: jax.Array | None = None,  # (n, d_pad) pre-dequantized codes
) -> jax.Array:
    """Coarse scores over per-query candidate lists: (m, R) fp32; pad
    entries (id -1) come back ``-inf``.  The gathered counterpart of
    :func:`ash_score_coarse_ref` (IVF partial probes): rowwise reduce
    over exact integers — order-invariant, so gathered and dense coarse
    scores agree bitwise on shared rows.
    """
    m, R = rows.shape
    safe = jnp.maximum(rows, 0)
    if values is None:
        d_pad = codes.shape[1] * Q.codes_per_word(b)
        V = Q.unpack_codes(
            codes[safe.reshape(-1)], d_pad, b
        ).astype(jnp.float32).reshape(m, R, -1)
    else:
        V = values[safe]
    dot = jnp.sum(
        q_int8.astype(jnp.float32)[:, None, :] * V, axis=-1
    )
    cl = cluster[safe]  # (m, R)
    bias = jnp.take_along_axis(
        ip_q_landmarks.astype(jnp.float32), cl, axis=1
    )
    base = _coarse_base(
        dot, q_scale, q_corr, scale.astype(jnp.float32)[safe],
        offset.astype(jnp.float32)[safe], bias,
    )
    if metric == "dot":
        out = base
    elif metric == "l2":
        qcol = qterm.astype(jnp.float32)[:, None]
        out = (2.0 * base - qcol) - rowterm.astype(jnp.float32)[safe]
    elif metric == "cos":
        qcol = qterm.astype(jnp.float32)[:, None]
        out = (base * qcol) * rowterm.astype(jnp.float32)[safe]
    else:
        raise ValueError(metric)
    return jnp.where(rows >= 0, out, -jnp.inf)


def ash_kv_attn_ref(
    q_k: jax.Array,  # (dk,) query projected into K-code space (W_k q)
    k_codes: jax.Array,  # (S, Wk) packed K codes
    k_scale: jax.Array,  # (S,)
    k_bias: jax.Array,  # (S,) per-position logit bias:
    #   <q, mu_k> + offset_k  (QUERY-COMPUTE + OFFSET folded outside)
    v_codes: jax.Array,  # (S, Wv) packed V codes
    v_scale: jax.Array,  # (S,) SCALE of the V encoder
    b_k: int,
    b_v: int,
    mask: jax.Array | None = None,  # (S,) bool; False = ignore
) -> tuple[jax.Array, jax.Array]:
    """Single-query decode attention over an ASH-compressed KV cache.

    logits_i = k_scale_i * <q_k, unpack(k_codes_i)> + k_bias_i
    p = softmax(logits)
    returns (acc (dv,), none_placeholder) where
      acc = sum_i p_i * v_scale_i * unpack(v_codes_i)
    The caller completes the output as W_v^T acc + mu_v (linear decode).
    """
    dk = k_codes.shape[1] * Q.codes_per_word(b_k)
    dv = v_codes.shape[1] * Q.codes_per_word(b_v)
    K = Q.unpack_codes(k_codes, dk, b_k).astype(jnp.float32)
    V = Q.unpack_codes(v_codes, dv, b_v).astype(jnp.float32)
    logits = (
        K @ q_k.astype(jnp.float32)
    ) * k_scale.astype(jnp.float32) + k_bias.astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits)
    acc = (p * v_scale.astype(jnp.float32)) @ V  # (dv,)
    return acc, p
