"""Decode attention over an ASH-compressed KV cache (Pallas TPU kernel).

Beyond-paper application of Eq. (20): the "database" is the KV cache.
K vectors are ASH-encoded (per-head projection W_k, codes packed b_k
bits/dim); V likewise.  For one new token:

  logits_i = k_scale_i * <W_k q, unpack(k_codes_i)> + k_bias_i
  p        = softmax(logits)                       (online, blockwise)
  acc      = sum_i p_i * v_scale_i * unpack(v_codes_i)   (reduced space!)

The linear ASH decoder means the V de-projection W_v^T is applied ONCE
per query *after* the reduction (outside the kernel) instead of once per
cached token — exactly the paper's "simple linear decoder" argument
(Section 2.2) transplanted to attention.  HBM traffic per step drops by
32/b_k vs a bf16 cache.

Kernel = flash-decoding-style online softmax over KV-length blocks with
in-register code unpacking; grid (S_blocks,), scratch: running (max,
denom, acc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quantization as Q
from repro.kernels.ash_score import _unpack_block

DEFAULT_BLOCK_S = 512
_NEG_INF = -1e30


def _kernel(
    qk_ref,  # (1, dk)
    k_codes_ref,  # (s_blk, wk)
    k_scale_ref,  # (1, s_blk)
    k_bias_ref,  # (1, s_blk)
    v_codes_ref,  # (s_blk, wv)
    v_scale_ref,  # (1, s_blk)
    mask_ref,  # (1, s_blk) int32 (1 = valid)
    acc_ref,  # out (1, dv) fp32
    denom_ref,  # out (1, 1) fp32
    m_scr,  # scratch (1, 1) running max
    d_scr,  # scratch (1, 1) running denom
    a_scr,  # scratch (1, dv) running acc
    *,
    b_k: int,
    b_v: int,
    n_s_blocks: int,
):
    s_idx = pl.program_id(0)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        a_scr[...] = jnp.zeros_like(a_scr)

    K = _unpack_block(k_codes_ref[...], b_k, jnp.float32)  # (s_blk, dk)
    q = qk_ref[...].astype(jnp.float32)  # (1, dk)
    logits = jax.lax.dot_general(
        q, K, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, s_blk)
    logits = logits * k_scale_ref[...].astype(jnp.float32) + k_bias_ref[
        ...
    ].astype(jnp.float32)
    logits = jnp.where(mask_ref[...] > 0, logits, _NEG_INF)

    m_prev = m_scr[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)  # (1, s_blk)
    d_scr[0, 0] = d_scr[0, 0] * corr + jnp.sum(p)
    V = _unpack_block(v_codes_ref[...], b_v, jnp.float32)  # (s_blk, dv)
    pv = p * v_scale_ref[...].astype(jnp.float32)  # (1, s_blk)
    a_scr[...] = a_scr[...] * corr + jax.lax.dot_general(
        pv, V, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[0, 0] = m_new

    @pl.when(s_idx == n_s_blocks - 1)
    def _final():
        acc_ref[...] = a_scr[...]
        denom_ref[...] = d_scr[...]


@functools.partial(
    jax.jit, static_argnames=("b_k", "b_v", "block_s", "interpret")
)
def ash_kv_attn_pallas(
    q_k: jax.Array,  # (dk,)
    k_codes: jax.Array,  # (S, Wk)
    k_scale: jax.Array,  # (S,)
    k_bias: jax.Array,  # (S,)
    v_codes: jax.Array,  # (S, Wv)
    v_scale: jax.Array,  # (S,)
    mask: jax.Array,  # (S,) bool
    *,
    b_k: int,
    b_v: int,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    """Returns acc (dv,) = sum_i softmax(logits)_i v_scale_i unpack(v_i).

    Caller applies the V decode: out = W_v^T acc + mu_v.
    Semantics == ref.ash_kv_attn_ref (first output).
    """
    S, Wk = k_codes.shape
    Wv = v_codes.shape[1]
    dk = Wk * Q.codes_per_word(b_k)
    dv = Wv * Q.codes_per_word(b_v)
    assert q_k.shape == (dk,)

    block_s = min(block_s, _round_up(S, 128))
    S_p = _round_up(S, block_s)
    pad = S_p - S
    k_codes = jnp.pad(k_codes, ((0, pad), (0, 0)))
    v_codes = jnp.pad(v_codes, ((0, pad), (0, 0)))
    k_scale2 = jnp.pad(k_scale, (0, pad)).reshape(1, S_p)
    k_bias2 = jnp.pad(k_bias, (0, pad)).reshape(1, S_p)
    v_scale2 = jnp.pad(v_scale, (0, pad)).reshape(1, S_p)
    mask2 = jnp.pad(mask.astype(jnp.int32), (0, pad)).reshape(1, S_p)
    qk2 = q_k.reshape(1, dk)

    grid = (S_p // block_s,)
    acc, denom = pl.pallas_call(
        functools.partial(
            _kernel, b_k=b_k, b_v=b_v, n_s_blocks=grid[0]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, dk), lambda i: (0, 0)),
            pl.BlockSpec((block_s, Wk), lambda i: (i, 0)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((block_s, Wv), lambda i: (i, 0)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, dv), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, dv), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qk2, k_codes, k_scale2, k_bias2, v_codes, v_scale2, mask2)
    return (acc / jnp.maximum(denom, 1e-30))[0]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
