"""Fused ASH asymmetric-scoring Pallas TPU kernel.

The TPU adaptation of the paper's AVX-512 Code 1 (see DESIGN.md §2):
batched scoring of m queries against n packed ASH codes is a dense
matmul, so the kernel

  1. streams packed uint32 code words HBM -> VMEM one (n_blk, w_blk)
     tile at a time (codes never exist unpacked in HBM: 32/b codes per
     word, a 16x-32x traffic reduction vs fp32 vectors);
  2. unpacks in-register (shift/mask -> odd-integer grid values, bf16);
  3. feeds the MXU: acc += q_tile (m_blk, d_blk) @ codes_tile^T;
  4. on the last reduction step applies the Eq. (20) epilogue
     out = acc * SCALE + one_hot(cluster) lookup of <q, mu_c> + OFFSET,
     with the landmark lookup itself expressed as an MXU-friendly
     one-hot matmul (C <= 256).

Grid: (n_blocks, m_blocks, d_blocks), d innermost for accumulation in a
VMEM fp32 scratch tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quantization as Q

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_D = 512


def _unpack_block(words: jax.Array, b: int, compute_dtype) -> jax.Array:
    """(n_blk, w_blk) uint32 -> (n_blk, w_blk * 32//b) grid values."""
    k = 32 // b
    shifts = (jnp.arange(k, dtype=jnp.uint32) * b).astype(jnp.uint32)
    mask = jnp.uint32(2**b - 1)
    grouped = (words[:, :, None] >> shifts[None, None, :]) & mask
    levels = grouped.reshape(words.shape[0], -1)
    return (
        2 * levels.astype(jnp.int32) - (2**b - 1)
    ).astype(compute_dtype)


def _kernel(
    q_ref,  # (m_blk, d_blk)
    codes_ref,  # (n_blk, w_blk) uint32
    scale_ref,  # (1, n_blk)
    offset_ref,  # (1, n_blk)
    cluster_ref,  # (1, n_blk) int32
    ipq_ref,  # (m_blk, C)
    out_ref,  # (m_blk, n_blk)
    acc_ref,  # scratch (m_blk, n_blk) fp32
    *,
    b: int,
    n_d_blocks: int,
    compute_dtype,
):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vals = _unpack_block(codes_ref[...], b, compute_dtype)  # (n_blk, d_blk)
    q = q_ref[...].astype(compute_dtype)
    acc_ref[...] += jax.lax.dot_general(
        q,
        vals,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_idx == n_d_blocks - 1)
    def _epilogue():
        C = ipq_ref.shape[1]
        cl = cluster_ref[0, :]  # (n_blk,)
        onehot = (
            cl[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
        ).astype(jnp.float32)  # (n_blk, C)
        bias = jax.lax.dot_general(
            ipq_ref[...].astype(jnp.float32),
            onehot,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (m_blk, n_blk)
        out_ref[...] = (
            acc_ref[...] * scale_ref[0, :][None, :].astype(jnp.float32)
            + bias
            + offset_ref[0, :][None, :].astype(jnp.float32)
        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "b", "block_m", "block_n", "block_d", "interpret", "compute_dtype"
    ),
)
def ash_score_pallas(
    codes: jax.Array,  # (n, Wd) uint32
    q_proj: jax.Array,  # (m, d_pad)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,)
    ip_q_landmarks: jax.Array,  # (m, C)
    *,
    b: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """(m, n) fp32 asymmetric scores; semantics == ref.ash_score_ref."""
    n, Wd = codes.shape
    m, d_pad = q_proj.shape
    k = Q.codes_per_word(b)
    assert Wd * k == d_pad, (Wd, k, d_pad)
    C = ip_q_landmarks.shape[1]

    block_m = min(block_m, _round_up(m, 8))
    block_n = min(block_n, _round_up(n, 128))
    block_d = min(block_d, d_pad)
    assert block_d % k == 0
    block_w = block_d // k

    # Pad every operand to block multiples (scores for padded rows are
    # sliced away; padded q columns are zero so they add nothing).
    m_p = _round_up(m, block_m)
    n_p = _round_up(n, block_n)
    d_p = _round_up(d_pad, block_d)
    w_p = d_p // k
    codes = jnp.pad(codes, ((0, n_p - n), (0, w_p - Wd)))
    q_proj = jnp.pad(q_proj, ((0, m_p - m), (0, d_p - d_pad)))
    scale2 = jnp.pad(scale, (0, n_p - n)).reshape(1, n_p)
    offset2 = jnp.pad(offset, (0, n_p - n)).reshape(1, n_p)
    cluster2 = jnp.pad(cluster, (0, n_p - n)).reshape(1, n_p)
    ipq = jnp.pad(ip_q_landmarks, ((0, m_p - m), (0, 0)))

    grid = (n_p // block_n, m_p // block_m, d_p // block_d)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            b=b,
            n_d_blocks=grid[2],
            compute_dtype=compute_dtype,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_d), lambda i, j, k_: (j, k_)),
            pl.BlockSpec((block_n, block_w), lambda i, j, k_: (i, k_)),
            pl.BlockSpec((1, block_n), lambda i, j, k_: (0, i)),
            pl.BlockSpec((1, block_n), lambda i, j, k_: (0, i)),
            pl.BlockSpec((1, block_n), lambda i, j, k_: (0, i)),
            pl.BlockSpec((block_m, C), lambda i, j, k_: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k_: (j, i)),
        out_shape=jax.ShapeDtypeStruct((m_p, n_p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(q_proj, codes, scale2, offset2, cluster2, ipq)
    return out[:m, :n]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
