"""Fused ASH asymmetric-scoring Pallas TPU kernel family.

The TPU adaptation of the paper's AVX-512 Code 1 (see DESIGN.md §2):
batched scoring of m queries against n packed ASH codes is a dense
matmul, so every kernel in this file

  1. streams packed uint32 code words HBM -> VMEM one (n_blk, w_blk)
     tile at a time (codes never exist unpacked in HBM: 32/b codes per
     word, a 16x-32x traffic reduction vs fp32 vectors);
  2. unpacks in-register (shift/mask -> odd-integer grid values, bf16);
  3. feeds the MXU: acc += q_tile (m_blk, d_blk) @ codes_tile^T;
  4. on the last reduction step applies a metric epilogue over the
     accumulated Eq. (20) base score, entirely in VMEM.

Metric epilogues (``metric=``) — all emit HIGHER-IS-BETTER scores:

  dot   base = acc * SCALE + one_hot(cluster) lookup of <q, mu_c>
             + OFFSET            (Eq. 20; the landmark lookup is itself
             an MXU-friendly one-hot matmul, C <= 256)
  l2    2 * base - ||q||^2 - L2CONST_i          == -||q - x_i||^2
  cos   base * (1/||q||) * (1/||x_i||)          (Eq. A.5 norm estimate)

The l2/cos row constants (``L2CONST_i = ||x-mu*||^2 + 2<x,mu*> -
||mu*||^2`` and the Eq. A.5 inverse norm) are query-independent and
recovered once at encode/build time into an ``ASHStats`` structure (see
``repro.core.types``), so neither metric ever unpacks the database in
HBM — they are pure per-tile epilogues over the same packed-code MXU
accumulation as dot.

Fused selection (:func:`ash_score_topk_pallas`): instead of writing the
(m, n) score matrix back to HBM and running a separate ``top_k`` pass,
each (m_blk, n_blk) output tile keeps only its partial top-k̃ of
(score, global id) pairs — an iterative VPU max/argmax sweep in VMEM —
and the kernel emits a (m, n_blocks * k̃) candidate strip merged by one
small final two-key sort on the host side of the call.  HBM traffic for
selection drops from O(m·n) fp32 to O(m · n/block_n · k̃).

  * k̃ accuracy/VMEM trade-off: results are EXACTLY the materialized
    ``lax.top_k`` (values and indices, including tie order) whenever
    k <= k̃, because a row's global rank-r element ranks <= r inside
    its own tile.  k̃ < k trades exactness for a smaller candidate
    strip and fewer selection sweeps (recall-style operation; the
    routed index paths never do this).  Cost: k̃ VPU sweeps over each
    tile + 2 * k̃ * n/block_n fp32+int32 VMEM per query row.
  * Ties follow the ``lax.top_k`` convention (lowest id first): tiles
    select by (score desc, id asc) and the merge sorts candidates with
    a two-key ``lax.sort`` on (-score, id).
  * Rows beyond the real n (block padding) are masked to -inf inside
    the kernel; exhausted tiles emit int32-max sentinel ids which the
    merge maps to -1 (they can only surface when k exceeds the number
    of candidates actually emitted, i.e. never for k <= min(n, k̃)).

Masked-gather variants (:func:`ash_score_gather_pallas`,
:func:`ash_score_gather_topk_pallas`): the same epilogues and fused
selection over PER-QUERY candidate lists (IVF partial probes) instead
of a dense row range.  The candidate row ids arrive as a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``) so the
kernel DMA-gathers each candidate's PACKED code word strip HBM -> VMEM
directly — neither the unpacked codes nor the (m, R) score matrix ever
exist in HBM, only the 16x-32x-compressed words of the rows actually
probed move.  Pad entries (row id -1) are masked to ``-inf`` in the
epilogue.  The dense selection kernel absorbs row-validity masking the
same way: a runtime (1, n) int32 mask operand folds the sharded
backend's ``n_real`` pad truncation AND the index layers' tombstone
(deleted-row) bitmap into the kernel's id masking, so one compiled
program serves every shard of a shard_map and every mutation state
(deletes never recompile; the gather path instead drops tombstoned ids
from the candidate lists before any DMA is issued).

Grid: (n_blocks, m_blocks, d_blocks), d innermost for accumulation in a
VMEM fp32 scratch tile; the gather variants use (m, r_blocks, d_blocks)
— one query per row step, since each query gathers its own candidates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quantization as Q

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_D = 512

METRICS = ("dot", "l2", "cos")
_ID_SENTINEL = jnp.iinfo(jnp.int32).max


def _unpack_block(words: jax.Array, b: int, compute_dtype) -> jax.Array:
    """(n_blk, w_blk) uint32 -> (n_blk, w_blk * 32//b) grid values."""
    k = 32 // b
    shifts = (jnp.arange(k, dtype=jnp.uint32) * b).astype(jnp.uint32)
    mask = jnp.uint32(2**b - 1)
    grouped = (words[:, :, None] >> shifts[None, None, :]) & mask
    levels = grouped.reshape(words.shape[0], -1)
    return (
        2 * levels.astype(jnp.int32) - (2**b - 1)
    ).astype(compute_dtype)


def _accumulate(q_ref, codes_ref, acc_ref, *, b, compute_dtype):
    """acc += q_tile @ unpack(codes_tile)^T — shared matmul prologue."""
    vals = _unpack_block(codes_ref[...], b, compute_dtype)  # (n_blk, d_blk)
    q = q_ref[...].astype(compute_dtype)
    acc_ref[...] += jax.lax.dot_general(
        q,
        vals,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _bias_lookup(cluster_ref, ipq_ref):
    """(m_blk, n_blk) landmark bias <q, mu_{c*_i}> via a one-hot matmul
    (exactly one non-zero term per column, so it is bitwise equal to the
    oracle's gather)."""
    C = ipq_ref.shape[1]
    cl = cluster_ref[0, :]  # (n_blk,)
    onehot = (
        cl[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
    ).astype(jnp.float32)  # (n_blk, C)
    return jax.lax.dot_general(
        ipq_ref[...].astype(jnp.float32),
        onehot,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (m_blk, n_blk)


def _metric_tail(base, qterm_ref, rowterm_ref, metric):
    """Shared l2/cos epilogue over an Eq. (20) base-score tile."""
    if metric == "dot":
        return base
    qcol = qterm_ref[0, :].astype(jnp.float32)[:, None]  # (m_blk, 1)
    rrow = rowterm_ref[0, :].astype(jnp.float32)[None, :]  # (1, n_blk)
    if metric == "l2":
        return (2.0 * base - qcol) - rrow  # == -||q - x||^2
    if metric == "cos":
        return (base * qcol) * rrow
    raise ValueError(metric)


def _epilogue_scores(
    acc, scale_ref, offset_ref, cluster_ref, ipq_ref, qterm_ref,
    rowterm_ref, *, metric,
):
    """Tile scores (m_blk, n_blk) fp32 from the accumulated DOT-PROD.

    The exact op order here is mirrored by ``ref.ash_score_metric_ref``
    so compiled/interpreted kernels and the jnp oracle agree to the
    reduction-order level.
    """
    bias = _bias_lookup(cluster_ref, ipq_ref)
    base = (
        acc * scale_ref[0, :][None, :].astype(jnp.float32)
        + bias
        + offset_ref[0, :][None, :].astype(jnp.float32)
    )
    return _metric_tail(base, qterm_ref, rowterm_ref, metric)


def _kernel(
    q_ref,  # (m_blk, d_blk)
    codes_ref,  # (n_blk, w_blk) uint32
    scale_ref,  # (1, n_blk)
    offset_ref,  # (1, n_blk)
    cluster_ref,  # (1, n_blk) int32
    ipq_ref,  # (m_blk, C)
    qterm_ref,  # (1, m_blk) metric query term (zeros for dot)
    rowterm_ref,  # (1, n_blk) metric row term (zeros for dot)
    out_ref,  # (m_blk, n_blk)
    acc_ref,  # scratch (m_blk, n_blk) fp32
    *,
    b: int,
    n_d_blocks: int,
    compute_dtype,
    metric: str,
):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(q_ref, codes_ref, acc_ref, b=b, compute_dtype=compute_dtype)

    @pl.when(k_idx == n_d_blocks - 1)
    def _epilogue():
        out_ref[...] = _epilogue_scores(
            acc_ref[...], scale_ref, offset_ref, cluster_ref, ipq_ref,
            qterm_ref, rowterm_ref, metric=metric,
        ).astype(out_ref.dtype)


def _select_topk(scores, valid, col0, k_tilde, vals_ref, ids_ref):
    """Per-tile partial top-k̃ of ``scores`` (m_blk, n_blk) into the
    (m_blk, k_tilde) output refs; shared by the dense and gather
    selection kernels.

    Iterative partial top-k̃: k̃ VPU max sweeps over the tile, ties to
    the LOWEST id (the lax.top_k convention) via a min over the argmax
    candidate set.  ``valid`` (not a -inf re-mask) tracks taken columns
    so rows whose scores are genuinely -inf are still emitted once
    each, in ascending-id order; invalid columns (block padding, masked
    rows, gather pad ids) never surface.  Emitted ids are
    ``col0 + column``; exhausted tiles emit the int32-max sentinel.
    """
    local = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    neg_inf = jnp.float32(-jnp.inf)
    n_blk = scores.shape[1]
    for t in range(k_tilde):
        masked = jnp.where(valid, scores, neg_inf)
        best = jnp.max(masked, axis=1)  # (m_blk,)
        cand = jnp.where(
            valid & (masked == best[:, None]), local, n_blk
        )
        bid = jnp.min(cand, axis=1)  # n_blk == tile exhausted
        has = bid < n_blk
        vals_ref[:, t] = jnp.where(has, best, neg_inf)
        ids_ref[:, t] = jnp.where(has, bid + col0, _ID_SENTINEL)
        valid = valid & (local != bid[:, None])


def _topk_kernel(
    q_ref,
    codes_ref,
    scale_ref,
    offset_ref,
    cluster_ref,
    ipq_ref,
    qterm_ref,
    rowterm_ref,
    *rest,  # [mask_ref,] vals_ref, ids_ref, acc_ref — see use_mask
    b: int,
    n_d_blocks: int,
    compute_dtype,
    metric: str,
    k_tilde: int,
    block_n: int,
    n_real: int,
    use_mask: bool,
):
    # refs after the shared operand block depend on the masking mode:
    #   use_mask:  mask_ref (1, n_blk) int32 runtime row-validity
    #              (0 = masked), then vals/ids outputs + acc scratch
    #   else:      vals/ids outputs + acc scratch only — validity is
    #              the static block-padding predicate col < n_real
    if use_mask:
        mask_ref, vals_ref, ids_ref, acc_ref = rest
    else:
        vals_ref, ids_ref, acc_ref = rest
    k_idx = pl.program_id(2)
    # program_id must be read outside the pl.when body (interpret mode
    # lowers the body through lax.cond, where the primitive is absent)
    col0 = pl.program_id(0) * block_n

    @pl.when(k_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(q_ref, codes_ref, acc_ref, b=b, compute_dtype=compute_dtype)

    @pl.when(k_idx == n_d_blocks - 1)
    def _select():
        scores = _epilogue_scores(
            acc_ref[...], scale_ref, offset_ref, cluster_ref, ipq_ref,
            qterm_ref, rowterm_ref, metric=metric,
        )  # (m_blk, n_blk) fp32
        if use_mask:
            # the mask operand is a RUNTIME per-row validity vector
            # folding three maskings into one id mask: block-padding
            # columns beyond the real n (always 0 there), the sharded
            # backend's per-shard n_real truncation, and tombstoned
            # (deleted) rows — one compiled program serves every shard
            # and every mutation state
            valid = jnp.broadcast_to(mask_ref[...] != 0, scores.shape)
        else:
            # unmasked scan (no deletes, no sharding): block padding is
            # the only invalid region and n is static — no operand
            local = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
            valid = (local + col0) < n_real
        _select_topk(scores, valid, col0, k_tilde, vals_ref, ids_ref)


def _pad_operands(
    codes, q_proj, scale, offset, cluster, ip_q_landmarks, qterm, rowterm,
    *, b, block_m, block_n, block_d,
):
    """Pad every operand to block multiples; returns padded operands +
    the (m_p, n_p, grid) geometry.  Scores for padded rows/cols are
    sliced away (materializing kernel) or masked (selection kernel);
    padded q columns are zero so they add nothing."""
    n, Wd = codes.shape
    m, d_pad = q_proj.shape
    k = Q.codes_per_word(b)
    assert Wd * k == d_pad, (Wd, k, d_pad)

    block_m = min(block_m, _round_up(m, 8))
    block_n = min(block_n, _round_up(n, 128))
    block_d = min(block_d, d_pad)
    assert block_d % k == 0
    block_w = block_d // k

    m_p = _round_up(m, block_m)
    n_p = _round_up(n, block_n)
    d_p = _round_up(d_pad, block_d)
    w_p = d_p // k
    codes = jnp.pad(codes, ((0, n_p - n), (0, w_p - Wd)))
    q_proj = jnp.pad(q_proj, ((0, m_p - m), (0, d_p - d_pad)))
    scale2 = jnp.pad(scale, (0, n_p - n)).reshape(1, n_p)
    offset2 = jnp.pad(offset, (0, n_p - n)).reshape(1, n_p)
    cluster2 = jnp.pad(cluster, (0, n_p - n)).reshape(1, n_p)
    ipq = jnp.pad(ip_q_landmarks, ((0, m_p - m), (0, 0)))
    if qterm is None:
        qterm = jnp.zeros((m,), jnp.float32)
    if rowterm is None:
        rowterm = jnp.zeros((n,), jnp.float32)
    qterm2 = jnp.pad(
        qterm.astype(jnp.float32), (0, m_p - m)
    ).reshape(1, m_p)
    rowterm2 = jnp.pad(
        rowterm.astype(jnp.float32), (0, n_p - n)
    ).reshape(1, n_p)

    grid = (n_p // block_n, m_p // block_m, d_p // block_d)
    operands = (
        q_proj, codes, scale2, offset2, cluster2, ipq, qterm2, rowterm2
    )
    geom = dict(
        m=m, n=n, m_p=m_p, n_p=n_p, grid=grid,
        block_m=block_m, block_n=block_n, block_d=block_d,
        block_w=block_w, C=ip_q_landmarks.shape[1],
    )
    return operands, geom


def _in_specs(g):
    # trailing *_ tolerates grid specs that append extra index_map args
    # (kept permissive; the dense kernels run on a plain grid — the
    # selection kernel's row mask is a regular blocked operand now)
    return [
        pl.BlockSpec(
            (g["block_m"], g["block_d"]), lambda i, j, k_, *_: (j, k_)
        ),
        pl.BlockSpec(
            (g["block_n"], g["block_w"]), lambda i, j, k_, *_: (i, k_)
        ),
        pl.BlockSpec((1, g["block_n"]), lambda i, j, k_, *_: (0, i)),
        pl.BlockSpec((1, g["block_n"]), lambda i, j, k_, *_: (0, i)),
        pl.BlockSpec((1, g["block_n"]), lambda i, j, k_, *_: (0, i)),
        pl.BlockSpec((g["block_m"], g["C"]), lambda i, j, k_, *_: (j, 0)),
        pl.BlockSpec((1, g["block_m"]), lambda i, j, k_, *_: (0, j)),
        pl.BlockSpec((1, g["block_n"]), lambda i, j, k_, *_: (0, i)),
    ]


@functools.partial(
    jax.jit,
    static_argnames=(
        "b", "metric", "block_m", "block_n", "block_d", "interpret",
        "compute_dtype",
    ),
)
def ash_score_pallas(
    codes: jax.Array,  # (n, Wd) uint32
    q_proj: jax.Array,  # (m, d_pad)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,)
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None = None,  # (m,) metric query term
    rowterm: jax.Array | None = None,  # (n,) metric row term
    *,
    b: int,
    metric: str = "dot",
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """(m, n) fp32 scores, higher-is-better for every metric.

    ``metric="dot"`` matches ``ref.ash_score_ref``; ``"l2"``/``"cos"``
    additionally need the per-row/per-query epilogue terms (see
    ``repro.kernels.ops._metric_operands``) and match
    ``ref.ash_score_metric_ref``.
    """
    assert metric in METRICS, metric
    operands, g = _pad_operands(
        codes, q_proj, scale, offset, cluster, ip_q_landmarks,
        qterm, rowterm,
        b=b, block_m=block_m, block_n=block_n, block_d=block_d,
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            b=b,
            n_d_blocks=g["grid"][2],
            compute_dtype=compute_dtype,
            metric=metric,
        ),
        grid=g["grid"],
        in_specs=_in_specs(g),
        out_specs=pl.BlockSpec(
            (g["block_m"], g["block_n"]), lambda i, j, k_: (j, i)
        ),
        out_shape=jax.ShapeDtypeStruct((g["m_p"], g["n_p"]), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g["block_m"], g["block_n"]), jnp.float32)
        ],
        interpret=interpret,
    )(*operands)
    return out[: g["m"], : g["n"]]


@functools.partial(
    jax.jit,
    static_argnames=(
        "b", "k", "k_tilde", "metric", "block_m", "block_n", "block_d",
        "interpret", "compute_dtype",
    ),
)
def ash_score_topk_pallas(
    codes: jax.Array,  # (n, Wd) uint32
    q_proj: jax.Array,  # (m, d_pad)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,)
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None = None,
    rowterm: jax.Array | None = None,
    n_valid: jax.Array | None = None,  # scalar: rows >= this are masked
    row_valid: jax.Array | None = None,  # (n,) bool/int: 0 = masked row
    *,
    b: int,
    k: int,
    k_tilde: int | None = None,
    metric: str = "dot",
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, jax.Array]:
    """Fused scan + selection: top-k (scores, ids), each (m, k).

    The (m, n) score matrix never exists — each output tile emits its
    partial top-k̃ and one two-key sort merges the (m, n_blocks * k̃)
    candidate strip.  Exactly equal to ``top_k(ash_score_pallas(...))``
    (values, ids and tie order) for ``k <= k̃``; ``k̃`` defaults to
    ``k``.  Ids of exhausted slots come back as -1 (only reachable when
    ``k > min(n, k̃)``).

    Row-validity masking: when either ``n_valid`` or ``row_valid`` is
    given, they fold into ONE runtime (1, n_p) int32 mask operand — no
    recompilation between mutation states or shard shapes.  Without
    them (the common unmutated, unsharded scan) no mask operand exists
    at all: block padding is masked by a static predicate.

    * ``n_valid`` (scalar): rows at or beyond it score ``-inf`` — the
      sharded backend's per-shard ``n_real`` pad-row truncation.
    * ``row_valid`` ((n,) bool): rows whose entry is 0 score ``-inf``
      and are excluded from selection exactly like block padding — the
      index layers' tombstone (deleted-row) bitmap.
    """
    assert metric in METRICS, metric
    n = codes.shape[0]
    operands, g = _pad_operands(
        codes, q_proj, scale, offset, cluster, ip_q_landmarks,
        qterm, rowterm,
        b=b, block_m=block_m, block_n=block_n, block_d=block_d,
    )
    use_mask = n_valid is not None or row_valid is not None
    in_specs = _in_specs(g)
    if use_mask:
        if row_valid is None:
            mask = jnp.ones((n,), jnp.int32)
        else:
            mask = row_valid.astype(jnp.int32)
        if n_valid is not None:
            mask = mask * (
                jnp.arange(n, dtype=jnp.int32)
                < jnp.asarray(n_valid, jnp.int32)
            ).astype(jnp.int32)
        operands = operands + (
            jnp.pad(mask, (0, g["n_p"] - n)).reshape(1, g["n_p"]),
        )
        in_specs = in_specs + [
            pl.BlockSpec((1, g["block_n"]), lambda i, j, k_: (0, i)),
        ]
    if k_tilde is None:
        k_tilde = k
    k_tilde = min(k_tilde, g["block_n"])
    n_blocks = g["grid"][0]
    if k > n_blocks * k_tilde:
        raise ValueError(
            f"k={k} exceeds the {n_blocks} x k_tilde={k_tilde} candidate "
            f"strip; raise k_tilde or use the materializing kernel"
        )
    vals, ids = pl.pallas_call(
        functools.partial(
            _topk_kernel,
            b=b,
            n_d_blocks=g["grid"][2],
            compute_dtype=compute_dtype,
            metric=metric,
            k_tilde=k_tilde,
            block_n=g["block_n"],
            n_real=n,
            use_mask=use_mask,
        ),
        grid=g["grid"],
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec(
                (g["block_m"], k_tilde), lambda i, j, k_: (j, i)
            ),
            pl.BlockSpec(
                (g["block_m"], k_tilde), lambda i, j, k_: (j, i)
            ),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g["m_p"], n_blocks * k_tilde), jnp.float32),
            jax.ShapeDtypeStruct((g["m_p"], n_blocks * k_tilde), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g["block_m"], g["block_n"]), jnp.float32)
        ],
        interpret=interpret,
    )(*operands)
    vals, ids = vals[: g["m"]], ids[: g["m"]]
    # Merge: (score desc, id asc) — bit-equal to lax.top_k over the
    # materialized row (candidate tiles are already in ascending-id
    # order, so the two-key sort reproduces top_k's tie behaviour).
    neg, sid = jax.lax.sort((-vals, ids), dimension=1, num_keys=2)
    out_s, out_i = -neg[:, :k], sid[:, :k]
    return out_s, jnp.where(out_i == _ID_SENTINEL, -1, out_i)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# Masked-gather kernels (IVF partial probes: per-query candidate lists)
# ---------------------------------------------------------------------------

DEFAULT_BLOCK_R = 128


def _gather_tile(
    rows_sref, codes_hbm, codes_vmem, sem, r0, block_r, block_w,
):
    """DMA-gather one (block_r, block_w) packed-code tile into VMEM.

    ``rows_sref`` is the scalar-prefetch candidate-row table; row ids
    drive per-candidate async copies of the packed word strip for the
    current d-block (pad ids are clamped to row 0 — their scores are
    masked in the epilogue, the fetch just has to be in-bounds).  All
    copies start before any is awaited so the gather pipelines.
    """
    i = pl.program_id(0)
    kd = pl.program_id(2)
    w0 = kd * block_w
    for t in range(block_r):
        row = jnp.maximum(rows_sref[i, r0 + t], 0)
        pltpu.make_async_copy(
            codes_hbm.at[row, pl.ds(w0, block_w)],
            codes_vmem.at[t],
            sem.at[t],
        ).start()
    for t in range(block_r):
        row = jnp.maximum(rows_sref[i, r0 + t], 0)
        pltpu.make_async_copy(
            codes_hbm.at[row, pl.ds(w0, block_w)],
            codes_vmem.at[t],
            sem.at[t],
        ).wait()


def _gather_accumulate(
    rows_sref, codes_hbm, codes_vmem, sem, q_ref, acc_ref,
    *, b, block_r, block_w, compute_dtype,
):
    """Shared prologue of both gather kernels: zero the accumulator on
    the first d-step, DMA-gather this (r-tile, d-block) of packed
    codes, unpack in-register and accumulate the DOT-PROD term.
    Returns (k_idx, r0) for the caller's epilogue predicate."""
    k_idx = pl.program_id(2)
    r0 = pl.program_id(1) * block_r

    @pl.when(k_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _gather_tile(rows_sref, codes_hbm, codes_vmem, sem, r0, block_r, block_w)
    vals = _unpack_block(codes_vmem[...], b, compute_dtype)
    acc_ref[...] += jax.lax.dot_general(
        q_ref[...].astype(compute_dtype),
        vals,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return k_idx, r0


def _gather_kernel(
    rows_sref,  # scalar prefetch: (m, R_p) int32 candidate rows, -1 pad
    q_ref,  # (1, d_blk)
    codes_hbm,  # (n, w_p) uint32, HBM-resident (pl.ANY)
    scale_ref,  # (1, r_blk) gathered per-candidate
    offset_ref,  # (1, r_blk)
    cluster_ref,  # (1, r_blk) int32
    ipq_ref,  # (1, C)
    qterm_ref,  # (1, 1)
    rowterm_ref,  # (1, r_blk)
    rows_ref,  # (1, r_blk) int32 — VMEM copy of the tile's row ids
    out_ref,  # (1, r_blk)
    codes_vmem,  # scratch (r_blk, w_blk) uint32
    acc_ref,  # scratch (1, r_blk) fp32
    sem,  # DMA semaphores (r_blk,)
    *,
    b: int,
    n_d_blocks: int,
    compute_dtype,
    metric: str,
    block_r: int,
    block_w: int,
):
    k_idx, _ = _gather_accumulate(
        rows_sref, codes_hbm, codes_vmem, sem, q_ref, acc_ref,
        b=b, block_r=block_r, block_w=block_w,
        compute_dtype=compute_dtype,
    )

    @pl.when(k_idx == n_d_blocks - 1)
    def _epilogue():
        scores = _epilogue_scores(
            acc_ref[...], scale_ref, offset_ref, cluster_ref, ipq_ref,
            qterm_ref, rowterm_ref, metric=metric,
        )
        out_ref[...] = jnp.where(
            rows_ref[...] >= 0, scores, jnp.float32(-jnp.inf)
        )


def _gather_topk_kernel(
    rows_sref,
    q_ref,
    codes_hbm,
    scale_ref,
    offset_ref,
    cluster_ref,
    ipq_ref,
    qterm_ref,
    rowterm_ref,
    rows_ref,
    vals_ref,  # (1, k_tilde) fp32
    ids_ref,  # (1, k_tilde) int32 — candidate POSITIONS in the list
    codes_vmem,
    acc_ref,
    sem,
    *,
    b: int,
    n_d_blocks: int,
    compute_dtype,
    metric: str,
    block_r: int,
    block_w: int,
    k_tilde: int,
):
    k_idx, r0 = _gather_accumulate(
        rows_sref, codes_hbm, codes_vmem, sem, q_ref, acc_ref,
        b=b, block_r=block_r, block_w=block_w,
        compute_dtype=compute_dtype,
    )

    @pl.when(k_idx == n_d_blocks - 1)
    def _select():
        scores = _epilogue_scores(
            acc_ref[...], scale_ref, offset_ref, cluster_ref, ipq_ref,
            qterm_ref, rowterm_ref, metric=metric,
        )
        # pad-id masking IS the validity mask: padded positions (and
        # R-padding, which also carries id -1) never surface
        valid = rows_ref[...] >= 0
        _select_topk(scores, valid, r0, k_tilde, vals_ref, ids_ref)


def _pad_gather_operands(
    codes, rows, q_proj, scale, offset, cluster, ip_q_landmarks,
    qterm, rowterm, *, b, block_r, block_d,
):
    """Pad/gather the masked-gather operands; mirrors
    :func:`_pad_operands` for the per-candidate layout.

    The candidate axis pads with id -1 (masked like real pad entries);
    per-row header vectors are pre-gathered to (m, R_p) on the host —
    they are the same size as the output and tiny next to the packed
    codes, which stay in HBM and are DMA-gathered in-kernel.
    """
    n, Wd = codes.shape
    m, d_pad = q_proj.shape
    R = rows.shape[1]
    kpw = Q.codes_per_word(b)
    assert Wd * kpw == d_pad, (Wd, kpw, d_pad)

    block_r = min(block_r, _round_up(R, 128))
    block_d = min(block_d, d_pad)
    assert block_d % kpw == 0
    block_w = block_d // kpw

    R_p = _round_up(R, block_r)
    d_p = _round_up(d_pad, block_d)
    w_p = d_p // kpw
    rows_p = jnp.pad(rows.astype(jnp.int32), ((0, 0), (0, R_p - R)),
                     constant_values=-1)
    safe = jnp.maximum(rows_p, 0)
    codes_p = jnp.pad(codes, ((0, 0), (0, w_p - Wd)))
    q_p = jnp.pad(q_proj, ((0, 0), (0, d_p - d_pad)))
    scale_g = scale.astype(jnp.float32)[safe]
    offset_g = offset.astype(jnp.float32)[safe]
    cluster_g = cluster[safe].astype(jnp.int32)
    if qterm is None:
        qterm = jnp.zeros((m,), jnp.float32)
    if rowterm is None:
        rowterm_g = jnp.zeros((m, R_p), jnp.float32)
    else:
        rowterm_g = rowterm.astype(jnp.float32)[safe]
    qterm2 = qterm.astype(jnp.float32).reshape(m, 1)

    grid = (m, R_p // block_r, d_p // block_d)
    operands = (
        rows_p, q_p, codes_p, scale_g, offset_g, cluster_g,
        ip_q_landmarks, qterm2, rowterm_g, rows_p,
    )
    geom = dict(
        m=m, R=R, R_p=R_p, grid=grid, block_r=block_r,
        block_d=block_d, block_w=block_w, C=ip_q_landmarks.shape[1],
    )
    return operands, geom


def _gather_in_specs(g):
    return [
        pl.BlockSpec((1, g["block_d"]), lambda i, j, kd, *_: (i, kd)),
        pl.BlockSpec(memory_space=pltpu.ANY),  # codes stay in HBM
        pl.BlockSpec((1, g["block_r"]), lambda i, j, kd, *_: (i, j)),
        pl.BlockSpec((1, g["block_r"]), lambda i, j, kd, *_: (i, j)),
        pl.BlockSpec((1, g["block_r"]), lambda i, j, kd, *_: (i, j)),
        pl.BlockSpec((1, g["C"]), lambda i, j, kd, *_: (i, 0)),
        pl.BlockSpec((1, 1), lambda i, j, kd, *_: (i, 0)),
        pl.BlockSpec((1, g["block_r"]), lambda i, j, kd, *_: (i, j)),
        pl.BlockSpec((1, g["block_r"]), lambda i, j, kd, *_: (i, j)),
    ]


def _gather_scratch(g):
    return [
        pltpu.VMEM((g["block_r"], g["block_w"]), jnp.uint32),
        pltpu.VMEM((1, g["block_r"]), jnp.float32),
        pltpu.SemaphoreType.DMA((g["block_r"],)),
    ]


@functools.partial(
    jax.jit,
    static_argnames=(
        "b", "metric", "block_r", "block_d", "interpret", "compute_dtype",
    ),
)
def ash_score_gather_pallas(
    codes: jax.Array,  # (n, Wd) uint32
    rows: jax.Array,  # (m, R) int32 candidate rows, -1 = padding
    q_proj: jax.Array,  # (m, d_pad)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,)
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None = None,
    rowterm: jax.Array | None = None,
    *,
    b: int,
    metric: str = "dot",
    block_r: int = DEFAULT_BLOCK_R,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """Masked-gather scores: (m, R) fp32, higher-is-better; pad entries
    (row id -1) come back ``-inf``.  Matches
    ``ref.ash_score_gather_ref``.

    Query i is scored against its own candidate list ``rows[i]`` (IVF
    partial probes).  Candidate row ids ride a scalar-prefetch operand
    and the kernel DMA-gathers each candidate's packed word strip
    HBM -> VMEM — the database is never unpacked in HBM and only probed
    rows move.
    """
    assert metric in METRICS, metric
    operands, g = _pad_gather_operands(
        codes, rows, q_proj, scale, offset, cluster, ip_q_landmarks,
        qterm, rowterm, b=b, block_r=block_r, block_d=block_d,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=g["grid"],
        in_specs=_gather_in_specs(g),
        out_specs=pl.BlockSpec(
            (1, g["block_r"]), lambda i, j, kd, *_: (i, j)
        ),
        scratch_shapes=_gather_scratch(g),
    )
    out = pl.pallas_call(
        functools.partial(
            _gather_kernel,
            b=b,
            n_d_blocks=g["grid"][2],
            compute_dtype=compute_dtype,
            metric=metric,
            block_r=g["block_r"],
            block_w=g["block_w"],
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g["m"], g["R_p"]), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:, : g["R"]]


@functools.partial(
    jax.jit,
    static_argnames=(
        "b", "k", "k_tilde", "metric", "block_r", "block_d", "interpret",
        "compute_dtype",
    ),
)
def ash_score_gather_topk_pallas(
    codes: jax.Array,
    rows: jax.Array,  # (m, R) int32 candidate rows, -1 = padding
    q_proj: jax.Array,
    scale: jax.Array,
    offset: jax.Array,
    cluster: jax.Array,
    ip_q_landmarks: jax.Array,
    qterm: jax.Array | None = None,
    rowterm: jax.Array | None = None,
    *,
    b: int,
    k: int,
    k_tilde: int | None = None,
    metric: str = "dot",
    block_r: int = DEFAULT_BLOCK_R,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, jax.Array]:
    """Fused masked-gather scan + selection: (scores, payload rows),
    each (m, k).

    Equal to ``top_k(ash_score_gather_pallas(...), k)`` with positions
    mapped back through ``rows`` (values, ids and tie order — ties
    break to the lowest candidate POSITION, the ``lax.top_k``
    convention) for ``k <= k̃``.  Slots without a candidate (pad ids,
    or k beyond the emitted strip) come back score ``-inf`` / row -1.
    """
    assert metric in METRICS, metric
    operands, g = _pad_gather_operands(
        codes, rows, q_proj, scale, offset, cluster, ip_q_landmarks,
        qterm, rowterm, b=b, block_r=block_r, block_d=block_d,
    )
    if k_tilde is None:
        k_tilde = k
    k_tilde = min(k_tilde, g["block_r"])
    n_r_blocks = g["grid"][1]
    if k > n_r_blocks * k_tilde:
        raise ValueError(
            f"k={k} exceeds the {n_r_blocks} x k_tilde={k_tilde} "
            f"candidate strip; raise k_tilde or use the materializing "
            f"kernel"
        )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=g["grid"],
        in_specs=_gather_in_specs(g),
        out_specs=[
            pl.BlockSpec((1, k_tilde), lambda i, j, kd, *_: (i, j)),
            pl.BlockSpec((1, k_tilde), lambda i, j, kd, *_: (i, j)),
        ],
        scratch_shapes=_gather_scratch(g),
    )
    vals, pos = pl.pallas_call(
        functools.partial(
            _gather_topk_kernel,
            b=b,
            n_d_blocks=g["grid"][2],
            compute_dtype=compute_dtype,
            metric=metric,
            block_r=g["block_r"],
            block_w=g["block_w"],
            k_tilde=k_tilde,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((g["m"], n_r_blocks * k_tilde),
                                 jnp.float32),
            jax.ShapeDtypeStruct((g["m"], n_r_blocks * k_tilde),
                                 jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    # merge identical to the dense kernel: (score desc, position asc)
    neg, spos = jax.lax.sort((-vals, pos), dimension=1, num_keys=2)
    out_s, out_p = -neg[:, :k], spos[:, :k]
    rows_p = operands[0]  # (m, R_p), -1-padded
    out_rows = jnp.take_along_axis(
        rows_p, jnp.clip(out_p, 0, g["R_p"] - 1), axis=1
    )
    return out_s, jnp.where(out_p == _ID_SENTINEL, -1, out_rows)


# ---------------------------------------------------------------------------
# Symmetric int8 coarse-scan kernels (first pass of coarse -> refine)
# ---------------------------------------------------------------------------
#
# Same tile structure as the asymmetric family, but the query side is the
# per-query int8 quantization of q_proj (``core.prepare_coarse_queries``),
# so the matmul accumulates INTEGER products with
# ``preferred_element_type=jnp.int32`` — int8 x int8 native MXU throughput
# instead of fp32/bf16 for the bulk scan.  The epilogue rescales the
# integer accumulation (``acc * q_scale``), folds the per-query residual
# correction ``q_corr`` into the landmark bias, then applies the exact
# Eq. (20) base + metric op order of the asymmetric epilogue.  Bitwise
# contract: both operands are exact small integers (|q| <= 127,
# |v| <= 2^b - 1 <= 255), so every partial sum stays below
# 127 * 255 * 512 < 2^24 for d_pad <= 512 — the int32 accumulation here,
# the oracle's fp32 matmul over the same integers, and the CoarseCodes
# fp32 value-cache path all produce identical scores bit for bit.


def _coarse_operand_dtype(b: int):
    # grid values reach +-(2^b - 1): int8 holds them for b <= 4, b=8
    # (+-255) promotes both operands to int32 (accumulation unchanged)
    return jnp.int8 if b <= 4 else jnp.int32


def _coarse_accumulate(q_ref, codes_ref, acc_ref, *, b):
    """acc(int32) += q_int8 @ unpack(codes)^T — integer MXU prologue."""
    dt = _coarse_operand_dtype(b)
    vals = _unpack_block(codes_ref[...], b, dt)  # (n_blk, d_blk)
    acc_ref[...] += jax.lax.dot_general(
        q_ref[...].astype(dt),
        vals,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _coarse_epilogue_scores(
    acc, qscale_ref, qcorr_ref, scale_ref, offset_ref, cluster_ref,
    ipq_ref, qterm_ref, rowterm_ref, *, metric,
):
    """Coarse tile scores (m_blk, n_blk) fp32; op order mirrored by
    ``ref.ash_score_coarse_ref`` (and its ``_coarse_base`` helper)."""
    bias = _bias_lookup(cluster_ref, ipq_ref)
    dotc = (
        acc.astype(jnp.float32)
        * qscale_ref[0, :].astype(jnp.float32)[:, None]
    )
    biasq = bias + qcorr_ref[0, :].astype(jnp.float32)[:, None]
    base = (
        dotc * scale_ref[0, :][None, :].astype(jnp.float32)
        + biasq
        + offset_ref[0, :][None, :].astype(jnp.float32)
    )
    return _metric_tail(base, qterm_ref, rowterm_ref, metric)


def _coarse_kernel(
    q_ref,  # (m_blk, d_blk) int8
    codes_ref,  # (n_blk, w_blk) uint32
    scale_ref,  # (1, n_blk)
    offset_ref,  # (1, n_blk)
    cluster_ref,  # (1, n_blk) int32
    ipq_ref,  # (m_blk, C)
    qterm_ref,  # (1, m_blk)
    rowterm_ref,  # (1, n_blk)
    qscale_ref,  # (1, m_blk) per-query int8 scale
    qcorr_ref,  # (1, m_blk) per-query residual correction
    out_ref,  # (m_blk, n_blk)
    acc_ref,  # scratch (m_blk, n_blk) int32
    *,
    b: int,
    n_d_blocks: int,
    metric: str,
):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _coarse_accumulate(q_ref, codes_ref, acc_ref, b=b)

    @pl.when(k_idx == n_d_blocks - 1)
    def _epilogue():
        out_ref[...] = _coarse_epilogue_scores(
            acc_ref[...], qscale_ref, qcorr_ref, scale_ref, offset_ref,
            cluster_ref, ipq_ref, qterm_ref, rowterm_ref, metric=metric,
        ).astype(out_ref.dtype)


def _coarse_topk_kernel(
    q_ref,
    codes_ref,
    scale_ref,
    offset_ref,
    cluster_ref,
    ipq_ref,
    qterm_ref,
    rowterm_ref,
    qscale_ref,
    qcorr_ref,
    *rest,  # [mask_ref,] vals_ref, ids_ref, acc_ref — see use_mask
    b: int,
    n_d_blocks: int,
    metric: str,
    k_tilde: int,
    block_n: int,
    n_real: int,
    use_mask: bool,
):
    # trailing refs follow the _topk_kernel convention: an optional
    # runtime (1, n_blk) int32 row-validity operand, then the vals/ids
    # candidate-strip outputs and the int32 accumulator scratch
    if use_mask:
        mask_ref, vals_ref, ids_ref, acc_ref = rest
    else:
        vals_ref, ids_ref, acc_ref = rest
    k_idx = pl.program_id(2)
    col0 = pl.program_id(0) * block_n

    @pl.when(k_idx == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _coarse_accumulate(q_ref, codes_ref, acc_ref, b=b)

    @pl.when(k_idx == n_d_blocks - 1)
    def _select():
        scores = _coarse_epilogue_scores(
            acc_ref[...], qscale_ref, qcorr_ref, scale_ref, offset_ref,
            cluster_ref, ipq_ref, qterm_ref, rowterm_ref, metric=metric,
        )  # (m_blk, n_blk) fp32
        if use_mask:
            valid = jnp.broadcast_to(mask_ref[...] != 0, scores.shape)
        else:
            local = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
            valid = (local + col0) < n_real
        _select_topk(scores, valid, col0, k_tilde, vals_ref, ids_ref)


def _pad_coarse_operands(
    codes, q_int8, q_scale, q_corr, scale, offset, cluster,
    ip_q_landmarks, qterm, rowterm, *, b, block_m, block_n, block_d,
):
    """Coarse-operand padding: the shared 8-operand block (query side is
    the int8 matrix — zero padding contributes nothing to the integer
    accumulation) plus the two per-query (1, m_p) epilogue vectors."""
    operands, g = _pad_operands(
        codes, q_int8, scale, offset, cluster, ip_q_landmarks,
        qterm, rowterm,
        b=b, block_m=block_m, block_n=block_n, block_d=block_d,
    )
    m, m_p = g["m"], g["m_p"]
    qscale2 = jnp.pad(
        q_scale.astype(jnp.float32), (0, m_p - m)
    ).reshape(1, m_p)
    qcorr2 = jnp.pad(
        q_corr.astype(jnp.float32), (0, m_p - m)
    ).reshape(1, m_p)
    return operands + (qscale2, qcorr2), g


def _coarse_in_specs(g):
    return _in_specs(g) + [
        pl.BlockSpec((1, g["block_m"]), lambda i, j, k_, *_: (0, j)),
        pl.BlockSpec((1, g["block_m"]), lambda i, j, k_, *_: (0, j)),
    ]


@functools.partial(
    jax.jit,
    static_argnames=(
        "b", "metric", "block_m", "block_n", "block_d", "interpret",
    ),
)
def ash_score_coarse_pallas(
    codes: jax.Array,  # (n, Wd) uint32
    q_int8: jax.Array,  # (m, d_pad) int8 quantized query projections
    q_scale: jax.Array,  # (m,) per-query symmetric scale
    q_corr: jax.Array,  # (m,) residual correction term
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,)
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None = None,
    rowterm: jax.Array | None = None,
    *,
    b: int,
    metric: str = "dot",
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """Materializing coarse scan: (m, n) fp32 symmetric int8 scores,
    higher-is-better.  Matches ``ref.ash_score_coarse_ref`` bitwise.

    No ``compute_dtype`` knob: the matmul operand dtype is fixed by the
    bitrate (int8 for b <= 4, int32 for b=8) and accumulation is always
    int32 — the whole point of the coarse pass.
    """
    assert metric in METRICS, metric
    operands, g = _pad_coarse_operands(
        codes, q_int8, q_scale, q_corr, scale, offset, cluster,
        ip_q_landmarks, qterm, rowterm,
        b=b, block_m=block_m, block_n=block_n, block_d=block_d,
    )
    out = pl.pallas_call(
        functools.partial(
            _coarse_kernel,
            b=b,
            n_d_blocks=g["grid"][2],
            metric=metric,
        ),
        grid=g["grid"],
        in_specs=_coarse_in_specs(g),
        out_specs=pl.BlockSpec(
            (g["block_m"], g["block_n"]), lambda i, j, k_: (j, i)
        ),
        out_shape=jax.ShapeDtypeStruct((g["m_p"], g["n_p"]), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g["block_m"], g["block_n"]), jnp.int32)
        ],
        interpret=interpret,
    )(*operands)
    return out[: g["m"], : g["n"]]


@functools.partial(
    jax.jit,
    static_argnames=(
        "b", "k", "k_tilde", "metric", "block_m", "block_n", "block_d",
        "interpret",
    ),
)
def ash_score_coarse_topk_pallas(
    codes: jax.Array,  # (n, Wd) uint32
    q_int8: jax.Array,  # (m, d_pad) int8
    q_scale: jax.Array,  # (m,)
    q_corr: jax.Array,  # (m,)
    scale: jax.Array,  # (n,)
    offset: jax.Array,  # (n,)
    cluster: jax.Array,  # (n,)
    ip_q_landmarks: jax.Array,  # (m, C)
    qterm: jax.Array | None = None,
    rowterm: jax.Array | None = None,
    n_valid: jax.Array | None = None,  # scalar: rows >= this are masked
    row_valid: jax.Array | None = None,  # (n,) bool/int: 0 = masked row
    *,
    b: int,
    k: int,
    k_tilde: int | None = None,
    metric: str = "dot",
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused coarse scan + shortlist selection: top-k (scores, ids),
    each (m, k) — the FIRST PASS of the coarse -> refine pipeline, so
    ``k`` here is the shortlist size L, not the final k.

    Same selection machinery, mask folding, and ``lax.top_k`` tie
    contract as :func:`ash_score_topk_pallas`, over the integer-domain
    coarse scores: exactly ``top_k(ash_score_coarse_pallas(...), k)``
    for ``k <= k̃``.  The (m, n) coarse score matrix never reaches HBM;
    the emitted ids feed ``ash_score_gather_topk_pallas`` for the
    asymmetric refine.
    """
    assert metric in METRICS, metric
    n = codes.shape[0]
    operands, g = _pad_coarse_operands(
        codes, q_int8, q_scale, q_corr, scale, offset, cluster,
        ip_q_landmarks, qterm, rowterm,
        b=b, block_m=block_m, block_n=block_n, block_d=block_d,
    )
    use_mask = n_valid is not None or row_valid is not None
    in_specs = _coarse_in_specs(g)
    if use_mask:
        if row_valid is None:
            mask = jnp.ones((n,), jnp.int32)
        else:
            mask = row_valid.astype(jnp.int32)
        if n_valid is not None:
            mask = mask * (
                jnp.arange(n, dtype=jnp.int32)
                < jnp.asarray(n_valid, jnp.int32)
            ).astype(jnp.int32)
        operands = operands + (
            jnp.pad(mask, (0, g["n_p"] - n)).reshape(1, g["n_p"]),
        )
        in_specs = in_specs + [
            pl.BlockSpec((1, g["block_n"]), lambda i, j, k_: (0, i)),
        ]
    if k_tilde is None:
        k_tilde = k
    k_tilde = min(k_tilde, g["block_n"])
    n_blocks = g["grid"][0]
    if k > n_blocks * k_tilde:
        raise ValueError(
            f"k={k} exceeds the {n_blocks} x k_tilde={k_tilde} candidate "
            f"strip; raise k_tilde or use the materializing kernel"
        )
    vals, ids = pl.pallas_call(
        functools.partial(
            _coarse_topk_kernel,
            b=b,
            n_d_blocks=g["grid"][2],
            metric=metric,
            k_tilde=k_tilde,
            block_n=g["block_n"],
            n_real=n,
            use_mask=use_mask,
        ),
        grid=g["grid"],
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec(
                (g["block_m"], k_tilde), lambda i, j, k_: (j, i)
            ),
            pl.BlockSpec(
                (g["block_m"], k_tilde), lambda i, j, k_: (j, i)
            ),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g["m_p"], n_blocks * k_tilde), jnp.float32),
            jax.ShapeDtypeStruct((g["m_p"], n_blocks * k_tilde), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g["block_m"], g["block_n"]), jnp.int32)
        ],
        interpret=interpret,
    )(*operands)
    vals, ids = vals[: g["m"]], ids[: g["m"]]
    neg, sid = jax.lax.sort((-vals, ids), dimension=1, num_keys=2)
    out_s, out_i = -neg[:, :k], sid[:, :k]
    return out_s, jnp.where(out_i == _ID_SENTINEL, -1, out_i)
