"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to auto: Python-interpret the kernel body on CPU
(this container), compile on TPU.  Both paths are validated against the
pure-jnp oracles in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quantization as Q
from repro.core import scoring as S
from repro.core.types import ASHModel, ASHPayload, QueryPrep
from repro.kernels import ref
from repro.kernels.ash_score import ash_score_pallas
from repro.kernels.ash_kv_attn import ash_kv_attn_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() == "cpu"


def ash_score(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Drop-in fused replacement for scoring.score_dot: (m, n) fp32.

    use_pallas=None (auto): the fused kernel on TPU, the identical-
    semantics jnp oracle on CPU (interpret mode is for validation, far
    too slow for serving).
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    d_pad = payload.codes.shape[1] * Q.codes_per_word(payload.b)
    q_proj = prep.q_proj
    if q_proj.shape[-1] < d_pad:
        q_proj = jnp.pad(q_proj, ((0, 0), (0, d_pad - q_proj.shape[-1])))
    args = (
        payload.codes,
        q_proj,
        payload.scale.astype(jnp.float32),
        payload.offset.astype(jnp.float32),
        payload.cluster,
        prep.ip_q_landmarks,
    )
    if not use_pallas:
        return ref.ash_score_ref(*args, b=payload.b)
    return ash_score_pallas(
        *args, b=payload.b, interpret=interpret,
        compute_dtype=compute_dtype,
    )


def ash_kv_attention(
    q_k: jax.Array,  # (..., dk) projected queries (W_k q)
    k_codes: jax.Array,  # (..., S, Wk)
    k_scale: jax.Array,  # (..., S)
    k_bias: jax.Array,  # (..., S)
    v_codes: jax.Array,  # (..., S, Wv)
    v_scale: jax.Array,  # (..., S)
    mask: jax.Array,  # (..., S)
    *,
    b_k: int,
    b_v: int,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched (vmapped over leading dims) ASH-KV decode attention.

    Returns the reduced-space accumulation (..., dv); caller decodes with
    W_v^T and adds mu_v.
    """
    if interpret is None:
        interpret = _auto_interpret()

    if not use_pallas:
        def one(qk, kc, ks, kb, vc, vs, mk):
            acc, _ = ref.ash_kv_attn_ref(
                qk, kc, ks, kb, vc, vs, b_k, b_v, mask=mk
            )
            return acc
    else:
        def one(qk, kc, ks, kb, vc, vs, mk):
            return ash_kv_attn_pallas(
                qk, kc, ks, kb, vc, vs, mk,
                b_k=b_k, b_v=b_v, interpret=interpret,
            )

    fn = one
    batch_dims = q_k.ndim - 1
    for _ in range(batch_dims):
        fn = jax.vmap(fn)
    return fn(q_k, k_codes, k_scale, k_bias, v_codes, v_scale, mask)
