"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to auto: Python-interpret the kernel body on CPU
(this container), compile on TPU.  Both paths are validated against the
pure-jnp oracles in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quantization as Q
from repro.core import scoring as S
from repro.core.types import ASHModel, ASHPayload, ASHStats, QueryPrep
from repro.kernels import ref
from repro.kernels.ash_score import (
    ash_score_gather_pallas,
    ash_score_gather_topk_pallas,
    ash_score_pallas,
    ash_score_topk_pallas,
)
from repro.kernels.ash_kv_attn import ash_kv_attn_pallas

_EPS = 1e-12

# Largest per-tile partial top-k the fused-selection path accepts: the
# selection epilogue is k̃ VPU sweeps per tile and 2·k̃·n_blocks VMEM
# candidate words per query row, so the index layers fall back to
# materialize-then-top_k beyond this (scores of the two kernels are
# identical per element, so the routing choice never changes results).
FUSED_TOPK_MAX_K = 128


def _auto_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _metric_operands(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    stats: ASHStats | None,
    metric: str,
):
    """(qterm, rowterm) epilogue vectors for the fused kernel/oracle.

    dot needs none; l2/cos derive theirs from the encode-time
    ``ASHStats`` (built on the fly when ``stats`` is None — that
    fallback unpacks the database once and defeats the fused path's
    purpose, so index backends persist stats alongside the payload).
    """
    if metric == "dot":
        return None, None
    if stats is None:
        stats = S.payload_stats(model, payload)
    if metric == "l2":
        res = stats.res_norm.astype(jnp.float32)
        rowterm = (
            res * res
            + 2.0 * stats.ip_x_mu.astype(jnp.float32)
            - model.landmark_sq_norms[payload.cluster]
        )  # == ||x||^2 recovered: -l2 = 2<q,x> - ||q||^2 - ||x||^2
        return prep.q_sq_norm.astype(jnp.float32), rowterm
    if metric == "cos":
        qterm = 1.0 / jnp.sqrt(jnp.maximum(prep.q_sq_norm, _EPS))
        rowterm = 1.0 / jnp.sqrt(jnp.maximum(stats.x_sq, _EPS))
        return qterm.astype(jnp.float32), rowterm.astype(jnp.float32)
    raise ValueError(metric)


def _score_args(prep: QueryPrep, payload: ASHPayload):
    d_pad = payload.codes.shape[1] * Q.codes_per_word(payload.b)
    q_proj = prep.q_proj
    if q_proj.shape[-1] < d_pad:
        q_proj = jnp.pad(q_proj, ((0, 0), (0, d_pad - q_proj.shape[-1])))
    return (
        payload.codes,
        q_proj,
        payload.scale.astype(jnp.float32),
        payload.offset.astype(jnp.float32),
        payload.cluster,
        prep.ip_q_landmarks,
    )


def ash_score(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Fused all-metric scoring: (m, n) fp32, higher-is-better.

    metric="dot" is a drop-in fused replacement for scoring.score_dot;
    "l2"/"cos" apply the stats-driven epilogues (negated squared
    distance / Eq. A.5 cosine) without unpacking the database.

    use_pallas=None (auto): the fused kernel on TPU, the identical-
    semantics jnp oracle on CPU (interpret mode is for validation, far
    too slow for serving).
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    args = _score_args(prep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        return ref.ash_score_metric_ref(
            *args, qterm, rowterm, b=payload.b, metric=metric
        )
    return ash_score_pallas(
        *args, qterm, rowterm, b=payload.b, metric=metric,
        interpret=interpret, compute_dtype=compute_dtype,
    )


def mask_valid_rows(
    scores: jax.Array, n_valid=None, row_valid=None
) -> jax.Array:
    """Force masked columns to ``-inf`` — the materialized-path
    equivalent of the fused kernel's runtime row-validity mask operand.
    ``n_valid`` (static int or traced scalar) masks columns at/beyond
    it; ``row_valid`` ((n,) bool) masks tombstoned rows."""
    return ref.mask_rows_ref(scores, n_valid, row_valid)


def ash_score_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    k: int,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    k_tilde: int | None = None,
    n_valid=None,
    row_valid=None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Fused scan + on-chip selection: top-k (scores, row ids), (m, k).

    On TPU the (m, n) score matrix never reaches HBM — each output tile
    emits a partial top-k̃ merged by one small two-key sort.  Results
    equal ``lax.top_k(ash_score(...), k)`` exactly (values, ids, tie
    order) for ``k <= k̃`` (default ``k̃ = k``).  The CPU oracle
    materializes and calls ``lax.top_k`` — identical semantics.

    ``n_valid`` (int or traced scalar) masks rows at/beyond it to
    ``-inf`` inside the scan — the sharded backend's per-shard pad-row
    masking; ``row_valid`` ((n,) bool) additionally masks tombstoned
    rows.  Both fold into the kernel's single runtime mask operand, so
    deletes never trigger a recompile.
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    args = _score_args(prep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        scores = ref.ash_score_metric_ref(
            *args, qterm, rowterm, b=payload.b, metric=metric
        )
        scores = mask_valid_rows(scores, n_valid, row_valid)
        return jax.lax.top_k(scores, k)
    return ash_score_topk_pallas(
        *args, qterm, rowterm, n_valid, row_valid, b=payload.b, k=k,
        k_tilde=k_tilde, metric=metric, interpret=interpret,
        compute_dtype=compute_dtype,
    )


def ash_score_gather(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    rows: jax.Array,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Fused masked-gather scoring: (m, R) fp32, higher-is-better.

    Query i scores its own candidate list ``rows[i]`` (payload row ids,
    -1 = padding → score ``-inf``) — the IVF partial-probe primitive.
    On TPU the kernel DMA-gathers packed code rows via scalar prefetch;
    the CPU oracle (``ref.ash_score_gather_ref``) is rowwise and
    batch-shape-invariant, so engine bucketing stays bit-identical.
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    codes, q_proj, scale, offset, cluster, ipq = _score_args(prep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        return ref.ash_score_gather_ref(
            codes, rows, q_proj, scale, offset, cluster, ipq,
            qterm, rowterm, b=payload.b, metric=metric,
        )
    return ash_score_gather_pallas(
        codes, rows, q_proj, scale, offset, cluster, ipq, qterm, rowterm,
        b=payload.b, metric=metric, interpret=interpret,
        compute_dtype=compute_dtype,
    )


def ash_score_gather_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    rows: jax.Array,
    k: int,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    k_tilde: int | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Fused masked-gather scan + selection: (scores, payload rows),
    each (m, k); pad slots come back score ``-inf`` / row -1.

    Equal to ``top_k(ash_score_gather(...), k)`` with positions mapped
    back through ``rows`` — on TPU without the (m, R) score matrix ever
    reaching HBM.  Requires ``k <= rows.shape[1]``.
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    codes, q_proj, scale, offset, cluster, ipq = _score_args(prep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        scores = ref.ash_score_gather_ref(
            codes, rows, q_proj, scale, offset, cluster, ipq,
            qterm, rowterm, b=payload.b, metric=metric,
        )
        s, pos = jax.lax.top_k(scores, k)
        return s, jnp.take_along_axis(rows, pos, axis=1)
    return ash_score_gather_topk_pallas(
        codes, rows, q_proj, scale, offset, cluster, ipq, qterm, rowterm,
        b=payload.b, k=k, k_tilde=k_tilde, metric=metric,
        interpret=interpret, compute_dtype=compute_dtype,
    )


def ash_kv_attention(
    q_k: jax.Array,  # (..., dk) projected queries (W_k q)
    k_codes: jax.Array,  # (..., S, Wk)
    k_scale: jax.Array,  # (..., S)
    k_bias: jax.Array,  # (..., S)
    v_codes: jax.Array,  # (..., S, Wv)
    v_scale: jax.Array,  # (..., S)
    mask: jax.Array,  # (..., S)
    *,
    b_k: int,
    b_v: int,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched (vmapped over leading dims) ASH-KV decode attention.

    Returns the reduced-space accumulation (..., dv); caller decodes with
    W_v^T and adds mu_v.
    """
    if interpret is None:
        interpret = _auto_interpret()

    if not use_pallas:
        def one(qk, kc, ks, kb, vc, vs, mk):
            acc, _ = ref.ash_kv_attn_ref(
                qk, kc, ks, kb, vc, vs, b_k, b_v, mask=mk
            )
            return acc
    else:
        def one(qk, kc, ks, kb, vc, vs, mk):
            return ash_kv_attn_pallas(
                qk, kc, ks, kb, vc, vs, mk,
                b_k=b_k, b_v=b_v, interpret=interpret,
            )

    fn = one
    batch_dims = q_k.ndim - 1
    for _ in range(batch_dims):
        fn = jax.vmap(fn)
    return fn(q_k, k_codes, k_scale, k_bias, v_codes, v_scale, mask)
