"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to auto: Python-interpret the kernel body on CPU
(this container), compile on TPU.  Both paths are validated against the
pure-jnp oracles in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quantization as Q
from repro.core import scoring as S
from repro.core.types import (
    ASHModel, ASHPayload, ASHStats, CoarseCodes, CoarseQueryPrep,
    QueryPrep,
)
from repro.kernels import ref
from repro.kernels.ash_score import (
    ash_score_coarse_pallas,
    ash_score_coarse_topk_pallas,
    ash_score_gather_pallas,
    ash_score_gather_topk_pallas,
    ash_score_pallas,
    ash_score_topk_pallas,
)
from repro.kernels.ash_kv_attn import ash_kv_attn_pallas

_EPS = 1e-12

# Largest per-tile partial top-k the fused-selection path accepts: the
# selection epilogue is k̃ VPU sweeps per tile and 2·k̃·n_blocks VMEM
# candidate words per query row, so the index layers fall back to
# materialize-then-top_k beyond this (scores of the two kernels are
# identical per element, so the routing choice never changes results).
FUSED_TOPK_MAX_K = 128

# Default coarse shortlist size L for the coarse -> refine pipeline,
# picked by the recall-vs-shortlist sweep in benchmarks/kernel_bench.py
# (kernel/coarse_shortlist_sweep): the smallest power of two whose
# coarse-shortlist recall@10 against the pure asymmetric path clears
# 99% at the benchmark corpus shape.  Small L matters beyond recall:
# selection cost grows with L on every backend (k̃ VPU sweeps per tile
# fused, O(L) partial-selection work in XLA:CPU's TopK), so the sweep's
# floor is also the fast point — ``execute_plan`` raises L to the
# requested top-k/rerank depth when callers need more.
DEFAULT_SHORTLIST = 32


def _auto_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _metric_operands(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    stats: ASHStats | None,
    metric: str,
):
    """(qterm, rowterm) epilogue vectors for the fused kernel/oracle.

    dot needs none; l2/cos derive theirs from the encode-time
    ``ASHStats`` (built on the fly when ``stats`` is None — that
    fallback unpacks the database once and defeats the fused path's
    purpose, so index backends persist stats alongside the payload).
    """
    if metric == "dot":
        return None, None
    if stats is None:
        stats = S.payload_stats(model, payload)
    if metric == "l2":
        res = stats.res_norm.astype(jnp.float32)
        rowterm = (
            res * res
            + 2.0 * stats.ip_x_mu.astype(jnp.float32)
            - model.landmark_sq_norms[payload.cluster]
        )  # == ||x||^2 recovered: -l2 = 2<q,x> - ||q||^2 - ||x||^2
        return prep.q_sq_norm.astype(jnp.float32), rowterm
    if metric == "cos":
        qterm = 1.0 / jnp.sqrt(jnp.maximum(prep.q_sq_norm, _EPS))
        rowterm = 1.0 / jnp.sqrt(jnp.maximum(stats.x_sq, _EPS))
        return qterm.astype(jnp.float32), rowterm.astype(jnp.float32)
    raise ValueError(metric)


def _score_args(prep: QueryPrep, payload: ASHPayload):
    d_pad = payload.codes.shape[1] * Q.codes_per_word(payload.b)
    q_proj = prep.q_proj
    if q_proj.shape[-1] < d_pad:
        q_proj = jnp.pad(q_proj, ((0, 0), (0, d_pad - q_proj.shape[-1])))
    return (
        payload.codes,
        q_proj,
        payload.scale.astype(jnp.float32),
        payload.offset.astype(jnp.float32),
        payload.cluster,
        prep.ip_q_landmarks,
    )


def ash_score(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Fused all-metric scoring: (m, n) fp32, higher-is-better.

    metric="dot" is a drop-in fused replacement for scoring.score_dot;
    "l2"/"cos" apply the stats-driven epilogues (negated squared
    distance / Eq. A.5 cosine) without unpacking the database.

    use_pallas=None (auto): the fused kernel on TPU, the identical-
    semantics jnp oracle on CPU (interpret mode is for validation, far
    too slow for serving).
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    args = _score_args(prep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        return ref.ash_score_metric_ref(
            *args, qterm, rowterm, b=payload.b, metric=metric
        )
    return ash_score_pallas(
        *args, qterm, rowterm, b=payload.b, metric=metric,
        interpret=interpret, compute_dtype=compute_dtype,
    )


def mask_valid_rows(
    scores: jax.Array, n_valid=None, row_valid=None
) -> jax.Array:
    """Force masked columns to ``-inf`` — the materialized-path
    equivalent of the fused kernel's runtime row-validity mask operand.
    ``n_valid`` (static int or traced scalar) masks columns at/beyond
    it; ``row_valid`` ((n,) bool) masks tombstoned rows."""
    return ref.mask_rows_ref(scores, n_valid, row_valid)


def ash_score_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    k: int,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    k_tilde: int | None = None,
    n_valid=None,
    row_valid=None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Fused scan + on-chip selection: top-k (scores, row ids), (m, k).

    On TPU the (m, n) score matrix never reaches HBM — each output tile
    emits a partial top-k̃ merged by one small two-key sort.  Results
    equal ``lax.top_k(ash_score(...), k)`` exactly (values, ids, tie
    order) for ``k <= k̃`` (default ``k̃ = k``).  The CPU oracle
    materializes and calls ``lax.top_k`` — identical semantics.

    ``n_valid`` (int or traced scalar) masks rows at/beyond it to
    ``-inf`` inside the scan — the sharded backend's per-shard pad-row
    masking; ``row_valid`` ((n,) bool) additionally masks tombstoned
    rows.  Both fold into the kernel's single runtime mask operand, so
    deletes never trigger a recompile.
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    args = _score_args(prep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        scores = ref.ash_score_metric_ref(
            *args, qterm, rowterm, b=payload.b, metric=metric
        )
        scores = mask_valid_rows(scores, n_valid, row_valid)
        return jax.lax.top_k(scores, k)
    return ash_score_topk_pallas(
        *args, qterm, rowterm, n_valid, row_valid, b=payload.b, k=k,
        k_tilde=k_tilde, metric=metric, interpret=interpret,
        compute_dtype=compute_dtype,
    )


def ash_score_gather(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    rows: jax.Array,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Fused masked-gather scoring: (m, R) fp32, higher-is-better.

    Query i scores its own candidate list ``rows[i]`` (payload row ids,
    -1 = padding → score ``-inf``) — the IVF partial-probe primitive.
    On TPU the kernel DMA-gathers packed code rows via scalar prefetch;
    the CPU oracle (``ref.ash_score_gather_ref``) is rowwise and
    batch-shape-invariant, so engine bucketing stays bit-identical.
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    codes, q_proj, scale, offset, cluster, ipq = _score_args(prep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        return ref.ash_score_gather_ref(
            codes, rows, q_proj, scale, offset, cluster, ipq,
            qterm, rowterm, b=payload.b, metric=metric,
        )
    return ash_score_gather_pallas(
        codes, rows, q_proj, scale, offset, cluster, ipq, qterm, rowterm,
        b=payload.b, metric=metric, interpret=interpret,
        compute_dtype=compute_dtype,
    )


def ash_score_gather_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    rows: jax.Array,
    k: int,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    k_tilde: int | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    compute_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Fused masked-gather scan + selection: (scores, payload rows),
    each (m, k); pad slots come back score ``-inf`` / row -1.

    Equal to ``top_k(ash_score_gather(...), k)`` with positions mapped
    back through ``rows`` — on TPU without the (m, R) score matrix ever
    reaching HBM.  Requires ``k <= rows.shape[1]``.
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    codes, q_proj, scale, offset, cluster, ipq = _score_args(prep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        scores = ref.ash_score_gather_ref(
            codes, rows, q_proj, scale, offset, cluster, ipq,
            qterm, rowterm, b=payload.b, metric=metric,
        )
        s, pos = jax.lax.top_k(scores, k)
        return s, jnp.take_along_axis(rows, pos, axis=1)
    return ash_score_gather_topk_pallas(
        codes, rows, q_proj, scale, offset, cluster, ipq, qterm, rowterm,
        b=payload.b, k=k, k_tilde=k_tilde, metric=metric,
        interpret=interpret, compute_dtype=compute_dtype,
    )


def _coarse_inputs(
    prep: QueryPrep,
    payload: ASHPayload,
    coarse: CoarseCodes | None,
    cprep: CoarseQueryPrep | None,
):
    """Resolve the coarse cache + per-query quantization, building each
    on the fly when absent (the cache fallback unpacks the database once
    per call — index backends persist ``CoarseCodes`` alongside
    ``ASHStats`` to avoid exactly that)."""
    if coarse is None:
        coarse = S.coarse_codes(payload)
    if cprep is None:
        cprep = S.prepare_coarse_queries(prep, coarse.mean)
    return coarse, cprep


def _coarse_score_args(
    prep: QueryPrep, cprep: CoarseQueryPrep, payload: ASHPayload
):
    """Kernel/oracle operand tuple; zero-pads q_int8 to the packed-code
    width (zero int8 columns add nothing to the accumulation)."""
    d_pad = payload.codes.shape[1] * Q.codes_per_word(payload.b)
    qi = cprep.q_int8
    if qi.shape[-1] < d_pad:
        qi = jnp.pad(qi, ((0, 0), (0, d_pad - qi.shape[-1])))
    return (
        payload.codes,
        qi,
        cprep.q_scale.astype(jnp.float32),
        cprep.q_corr.astype(jnp.float32),
        payload.scale.astype(jnp.float32),
        payload.offset.astype(jnp.float32),
        payload.cluster,
        prep.ip_q_landmarks,
    )


# The coarse oracle branches are jitted at module level: the coarse
# bitwise contract (kernel == oracle, exact-integer accumulation + an
# identical float epilogue) holds when both sides compile as fused XLA
# programs — eager op-by-op dispatch blocks the FMA contraction XLA
# applies inside fusions, shifting the epilogue by an ulp.  Index
# backends already call these inside their own jit (nested jit inlines);
# the module-level jit makes standalone calls identical.
@functools.partial(jax.jit, static_argnames=("b", "metric"))
def _coarse_ref_scores(
    codes, qi, qs, qc, scale, offset, cluster, ipq, qterm, rowterm,
    values, *, b, metric,
):
    return ref.ash_score_coarse_ref(
        codes, qi, qs, qc, scale, offset, cluster, ipq, qterm, rowterm,
        b=b, metric=metric, values=values,
    )


@functools.partial(jax.jit, static_argnames=("b", "metric", "k"))
def _coarse_ref_topk(
    codes, qi, qs, qc, scale, offset, cluster, ipq, qterm, rowterm,
    values, n_valid, row_valid, *, b, metric, k,
):
    scores = ref.ash_score_coarse_ref(
        codes, qi, qs, qc, scale, offset, cluster, ipq, qterm, rowterm,
        b=b, metric=metric, values=values,
    )
    scores = ref.mask_rows_ref(scores, n_valid, row_valid)
    return jax.lax.top_k(scores, k)


@functools.partial(jax.jit, static_argnames=("b", "metric"))
def _coarse_gather_ref_scores(
    codes, rows, qi, qs, qc, scale, offset, cluster, ipq, qterm,
    rowterm, values, *, b, metric,
):
    return ref.ash_score_coarse_gather_ref(
        codes, rows, qi, qs, qc, scale, offset, cluster, ipq, qterm,
        rowterm, b=b, metric=metric, values=values,
    )


def ash_score_coarse(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    coarse: CoarseCodes | None = None,
    cprep: CoarseQueryPrep | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Symmetric int8 coarse scores: (m, n) fp32, higher-is-better.

    The first-pass estimator of the coarse -> refine pipeline: queries
    are int8-quantized per query (``core.prepare_coarse_queries``) and
    the scan accumulates integer products — int8 MXU throughput on TPU,
    one cached-values BLAS matmul (no per-call unpack) on CPU.  Oracle
    and kernel are BITWISE equal (exact-integer accumulation), so the
    routing choice never changes results; both differ from the
    asymmetric score by design (quantization of the query side).
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    coarse, cprep = _coarse_inputs(prep, payload, coarse, cprep)
    args = _coarse_score_args(prep, cprep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        return _coarse_ref_scores(
            *args, qterm, rowterm, coarse.values, b=payload.b,
            metric=metric,
        )
    return ash_score_coarse_pallas(
        *args, qterm, rowterm, b=payload.b, metric=metric,
        interpret=interpret,
    )


def ash_score_coarse_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    k: int,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    coarse: CoarseCodes | None = None,
    cprep: CoarseQueryPrep | None = None,
    k_tilde: int | None = None,
    n_valid=None,
    row_valid=None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused coarse scan + shortlist selection: top-k (scores, ids).

    ``k`` is the SHORTLIST size L of the coarse -> refine pipeline, so
    unlike :func:`ash_score_topk` this wrapper routes its own
    ``FUSED_TOPK_MAX_K`` fallback (shortlists routinely exceed the
    fused-selection cap): beyond it the materializing coarse kernel +
    ``lax.top_k`` runs instead, with identical per-element scores.
    Masking semantics (``n_valid``/``row_valid``) match
    :func:`ash_score_topk`.
    """
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if interpret is None:
        interpret = _auto_interpret()
    coarse, cprep = _coarse_inputs(prep, payload, coarse, cprep)
    args = _coarse_score_args(prep, cprep, payload)
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    if not use_pallas:
        return _coarse_ref_topk(
            *args, qterm, rowterm, coarse.values, n_valid, row_valid,
            b=payload.b, metric=metric, k=k,
        )
    if k > FUSED_TOPK_MAX_K:
        scores = ash_score_coarse_pallas(
            *args, qterm, rowterm, b=payload.b, metric=metric,
            interpret=interpret,
        )
        scores = mask_valid_rows(scores, n_valid, row_valid)
        return jax.lax.top_k(scores, k)
    return ash_score_coarse_topk_pallas(
        *args, qterm, rowterm, n_valid, row_valid, b=payload.b, k=k,
        k_tilde=k_tilde, metric=metric, interpret=interpret,
    )


def ash_score_coarse_gather(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    rows: jax.Array,
    *,
    metric: str = "dot",
    stats: ASHStats | None = None,
    coarse: CoarseCodes | None = None,
    cprep: CoarseQueryPrep | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Coarse scores over per-query candidate lists: (m, R) fp32, pad
    ids (-1) come back ``-inf`` — the IVF partial-probe coarse pass.

    Runs the jnp oracle on every backend for now: candidate lists are
    small relative to dense scans, so the integer-matmul win is marginal
    and a DMA-gather coarse kernel is future work (the refine stage
    still uses the fused asymmetric gather kernel).
    """
    del use_pallas, interpret  # oracle-only (see docstring)
    coarse, cprep = _coarse_inputs(prep, payload, coarse, cprep)
    codes, qi, qs, qc, scale, offset, cluster, ipq = _coarse_score_args(
        prep, cprep, payload
    )
    qterm, rowterm = _metric_operands(model, prep, payload, stats, metric)
    return _coarse_gather_ref_scores(
        codes, rows, qi, qs, qc, scale, offset, cluster, ipq,
        qterm, rowterm, coarse.values, b=payload.b, metric=metric,
    )


def sort_candidate_rows(rows: jax.Array) -> jax.Array:
    """Ascending-id sort of a (m, R) candidate-row matrix with -1 pads
    pushed to the end.

    The gather kernels break score ties by candidate POSITION, so
    feeding the refine stage an ascending-id list makes its tie order
    the ``lax.top_k`` convention (lowest id first) — required for the
    shortlist pipeline to match dense scans whenever the shortlist
    covers every survivor.
    """
    big = jnp.iinfo(jnp.int32).max
    s = jnp.sort(jnp.where(rows < 0, big, rows.astype(jnp.int32)), axis=1)
    return jnp.where(s == big, -1, s)


def _refine_topk(
    model, prep, payload, rows, k, *, metric, stats, use_pallas,
    interpret,
):
    """Asymmetric refine stage shared by both pipelines, honouring the
    FUSED_TOPK_MAX_K routing contract for large refine shortlists."""
    if use_pallas is None:
        use_pallas = not _auto_interpret()
    if use_pallas and k > FUSED_TOPK_MAX_K:
        sc = ash_score_gather(
            model, prep, payload, rows, metric=metric, stats=stats,
            use_pallas=use_pallas, interpret=interpret,
        )
        s, pos = jax.lax.top_k(sc, k)
        return s, jnp.take_along_axis(rows, pos, axis=1)
    return ash_score_gather_topk(
        model, prep, payload, rows, k, metric=metric, stats=stats,
        use_pallas=use_pallas, interpret=interpret,
    )


def coarse_refine_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    k: int,
    *,
    shortlist: int,
    metric: str = "dot",
    stats: ASHStats | None = None,
    coarse: CoarseCodes | None = None,
    cprep: CoarseQueryPrep | None = None,
    n_valid=None,
    row_valid=None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Dense two-stage scan: int8 coarse shortlist (size L) refined by
    the fused asymmetric gather — top-k (scores, row ids), (m, k).

    Stage 1 selects the L highest COARSE scores (masked rows never
    survive: slots whose coarse score is ``-inf`` are dropped to pad id
    -1 so the refine cannot resurrect them).  Stage 2 rescores the
    shortlist with the full asymmetric Eq. (20) path, ids ascending so
    ties land in ``lax.top_k`` order.  Requires ``k <= shortlist``;
    callers that also exact-rerank pass ``k = refine_k``.
    """
    L = min(shortlist, payload.n)
    if k > L:
        raise ValueError(f"k={k} exceeds shortlist={L}")
    svals, ids = ash_score_coarse_topk(
        model, prep, payload, L, metric=metric, stats=stats,
        coarse=coarse, cprep=cprep, n_valid=n_valid, row_valid=row_valid,
        use_pallas=use_pallas, interpret=interpret,
    )
    rows = sort_candidate_rows(jnp.where(jnp.isneginf(svals), -1, ids))
    return _refine_topk(
        model, prep, payload, rows, k, metric=metric, stats=stats,
        use_pallas=use_pallas, interpret=interpret,
    )


def coarse_refine_gather_topk(
    model: ASHModel,
    prep: QueryPrep,
    payload: ASHPayload,
    rows: jax.Array,
    k: int,
    *,
    shortlist: int,
    metric: str = "dot",
    stats: ASHStats | None = None,
    coarse: CoarseCodes | None = None,
    cprep: CoarseQueryPrep | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Gathered two-stage scan (IVF partial probes): coarse-score the
    (m, R) candidate lists, keep the top-L rows per query, refine those
    asymmetrically — top-k (scores, payload rows), (m, k)."""
    R = rows.shape[1]
    L = min(shortlist, R)
    if k > L:
        raise ValueError(f"k={k} exceeds shortlist={L}")
    scores = ash_score_coarse_gather(
        model, prep, payload, rows, metric=metric, stats=stats,
        coarse=coarse, cprep=cprep, use_pallas=use_pallas,
        interpret=interpret,
    )
    svals, pos = jax.lax.top_k(scores, L)
    cand = jnp.take_along_axis(rows, pos, axis=1)
    cand = sort_candidate_rows(jnp.where(jnp.isneginf(svals), -1, cand))
    return _refine_topk(
        model, prep, payload, cand, k, metric=metric, stats=stats,
        use_pallas=use_pallas, interpret=interpret,
    )


def ash_kv_attention(
    q_k: jax.Array,  # (..., dk) projected queries (W_k q)
    k_codes: jax.Array,  # (..., S, Wk)
    k_scale: jax.Array,  # (..., S)
    k_bias: jax.Array,  # (..., S)
    v_codes: jax.Array,  # (..., S, Wv)
    v_scale: jax.Array,  # (..., S)
    mask: jax.Array,  # (..., S)
    *,
    b_k: int,
    b_v: int,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched (vmapped over leading dims) ASH-KV decode attention.

    Returns the reduced-space accumulation (..., dv); caller decodes with
    W_v^T and adds mu_v.
    """
    if interpret is None:
        interpret = _auto_interpret()

    if not use_pallas:
        def one(qk, kc, ks, kb, vc, vs, mk):
            acc, _ = ref.ash_kv_attn_ref(
                qk, kc, ks, kb, vc, vs, b_k, b_v, mask=mk
            )
            return acc
    else:
        def one(qk, kc, ks, kb, vc, vs, mk):
            return ash_kv_attn_pallas(
                qk, kc, ks, kb, vc, vs, mk,
                b_k=b_k, b_v=b_v, interpret=interpret,
            )

    fn = one
    batch_dims = q_k.ndim - 1
    for _ in range(batch_dims):
        fn = jax.vmap(fn)
    return fn(q_k, k_codes, k_scale, k_bias, v_codes, v_scale, mask)
