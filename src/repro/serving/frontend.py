"""ServingFrontend — a driver thread that owns the flush cadence.

The bare :class:`~repro.serving.engine.QueryEngine` is caller-driven:
``submit`` flushes inline on size/pressure, ``result()`` flushes the
caller's own group, and timeout flushes only happen if a serving loop
remembers to ``poll()``.  That is fine single-threaded and useless
under concurrency — an eager ``result()`` caller defeats batching by
flushing a half-full bucket, and nobody owns the timeout cadence.

``ServingFrontend`` puts the engine in **driven** mode and runs ONE
dedicated driver thread that owns every flush decision:

* **size-triggered** — the driver wakes the instant a submission makes
  a group flushable (bucket fillable, over-budget bill, or queue
  pressure — an event, not a poll race) and flushes any group that can
  fill the largest batch bucket; sub-bucket submissions don't wake it
  (they ride the poll tick), so a burst costs one driver scan;
* **deadline/timeout-triggered** — each driver tick runs
  ``engine.poll()``, which flushes groups past ``max_wait_s`` and
  groups whose earliest per-request ``deadline_s`` arrived;
* **mutation cadence** — aged or overflowing mutation backlogs apply
  on the driver too (via ``poll``/``flush_ready``).

Caller-facing API:

* ``frontend.submit(...)`` / ``frontend.search(...)`` — thread-safe
  blocking submission from any number of client threads, with
  **bounded-queue backpressure**: when the engine's queued rows exceed
  ``max_queue_rows``, submitters block (on a condition, not a spin)
  until the driver drains space, up to ``submit_timeout_s``.
* ``await frontend.asearch(...)`` — asyncio facade: the ticket's done
  callback bridges to a ``Future`` on the caller's event loop, so an
  async HTTP handler never blocks a worker thread on ``result()``.
* ``frontend.stop(drain=True)`` — graceful shutdown: refuse new
  submissions, serve everything queued (flush reason "drain"), apply
  pending mutations, then join the driver.  ``drain=False`` fails
  queued query tickets with :class:`FrontendClosed` instead (mutations
  still apply — their rows are already staged on the index).

Use it as a context manager::

    with ServingFrontend(engine) as fe:
        t = fe.submit(q, k=10)
        scores, ids = t.result(timeout=1.0)

Every submission path is safe from any thread, and from coroutines via
``asearch``/``asubmit_add``/``asubmit_delete``.  ``engine.stats``
gauges (queue depth, oldest ticket age, flush reasons, queue HWM) stay
live through ``engine.stats.snapshot()``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.serving.engine import MutationTicket, QueryEngine, Ticket


class FrontendClosed(RuntimeError):
    """Raised on submission to a stopped frontend, and used to fail
    queued tickets on a non-draining ``stop()``."""


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Driver cadence + backpressure knobs.

    ``poll_interval_s`` bounds how late a timeout/deadline flush can
    fire when no submissions arrive (the driver also wakes instantly
    on every submit, so size flushes never wait on it).

    ``max_queue_rows`` is the backpressure gate for *blocking
    submitters* (None = the engine's own ``max_pending``); the engine
    never drops work — submitters wait for space instead, up to
    ``submit_timeout_s`` (None = forever).

    ``default_deadline_s`` is attached to submissions that don't carry
    their own ``deadline_s`` (None = no deadline: the ``max_wait_s``
    timeout cadence alone bounds queueing).
    """

    poll_interval_s: float = 0.0005
    max_queue_rows: Optional[int] = None
    submit_timeout_s: Optional[float] = None
    default_deadline_s: Optional[float] = None
    drain_timeout_s: float = 30.0
    # supervision: after this many CONSECUTIVE driver-tick failures the
    # driver fails every queued query ticket with the captured cause
    # (and keeps doing so while the fault persists) instead of letting
    # callers hang until their timeout
    max_driver_failures: int = 5

    def __post_init__(self):
        if self.poll_interval_s <= 0:
            raise ValueError(
                f"poll_interval_s must be > 0: {self.poll_interval_s}"
            )
        if self.max_queue_rows is not None and self.max_queue_rows < 1:
            raise ValueError(
                f"max_queue_rows must be >= 1: {self.max_queue_rows}"
            )
        if self.max_driver_failures < 1:
            raise ValueError(
                f"max_driver_failures must be >= 1: "
                f"{self.max_driver_failures}"
            )


class ServingFrontend:
    """See the module docstring."""

    def __init__(
        self,
        engine: QueryEngine,
        config: Optional[FrontendConfig] = None,
        **overrides,
    ):
        if config is None:
            config = FrontendConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.engine = engine
        self.config = config
        self._max_rows = (
            config.max_queue_rows
            if config.max_queue_rows is not None
            else engine.config.max_pending
        )
        self._work = threading.Event()
        self._closed = False
        self._started = False
        engine.driven = True
        engine._on_work = self._work.set
        self._driver = threading.Thread(
            target=self._drive, name="ash-serving-driver", daemon=True
        )

    # -- lifecycle ----------------------------------------------------

    def start(self) -> "ServingFrontend":
        if not self._started:
            self._started = True
            self._driver.start()
        return self

    def __enter__(self) -> "ServingFrontend":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop(drain=exc_type is None)

    @property
    def running(self) -> bool:
        return self._started and not self._closed

    def stop(self, drain: bool = True) -> None:
        """Shut the frontend down.  ``drain=True`` serves everything
        queued first (bounded by ``drain_timeout_s``); ``drain=False``
        fails queued query tickets with :class:`FrontendClosed`.
        Pending mutations apply either way (their rows are already
        staged on the index).  Idempotent; the engine is returned to
        undriven (caller-flushed) mode."""
        eng = self.engine
        with eng._lock:
            if self._closed:
                return
            self._closed = True
            eng._space.notify_all()  # wake blocked submitters to fail
        self._work.set()  # wake the driver so it can exit
        if self._started:
            self._driver.join(timeout=self.config.drain_timeout_s)
        if drain:
            eng.drain()
        else:
            eng._abort_pending(FrontendClosed("frontend stopped"))
        eng.driven = False
        eng._on_work = None

    # -- the driver thread --------------------------------------------

    def _drive(self) -> None:
        eng = self.engine
        while True:
            self._work.wait(self.config.poll_interval_s)
            self._work.clear()
            if self._closed:
                return  # stop() drains after the join
            try:
                # one pressure sample per tick, taken before any flush
                # drains the backlog, so every group flushed this tick
                # sees the same load-adaptive nprobe decision
                p = eng.queue_pressure()
                eng.flush_ready(p)  # size + budget + pressure
                eng.poll(p)  # timeout + deadline + aged mutations
                with eng._lock:
                    eng.stats.driver_consecutive_failures = 0
            except Exception as e:
                # fused-call errors already resolved their tickets and
                # the driver must outlive them — but record every
                # failure, and once the fault proves persistent stop
                # hanging callers: fail the queued tickets with the
                # captured cause
                with eng._lock:
                    eng.stats.driver_failures += 1
                    eng.stats.driver_consecutive_failures += 1
                    eng.stats.driver_last_error = repr(e)
                    streak = eng.stats.driver_consecutive_failures
                if streak >= self.config.max_driver_failures:
                    try:
                        eng._abort_pending(e)
                    except Exception:
                        pass

    # -- supervision --------------------------------------------------

    def healthy(self) -> bool:
        """False once the driver thread is gone or stuck in a failure
        streak of ``max_driver_failures`` or more (details in
        ``engine.stats.snapshot()["supervision"]``)."""
        if not self.running or not self._driver.is_alive():
            return False
        with self.engine._lock:
            streak = self.engine.stats.driver_consecutive_failures
        return streak < self.config.max_driver_failures

    @property
    def last_error(self) -> Optional[str]:
        with self.engine._lock:
            return self.engine.stats.driver_last_error

    # -- blocking submission ------------------------------------------

    def submit(self, queries, k: int = 10, **kw) -> Ticket:
        """Thread-safe blocking submission with backpressure; returns
        the engine's :class:`Ticket`.  Blocks while the queue is at
        ``max_queue_rows`` until the driver drains space (up to
        ``submit_timeout_s``; raises TimeoutError after).  Raises
        :class:`FrontendClosed` once stopped."""
        if (
            "deadline_s" not in kw
            and self.config.default_deadline_s is not None
        ):
            kw["deadline_s"] = self.config.default_deadline_s
        eng = self.engine
        # cheap rejection before touching the queue; full validation
        # happens in engine.submit under the lock
        if self._closed:
            raise FrontendClosed("frontend stopped")
        q = np.asarray(queries)
        n_rows = 1 if q.ndim <= 1 else int(q.shape[0])
        deadline = (
            None if self.config.submit_timeout_s is None
            else time.perf_counter() + self.config.submit_timeout_s
        )
        with eng._space:
            while (
                not self._closed
                and eng._pending_rows + n_rows > self._max_rows
                and eng._pending_rows > 0
            ):
                remaining = (
                    None if deadline is None
                    else deadline - time.perf_counter()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"queue full ({eng._pending_rows} rows) for "
                        f"{self.config.submit_timeout_s}s"
                    )
                self._work.set()  # make sure the driver is draining
                eng._space.wait(
                    remaining if remaining is not None
                    else self.config.poll_interval_s
                )
            if self._closed:
                raise FrontendClosed("frontend stopped")
            # still under the (re-entrant) lock: the space check and
            # the enqueue are atomic, so the bound is hard
            return eng.submit(queries, k, **kw)

    def search(self, queries, k: int = 10, timeout: Optional[float] = None,
               **kw):
        """Blocking submit + resolve.  (scores, ids), each (m, k)."""
        return self.submit(queries, k, **kw).result(timeout)

    def submit_add(self, rows, **kw) -> MutationTicket:
        if self._closed:
            raise FrontendClosed("frontend stopped")
        return self.engine.submit_add(rows, **kw)

    def submit_delete(self, ids, **kw) -> MutationTicket:
        if self._closed:
            raise FrontendClosed("frontend stopped")
        return self.engine.submit_delete(ids, **kw)

    # -- asyncio facade -----------------------------------------------

    async def _bridge(self, submit_fn):
        """Run a blocking submit in the loop's executor, then bridge
        the ticket's done callback to an asyncio Future."""
        loop = asyncio.get_running_loop()
        ticket = await loop.run_in_executor(None, submit_fn)
        fut: asyncio.Future = loop.create_future()

        def _done(t):
            def _resolve():
                if fut.cancelled():
                    return
                if t.error is not None:
                    fut.set_exception(
                        RuntimeError("request failed in its fused batch")
                    )
                    fut.exception()  # consumed: cancellation is benign
                else:
                    fut.set_result(t._result)

            loop.call_soon_threadsafe(_resolve)

        ticket.add_done_callback(_done)
        return await fut

    async def asearch(self, queries, k: int = 10, **kw):
        """``await``-able search: (scores, ids) numpy arrays.  The
        submission (which may block on backpressure) runs in the
        loop's executor; resolution is callback-driven — no thread
        parks in ``result()``."""
        return await self._bridge(lambda: self.submit(queries, k, **kw))

    async def asubmit_add(self, rows, **kw):
        """``await``-able add; resolves to the assigned user ids."""
        return await self._bridge(lambda: self.submit_add(rows, **kw))

    async def asubmit_delete(self, ids, **kw):
        """``await``-able delete; resolves to rows newly removed."""
        return await self._bridge(lambda: self.submit_delete(ids, **kw))
