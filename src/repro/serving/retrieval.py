"""ASH-compressed candidate retrieval — the paper's technique as a
first-class serving feature for the recsys architectures.

The item-embedding table (SASRec) or candidate set is encoded ONCE
offline; per request the user-state vector scores all candidates through
the fused asymmetric kernel (Pallas on TPU, oracle on CPU), followed by
top-k.  Payload is 32D/(bd)x smaller than the fp32 table, and the
scoring matmul reads packed codes only.

Requests route through the micro-batching :class:`QueryEngine`
(``repro.serving.engine``): one engine per index (cached here), so
repeated user vectors hit the prep cache and request shapes collapse
onto the engine's bucketed jit traces.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.core import ASHConfig
from repro.index import AshIndex
from repro.serving.engine import QueryEngine


def build_index(
    key: jax.Array,
    embeddings: jax.Array,  # (n_items, e)
    *,
    bits: int = 4,
    reduce: int = 1,
    n_landmarks: int = 16,
    learned: bool = True,
    backend: str = "flat",
    metric: str = "dot",
) -> AshIndex:
    """Compress a candidate catalog into a searchable ``AshIndex``."""
    e = embeddings.shape[1]
    cfg = ASHConfig(b=bits, d=e // reduce, n_landmarks=n_landmarks)
    return AshIndex.build(
        key, embeddings, cfg, backend=backend, metric=metric,
        learned=learned,
    )


def engine_for(index: AshIndex, **overrides) -> QueryEngine:
    """The (cached) serving engine fronting ``index``.  Overrides only
    apply on first construction for a given index.

    Cached on the index instance itself so the engine (and its prep
    cache) lives exactly as long as the index it fronts.  The default
    bucket ladder is power-of-two dense: synchronous one-shot callers
    with power-of-two batch sizes (the common recsys request shapes)
    pad by at most 2x and usually not at all.
    """
    engine = getattr(index, "_serving_engine", None)
    if engine is None:
        overrides.setdefault("batch_buckets", (8, 16, 32, 64, 128))
        engine = QueryEngine(index, **overrides)
        index._serving_engine = engine
    return engine


def serve_topk(
    index: AshIndex,
    user_vecs: jax.Array,  # (B, e)
    k: int = 10,
    use_pallas: Optional[bool] = None,  # auto: kernel on TPU, oracle on CPU
    *,
    engine: Optional[QueryEngine] = None,
) -> "tuple[np.ndarray, np.ndarray]":
    """Top-k ASH MIPS through the engine's fused scoring path.
    Returns host-side (numpy) scores and ids, each (B, k)."""
    eng = engine if engine is not None else engine_for(index)
    return eng.search(user_vecs, k=k, use_pallas=use_pallas)


def sasrec_retrieve(
    params: dict,
    seq: jax.Array,
    index: AshIndex,
    cfg,
    k: int = 10,
    *,
    engine: Optional[QueryEngine] = None,
) -> "tuple[np.ndarray, np.ndarray]":
    """End-to-end SASRec next-item retrieval over the compressed
    catalog: user sequences -> user state -> engine-batched ASH MIPS."""
    from repro.models import sasrec as SR

    u = SR.user_state(params, seq, cfg)
    return serve_topk(index, u, k=k, engine=engine)
