"""ASH-compressed candidate retrieval — the paper's technique as a
first-class serving feature for the recsys architectures.

The item-embedding table (SASRec) or candidate set is encoded ONCE
offline; per request the user-state vector scores all candidates through
the fused asymmetric kernel (Pallas on TPU, oracle on CPU), followed by
top-k.  Payload is 32D/(bd)x smaller than the fp32 table, and the
scoring matmul reads packed codes only.

This module is now a thin layer over ``repro.index.AshIndex``:
:func:`build_index` returns an ``AshIndex`` (flat backend, fused dot
kernel at search time); ``build_candidate_index``/:func:`retrieve` are
deprecation shims over the same path kept for one release.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core import ASHConfig, ASHModel, ASHPayload
from repro.index import AshIndex
from repro.index import common as C


def build_index(
    key: jax.Array,
    embeddings: jax.Array,  # (n_items, e)
    *,
    bits: int = 4,
    reduce: int = 1,
    n_landmarks: int = 16,
    learned: bool = True,
    backend: str = "flat",
    metric: str = "dot",
) -> AshIndex:
    """Compress a candidate catalog into a searchable ``AshIndex``."""
    e = embeddings.shape[1]
    cfg = ASHConfig(b=bits, d=e // reduce, n_landmarks=n_landmarks)
    return AshIndex.build(
        key, embeddings, cfg, backend=backend, metric=metric,
        learned=learned,
    )


def serve_topk(
    index: AshIndex,
    user_vecs: jax.Array,  # (B, e)
    k: int = 10,
    use_pallas: Optional[bool] = None,  # auto: kernel on TPU, oracle on CPU
) -> tuple[jax.Array, jax.Array]:
    """Top-k ASH MIPS through the fused scoring kernel."""
    return index.search(user_vecs, k=k, use_pallas=use_pallas)


def sasrec_retrieve(params: dict, seq: jax.Array, index, *args, k=10):
    """End-to-end SASRec next-item retrieval over the compressed
    catalog.

    New call shape: ``sasrec_retrieve(params, seq, index, cfg, k=...)``
    with an ``AshIndex``.  The legacy
    ``sasrec_retrieve(params, seq, model, payload, cfg, k=...)`` shape
    still works for one release.
    """
    from repro.models import sasrec as SR

    if isinstance(index, AshIndex):
        (cfg,) = args
    else:  # legacy (model, payload, cfg)
        payload, cfg = args
        index = AshIndex.from_parts(index, payload)
    u = SR.user_state(params, seq, cfg)
    return serve_topk(index, u, k=k)


# ---------------------------------------------------------------------------
# Deprecated shims (one release)
# ---------------------------------------------------------------------------


def build_candidate_index(
    key: jax.Array,
    embeddings: jax.Array,
    *,
    bits: int = 4,
    reduce: int = 1,
    n_landmarks: int = 16,
    learned: bool = True,
) -> tuple[ASHModel, ASHPayload]:
    """Deprecated: use :func:`build_index` (returns an ``AshIndex``)."""
    C.warn_deprecated(
        "repro.serving.retrieval.build_candidate_index",
        "repro.serving.retrieval.build_index",
    )
    index = build_index(
        key, embeddings, bits=bits, reduce=reduce,
        n_landmarks=n_landmarks, learned=learned,
    )
    return index.model, index.payload


def retrieve(
    model: ASHModel,
    payload: ASHPayload,
    user_vecs: jax.Array,
    k: int = 10,
    use_pallas: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Deprecated: use ``AshIndex.search(..., use_pallas=...)``."""
    C.warn_deprecated(
        "repro.serving.retrieval.retrieve",
        "repro.index.AshIndex.search",
    )
    return serve_topk(
        AshIndex.from_parts(model, payload), user_vecs, k=k,
        use_pallas=use_pallas,
    )
