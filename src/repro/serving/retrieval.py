"""ASH-compressed candidate retrieval — the paper's technique as a
first-class serving feature for the recsys architectures.

The item-embedding table (SASRec) or candidate set is encoded ONCE
offline; per request the user-state vector scores all candidates through
the fused asymmetric kernel (Pallas on TPU, oracle on CPU), followed by
top-k.  Payload is 32D/(bd)x smaller than the fp32 table, and the
scoring matmul reads packed codes only.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ASHConfig, ASHModel, ASHPayload
from repro.core import ash as A
from repro.core import scoring as S
from repro.kernels import ops as K


def build_candidate_index(
    key: jax.Array,
    embeddings: jax.Array,  # (n_items, e)
    *,
    bits: int = 4,
    reduce: int = 1,
    n_landmarks: int = 16,
    learned: bool = True,
) -> tuple[ASHModel, ASHPayload]:
    e = embeddings.shape[1]
    cfg = ASHConfig(b=bits, d=e // reduce, n_landmarks=n_landmarks)
    if learned:
        model, _ = A.train(key, embeddings, cfg)
    else:
        model = A.random_model(key, e, cfg, X_for_landmarks=embeddings)
    return model, A.encode(model, embeddings)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas"))
def retrieve(
    model: ASHModel,
    payload: ASHPayload,
    user_vecs: jax.Array,  # (B, e)
    k: int = 10,
    use_pallas: bool | None = None,  # auto: kernel on TPU, oracle on CPU
) -> tuple[jax.Array, jax.Array]:
    """Top-k ASH MIPS: returns (scores, item ids), each (B, k)."""
    prep = S.prepare_queries(model, user_vecs)
    scores = K.ash_score(model, prep, payload, use_pallas=use_pallas)
    return jax.lax.top_k(scores, k)


def sasrec_retrieve(
    params: dict,
    seq: jax.Array,
    model: ASHModel,
    payload: ASHPayload,
    cfg,
    k: int = 10,
):
    """End-to-end SASRec next-item retrieval over the compressed
    catalog."""
    from repro.models import sasrec as SR

    u = SR.user_state(params, seq, cfg)
    return retrieve(model, payload, u, k=k)
