"""BackgroundCompactor — tombstone eviction off the serving path.

``AshIndex.compact`` rewrites codes/stats/raw over the surviving rows;
run synchronously (as engine ``auto_compact`` did before this module)
it stalls every in-flight query of that index for the whole rewrite.
The compactor moves the rewrite to a worker thread and keeps only the
POINTER SWAP on the serving path:

1. **snapshot** — under the engine's per-index mutation barrier (so no
   search or mutation apply is mid-flight), record the index's
   ``mutation_epoch`` and take a shallow copy of its backend state.
   Backend states are immutable-array containers, so a shallow copy
   is a consistent snapshot.
2. **build** — OFF the lock, run the backend's ``compact`` on the
   snapshot: flat/IVF compaction is pure (returns a new state); the
   sharded backend mutates the state it is given, which here is the
   private copy.  Searches and mutations proceed concurrently against
   the live state the whole time.
3. **swap** — re-acquire the barrier and compare epochs.  Unchanged ⇒
   no mutation landed since the snapshot: install the survivor state
   atomically (a single attribute assignment under the same lock every
   fused call holds).  Changed ⇒ the built state is stale: drop it and
   retry from a fresh snapshot (the rebuild includes the delta), up to
   ``max_retries`` — a hot index just keeps its tombstones until the
   next request, which is always safe (tombstones are masked at scan
   time; compaction is an optimization, never a correctness event).

Because the swap happens under the same lock as every search and
mutation apply, and only when the epoch proves the searchable state
is unchanged, results are bit-identical to a fresh build over the
survivors regardless of when the swap lands — PR 5's compaction
contract, preserved under concurrency.

The engine routes ``auto_compact`` here when a compactor is attached
(``BackgroundCompactor(engine)`` attaches itself); telemetry lands in
``engine.stats`` (``compact_runs`` / ``compact_retries`` /
``compact_swap_ms`` / ``compact_blocked_ms`` — the last being the only
serving-path time compaction still costs).

    with BackgroundCompactor(engine) as compactor:
        ...  # engine auto_compact now signals the worker
        compactor.request("default")   # or: explicit kick
        compactor.wait_idle()          # test/drain helper
"""
from __future__ import annotations

import copy
import threading
import time
from typing import Optional

from repro.serving.engine import QueryEngine
from repro.testing import faults

# the instant before the survivor state is installed: a crash here
# loses the compaction (never a correctness event — recovery replays
# the WAL over the last checkpoint) but must never corrupt anything
_FAULT_SWAP = faults.point("compactor.swap")


class BackgroundCompactor:
    """See the module docstring."""

    def __init__(
        self,
        engine: QueryEngine,
        max_dead_fraction: Optional[float] = None,
        max_retries: int = 3,
        max_failures: int = 3,
    ):
        self.engine = engine
        # threshold precedence: explicit arg, else the engine's
        # auto_compact, else 0.0 (any tombstone triggers)
        if max_dead_fraction is None:
            max_dead_fraction = engine.config.auto_compact or 0.0
        self.max_dead_fraction = max_dead_fraction
        self.max_retries = max_retries
        # consecutive run_once failures before healthy() turns False
        self.max_failures = max_failures
        self._work = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._lock = threading.Lock()
        self._requested: set[str] = set()
        self._closed = False
        self._started = False
        self._worker = threading.Thread(
            target=self._run, name="ash-compactor", daemon=True
        )
        engine._compactor = self

    # -- lifecycle ----------------------------------------------------

    def start(self) -> "BackgroundCompactor":
        if not self._started:
            self._started = True
            self._worker.start()
        return self

    def __enter__(self) -> "BackgroundCompactor":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    def stop(self) -> None:
        """Stop the worker (a build in flight finishes its swap
        attempt first) and detach from the engine — ``auto_compact``
        falls back to synchronous.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._work.set()
        if self._started:
            self._worker.join(timeout=60.0)
        if self.engine._compactor is self:
            self.engine._compactor = None

    # -- requests -----------------------------------------------------

    def request(self, name: str = "default") -> None:
        """Queue ``name`` for compaction and wake the worker.
        Non-blocking — safe to call from ``_apply_mutations`` while it
        holds the mutation barrier."""
        with self._lock:
            self._requested.add(name)
            self._idle.clear()
        self._work.set()

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until every queued request has been processed (the
        drain/test helper).  True if idle was reached."""
        return self._idle.wait(timeout)

    # -- the worker ---------------------------------------------------

    def _run(self) -> None:
        while True:
            self._work.wait()
            self._work.clear()
            while True:
                with self._lock:
                    if self._closed:
                        self._requested.clear()
                        self._idle.set()
                        return
                    if not self._requested:
                        self._idle.set()
                        break
                    name = self._requested.pop()
                try:
                    self.run_once(name)
                    with self.engine._lock:
                        self.engine.stats \
                            .compact_consecutive_failures = 0
                except Exception as e:
                    # a failed build must not kill the worker (the
                    # index keeps serving with tombstones masked) —
                    # but it must not vanish either: record it where
                    # snapshot()["supervision"] and healthy() look
                    with self.engine._lock:
                        self.engine.stats.compact_failures += 1
                        self.engine.stats \
                            .compact_consecutive_failures += 1
                        self.engine.stats.compact_last_error = repr(e)

    # -- supervision --------------------------------------------------

    def healthy(self) -> bool:
        """False once the worker thread is gone (while started and not
        stopped) or stuck in a failure streak of ``max_failures`` or
        more."""
        if self._closed or (self._started and not self._worker.is_alive()):
            return False
        with self.engine._lock:
            streak = self.engine.stats.compact_consecutive_failures
        return streak < self.max_failures

    @property
    def last_error(self) -> Optional[str]:
        with self.engine._lock:
            return self.engine.stats.compact_last_error

    def run_once(self, name: str = "default") -> bool:
        """One snapshot → build → epoch-checked swap cycle (with
        bounded retries).  Synchronous — tests and drain paths call it
        directly.  True iff a survivor state was swapped in.  After a
        successful swap, an attached :class:`DurableIndex` is
        checkpointed (then its covered WAL segments dropped) so the
        log stays bounded — the natural truncation point, since the
        compacted state is exactly what replay would rebuild."""
        eng = self.engine
        barrier = eng.mutation_barrier(name)
        for attempt in range(self.max_retries + 1):
            # 1. snapshot under the barrier: nothing is mid-search or
            #    mid-apply, so state + epoch are mutually consistent
            with barrier:
                idx = eng._indexes.get(name)
                if idx is None:
                    return False
                if (
                    idx.dead_fraction <= self.max_dead_fraction
                    or idx.n_live == 0
                ):
                    return False
                epoch = idx.mutation_epoch
                snapshot = copy.copy(idx._state)
            # 2. build survivors OFF the lock — searches keep flowing
            new_state = idx._backend.compact(snapshot)
            # 3. swap iff no mutation landed since the snapshot
            t_wait = time.perf_counter()
            swapped = False
            with barrier:
                t_swap = time.perf_counter()
                blocked_ms = (t_swap - t_wait) * 1e3
                if eng._indexes.get(name) is not idx:
                    return False  # name was rebound mid-build
                if idx.mutation_epoch == epoch:
                    faults.fire(_FAULT_SWAP)
                    idx._state = new_state
                    idx._mutation_epoch += 1
                    swap_ms = (time.perf_counter() - t_swap) * 1e3
                    with eng._lock:
                        eng.stats.compact_runs += 1
                        eng.stats.compact_swap_ms += swap_ms
                        eng.stats.compact_blocked_ms += blocked_ms
                    swapped = True
            if swapped:
                # checkpoint-then-truncate OFF the barrier (the
                # checkpoint re-acquires it only for its brief
                # snapshot+rotate step) so serving never waits on the
                # checkpoint write
                self._checkpoint_after_swap(name, barrier)
                return True
            # stale build: a mutation landed mid-rebuild — retry from
            # a fresh snapshot (which includes the delta)
            with eng._lock:
                eng.stats.compact_retries += 1
        return False

    def _checkpoint_after_swap(self, name: str, barrier) -> None:
        durable = self.engine.durability(name)
        if durable is None:
            return
        with barrier:  # WAL appends are serialized by the barrier
            durable.log_marker("compact")
        durable.checkpoint(barrier=barrier)
