"""Byte-bounded LRU cache shared by the serving layer.

Generalized from the engine's prep-cache bookkeeping so every
byte-budgeted cache in the stack — the per-row ``QueryPrep`` LRU in
:mod:`repro.serving.engine` and the device-resident inverted-list hot
set in :mod:`repro.index.tiered` — runs the same eviction machinery
and reports the same gauge vocabulary.

Not internally locked: callers serialize access themselves (the engine
holds its global lock around cache operations; the tiered backend
serializes through the per-index mutation barrier).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Iterator, Optional


def _default_nbytes(value: Any) -> int:
    """Byte size of a cached value: a single array-like, or any
    tuple/list/dict of array-likes (anything exposing ``.nbytes``)."""
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    if isinstance(value, dict):
        value = value.values()
    return sum(_default_nbytes(v) for v in value)


class ByteLRU:
    """LRU mapping hashable keys to values under a byte budget.

    ``max_bytes`` bounds the summed size of cached values (sized by
    ``nbytes_of``, default: summed ``.nbytes`` over the value's
    arrays); ``max_entries`` optionally bounds the entry count.  A
    value larger than the whole budget is admitted and immediately
    evicted — ``put`` never raises, a zero-byte budget simply caches
    nothing (every lookup misses, which is exactly the cold-cache
    semantics the tiered backend's paging tests rely on).

    ``hits`` / ``misses`` / ``evictions`` count ``get`` outcomes and
    evicted entries for the owner's gauges.
    """

    def __init__(
        self,
        max_bytes: int,
        *,
        max_entries: Optional[int] = None,
        nbytes_of: Callable[[Any], int] = _default_nbytes,
    ):
        self.max_bytes = int(max_bytes)
        self.max_entries = max_entries
        self._nbytes_of = nbytes_of
        self._data: "OrderedDict[Any, Any]" = OrderedDict()
        self._sizes: Dict[Any, int] = {}
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def keys(self) -> Iterator:
        return iter(self._data.keys())

    def get(self, key, default=None):
        """Look up ``key``; a hit refreshes its recency."""
        entry = self._data.get(key)
        if entry is None:
            self.misses += 1
            return default
        self._data.move_to_end(key)
        self.hits += 1
        return entry

    def peek(self, key, default=None):
        """Look up without touching recency or hit/miss counters
        (residency probes, e.g. the paging cost bill)."""
        return self._data.get(key, default)

    def put(self, key, value) -> None:
        """Insert or replace ``key``, then evict LRU-first until the
        budget holds."""
        old = self._data.pop(key, None)
        if old is not None:
            self.nbytes -= self._sizes.pop(key)
        size = int(self._nbytes_of(value))
        self._data[key] = value
        self._sizes[key] = size
        self.nbytes += size
        self.evict()

    def pop(self, key, default=None):
        """Remove ``key`` (no eviction counted: the caller invalidated
        it, it did not age out)."""
        entry = self._data.pop(key, None)
        if entry is None:
            return default
        self.nbytes -= self._sizes.pop(key)
        return entry

    def evict(self) -> int:
        """Evict LRU-first until within budget; returns entries evicted."""
        n = 0
        while self._data and (
            self.nbytes > self.max_bytes
            or (self.max_entries is not None
                and len(self._data) > self.max_entries)
        ):
            key, _ = self._data.popitem(last=False)
            self.nbytes -= self._sizes.pop(key)
            self.evictions += 1
            n += 1
        return n

    def clear(self) -> None:
        self._data.clear()
        self._sizes.clear()
        self.nbytes = 0

    def stats(self) -> Dict[str, int]:
        """Gauge snapshot (counters are lifetime, not interval)."""
        total = self.hits + self.misses
        return {
            "entries": len(self._data),
            "nbytes": self.nbytes,
            "max_bytes": self.max_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "evictions": self.evictions,
        }
